"""The array-backed search space: integer rows as the native config form.

A ``CompiledSpace`` numbers the valid configs 0..n_valid-1 in enumeration
order (ascending flat Cartesian index — the legacy DFS order). Every hot
query is row-native:

  * ``neighbors_rows(row, mode)``   — one CSR slice (no per-call work)
  * ``random_row(rng)``             — the legacy rejection sampler, drawing
                                      from ``rng`` in the exact same order
  * ``repair_vidx / decode_rows``   — nearest-valid repair over precomputed
                                      single-move tables (repair.py)
  * ``rows_of_vidx``                — batch index-tuple -> row gather

Value tuples (``configs``), config-id strings (``ids``), and their inverse
maps are lazy row-indexed tables: they exist for the serialization /
recording / journal boundary and for human-facing output, never for the
search loop itself. RNG behaviour is a compatibility contract: every
``rng`` draw here happens at the same point in the stream, with the same
modulus, as the pre-compilation scalar implementation
(``core.space.reference``), so traces are bit-identical.
"""
from __future__ import annotations

import random
from typing import Sequence

import numpy as np

from ..tunable import Config, Constraint, Tunable
from . import neighbors as _neighbors
from . import repair as _repair

NEIGHBOR_MODES = ("hamming", "strictly_adjacent")


class CompiledSpace:
    """Immutable compiled form of one constrained search space. Build via
    ``core.space.compile_space`` (or ``SearchSpace.compiled``)."""

    def __init__(self, tunables: Sequence[Tunable],
                 constraints: Sequence[Constraint], name: str,
                 cards: tuple, strides: tuple, cartesian_size: int,
                 valid_flat: np.ndarray, vidx: np.ndarray,
                 bitmap: np.ndarray, compile_seconds: float = 0.0):
        self.tunables = tuple(tunables)
        self.constraints = tuple(constraints)
        self.name = name
        self.cards = cards                      # per-tunable cardinalities
        self.strides = strides                  # C-order flat strides
        self.strides_np = np.asarray(strides, dtype=np.int64)
        self.cartesian_size = cartesian_size
        self.n_tunables = len(self.tunables)
        self.valid_flat = valid_flat            # (n_valid,) sorted flats
        self.vidx = vidx                        # (n_valid, T) value indices
        self.bitmap = bitmap                    # (cartesian,) validity
        self.n_valid = len(valid_flat)
        self.compile_seconds = compile_seconds
        row_of_flat = np.full(cartesian_size, -1, dtype=np.int32)
        row_of_flat[valid_flat] = np.arange(self.n_valid, dtype=np.int32)
        self.row_of_flat = row_of_flat
        # rejection sampling draws an *index* per tunable with the same
        # rng.choice modulus the scalar sampler used on the value tuple
        self._choice_seqs = tuple(tuple(range(c)) for c in cards)
        self._x_hi = np.array([c - 1 for c in cards], dtype=np.float64)
        # lazy row-indexed boundary tables
        self._configs: list | None = None
        self._idx_tuples: list | None = None
        self._ids: list | None = None
        self._id_to_row: dict | None = None
        self._csr: dict = {}
        self._repair_state: tuple | None = None
        # idx-tuple -> row (or FALLBACK) front cache: population strategies
        # repair the same bred children every generation, and the tuple
        # dict hit is ~4x cheaper than recomputing the flat index (the old
        # implementation's _repair/_validity dict caches, consolidated)
        self._repair_tuples: dict = {}
        # device-array mirror (core.engine_jax.SpaceTables), never pickled
        self._jax = None

    def __getstate__(self) -> dict:
        """Pickle only the compiled core. Lazy boundary tables rebuild on
        demand, and device arrays must never cross a process boundary: a
        pool worker re-materializes them against whatever backend it
        actually has (CPU jit, or none — the numpy engine), instead of
        inheriting handles to a device that does not exist in its process
        (tests/test_parallel.py pins this)."""
        state = self.__dict__.copy()
        state.update(_configs=None, _idx_tuples=None, _ids=None,
                     _id_to_row=None, _csr={}, _repair_state=None,
                     _repair_tuples={}, _jax=None)
        return state

    # ------------------------------------------------------- boundary tables
    @property
    def configs(self) -> list:
        """Row -> value tuple. The only place value tuples materialize."""
        if self._configs is None:
            cols = [np.array(t.values, dtype=object)[self.vidx[:, i]].tolist()
                    for i, t in enumerate(self.tunables)]
            self._configs = list(zip(*cols)) if cols else []
        return self._configs

    @property
    def idx_tuples(self) -> list:
        """Row -> value-index tuple (pure-int genomes for GA-style ops)."""
        if self._idx_tuples is None:
            self._idx_tuples = list(map(tuple, self.vidx.tolist()))
        return self._idx_tuples

    @property
    def ids(self) -> list:
        """Row -> config-id string (the T4 cache key form)."""
        if self._ids is None:
            self._ids = [",".join(map(str, cfg)) for cfg in self.configs]
        return self._ids

    @property
    def id_to_row(self) -> dict:
        if self._id_to_row is None:
            self._id_to_row = {k: i for i, k in enumerate(self.ids)}
        return self._id_to_row

    # ------------------------------------------------------------ row lookup
    def flat_of_vidx(self, idx: Sequence[int]) -> int:
        flat = 0
        for k, stride in zip(idx, self.strides):
            flat += k * stride
        return flat

    def row_of_vidx(self, idx: Sequence[int]) -> int:
        """Row for one value-index tuple; -1 when the config is invalid."""
        return int(self.row_of_flat[self.flat_of_vidx(idx)])

    def rows_of_vidx(self, mat) -> np.ndarray:
        """Batch row gather for a (P, T) value-index matrix."""
        flats = np.asarray(mat, dtype=np.int64) @ self.strides_np
        return self.row_of_flat[flats].astype(np.int64)

    def vidx_of_config(self, config: Config) -> tuple | None:
        """Value tuple -> value-index tuple; None if any value is not in
        its tunable's value set (out-of-vocabulary)."""
        idx = []
        for t, v in zip(self.tunables, config):
            pos = t.position.get(v)
            if pos is None:
                return None
            idx.append(pos)
        return tuple(idx)

    def row_of_config(self, config: Config) -> int:
        """Value tuple -> row; -1 for invalid or out-of-vocab configs."""
        if len(config) != self.n_tunables:
            return -1
        idx = self.vidx_of_config(config)
        return -1 if idx is None else self.row_of_vidx(idx)

    def x_of_row(self, row: int) -> np.ndarray:
        """Row -> float index vector (the continuous-relaxation coding)."""
        return self.vidx[row].astype(np.float64)

    # -------------------------------------------------------------- sampling
    def random_row(self, rng: random.Random) -> int:
        """Uniform over valid rows — draw-for-draw identical to the scalar
        rejection sampler (64 per-tunable ``rng.choice`` rounds, then one
        ``rng.randrange`` over the enumeration)."""
        bitmap, row_of_flat = self.bitmap, self.row_of_flat
        strides = self.strides
        for _ in range(64):
            flat = 0
            for seq, stride in zip(self._choice_seqs, strides):
                flat += rng.choice(seq) * stride
            if bitmap[flat]:
                return int(row_of_flat[flat])
        if not self.n_valid:
            raise ValueError(f"space {self.name!r} has no valid configs")
        return rng.randrange(self.n_valid)

    # ------------------------------------------------------------- neighbors
    def csr(self, strictly_adjacent: bool = False) -> tuple:
        """(indptr, indices) CSR neighbor table for one semantics, built
        once on first use."""
        mode = bool(strictly_adjacent)
        hit = self._csr.get(mode)
        if hit is None:
            hit = self._csr[mode] = _neighbors.build_csr(self, mode)
        return hit

    def neighbors_rows(self, row: int,
                       strictly_adjacent: bool = False) -> np.ndarray:
        """Valid neighbor rows of ``row`` in the exact legacy order
        (tunable-major, then by distance in the value order)."""
        indptr, indices = self.csr(strictly_adjacent)
        return indices[indptr[row]:indptr[row + 1]]

    # ---------------------------------------------------------------- repair
    def _repair(self) -> tuple:
        if self._repair_state is None:
            self._repair_state = _repair.make_state(self)
        return self._repair_state

    def repair_flat(self, flat: int, rng: random.Random) -> int:
        """Nearest-valid row for one (invalid) flat index: memoized BFS
        over single-tunable moves, then the random-restart fallback — the
        only part that draws from ``rng``, in the exact scalar order."""
        row = int(self.row_of_flat[flat])
        if row >= 0:
            return row
        memo, move_orders = self._repair()
        row = int(memo[flat])
        if row == _repair.UNSET:
            row = _repair.bfs(self, move_orders, flat)
            memo[flat] = row
        if row >= 0:
            return row
        return self.random_row(rng)

    def repair_vidx(self, idx: Sequence[int], rng: random.Random) -> int:
        """Nearest-valid row for a value-index tuple (``nearest_valid``).

        The deterministic outcome (valid row, or BFS result) is memoized
        per tuple; only the random-restart fallback stays per-call (it
        draws from ``rng`` — caching it would correlate runs)."""
        idx = tuple(idx)
        hit = self._repair_tuples.get(idx)
        if hit is None:
            flat = self.flat_of_vidx(idx)
            row = int(self.row_of_flat[flat])
            if row < 0:
                memo, move_orders = self._repair()
                row = int(memo[flat])
                if row == _repair.UNSET:
                    row = _repair.bfs(self, move_orders, flat)
                    memo[flat] = row
            hit = self._repair_tuples[idx] = row
        if hit >= 0:
            return hit
        return self.random_row(rng)

    def repair_x(self, x, rng: random.Random) -> int:
        """Round/clip one continuous index vector and repair — the scalar
        ``from_indices`` + ``nearest_valid`` composition (Python ``round``:
        half-to-even, identical to the batched ``np.rint`` path)."""
        idx = tuple(max(0, min(c - 1, int(round(float(xi)))))
                    for xi, c in zip(x, self.cards))
        return self.repair_vidx(idx, rng)

    def decode_rows(self, x, rng: random.Random) -> np.ndarray:
        """Vectorized round/clip + repair of a (P, T) index matrix into
        rows — the ask half of a population strategy's batch step. Valid
        positions resolve in one gather; only invalid rows walk the repair
        tables, in row order, so fallback draws hit ``rng`` exactly as the
        per-particle scalar loop did."""
        x = np.asarray(x, dtype=np.float64)
        k = np.clip(np.rint(x), 0.0, self._x_hi).astype(np.int64)
        flats = k @ self.strides_np
        rows = self.row_of_flat[flats].astype(np.int64)
        for j in np.nonzero(rows < 0)[0].tolist():
            rows[j] = self.repair_flat(int(flats[j]), rng)
        return rows

    # ------------------------------------------------------------------ misc
    def stats(self) -> dict:
        """Per-space summary for ``python -m repro spaces`` and the docs:
        sizes, valid fraction, compile time, neighbor-degree distribution."""
        degrees = {}
        for label, mode in (("strictly_adjacent", True), ("hamming", False)):
            if self.n_valid:
                counts = np.diff(self.csr(mode)[0])
                degrees[label] = {
                    "min": int(counts.min()),
                    "median": float(np.median(counts)),
                    "mean": float(counts.mean()),
                    "max": int(counts.max()),
                }
            else:
                degrees[label] = {"min": 0, "median": 0.0, "mean": 0.0,
                                  "max": 0}
        return {
            "name": self.name,
            "n_tunables": self.n_tunables,
            "cartesian_size": self.cartesian_size,
            "n_valid": self.n_valid,
            "valid_fraction": (self.n_valid / self.cartesian_size
                               if self.cartesian_size else 0.0),
            "compile_seconds": self.compile_seconds,
            "degrees": degrees,
        }

    def __repr__(self):
        return (f"CompiledSpace({self.name!r}, valid={self.n_valid}/"
                f"{self.cartesian_size})")
