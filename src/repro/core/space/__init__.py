"""Compiled, array-backed search spaces (the index-native core).

A ``SearchSpace`` (``core.searchspace``, now a thin facade) compiles once
into a :class:`CompiledSpace`: a validity bitmap over the Cartesian
product, a ``(n_valid, n_tunables)`` value-index matrix, CSR neighbor
tables for both neighbor semantics, and single-move repair tables. Integer
row indices are the native config representation through the whole
simulation hot path — value tuples and config-id strings materialize only
at the API / recording / journal serialization boundary.

Module map:
  compile.py    blocked vectorized enumeration -> CompiledSpace
  compiled.py   the array-backed space: row-native queries
  neighbors.py  CSR neighbor-table construction (both semantics)
  repair.py     nearest-valid repair: move tables + flat-index BFS
  rows.py       RowBatch — integer config batches that materialize value
                tuples lazily (so non-simulation runners keep working)
  reference.py  the frozen pre-compilation SearchSpace (scalar parity and
                benchmark reference; see tests/test_space_compiled.py)
"""
from .compile import compile_space
from .compiled import CompiledSpace
from .rows import RowBatch

__all__ = ["CompiledSpace", "RowBatch", "compile_space"]
