"""The frozen pre-compilation search space — scalar parity reference.

This is the ``core.searchspace.SearchSpace`` implementation exactly as it
existed before the compiled ``core.space`` subsystem replaced it:
recursive-DFS enumeration, lazy per-config dict caches for validity /
neighbors / repair / ids. It is kept in-tree — like the scalar simulation
engine (``SimulationRunner(columnar=False)``) and the ``*_scalar``
methodology functions — as the oracle the compiled path is pinned against:

  * tests/test_space_compiled.py sweeps compiled ``neighbors`` /
    ``is_valid`` / ``random_config`` / ``decode_batch`` / ``nearest_valid``
    against this class, element-for-element and rng-draw-for-draw;
  * benchmarks/bench_simulate.py uses it as the denominator of the
    ``space_compile`` and ``local_search`` components.

Do not "improve" this module; its value is that it does not move.
"""
from __future__ import annotations

import random
from typing import Iterable, Mapping, Sequence

import numpy as np

from ..tunable import Config, Constraint, Tunable


class ReferenceSearchSpace:
    def __init__(self, tunables: Sequence[Tunable],
                 constraints: Sequence[Constraint] = (),
                 name: str = "space"):
        if not tunables:
            raise ValueError("search space needs at least one tunable")
        names = [t.name for t in tunables]
        if len(set(names)) != len(names):
            raise ValueError("duplicate tunable names")
        self.name = name
        self.tunables = tuple(tunables)
        self.constraints = tuple(constraints)
        self._names = tuple(names)
        self._index = {n: i for i, n in enumerate(names)}
        self._valid: list[Config] | None = None
        self._valid_set: frozenset | None = None
        # hot-path caches: simulated tuning calls neighbors()/nearest_valid()
        # and config_id() millions of times on the same few thousand configs
        self._nbr_cache: dict[tuple, list[Config]] = {}
        self._repair_cache: dict[Config, Config] = {}
        self._id_cache: dict[Config, str] = {}
        self._validity_cache: dict[Config, bool] = {}
        self._decode_tables: tuple | None = None

    # ------------------------------------------------------------------ views
    @property
    def names(self) -> tuple:
        return self._names

    def as_dict(self, config: Config) -> dict:
        return dict(zip(self._names, config))

    def from_dict(self, d: Mapping) -> Config:
        return tuple(d[n] for n in self._names)

    @property
    def cartesian_size(self) -> int:
        n = 1
        for t in self.tunables:
            n *= t.cardinality
        return n

    # ------------------------------------------------------------ enumeration
    def is_valid(self, config: Config) -> bool:
        hit = self._validity_cache.get(config)
        if hit is None:
            hit = self._validity_cache[config] = self._compute_valid(config)
        return hit

    def _compute_valid(self, config: Config) -> bool:
        if len(config) != len(self.tunables):
            return False
        for t, v in zip(self.tunables, config):
            if v not in t.values:
                return False
        d = self.as_dict(config)
        return all(c(d) for c in self.constraints)

    def _enumerate(self) -> list[Config]:
        if self._valid is None:
            out: list[Config] = []
            # depth-first product with early constraint checks on full
            # configs; spaces here are <= ~1e6 cartesian, fine to enumerate.
            def rec(i: int, prefix: tuple):
                if i == len(self.tunables):
                    d = dict(zip(self._names, prefix))
                    if all(c(d) for c in self.constraints):
                        out.append(prefix)
                    return
                for v in self.tunables[i].values:
                    rec(i + 1, prefix + (v,))
            rec(0, ())
            self._valid = out
            self._valid_set = frozenset(out)
        return self._valid

    @property
    def valid_configs(self) -> list:
        return list(self._enumerate())

    @property
    def size(self) -> int:
        return len(self._enumerate())

    def config_id(self, config: Config) -> str:
        key = self._id_cache.get(config)
        if key is None:
            key = self._id_cache[config] = ",".join(str(v) for v in config)
        return key

    def config_ids(self, configs: Sequence[Config]) -> list[str]:
        cache = self._id_cache
        out = []
        for config in configs:
            key = cache.get(config)
            if key is None:
                key = cache[config] = ",".join(str(v) for v in config)
            out.append(key)
        return out

    def config_from_id(self, key: str) -> Config:
        parts = key.split(",")
        out = []
        for t, s in zip(self.tunables, parts):
            match = None
            for v in t.values:
                if str(v) == s:
                    match = v
                    break
            if match is None:
                raise KeyError(f"{s!r} not a value of {t.name!r}")
            out.append(match)
        return tuple(out)

    # --------------------------------------------------------------- sampling
    def random_config(self, rng: random.Random) -> Config:
        for _ in range(64):
            c = tuple(rng.choice(t.values) for t in self.tunables)
            if self.is_valid(c):
                return c
        valid = self._enumerate()
        if not valid:
            raise ValueError(f"space {self.name!r} has no valid configs")
        return valid[rng.randrange(len(valid))]

    # ------------------------------------------------------------- neighbors
    def neighbors(self, config: Config, strictly_adjacent: bool = False) -> list:
        key = (config, strictly_adjacent)
        hit = self._nbr_cache.get(key)
        if hit is not None:
            return hit
        out: list[Config] = []
        for i, t in enumerate(self.tunables):
            j = t.index_of(config[i])
            if strictly_adjacent:
                cand = [k for k in (j - 1, j + 1) if 0 <= k < t.cardinality]
            else:
                cand = sorted((k for k in range(t.cardinality) if k != j),
                              key=lambda k: abs(k - j))
            for k in cand:
                c = config[:i] + (t.values[k],) + config[i + 1:]
                if self.is_valid(c):
                    out.append(c)
        self._nbr_cache[key] = out
        return out

    # ---------------------------------------------------- index-vector coding
    def to_indices(self, config: Config) -> np.ndarray:
        return np.array([t.index_of(v) for t, v in zip(self.tunables, config)],
                        dtype=np.float64)

    def from_indices(self, x: Iterable) -> Config:
        out = []
        for t, xi in zip(self.tunables, x):
            k = int(round(float(xi)))
            k = max(0, min(t.cardinality - 1, k))
            out.append(t.values[k])
        return tuple(out)

    def decode_batch(self, x: "np.ndarray", rng: random.Random) -> list:
        x = np.asarray(x, dtype=np.float64)
        if self._decode_tables is None:
            self._decode_tables = (
                [np.array(t.values, dtype=object) for t in self.tunables],
                np.array([t.cardinality - 1 for t in self.tunables],
                         dtype=np.float64))
        tables, hi = self._decode_tables
        k = np.clip(np.rint(x), 0.0, hi).astype(np.intp)
        columns = [tables[i][k[:, i]].tolist() for i in range(len(tables))]
        return [self.nearest_valid(c, rng) for c in zip(*columns)]

    def nearest_valid(self, config: Config, rng: random.Random) -> Config:
        if self.is_valid(config):
            return config
        hit = self._repair_cache.get(config)
        if hit is not None:
            return hit
        frontier = [config]
        seen = {config}
        for _depth in range(3):
            nxt: list[Config] = []
            for c in frontier:
                for i, t in enumerate(self.tunables):
                    j = t.index_of(c[i]) if c[i] in t.values else 0
                    order = sorted(range(t.cardinality), key=lambda k: abs(k - j))
                    for k in order:
                        cc = c[:i] + (t.values[k],) + c[i + 1:]
                        if cc in seen:
                            continue
                        seen.add(cc)
                        if self.is_valid(cc):
                            self._repair_cache[config] = cc
                            return cc
                        nxt.append(cc)
            frontier = nxt[:256]
        return self.random_config(rng)

    @property
    def bounds(self) -> list:
        return [(0.0, float(t.cardinality - 1)) for t in self.tunables]

    def __repr__(self):
        return (f"ReferenceSearchSpace({self.name!r}, "
                f"tunables={len(self.tunables)}, "
                f"cartesian={self.cartesian_size})")
