"""CSR neighbor tables: whole neighborhoods as precomputed index slices.

The legacy space built each config's neighbor list lazily — tuple slicing
plus a constraint call per candidate, memoized per (config, mode) in an
unbounded dict. Here both neighbor semantics compile once into CSR form
(``indptr`` of length n_valid+1, ``indices`` of total degree), built in
row blocks from pure stride arithmetic against the validity bitmap.

Order is part of the contract (simulated annealing indexes ``nbrs[k]`` by
an rng draw, so it is rng-stream-visible): per row, candidates appear
tunable-major in declaration order; within a tunable, ordered by distance
in the value order with the smaller index first on ties (``hamming``), or
``j-1`` then ``j+1`` (``strictly_adjacent``) — exactly the legacy
enumeration.
"""
from __future__ import annotations

import numpy as np

_BLOCK = 4096


def _cand_table(card: int, strictly_adjacent: bool) -> np.ndarray:
    """(card, width) candidate value-index table per current index ``j``;
    -1 pads impossible moves (value-set edges)."""
    if strictly_adjacent:
        table = np.full((card, 2), -1, dtype=np.int64)
        for j in range(card):
            pos = 0
            for k in (j - 1, j + 1):
                if 0 <= k < card:
                    table[j, pos] = k
                    pos += 1
        return table
    table = np.empty((card, max(card - 1, 0)), dtype=np.int64)
    for j in range(card):
        table[j] = sorted((k for k in range(card) if k != j),
                          key=lambda k: abs(k - j))
    return table


def build_csr(cs, strictly_adjacent: bool) -> tuple:
    """Build one semantics' CSR table for a ``CompiledSpace``."""
    tables = [_cand_table(c, strictly_adjacent) for c in cs.cards]
    indptr = np.zeros(cs.n_valid + 1, dtype=np.int64)
    chunks: list[np.ndarray] = []
    for start in range(0, cs.n_valid, _BLOCK):
        stop = min(start + _BLOCK, cs.n_valid)
        V = cs.vidx[start:stop]
        F = cs.valid_flat[start:stop]
        cols = []
        for i in range(cs.n_tunables):
            cand = tables[i][V[:, i]]              # (m, width)
            if cand.shape[1] == 0:
                continue
            pad = cand < 0
            delta = (cand - V[:, i:i + 1].astype(np.int64)) * cs.strides[i]
            flat = F[:, None] + np.where(pad, 0, delta)
            rows = cs.row_of_flat[flat].astype(np.int64)
            cols.append(np.where(pad, -1, rows))
        if not cols:
            continue
        block = np.hstack(cols)                    # (m, S), legacy order
        mask = block >= 0
        indptr[start + 1:stop + 1] = mask.sum(axis=1)
        chunks.append(block[mask])                 # row-major == in-order
    np.cumsum(indptr, out=indptr)
    indices = (np.concatenate(chunks).astype(np.int32) if chunks
               else np.empty(0, dtype=np.int32))
    return indptr, indices
