"""RowBatch: a batch of configs as integer rows of a compiled space.

This is how index-native strategies hand a generation to a runner without
materializing value tuples: ``SimulationRunner`` recognizes the type and
resolves the whole batch through row-indexed arrays (``runner._run_rows``),
while any other runner — live, cost-model, recording, the meta level's
``FunctionRunner`` — simply iterates it and receives ordinary value tuples
(``Sequence`` semantics), keeping the ``BatchRunner`` contract intact.

Pickling degrades to a plain list of value tuples: a RowBatch only ever
appears transiently (an in-flight ask), and shipping the compiled arrays
inside a mid-run checkpoint would bloat it for data the resume path
regenerates anyway.
"""
from __future__ import annotations

from collections.abc import Sequence

import numpy as np


class RowBatch(Sequence):
    __slots__ = ("compiled", "rows")

    def __init__(self, compiled, rows):
        # rows stays whatever sequence the caller built (tuple, list, or
        # ndarray — CSR slices arrive as arrays, single moves as tuples);
        # normalizing eagerly would cost an asarray per ask on the hottest
        # single-config path (simulated annealing's walk)
        self.compiled = compiled
        self.rows = rows

    def row_list(self) -> list:
        rows = self.rows
        return rows.tolist() if isinstance(rows, np.ndarray) else list(rows)

    def __len__(self) -> int:
        return len(self.rows)

    def __getitem__(self, i):
        if isinstance(i, slice):
            return RowBatch(self.compiled, self.rows[i])
        return self.compiled.configs[int(self.rows[i])]

    def __iter__(self):
        configs = self.compiled.configs
        for r in self.row_list():
            yield configs[r]

    def __reduce__(self):
        # serialize as the value tuples this batch denotes (see docstring)
        return (list, (list(self),))

    def __repr__(self):
        return f"RowBatch({self.compiled.name!r}, n={len(self.rows)})"
