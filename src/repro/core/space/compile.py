"""Blocked vectorized space enumeration: Cartesian product -> CompiledSpace.

The legacy enumeration was a recursive depth-first product with a Python
dict built per leaf. Here the product is never materialized config-by-
config: flat Cartesian indices are processed in numpy chunks, the value-
index matrix of each chunk comes from stride arithmetic, and only the
constraint predicates themselves still run per row (they are arbitrary
Python callables over config dicts). Two fast paths skip even that:

  * no constraints — the whole product is valid; the bitmap is constant;
  * a single membership constraint (caches loaded from disk reconstruct
    their space as "config id is in the recorded result set",
    ``cache._Membership``) — the member keys are parsed straight into flat
    indices, making compilation O(n_valid) instead of O(cartesian) with a
    string join per config.

Enumeration order is identical to the legacy DFS: ascending flat index in
C order (last tunable fastest). Everything downstream (row numbering,
``valid_configs``, random-fallback draws) depends on that order.
"""
from __future__ import annotations

import time
from typing import Sequence

import numpy as np

from ..tunable import Constraint, Tunable
from .compiled import CompiledSpace

# constraint evaluation block: large enough to amortize the per-chunk numpy
# calls, small enough that the object columns stay cache-resident
_CHUNK = 1 << 16


def _strides(cards: Sequence[int]) -> tuple:
    """C-order strides (last tunable fastest) — the DFS enumeration order."""
    out = [1] * len(cards)
    for i in range(len(cards) - 2, -1, -1):
        out[i] = out[i + 1] * cards[i + 1]
    return tuple(out)


def _membership_flats(tunables: Sequence[Tunable], names: tuple,
                      present) -> np.ndarray:
    """Parse membership keys (``"v1,v2,..."``) straight into sorted flat
    indices. Keys that do not decode to a Cartesian member are skipped —
    the DFS could never have produced them either."""
    strides = _strides([t.cardinality for t in tunables])
    flats = []
    n = len(tunables)
    for key in present:
        parts = key.split(",")
        if len(parts) != n:
            continue
        flat = 0
        for t, s, stride in zip(tunables, parts, strides):
            try:
                v = t.from_str(s)
            except KeyError:
                flat = -1
                break
            flat += t.position[v] * stride
        if flat >= 0:
            flats.append(flat)
    arr = np.unique(np.asarray(flats, dtype=np.int64))
    return arr


def compile_space(tunables: Sequence[Tunable],
                  constraints: Sequence[Constraint] = (),
                  name: str = "space") -> CompiledSpace:
    """Compile a constrained space into array form (see module docstring).

    Returns a :class:`CompiledSpace` whose ``compile_seconds`` records the
    wall cost (surfaced by ``python -m repro spaces`` and the
    ``space_compile`` benchmark component).
    """
    t0 = time.perf_counter()
    tunables = tuple(tunables)
    constraints = tuple(constraints)
    cards = tuple(t.cardinality for t in tunables)
    strides = _strides(cards)
    cartesian = 1
    for c in cards:
        cartesian *= c
    names = tuple(t.name for t in tunables)

    bitmap = np.zeros(cartesian, dtype=bool)
    member_fn = constraints[0].fn if len(constraints) == 1 else None
    if not constraints:
        bitmap[:] = True
        valid_flat = np.arange(cartesian, dtype=np.int64)
    elif (getattr(member_fn, "present", None) is not None
            and tuple(getattr(member_fn, "names", ())) == names
            # str collisions (1 vs "1") would make key parsing lossy where
            # the join-based membership predicate is not; fall back then
            and all(len(t._by_str) == t.cardinality for t in tunables)):
        valid_flat = _membership_flats(tunables, names, member_fn.present)
        bitmap[valid_flat] = True
    else:
        value_cols = [np.array(t.values, dtype=object) for t in tunables]
        for start in range(0, cartesian, _CHUNK):
            flats = np.arange(start, min(start + _CHUNK, cartesian),
                              dtype=np.int64)
            cols = [value_cols[i][(flats // strides[i]) % cards[i]].tolist()
                    for i in range(len(tunables))]
            ok = bitmap[start:start + len(flats)]
            for j, vals in enumerate(zip(*cols)):
                d = dict(zip(names, vals))
                ok[j] = all(c(d) for c in constraints)
        valid_flat = np.nonzero(bitmap)[0].astype(np.int64)

    vidx = np.empty((len(valid_flat), len(tunables)), dtype=np.int32)
    for i in range(len(tunables)):
        vidx[:, i] = (valid_flat // strides[i]) % cards[i]
    return CompiledSpace(tunables, constraints, name, cards, strides,
                         cartesian, valid_flat, vidx, bitmap,
                         compile_seconds=time.perf_counter() - t0)
