"""Nearest-valid repair: single-move order tables + flat-index BFS.

The legacy ``nearest_valid`` ran a breadth-first search over single-tunable
moves (depth 3, frontier capped at 256) with a dict-memoized outcome and a
random-restart fallback. The search itself draws nothing from the rng, so
its outcome is a pure function of the starting config — here it runs over
flat Cartesian indices against the validity bitmap, with the per-(tunable,
index) move orders precomputed, and memoizes into a flat int32 table
(including the "BFS exhausted" outcome, which the scalar code recomputed
on every visit; only the *fallback draw* stays per-call, in the exact
scalar order).

Move order is the legacy one: per frontier config, tunables in declaration
order; per tunable, candidate indices sorted by distance from the current
index (ties: smaller index first, which includes the no-op move first —
always already seen, always skipped, exactly as before).
"""
from __future__ import annotations

import numpy as np

UNSET = -2      # memo sentinel: repair not yet computed
FALLBACK = -1   # memo value: depth-3 BFS exhausted -> random fallback

_DEPTH = 3
_FRONTIER_CAP = 256


def make_state(cs) -> tuple:
    """(memo, move_orders) for one compiled space, allocated lazily on the
    first repair. ``memo`` is flat-indexed over the Cartesian product;
    ``move_orders[i][j]`` is the full candidate order for tunable ``i`` at
    value index ``j`` (the no-op first, like the scalar sort)."""
    memo = np.full(cs.cartesian_size, UNSET, dtype=np.int32)
    move_orders = tuple(
        tuple(tuple(sorted(range(card), key=lambda k: abs(k - j)))
              for j in range(card))
        for card in cs.cards)
    return memo, move_orders


def bfs(cs, move_orders, flat0: int) -> int:
    """The scalar BFS, verbatim, on flat indices: returns the repaired row
    or ``FALLBACK`` when depth-3 search exhausts."""
    bitmap = cs.bitmap
    row_of_flat = cs.row_of_flat
    strides = cs.strides
    cards = cs.cards
    n = cs.n_tunables
    seen = {flat0}
    frontier = [flat0]
    for _depth in range(_DEPTH):
        nxt: list[int] = []
        for f in frontier:
            for i in range(n):
                stride = strides[i]
                j = (f // stride) % cards[i]
                base = f - j * stride
                for k in move_orders[i][j]:
                    ff = base + k * stride
                    if ff in seen:
                        continue
                    seen.add(ff)
                    if bitmap[ff]:
                        return int(row_of_flat[ff])
                    nxt.append(ff)
        frontier = nxt[:_FRONTIER_CAP]
    return FALLBACK
