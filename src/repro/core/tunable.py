"""Tunable parameters and constraints for auto-tuning search spaces.

This mirrors the paper's setting (Sec. III-A): a search space ``X`` is the
Cartesian product of tunable parameters' value sets, filtered by user-defined
constraints (``restrictions`` in Kernel Tuner terminology).

A configuration is represented as an immutable ``tuple`` of values in the
order the tunables were declared; dict views are provided for readability.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Mapping, Sequence

Value = Any
Config = tuple  # tuple of values, one per tunable, in declaration order


@dataclasses.dataclass(frozen=True)
class Tunable:
    """One tunable parameter with its finite, ordered value set.

    ``values`` must be non-empty and free of duplicates. Order matters: local
    search strategies treat adjacent values as neighbors (the usual treatment
    of numerical parameters in auto-tuning).
    """

    name: str
    values: tuple

    def __post_init__(self):
        if not self.values:
            raise ValueError(f"tunable {self.name!r} has no values")
        if len(set(self.values)) != len(self.values):
            raise ValueError(f"tunable {self.name!r} has duplicate values")

    @property
    def cardinality(self) -> int:
        return len(self.values)

    def index_of(self, value: Value) -> int:
        return self.values.index(value)


@dataclasses.dataclass(frozen=True)
class Constraint:
    """A predicate over a configuration dict; False ⇒ config is invalid.

    ``fn`` receives a mapping {tunable_name: value}. ``description`` is used
    in the T1-format dataset export.
    """

    fn: Callable[[Mapping[str, Value]], bool]
    description: str = ""

    def __call__(self, conf: Mapping[str, Value]) -> bool:
        return bool(self.fn(conf))


def tunables_from_dict(d: Mapping[str, Sequence[Value]]) -> tuple:
    """Convenience: build Tunables from an ordered {name: values} mapping."""
    return tuple(Tunable(name, tuple(vals)) for name, vals in d.items())
