"""Tunable parameters and constraints for auto-tuning search spaces.

This mirrors the paper's setting (Sec. III-A): a search space ``X`` is the
Cartesian product of tunable parameters' value sets, filtered by user-defined
constraints (``restrictions`` in Kernel Tuner terminology).

A configuration is represented as an immutable ``tuple`` of values in the
order the tunables were declared; dict views are provided for readability.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Mapping, Sequence

Value = Any
Config = tuple  # tuple of values, one per tunable, in declaration order


@dataclasses.dataclass(frozen=True)
class Tunable:
    """One tunable parameter with its finite, ordered value set.

    ``values`` must be non-empty and free of duplicates. Order matters: local
    search strategies treat adjacent values as neighbors (the usual treatment
    of numerical parameters in auto-tuning).
    """

    name: str
    values: tuple

    def __post_init__(self):
        if not self.values:
            raise ValueError(f"tunable {self.name!r} has no values")
        if len(set(self.values)) != len(self.values):
            raise ValueError(f"tunable {self.name!r} has duplicate values")

    @property
    def cardinality(self) -> int:
        return len(self.values)

    @functools.cached_property
    def position(self) -> dict:
        """value -> index table; the O(1) core of ``index_of`` and of the
        compiled-space row lookups (``core.space``). First declaration wins
        like ``list.index`` (equal-hashing values such as ``1``/``1.0`` are
        already rejected as duplicates by ``__post_init__``, so this is
        belt-and-braces, not a reachable branch)."""
        table: dict = {}
        for i, v in enumerate(self.values):
            table.setdefault(v, i)
        return table

    @functools.cached_property
    def _by_str(self) -> dict:
        """str(value) -> value. First declaration wins on str collisions
        (e.g. ``1`` vs ``"1"``), matching the original linear scan."""
        table: dict = {}
        for v in self.values:
            table.setdefault(str(v), v)
        return table

    def index_of(self, value: Value) -> int:
        pos = self.position.get(value)
        if pos is None:
            # keep the canonical ValueError of the original list scan
            return self.values.index(value)
        return pos

    def from_str(self, s: str) -> Value:
        """The value whose ``str()`` is ``s`` (first match in declaration
        order). Replaces the O(cardinality) scan ``config_from_id`` used to
        do per serialized value — it is called per record on journal resume
        and cache merge."""
        try:
            return self._by_str[s]
        except KeyError:
            raise KeyError(f"{s!r} not a value of {self.name!r}") from None


@dataclasses.dataclass(frozen=True)
class Constraint:
    """A predicate over a configuration dict; False ⇒ config is invalid.

    ``fn`` receives a mapping {tunable_name: value}. ``description`` is used
    in the T1-format dataset export.
    """

    fn: Callable[[Mapping[str, Value]], bool]
    description: str = ""

    def __call__(self, conf: Mapping[str, Value]) -> bool:
        return bool(self.fn(conf))


def tunables_from_dict(d: Mapping[str, Sequence[Value]]) -> tuple:
    """Convenience: build Tunables from an ordered {name: values} mapping."""
    return tuple(Tunable(name, tuple(vals)) for name, vals in d.items())
