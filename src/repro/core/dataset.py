"""Benchmark Hub for Auto-Tuning — the FAIR dataset (paper Sec. III-D).

24 exhaustively brute-forced search spaces: the Cartesian product of four
real kernels (dedispersion, convolution, hotspot, GEMM — Sec. III-D) and six
device models (devices.py). Per kernel we store a T1-style input descriptor
(tunables, constraints, problem sizes) and per (kernel × device) a T4-mini
results file with 32 raw observations per configuration, zstd-compressed.

FAIR mapping (Sec. III-D):
  Findable     — hub/manifest.json indexes every file with checksums
  Accessible   — plain JSON(+zstd), open format, versioned
  Interoperable— T1/T4-style layouts shared with the autotuning-methodology
                 ecosystem
  Reusable     — directly consumable by the simulation mode without access
                 to the original "hardware" (here: without re-running the
                 cost model)

Build:  python -m repro.core.dataset build [--root hub]
"""
from __future__ import annotations

import argparse
import hashlib
import json
import os
import time

from .cache import CachedResult, CacheFile
from .costmodel import estimate
from .devices import HUB_DEVICES, TEST_DEVICES, TRAIN_DEVICES

HUB_VERSION = "1.0.0"
DEFAULT_ROOT = os.path.join(os.path.dirname(__file__), "..", "..", "..", "hub")


def _kernel_modules():
    from ..kernels import HUB_KERNELS  # late import: keeps dataset light
    return HUB_KERNELS


def brute_force(kernel_name: str, device) -> CacheFile:
    """Exhaustively evaluate one search space through the cost model —
    the simulated analogue of the paper's Table II brute-force runs."""
    mod = _kernel_modules()[kernel_name]
    space = mod.space()
    workload = mod.workload()
    results: dict[str, CachedResult] = {}
    sim_seconds = 0.0
    for config in space.valid_configs:
        cid = space.config_id(config)
        est = estimate(workload, space.as_dict(config), device, cid)
        results[cid] = CachedResult(est.status, est.time_s, est.times_s,
                                    est.compile_s, device.overhead_s)
        sim_seconds += results[cid].charge_s
    meta = {
        "hub_version": HUB_VERSION,
        "device_model": device.name,
        "n_configs": len(results),
        "n_ok": sum(1 for r in results.values() if r.status == "ok"),
        "simulated_bruteforce_hours": sim_seconds / 3600.0,
    }
    return CacheFile(kernel_name, device.name, space, results, meta)


def t1_descriptor(kernel_name: str) -> dict:
    """T1-style input descriptor for one kernel."""
    mod = _kernel_modules()[kernel_name]
    space = mod.space()
    return {
        "format": "T1-mini",
        "kernel_name": kernel_name,
        "objective": "time_s",
        "minimize": True,
        "tunable_parameters": {t.name: list(t.values) for t in space.tunables},
        "restrictions": [c.description for c in space.constraints],
        "cartesian_size": space.cartesian_size,
        "constrained_size": space.size,
    }


def _sha256(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def build_hub(root: str = DEFAULT_ROOT, progress=print) -> dict:
    """Brute-force all 24 spaces and write the FAIR layout. Returns manifest."""
    os.makedirs(root, exist_ok=True)
    manifest: dict = {
        "name": "Benchmark Hub for Auto-Tuning (simulated TPU device models)",
        "version": HUB_VERSION,
        "created_unix": time.time(),
        "train_devices": list(TRAIN_DEVICES),
        "test_devices": list(TEST_DEVICES),
        "kernels": {},
        "files": {},
        "bruteforce_hours": {},
    }
    t0 = time.perf_counter()
    for kname in _kernel_modules():
        kdir = os.path.join(root, kname)
        os.makedirs(kdir, exist_ok=True)
        t1_path = os.path.join(kdir, "t1.json")
        with open(t1_path, "w") as f:
            json.dump(t1_descriptor(kname), f, indent=1)
        manifest["kernels"][kname] = {"t1": os.path.relpath(t1_path, root)}
        manifest["bruteforce_hours"][kname] = {}
        for device in HUB_DEVICES:
            cache = brute_force(kname, device)
            out = os.path.join(kdir, f"{device.name}.t4.json.zst")
            cache.save(out)
            rel = os.path.relpath(out, root)
            manifest["files"][f"{kname}@{device.name}"] = {
                "path": rel,
                "sha256": _sha256(out),
                "n_configs": cache.meta["n_configs"],
                "n_ok": cache.meta["n_ok"],
            }
            manifest["bruteforce_hours"][kname][device.name] = round(
                cache.meta["simulated_bruteforce_hours"], 2)
            progress(f"  built {kname}@{device.name}: "
                     f"{cache.meta['n_ok']}/{cache.meta['n_configs']} ok, "
                     f"{cache.meta['simulated_bruteforce_hours']:.1f} simulated h")
    manifest["build_wall_seconds"] = time.perf_counter() - t0
    with open(os.path.join(root, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return manifest


def load_hub(root: str = DEFAULT_ROOT, kernels=None, devices=None) -> dict:
    """Load (kernel, device) -> CacheFile. Builds the hub if missing."""
    manifest_path = os.path.join(root, "manifest.json")
    if not os.path.exists(manifest_path):
        build_hub(root)
    with open(manifest_path) as f:
        manifest = json.load(f)
    out = {}
    for key, entry in manifest["files"].items():
        kname, dname = key.split("@")
        if kernels is not None and kname not in kernels:
            continue
        if devices is not None and dname not in devices:
            continue
        out[(kname, dname)] = CacheFile.load(os.path.join(root, entry["path"]))
    return out


def train_test_caches(root: str = DEFAULT_ROOT) -> tuple:
    """The paper's split: 4 kernels × 3 train devices / × 3 test devices."""
    all_caches = load_hub(root)
    train = [c for (k, d), c in sorted(all_caches.items()) if d in TRAIN_DEVICES]
    test = [c for (k, d), c in sorted(all_caches.items()) if d in TEST_DEVICES]
    return train, test


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("command", choices=["build", "info"])
    ap.add_argument("--root", default=DEFAULT_ROOT)
    args = ap.parse_args()
    if args.command == "build":
        m = build_hub(args.root)
        print(f"hub built at {os.path.abspath(args.root)} in "
              f"{m['build_wall_seconds']:.1f}s wall")
        total = sum(sum(v.values()) for v in m["bruteforce_hours"].values())
        print(f"simulated brute-force cost: {total:.0f} hours "
              f"(paper Table II analogue)")
    else:
        with open(os.path.join(args.root, "manifest.json")) as f:
            print(json.dumps(json.load(f), indent=1))


if __name__ == "__main__":
    main()
