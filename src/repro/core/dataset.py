"""Deprecated shim — the benchmark-hub dataset moved to ``repro.hub``.

The storage layer now lives in ``repro.hub.storage`` and the user-facing
facade is ``repro.api.Hub``; this module keeps the historical free-function
surface alive behind ``HubDeprecationWarning`` (escalated to an error under
pytest, so no in-tree caller can quietly regress to it).

Two behavior changes ride along with the move, on the shims too:
``DEFAULT_ROOT`` is normalized, and loading verifies the manifest's sha256
checksums and raises ``repro.hub.HubError`` on a missing/corrupt hub
instead of silently rebuilding (pass ``verify=False`` to skip digests).

Build:  python -m repro hub build [--root hub]
"""
from __future__ import annotations

import warnings

from ..deprecations import HubDeprecationWarning
from ..hub import storage as _storage
from ..hub.storage import (DEFAULT_ROOT, HUB_VERSION, HubError,  # noqa: F401
                           _sha256, brute_force, t1_descriptor)


def _warn(name: str) -> None:
    warnings.warn(
        f"repro.core.dataset.{name} is deprecated; use repro.hub.{name} "
        f"(or the repro.api.Hub facade)", HubDeprecationWarning, stacklevel=3)


def build_hub(root: str = DEFAULT_ROOT, progress=print) -> dict:
    _warn("build_hub")
    return _storage.build_hub(root, progress)


def load_hub(root: str = DEFAULT_ROOT, kernels=None, devices=None,
             verify: bool = True) -> dict:
    _warn("load_hub")
    return _storage.load_hub(root, kernels, devices, verify=verify)


def train_test_caches(root: str = DEFAULT_ROOT, verify: bool = True) -> tuple:
    _warn("train_test_caches")
    return _storage.train_test_caches(root, verify=verify)


def main() -> None:  # pragma: no cover - delegates to the hub CLI
    import argparse
    import json
    import os

    ap = argparse.ArgumentParser()
    ap.add_argument("command", choices=["build", "info"])
    ap.add_argument("--root", default=DEFAULT_ROOT)
    args = ap.parse_args()
    if args.command == "build":
        m = _storage.build_hub(args.root)
        print(f"hub built at {os.path.abspath(args.root)} in "
              f"{m['build_wall_seconds']:.1f}s wall")
        total = sum(sum(v.values()) for v in m["bruteforce_hours"].values())
        print(f"simulated brute-force cost: {total:.0f} hours "
              f"(paper Table II analogue)")
    else:
        print(json.dumps(_storage.read_manifest(args.root), indent=1))


if __name__ == "__main__":  # pragma: no cover
    main()
