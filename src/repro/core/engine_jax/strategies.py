"""Free-running population strategies on the device (vmap over runs).

Ports of the numpy GA / PSO / DE / random-search to pure-functional state
transitions: each strategy is a namespace of ``init``/``ask``/``tell``
functions over an explicit pytree state, stepped by one ``lax.scan`` over
generations inside ``free_run`` and vmapped over runs — R concurrent runs
x G generations resolve in a single dispatch.

Parity contract (see docs/performance.md): this mode is *statistically*
equivalent to the numpy strategies, not bit-identical. Device RNG is
threefry — it cannot replay ``random.Random``/``np.random.Generator``
streams — and two algorithmic substitutions keep the transitions
device-friendly:

  * repair: an invalid child/decode restarts at a uniform random valid row
    instead of walking the BFS nearest-valid move tables (the tables are
    host-side ragged structures);
  * GA ``disruptive_uniform`` crossover falls back to ``uniform`` (the
    guaranteed-half-swap needs data-dependent shuffling of the differing
    gene set).

Everything on the budget side *is* exact: generations charge through the
same ``budget_scan`` as replay-from-log (left-to-right float64, fresh-only,
pre-eval exhaustion check), revisits are free via a per-run ``seen`` bitmap,
and a run freezes at the generation where the numpy driver would have
caught ``BudgetExhausted``. Pinned seeds reproduce bit-for-bit against
themselves on a given backend.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from ..strategies.base import FAILURE_FITNESS
from .replay import _NO_MAX_E, _NO_MAX_S, budget_scan
from .tables import replay_tables, space_tables


def _rand_rows(key, n_valid: int, shape) -> jnp.ndarray:
    return jax.random.randint(key, shape, 0, n_valid)


def _decode(x, st, key):
    """Round/clip a (P, T) continuous index matrix to rows; invalid
    positions restart at a uniform random valid row (device-side stand-in
    for the BFS repair tables)."""
    k = jnp.clip(jnp.rint(x), 0.0, st["x_hi"]).astype(jnp.int64)
    flat = k @ st["strides"]
    rows = st["row_of_flat"][flat].astype(jnp.int32)
    rnd = _rand_rows(key, st["n_valid"], rows.shape).astype(jnp.int32)
    return jnp.where(rows < 0, rnd, rows)


# --------------------------------------------------------------- crossovers
def _cross_uniform(a, b, key, T):
    mask = jax.random.bernoulli(key, 0.5, a.shape)
    return jnp.where(mask, b, a), jnp.where(mask, a, b)


def _cross_single_point(a, b, key, T):
    if T < 2:
        return a, b
    pt = jax.random.randint(key, (a.shape[0],), 1, T)
    mask = jnp.arange(T)[None, :] >= pt[:, None]
    return jnp.where(mask, b, a), jnp.where(mask, a, b)


def _cross_two_point(a, b, key, T):
    if T < 3:
        return _cross_single_point(a, b, key, T)
    ki, kj = jax.random.split(key)
    i = jax.random.randint(ki, (a.shape[0],), 1, T)
    j = jax.random.randint(kj, (a.shape[0],), 1, T - 1)
    j = j + (j >= i)  # distinct uniform pair from 1..T-1
    lo, hi = jnp.minimum(i, j), jnp.maximum(i, j)
    ar = jnp.arange(T)[None, :]
    mask = (ar >= lo[:, None]) & (ar < hi[:, None])
    return jnp.where(mask, b, a), jnp.where(mask, a, b)


_CROSSOVERS = {
    "single_point": _cross_single_point,
    "two_point": _cross_two_point,
    "uniform": _cross_uniform,
    # device fallback: the disruptive variant's guaranteed-half swap of the
    # differing-gene set is data-dependent; plain uniform is the closest
    # shape-static operator
    "disruptive_uniform": _cross_uniform,
}


# ---------------------------------------------------------------- strategies
class _GA:
    name = "genetic_algorithm"
    defaults = {"method": "uniform", "popsize": 20, "maxiter": 100,
                "mutation_chance": 10}

    @staticmethod
    def init(st, P, hp):
        return {"pop": jnp.zeros((P, st["n_tunables"]), jnp.int32),
                "it": jnp.int32(0)}

    @staticmethod
    def ask(state, key, st, P, hp):
        need = state["it"] == 0
        init_pop = st["vidx"][_rand_rows(key, st["n_valid"], (P,))]
        pop = jnp.where(need, init_pop, state["pop"])
        rows = st["row_of_flat"][pop.astype(jnp.int64) @ st["strides"]]
        return rows.astype(jnp.int32), {**state, "pop": pop}

    @staticmethod
    def tell(state, rows, fitness, key, st, P, hp):
        T = st["n_tunables"]
        crossover = _CROSSOVERS[str(hp["method"])]
        p_mut = 1.0 / float(hp["mutation_chance"])
        pop = state["pop"]
        ranked = pop[jnp.argsort(fitness)]  # stable: ties by index
        n_pairs = max(1, (P - 1 + 1) // 2)
        kp, kc, km, kg, kr = jax.random.split(key, 5)
        # rank-weighted parent selection: best gets weight P, worst 1
        logits = jnp.log(jnp.arange(P, 0, -1).astype(jnp.float64))
        parents = jax.random.categorical(kp, logits, shape=(n_pairs, 2))
        c1, c2 = crossover(ranked[parents[:, 0]], ranked[parents[:, 1]],
                           kc, T)
        children = jnp.stack([c1, c2], axis=1).reshape(2 * n_pairs, T)[:P - 1]
        # per-gene mutation to a uniform value index of that tunable
        mut = jax.random.uniform(km, children.shape) < p_mut
        cards = jnp.asarray(st["cards"], dtype=jnp.float64)
        draws = jnp.floor(jax.random.uniform(kg, children.shape)
                          * cards[None, :]).astype(jnp.int32)
        children = jnp.where(mut, draws, children)
        # repair: invalid offspring restart at a random valid genome
        flat = children.astype(jnp.int64) @ st["strides"]
        bad = st["row_of_flat"][flat] < 0
        rescue = st["vidx"][_rand_rows(kr, st["n_valid"], (P - 1,))]
        children = jnp.where(bad[:, None], rescue, children)
        new_pop = jnp.concatenate([ranked[:1], children], axis=0)  # elitism
        it = state["it"] + 1
        it = jnp.where(it >= int(hp["maxiter"]), 0, it)  # restart
        return {"pop": new_pop, "it": it}


class _PSO:
    name = "pso"
    defaults = {"popsize": 20, "maxiter": 100, "c1": 2.0, "c2": 1.0,
                "w": 0.5}

    @staticmethod
    def init(st, P, hp):
        T = st["n_tunables"]
        return {"pos": jnp.zeros((P, T)), "vel": jnp.zeros((P, T)),
                "pbest": jnp.zeros((P, T)), "pbest_f": jnp.full(P, jnp.inf),
                "gbest": jnp.zeros(T), "gbest_f": jnp.inf,
                "it": jnp.int32(0)}

    @staticmethod
    def ask(state, key, st, P, hp):
        need = state["it"] == 0
        k1, k2, k3 = jax.random.split(key, 3)
        span = jnp.maximum(st["x_hi"], 1.0)
        pos0 = st["vidx"][_rand_rows(k1, st["n_valid"], (P,))].astype(
            jnp.float64)
        vel0 = jax.random.uniform(k2, pos0.shape, minval=-1.0,
                                  maxval=1.0) * span * 0.25
        pos = jnp.where(need, pos0, state["pos"])
        state = {**state,
                 "pos": pos,
                 "vel": jnp.where(need, vel0, state["vel"]),
                 "pbest": jnp.where(need, pos, state["pbest"]),
                 "pbest_f": jnp.where(need, jnp.inf, state["pbest_f"]),
                 "gbest": jnp.where(need, pos[0], state["gbest"]),
                 "gbest_f": jnp.where(need, jnp.inf, state["gbest_f"])}
        return _decode(pos, st, k3), state

    @staticmethod
    def tell(state, rows, fitness, key, st, P, hp):
        c1, c2 = float(hp["c1"]), float(hp["c2"])
        w = float(hp["w"])
        span = jnp.maximum(st["x_hi"], 1.0)
        x = st["vidx"][rows].astype(jnp.float64)
        better = fitness < state["pbest_f"]
        pbest = jnp.where(better[:, None], x, state["pbest"])
        pbest_f = jnp.where(better, fitness, state["pbest_f"])
        # sequential global-best update == first index achieving the min
        i = jnp.argmin(fitness)
        gb = fitness[i] < state["gbest_f"]
        gbest = jnp.where(gb, x[i], state["gbest"])
        gbest_f = jnp.where(gb, fitness[i], state["gbest_f"])
        k1, k2 = jax.random.split(key)
        pos = state["pos"]
        r1 = jax.random.uniform(k1, pos.shape)
        r2 = jax.random.uniform(k2, pos.shape)
        vel = (w * state["vel"] + c1 * r1 * (pbest - pos)
               + c2 * r2 * (gbest - pos))
        vel = jnp.clip(vel, -span, span)
        pos = jnp.clip(pos + vel, 0.0, st["x_hi"])
        it = state["it"] + 1
        it = jnp.where(it >= int(hp["maxiter"]), 0, it)
        return {"pos": pos, "vel": vel, "pbest": pbest, "pbest_f": pbest_f,
                "gbest": gbest, "gbest_f": gbest_f, "it": it}


class _DE:
    """DE/rand/1/bin, deferred updating (the whole-generation batch form —
    immediate updating is inherently sequential per member)."""

    name = "differential_evolution"
    defaults = {"popsize": 20, "maxiter": 100, "F": 0.8, "CR": 0.9}

    @staticmethod
    def init(st, P, hp):
        T = st["n_tunables"]
        return {"pop": jnp.zeros((P, T)), "fit": jnp.full(P, jnp.inf),
                "trial": jnp.zeros((P, T)), "initgen": jnp.bool_(True),
                "it": jnp.int32(0)}

    @staticmethod
    def ask(state, key, st, P, hp):
        F, CR = float(hp["F"]), float(hp["CR"])
        T = st["n_tunables"]
        need = state["it"] == 0
        k1, k2, k3, k4, k5 = jax.random.split(key, 5)
        pop0 = st["vidx"][_rand_rows(k1, st["n_valid"], (P,))].astype(
            jnp.float64)
        pop = jnp.where(need, pop0, state["pop"])
        # a,b,c: distinct members != i, via argsort of uniforms with the
        # diagonal masked (uniform ordered sample without replacement)
        u = jax.random.uniform(k2, (P, P)) + 2.0 * jnp.eye(P)
        abc = jnp.argsort(u, axis=1)[:, :3]
        a, b, c = pop[abc[:, 0]], pop[abc[:, 1]], pop[abc[:, 2]]
        mutant = jnp.clip(a + F * (b - c), 0.0, st["x_hi"])
        cross = jax.random.uniform(k3, (P, T)) < CR
        forced = jax.random.randint(k4, (P,), 0, T)
        cross = cross | (jnp.arange(T)[None, :] == forced[:, None])
        trial = jnp.where(cross, mutant, pop)
        trial = jnp.where(need, pop, trial)  # init generation asks the pop
        state = {**state, "pop": pop, "trial": trial, "initgen": need}
        return _decode(trial, st, k5), state

    @staticmethod
    def tell(state, rows, fitness, key, st, P, hp):
        initgen = state["initgen"]
        sel = initgen | (fitness <= state["fit"])
        pop = jnp.where(sel[:, None], state["trial"], state["pop"])
        fit = jnp.where(sel, fitness, state["fit"])
        it = state["it"] + 1
        it = jnp.where(it >= int(hp["maxiter"]) + 1, 0, it)
        return {**state, "pop": pop, "fit": fit, "it": it,
                "initgen": jnp.bool_(False)}


class _RandomSearch:
    """Sampling without replacement: one device permutation per run,
    consumed ``popsize`` rows per generation (the numpy strategy asks the
    whole permutation at once; chunking it per generation is observably
    identical under free budgets because revisits never occur)."""

    name = "random_search"
    defaults = {"popsize": 20}

    @staticmethod
    def init(st, P, hp):
        return {"perm": jnp.zeros(st["n_valid"], jnp.int32),
                "offset": jnp.int32(0), "it": jnp.int32(0)}

    @staticmethod
    def ask(state, key, st, P, hp):
        need = state["it"] == 0
        perm0 = jax.random.permutation(key, st["n_valid"]).astype(jnp.int32)
        perm = jnp.where(need, perm0, state["perm"])
        offset = jnp.where(need, 0, state["offset"])
        rows = jax.lax.dynamic_slice(perm, (offset,), (P,))
        return rows, {**state, "perm": perm, "offset": offset}

    @staticmethod
    def tell(state, rows, fitness, key, st, P, hp):
        # past the end, dynamic_slice clamps: the tail re-asks seen rows,
        # which are free revisits — same no-op as the finished numpy ask
        offset = jnp.minimum(state["offset"] + P,
                             max(0, st["n_valid"] - P))
        return {**state, "offset": offset, "it": state["it"] + 1}


FREE_RUN_STRATEGIES = {s.name: s for s in (_GA, _PSO, _DE, _RandomSearch)}


# ------------------------------------------------------------------ driver
@partial(jax.jit, static_argnums=(0, 1, 2, 3, 4))
def _free_run_jit(impl, P, G, hp_key, cards, keys, col_of_row, time_s,
                  charge_s, vidx, row_of_flat, strides, x_hi, mean_charge,
                  max_s, max_e):
    hp = dict(hp_key)
    n_valid, T = vidx.shape
    st = {"vidx": vidx, "row_of_flat": row_of_flat, "strides": strides,
          "x_hi": x_hi, "n_valid": int(n_valid), "n_tunables": int(T),
          "cards": cards}

    def one_run(key):
        k_loop = key
        state0 = impl.init(st, P, hp)
        carry0 = (state0, k_loop, jnp.zeros(n_valid, bool),
                  jnp.float64(0.0), jnp.int64(0),
                  jnp.float64(jnp.inf), jnp.int32(-1), jnp.int64(0),
                  jnp.bool_(False))

        def gen(carry, _):
            (state, key, seen, spent, evals, best_v, best_r, fresh_n,
             stopped) = carry
            key2, k_ask, k_tell = jax.random.split(key, 3)
            rows, state_a = impl.ask(state, k_ask, st, P, hp)
            # within-generation first occurrence: P is population-sized,
            # so the P x P pairwise compare beats any n_valid-sized scatter
            i = jnp.arange(P)
            dup = (rows[:, None] == rows[None, :]) & (i[:, None] > i[None, :])
            fresh = ~jnp.any(dup, axis=1) & ~seen[rows]
            col = col_of_row[rows]
            miss = col < 0
            safe = jnp.clip(col, 0)
            value = jnp.where(miss, jnp.inf, time_s[safe])
            charge = jnp.where(miss, mean_charge, charge_s[safe])
            accept, _t, spent2, evals2, exh = budget_scan(
                fresh, charge, spent, evals, max_s, max_e)
            seen2 = seen.at[rows].max(accept)
            fresh_n2 = fresh_n + jnp.sum(accept)
            okv = jnp.where(accept & jnp.isfinite(value), value, jnp.inf)
            j = jnp.argmin(okv)
            better = okv[j] < best_v
            best_v2 = jnp.where(better, okv[j], best_v)
            best_r2 = jnp.where(better, rows[j], best_r).astype(jnp.int32)
            fitness = jnp.where(jnp.isfinite(value), value, FAILURE_FITNESS)
            state_b = impl.tell(state_a, rows, fitness, k_tell, st, P, hp)
            # once exhausted the numpy driver stops stepping the strategy;
            # budget/seen/best are already monotone-frozen (no accepts can
            # follow a rejection), so only state + rng need the freeze
            state_c = jax.tree_util.tree_map(
                lambda old, new: jnp.where(stopped, old, new), state, state_b)
            key3 = jnp.where(stopped, key, key2)
            carry2 = (state_c, key3, seen2, spent2, evals2, best_v2,
                      best_r2, fresh_n2, stopped | exh)
            return carry2, (spent2, best_v2)

        carry, (curve_spent, curve_best) = jax.lax.scan(
            gen, carry0, None, length=G)
        (_state, _key, _seen, spent, evals, best_v, best_r, fresh_n,
         stopped) = carry
        return {"best_value": best_v, "best_row": best_r,
                "spent_seconds": spent, "spent_evals": evals,
                "fresh_evals": fresh_n, "exhausted": stopped,
                "curve_spent": curve_spent, "curve_best": curve_best}

    return jax.vmap(one_run)(keys)


def free_run(cache, strategy: str = "genetic_algorithm", *, runs: int = 32,
             seed: int = 0, generations: "int | None" = None,
             max_seconds: "float | None" = None,
             max_evals: "int | None" = None, **hyperparams) -> dict:
    """Run ``runs`` independent free-running campaigns of ``strategy`` on
    the device in one dispatch; returns numpy arrays keyed like
    ``SearchDriver`` observables (best value/row, spend, fresh evals,
    per-generation spend/best curves of shape (runs, generations)).

    Pinned-seed deterministic; statistically equivalent to the numpy
    strategies (module docstring has the exact contract)."""
    impl = FREE_RUN_STRATEGIES[strategy]
    unknown = set(hyperparams) - set(impl.defaults)
    if unknown:
        raise ValueError(f"{strategy}: unknown hyperparameters "
                         f"{sorted(unknown)}")
    hp = {**impl.defaults, **hyperparams}
    compiled = cache.space.compiled
    cols = cache.columns
    rt = replay_tables(cols, compiled)
    st = space_tables(compiled)
    if not compiled.n_valid:
        raise ValueError(f"space {compiled.name!r} has no valid configs")
    P = int(hp.get("popsize", 20))
    G = int(generations if generations is not None
            else hp.get("maxiter", 100))
    mean_charge = cache.mean_eval_charge() if rt.has_miss else 0.0
    max_s = _NO_MAX_S if max_seconds is None else float(max_seconds)
    max_e = _NO_MAX_E if max_evals is None else int(max_evals)
    hp_key = tuple(sorted(hp.items()))
    with enable_x64():
        keys = jax.random.split(jax.random.PRNGKey(int(seed)), int(runs))
        out = _free_run_jit(impl, P, G, hp_key, st.cards, keys,
                            rt.col_of_row, rt.time_s, rt.charge_s,
                            st.vidx, st.row_of_flat, st.strides, st.x_hi,
                            jnp.float64(mean_charge), jnp.float64(max_s),
                            jnp.int64(max_e))
        out = {k: np.asarray(v) for k, v in out.items()}
    return out
