"""Device-resident fused campaigns: whole tuning runs per XLA dispatch.

The ``campaign`` workload was stepping-bound: every generation of every
run paid one ask -> ``run_batch`` -> tell round-trip through the runner's
commit machinery, so a hyperparameter campaign (configs x spaces x repeats)
was ~10^4 host round-trips even though the jitted replay kernel resolves
millions of evaluations per dispatch. This module fuses the
budget-replay-commit leg of every concurrent run into a handful of vmapped
``_replay_vjit`` dispatches while keeping the bit-parity contract of the
replay-from-log tier (PR 4), not the statistical contract of the
free-running tier (PR 6).

The split that makes this possible: in simulation mode an observation's
*value* is a pure row lookup (``time_s[col_of_row[row]]``, inf for rows
outside the recorded set), and the array-native strategies (GA, PSO, DE,
random search) consume only ``observation.value`` in ``tell``. The ask/tell
trajectory is therefore *budget-independent* — the exact same numpy/python
RNG stream unfolds whether or not the budget would have stopped the run —
so the host can step the real strategy code as a **trajectory oracle**
against a precomputed value table (no Observation objects, no memo, no
budget), while the device performs the budget accounting (the
parity-critical left-to-right float64 ``lax.scan``) for *all* runs of a
campaign in one dispatch per segment. Everything the device rejects past
the exhaustion point is discarded, which is exactly what ``BudgetExhausted``
discards in the sequential loop: exhaustion is monotone (charges are
non-negative), so the committed prefix is identical.

Where draw counts are data-dependent (every strategy outside the allowlist,
bridge-adapted loops, empty caches whose imputed-miss error must surface on
the host), ``fuse_reason`` names the reason and the caller falls back to
the host drive — segmented host stepping remains the general path, the
device path is an eligibility-gated fast lane that commits bit-identical
state (tests/test_campaign_fused.py pins this against the numpy engine).
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp
from jax.experimental import enable_x64

from ..cache import CachedResult
from ..runner import INVALID, Observation, SimulationRunner
from ..space import RowBatch
from .replay import _budget_limits, _pad_len, _replay_vjit, first_occurrence
from .tables import replay_tables

# strategies whose ask/tell trajectory is host-replayable from values alone:
# tell reads only ``observation.value`` (never status/config/result), and
# retains no observation objects
FUSED_STRATEGIES = frozenset(
    {"random_search", "genetic_algorithm", "pso", "differential_evolution"})
# tell is a literal no-op: skip building the value feed entirely
_TELL_NOOP = frozenset({"random_search"})

# rows collected per run per segment before dispatching: large enough that
# budget-sized runs complete in one dispatch, small enough that a run whose
# budget exhausts early does not step its oracle far past the cutoff
SEGMENT_ROWS = 4096


class _ValueObs:
    """What the trajectory oracle tells the strategy: the minimal stand-in
    for an ``Observation`` (the fused strategies read only ``value``)."""

    __slots__ = ("value",)

    def __init__(self, value: float):
        self.value = value


def fuse_reason(driver) -> "str | None":
    """Why this driver cannot take the device-fused path (None = eligible).

    The reasons mirror the sequential semantics the fast lane must not
    change: bridge/legacy strategies have data-dependent ask streams, an
    empty cache must raise ``mean_eval_charge``'s error at the exact host
    point, and a GA/PSO/DE run with no budget cap never terminates — the
    sequential path at least surfaces progress while it spins.
    """
    from . import engine_available, unavailable_reason
    strategy = driver.strategy
    name = getattr(strategy, "name", type(strategy).__name__)
    if name not in FUSED_STRATEGIES:
        return (f"strategy {name!r} is not array-native "
                f"(trajectory not host-replayable from values alone)")
    if not engine_available():
        return f"jax engine unavailable ({unavailable_reason()})"
    runner = driver.runner
    if not isinstance(runner, SimulationRunner):
        return f"runner {type(runner).__name__} is not a SimulationRunner"
    if not runner.columnar:
        return "runner is scalar (engine='scalar' is the parity reference)"
    if len(runner.cache.columns) == 0:
        return ("cache is empty: the imputed-miss charge error must "
                "surface on the host")
    budget = runner.budget
    if (budget.max_seconds is None and budget.max_evals is None
            and name != "random_search"):
        return f"unbounded budget: {name} never finishes without a cap"
    return None


class FusedRun:
    """One tuning run's fused execution state: the oracle's optimistic
    bookkeeping plus the device-committed prefix."""

    __slots__ = ("driver", "seen", "spent", "evals", "evals0", "max_s",
                 "max_e", "approx_s", "approx_e", "no_more_asks", "done",
                 "exhausted", "acc_rows", "acc_t", "acc_v", "acc_c")

    def __init__(self, driver):
        runner = driver.runner
        self.driver = driver
        # the oracle's own copy: marked optimistically at ask time, while
        # the runner's row state is only touched by the final commit
        self.seen = runner._row_state()[0].copy()
        budget = runner.budget
        self.spent = budget.spent_seconds   # device-authoritative after
        self.evals = budget.spent_evals     # each segment
        self.evals0 = budget.spent_evals
        self.max_s, self.max_e = _budget_limits(budget)
        # host stop heuristic only — np.add.reduce may differ from the
        # device's left-to-right sum by ULPs, so these never decide
        # exhaustion, only when to stop extending a segment
        self.approx_s = self.spent
        self.approx_e = self.evals
        self.no_more_asks = driver.state.finished
        self.done = driver.state.finished
        self.exhausted = False
        # committed (device-accepted) prefix, appended per segment
        self.acc_rows: list = []
        self.acc_t: list = []
        self.acc_v: list = []
        self.acc_c: list = []

    # ------------------------------------------------------------- results
    @property
    def fresh_evals(self) -> int:
        return self.evals - self.evals0

    def trace(self) -> list:
        """The run's fresh-commit trace as ``(t_cum, value, None)`` tuples
        — ``score_trace`` ignores the config column, so the scores-only
        path never materializes configs or Observations."""
        if not self.acc_rows:
            return []
        t = np.concatenate(self.acc_t).tolist()
        v = np.concatenate(self.acc_v).tolist()
        return [(ti, vi, None) for ti, vi in zip(t, v)]

    def improvements(self) -> tuple:
        """The run's improvement step function ``(times, bests)`` as
        float64 arrays — what ``SpaceScorer.score_improvements`` consumes.

        Bit-identical to scanning ``trace()`` with the sequential
        ``value < best`` loop: ``np.fmin.accumulate`` over the committed
        value column takes the same float64 minima in the same order, and
        an improvement is exactly a strictly-smaller running minimum
        (non-finite values never improve — ``inf < inf`` is False in both
        formulations). Lets scores-only consumers skip the Python trace
        entirely."""
        if not self.acc_rows:
            return (np.empty(0, dtype=np.float64),
                    np.empty(0, dtype=np.float64))
        t = np.concatenate(self.acc_t)
        v = np.concatenate(self.acc_v)
        run_min = np.fmin.accumulate(np.where(np.isfinite(v), v, np.inf))
        imp = np.empty(len(v), dtype=bool)
        imp[0] = np.isfinite(run_min[0])
        imp[1:] = run_min[1:] < run_min[:-1]
        return t[imp], run_min[imp]


def _collect_segment(run: FusedRun, value_of_row: np.ndarray,
                     charge_of_row: np.ndarray) -> tuple:
    """Step the run's trajectory oracle until the segment is full, the
    approximate budget is spent, or the strategy stops asking. Returns the
    flattened ``(rows, fresh)`` stream for the device."""
    driver = run.driver
    strategy, state = driver.strategy, driver.state
    feed_values = strategy.name not in _TELL_NOOP
    parts_r: list = []
    parts_f: list = []
    n = 0
    while not run.no_more_asks:
        batch = strategy.ask(state)
        if not batch:
            run.no_more_asks = True
            break
        if not isinstance(batch, RowBatch):  # pragma: no cover - guarded
            raise TypeError(
                f"{strategy.name} asked {type(batch).__name__}, not a "
                f"RowBatch; fuse_reason should have rejected it")
        rows = np.asarray(batch.rows, dtype=np.int64)
        # large duplicate-free asks (random search's permutation) skip the
        # argsort in first_occurrence: one O(n) bincount proves
        # distinctness; small generation-sized asks stay on the generic
        # path where the argsort is already cheap
        if len(rows) >= 1024 and np.bincount(rows).max(initial=0) <= 1:
            fresh = ~run.seen[rows]
        else:
            fresh = first_occurrence(rows) & ~run.seen[rows]
        run.seen[rows[fresh]] = True
        parts_r.append(rows)
        parts_f.append(fresh)
        n += len(rows)
        run.approx_s += float(np.add.reduce(charge_of_row[rows[fresh]]))
        run.approx_e += int(np.count_nonzero(fresh))
        if feed_values:
            values = value_of_row[rows].tolist()
            strategy.tell(state, [_ValueObs(v) for v in values])
        if (n >= SEGMENT_ROWS or run.approx_s >= run.max_s
                or run.approx_e >= run.max_e):
            break
    if not parts_r:
        return np.empty(0, dtype=np.int64), np.empty(0, dtype=bool)
    return np.concatenate(parts_r), np.concatenate(parts_f)


def _drive_group(runs: "list[FusedRun]", cols, compiled) -> int:
    """Drive one cache group's runs to completion; returns the number of
    device dispatches (the whole point: a handful, not ~10^4)."""
    tables = replay_tables(cols, compiled)
    col_map = cols.rows_for_space(compiled)
    safe = np.clip(col_map, 0, None)
    if tables.has_miss:
        # non-empty cache (fuse_reason gates empty ones), so this is the
        # same finite value every miss commit would compute lazily
        mean_charge = runs[0].driver.runner.cache.mean_eval_charge()
        value_of_row = np.where(col_map >= 0, cols.time_s[safe], np.inf)
        charge_of_row = np.where(col_map >= 0, cols.charge_s[safe],
                                 mean_charge)
    else:
        mean_charge = 0.0
        value_of_row = cols.time_s[safe]
        charge_of_row = cols.charge_s[safe]
    dispatches = 0
    active = [r for r in runs if not r.done]
    while active:
        todo: list = []
        for run in active:
            rows, fresh = _collect_segment(run, value_of_row, charge_of_row)
            if len(rows) == 0:
                run.done = True
            else:
                todo.append((run, rows, fresh))
        if not todo:
            break
        # pad both axes to powers of two so the jit cache holds a handful
        # of (runs, length) shapes per space, not one per campaign round
        length = _pad_len(max(len(rows) for _run, rows, _f in todo))
        width = _pad_len(len(todo))
        rows_m = np.zeros((width, length), dtype=np.int64)
        fresh_m = np.zeros((width, length), dtype=bool)
        spent0 = np.zeros(width, dtype=np.float64)
        evals0 = np.zeros(width, dtype=np.int64)
        max_s = np.full(width, np.inf, dtype=np.float64)
        max_e = np.full(width, 2 ** 62, dtype=np.int64)
        for i, (run, rows, fresh) in enumerate(todo):
            rows_m[i, :len(rows)] = rows
            fresh_m[i, :len(fresh)] = fresh
            spent0[i] = run.spent
            evals0[i] = run.evals
            max_s[i] = run.max_s
            max_e[i] = run.max_e
        dispatches += 1
        with enable_x64():
            out = _replay_vjit(
                jnp.asarray(rows_m), jnp.asarray(fresh_m),
                tables.col_of_row, tables.time_s, tables.charge_s,
                jnp.float64(mean_charge), jnp.asarray(spent0),
                jnp.asarray(evals0), jnp.asarray(max_s),
                jnp.asarray(max_e))
        accept = np.asarray(out[0])
        t_after = np.asarray(out[1])
        value = np.asarray(out[2])
        charge = np.asarray(out[3])
        spent = np.asarray(out[4])
        evals = np.asarray(out[5])
        exhausted = np.asarray(out[6])
        survivors: list = []
        for i, (run, rows, _fresh) in enumerate(todo):
            n = len(rows)
            acc = np.nonzero(accept[i, :n])[0]
            if len(acc):
                run.acc_rows.append(rows[acc])
                run.acc_t.append(t_after[i, acc])
                run.acc_v.append(value[i, acc])
                run.acc_c.append(charge[i, acc])
            # chained-scan seed: the device's final (spent, evals) feeds
            # the next segment, so the left-to-right addition sequence is
            # one unbroken chain — bit-identical to a single long scan
            run.spent = float(spent[i])
            run.evals = int(evals[i])
            run.approx_s = run.spent
            run.approx_e = run.evals
            if exhausted[i]:
                run.exhausted = True
                run.done = True
            elif run.no_more_asks:
                run.done = True
            else:
                survivors.append(run)
        active = survivors
    return dispatches


def _commit_run(run: FusedRun) -> None:
    """Materialize the device-accepted prefix into the runner — memo,
    trace, budget, freshness — exactly as the sequential commit paths do
    (mirrors ``ReplayEngine.commit_rows``'s host-side commit), then finish
    the driver the way ``drive_many`` would."""
    driver = run.driver
    runner = driver.runner
    seen, obs_by_row, _col_arr, col_list, cols = runner._row_state()
    if run.acc_rows:
        rows = np.concatenate(run.acc_rows)
        t_col = np.concatenate(run.acc_t).tolist()
        vals = np.concatenate(run.acc_v).tolist()
        chgs = np.concatenate(run.acc_c).tolist()
        seen[rows] = True
        cs = runner.space.compiled
        cfg_tab, id_tab = cs.configs, cs.ids
        rows_l = rows.tolist()
        cfgs = [cfg_tab[r] for r in rows_l]
        records = cols.records
        new_obs = Observation.__new__
        set_dict = object.__setattr__
        memo = runner.memo
        for r, cfg, val, chg in zip(rows_l, cfgs, vals, chgs):
            col = col_list[r]
            if col >= 0:
                rec = records[col]
                status = rec.status
            else:
                rec = CachedResult("error", INVALID, (), chg)
                status = "error"
            obs = new_obs(Observation)
            set_dict(obs, "__dict__",
                     {"config": cfg, "value": val, "status": status,
                      "charge_s": chg, "result": rec})
            obs_by_row[r] = obs
            memo[id_tab[r]] = obs
        runner.trace.extend(zip(t_col, vals, cfgs))
        runner.fresh_evals += len(rows_l)
        runner._rows_memo_len = len(memo)
    budget = runner.budget
    budget.spent_seconds = run.spent
    budget.spent_evals = run.evals
    state = driver.state
    state.finished = True
    driver.exhausted = run.exhausted
    state.close()


def drive_fused(drivers, materialize: bool = True) -> "list[FusedRun]":
    """Drive every driver's campaign through the device-fused path.

    All drivers must be eligible (``fuse_reason(d) is None`` — callers
    partition first; this raises ``ValueError`` otherwise). Runs are
    grouped by (cache columns, compiled space) identity and each group
    resolves as a few vmapped dispatches. With ``materialize=True``
    (the ``drive_many`` contract) each runner's observable state — memo,
    trace, budget, ``fresh_evals`` — commits bit-identically to the
    sequential engines; ``materialize=False`` skips Observation/memo
    construction for scores-only callers (the methodology reads
    ``FusedRun.trace()``/``fresh_evals``/``spent`` instead).
    """
    runs: list[FusedRun] = []
    groups: dict = {}
    for d in drivers:
        reason = fuse_reason(d)
        if reason is not None:
            raise ValueError(
                f"driver is not device-fusable: {reason} "
                f"(partition with fuse_reason first)")
        run = FusedRun(d)
        runs.append(run)
        runner = d.runner
        key = (id(runner.cache.columns), id(runner.space.compiled))
        groups.setdefault(
            key, (runner.cache.columns, runner.space.compiled, []))[2].append(run)
    for cols, compiled, group in groups.values():
        _drive_group(group, cols, compiled)
    if materialize:
        for run in runs:
            _commit_run(run)
    else:
        for run in runs:
            run.driver.state.finished = True
            run.driver.exhausted = run.exhausted
            run.driver.state.close()
    return runs
