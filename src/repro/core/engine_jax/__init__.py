"""Jitted JAX replay engine (ROADMAP item 2: the simulator on the accelerator).

Two execution modes with two different parity contracts:

  * **Replay-from-log** (``ReplayEngine`` behind
    ``SimulationRunner(engine="jax")``, ``replay_many`` for fused multi-run
    workloads): the compiled space/cache tables live as device arrays and a
    ``lax.scan`` performs the budget accounting with the exact left-to-right
    float64 additions of the numpy engine. Given identical told
    observations, scores and traces are **bit-identical** to the numpy
    path — the numpy engine stays the parity oracle exactly as
    ``core.space.reference`` anchors compiled spaces
    (tests/test_engine_jax.py).

  * **Free-running** (``free_run``): GA / PSO / DE / random search step as
    pure-functional state transitions under ``jax.vmap`` over runs, with
    ``lax.scan`` driving whole generations so thousands of concurrent runs
    resolve in one dispatch. Device-side RNG (threefry) cannot replay
    numpy's ``Generator``/``random.Random`` streams, so this mode is
    **statistically equivalent** only: pinned seeds reproduce bit-for-bit
    against themselves, and distributions match the numpy strategies
    (docs/performance.md explains the contract).

JAX is an optional dependency: everything here degrades cleanly. When jax
(or a usable backend) is absent, ``engine_available()`` is False and a
``SimulationRunner(engine="jax")`` transparently falls back to the numpy
row path — safe precisely because replay-from-log is bit-identical either
way. Float64 is enabled per-dispatch via ``jax.experimental.enable_x64``,
so the engine does not depend on (or mutate) the process-global
``JAX_ENABLE_X64`` setting.
"""
from __future__ import annotations

try:
    import jax as _jax

    HAVE_JAX = True
    JAX_UNAVAILABLE_REASON = ""
except Exception as _exc:  # pragma: no cover - exercised on minimal envs
    HAVE_JAX = False
    JAX_UNAVAILABLE_REASON = f"{type(_exc).__name__}: {_exc}"

_BACKEND: "str | None | bool" = False  # False = not probed yet


def backend_name() -> "str | None":
    """Platform of the default jax backend (``"cpu"``/``"gpu"``/``"tpu"``),
    or None when jax is missing or cannot initialize any device. Probed
    once — a worker whose accelerator disappeared (process pools fork
    without device handles) lands on the CPU backend or on None, never on
    an exception."""
    global _BACKEND
    if _BACKEND is False:
        if not HAVE_JAX:
            _BACKEND = None
        else:
            try:
                _BACKEND = _jax.devices()[0].platform
            except Exception:  # pragma: no cover - no usable backend
                _BACKEND = None
    return _BACKEND


def engine_available() -> bool:
    """True when the jax engine can actually dispatch (import + backend)."""
    return backend_name() is not None


def unavailable_reason() -> str:
    if not HAVE_JAX:
        return JAX_UNAVAILABLE_REASON
    if backend_name() is None:  # pragma: no cover - no usable backend
        return "jax imported but no backend initialized"
    return ""


def require_jax() -> None:
    if not engine_available():
        raise RuntimeError(
            f"the jax engine is unavailable ({unavailable_reason()}); "
            f"use engine='numpy' or install jax")


if HAVE_JAX:
    from .campaign import FUSED_STRATEGIES, FusedRun  # noqa: F401
    from .campaign import drive_fused, fuse_reason  # noqa: F401
    from .replay import ReplayEngine, replay_many  # noqa: F401
    from .strategies import FREE_RUN_STRATEGIES, free_run  # noqa: F401
    from .tables import ReplayTables, SpaceTables  # noqa: F401
    from .tables import replay_tables, space_tables  # noqa: F401
