"""Device-resident mirrors of the compiled space and cache columns.

The numpy arrays stay the source of truth; these are one-time ``device_put``
copies memoized on their host objects (``CacheColumns._jax``,
``CompiledSpace._jax``) with the same single-entry protocol as
``CacheColumns.rows_for_space``. They are never pickled: both hosts drop
the memo in ``__getstate__``/``__reduce__`` paths, so a process-pool worker
rebuilds its tables against whatever backend it actually has
(tests/test_parallel.py pins this).

All float tables are created under ``enable_x64`` — jax's default float32
would silently truncate the cache's float64 charge/time columns and break
the bit-parity contract (the ``JAX_ENABLE_X64`` CI row guards the other
direction: the suite must also pass when x64 is on globally).
"""
from __future__ import annotations

import jax.numpy as jnp
from jax.experimental import enable_x64


class ReplayTables:
    """Replay-from-log tables for one (CacheColumns, CompiledSpace) pair:
    the space-row -> cache-row bridge plus the value/charge columns."""

    __slots__ = ("n_valid", "col_of_row", "time_s", "charge_s", "has_miss")

    def __init__(self, cols, compiled):
        col_map = cols.rows_for_space(compiled)
        with enable_x64():
            self.col_of_row = jnp.asarray(col_map, dtype=jnp.int32)
            self.time_s = jnp.asarray(cols.time_s)      # float64
            self.charge_s = jnp.asarray(cols.charge_s)  # float64
        self.n_valid = int(compiled.n_valid)
        self.has_miss = bool((col_map < 0).any()) if len(col_map) else False


class SpaceTables:
    """Free-running tables for one ``CompiledSpace``: the value-index
    matrix, validity lookup, and strides (device-side decode/repair)."""

    __slots__ = ("n_valid", "n_tunables", "cards", "vidx", "row_of_flat",
                 "strides", "x_hi")

    def __init__(self, compiled):
        with enable_x64():
            self.vidx = jnp.asarray(compiled.vidx, dtype=jnp.int32)
            self.row_of_flat = jnp.asarray(compiled.row_of_flat)
            self.strides = jnp.asarray(compiled.strides_np)
            self.x_hi = jnp.asarray(compiled._x_hi)
        self.n_valid = int(compiled.n_valid)
        self.n_tunables = int(compiled.n_tunables)
        self.cards = tuple(compiled.cards)


def replay_tables(cols, compiled) -> ReplayTables:
    """Memoized ``ReplayTables`` (single-entry, keyed by compiled-space
    identity — like ``CacheColumns.rows_for_space``)."""
    memo = cols._jax
    if memo is not None and memo[0] is compiled:
        return memo[1]
    tables = ReplayTables(cols, compiled)
    cols._jax = (compiled, tables)
    return tables


def space_tables(compiled) -> SpaceTables:
    """Memoized ``SpaceTables`` on the compiled space itself."""
    tables = compiled._jax
    if tables is None:
        tables = compiled._jax = SpaceTables(compiled)
    return tables
