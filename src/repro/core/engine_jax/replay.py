"""The jitted replay-from-log path: bit-identical to the numpy engine.

The kernel resolves one batch of space rows against the device tables:
value/charge gathers, then a ``lax.scan`` that accumulates the budget with
the exact left-to-right float64 additions of the scalar loop and
``np.cumsum`` (any parallel scan — ``jnp.cumsum`` included — reassociates
the sums and drifts by ULPs, which the parity suite would catch). The
scan's carry is deliberately minimal: ``(spent, evals)`` only. A rejected
fresh evaluation implies ``spent``/``evals`` already reached the cap, and
charges are non-negative, so exhaustion is monotone — the per-step
``stopped`` flag of a naive transcription is redundant, and dropping it
from the carry is worth ~15x on the CPU backend.

Within-batch first-occurrence dedup stays on the host (the same stable
argsort as ``SimulationRunner._commit_rows_vectorized``): a device
scatter-min over the whole batch costs more than the entire scan, and the
host mask is one cheap bool input. ``fresh`` therefore arrives fully
resolved (first occurrence x not-yet-seen), and the kernel only applies the
budget to it.

Batches are padded to power-of-two lengths so the jit cache holds a handful
of shapes per space instead of one per ask size.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from ..budget import BudgetExhausted
from ..cache import CachedResult
from ..runner import Observation
from .tables import ReplayTables, replay_tables

INVALID = float("inf")
_PAD_MIN = 8
# scan unroll: amortizes XLA's per-iteration loop overhead on CPU; measured
# best around 8 (4 is within noise, 16+ regresses from code bloat)
_UNROLL = 8
# unlimited-budget stand-ins (device scalars cannot be None)
_NO_MAX_S = float("inf")
_NO_MAX_E = 2 ** 62


def _pad_len(n: int) -> int:
    return max(_PAD_MIN, 1 << max(0, int(n - 1).bit_length()))


def first_occurrence(rows: np.ndarray) -> np.ndarray:
    """Host-side within-batch dedup mask — the exact stable-argsort
    first-occurrence computation of ``_commit_rows_vectorized``."""
    n = len(rows)
    order = np.argsort(rows, kind="stable")
    sorted_rows = rows[order]
    first_sorted = np.empty(n, dtype=bool)
    first_sorted[:1] = True
    first_sorted[1:] = sorted_rows[1:] != sorted_rows[:-1]
    first = np.empty(n, dtype=bool)
    first[order] = first_sorted
    return first


def budget_scan(fresh, charge, spent0, evals0, max_s, max_e):
    """Sequential budget accounting over one batch segment.

    Bit-for-bit the scalar commit loop: a fresh evaluation commits iff
    ``spent < max_s and evals < max_e`` *before* the eval; committed
    charges accumulate left-to-right in float64. Returns the accept mask,
    the after-commit spend per entry (the trace time column), the final
    ``(spent, evals)``, and whether any fresh evaluation was rejected
    (the ``BudgetExhausted`` point of the equivalent ``run`` loop)."""

    def body(carry, x):
        spent, evals = carry
        f, c = x
        commit = f & (spent < max_s) & (evals < max_e)
        spent2 = jnp.where(commit, spent + c, spent)
        return (spent2, evals + commit.astype(evals.dtype)), (commit, spent2)

    (spent, evals), (accept, t_after) = jax.lax.scan(
        body, (spent0, evals0), (fresh, charge), unroll=_UNROLL)
    exhausted = jnp.any(fresh & ~accept)
    return accept, t_after, spent, evals, exhausted


def _replay_segment(rows, fresh, col_of_row, time_s, charge_s, mean_charge,
                    spent0, evals0, max_s, max_e):
    """One run's segment commit: gathers + ``budget_scan``. Rows absent
    from the recorded set (col < 0) take the imputed-miss path — value inf,
    mean charge — like the keyed/scalar engines."""
    col = col_of_row[rows]
    miss = col < 0
    safe = jnp.clip(col, 0)
    value = jnp.where(miss, jnp.inf, time_s[safe])
    charge = jnp.where(miss, mean_charge, charge_s[safe])
    accept, t_after, spent, evals, exhausted = budget_scan(
        fresh, charge, spent0, evals0, max_s, max_e)
    return accept, t_after, value, charge, spent, evals, exhausted


_replay_jit = jax.jit(_replay_segment)
# fused multi-run variant: tables are shared, per-run rows/fresh/budget;
# one dispatch resolves every concurrent run's segment
_replay_vjit = jax.jit(jax.vmap(
    _replay_segment, in_axes=(0, 0, None, None, None, None, 0, 0, 0, 0)))


def _budget_limits(budget) -> tuple:
    max_s = _NO_MAX_S if budget.max_seconds is None else float(budget.max_seconds)
    max_e = _NO_MAX_E if budget.max_evals is None else int(budget.max_evals)
    return max_s, max_e


class ReplayEngine:
    """Row-batch resolution for one ``SimulationRunner`` on the jax device.

    The host stays the source of truth: observations, memo, trace, and
    budget commit exactly as ``_commit_rows_vectorized`` does, from arrays
    the kernel computed. Every batch containing a fresh row dispatches —
    including single-row asks — so the conformance suite exercises the
    device path at every shape; fully-memoized batches short-circuit to the
    same pure host gather as the numpy path (no engine semantics involved).
    """

    def __init__(self, runner):
        self.runner = runner
        self.dispatches = 0  # device kernel launches (conformance hook)

    def commit_rows(self, rows) -> "list | BudgetExhausted":
        runner = self.runner
        rows = np.asarray(rows, dtype=np.int64)
        n = len(rows)
        seen, obs_by_row, col_of_row, _col_list, cols = runner._row_state()
        if len(cols) == 0:
            # empty cache: every row is an imputed miss and
            # mean_eval_charge's clear error must surface at the exact
            # point the scalar path raises it — keep that on the host
            return runner._commit_rows_loop(rows)
        seen_rows = seen[rows]
        if seen_rows.all():
            # revisit-only batch: pure memo gather, nothing to account
            return [obs_by_row[r] for r in rows.tolist()]
        fresh = first_occurrence(rows) & ~seen_rows
        col_rows = col_of_row[rows]
        mean_charge = (runner.cache.mean_eval_charge()
                       if (col_rows[fresh] < 0).any() else 0.0)
        budget = runner.budget
        max_s, max_e = _budget_limits(budget)
        npad = _pad_len(n)
        rows_p = np.zeros(npad, dtype=np.int64)
        rows_p[:n] = rows
        fresh_p = np.zeros(npad, dtype=bool)
        fresh_p[:n] = fresh
        tables = replay_tables(cols, runner.space.compiled)
        self.dispatches += 1
        with enable_x64():
            out = _replay_jit(
                jnp.asarray(rows_p), jnp.asarray(fresh_p),
                tables.col_of_row, tables.time_s, tables.charge_s,
                jnp.float64(mean_charge),
                jnp.float64(budget.spent_seconds),
                jnp.int64(budget.spent_evals),
                jnp.float64(max_s), jnp.int64(max_e))
            accept, t_after, value, charge, spent, evals, exhausted = (
                np.asarray(o) for o in out)
        # ------------------------------------------------- host-side commit
        # (mirrors _commit_rows_vectorized: fresh commits build
        # Observations, revisits gather from the row-indexed object array)
        acc_idx = np.nonzero(accept[:n])[0]
        cut = len(acc_idx)
        if cut:
            acc_rows = rows[acc_idx]
            acc_cols = col_rows[acc_idx]
            seen[acc_rows] = True
            vals = value[acc_idx].tolist()
            chgs = charge[acc_idx].tolist()
            cs = runner.space.compiled
            cfg_tab, id_tab = cs.configs, cs.ids
            cfgs_acc = [cfg_tab[r] for r in acc_rows.tolist()]
            records = cols.records
            new_obs = Observation.__new__
            set_dict = object.__setattr__
            memo = runner.memo
            for r, col, cfg, val, chg in zip(acc_rows.tolist(),
                                             acc_cols.tolist(),
                                             cfgs_acc, vals, chgs):
                if col >= 0:
                    rec = records[col]
                    status = rec.status
                else:
                    rec = CachedResult("error", INVALID, (), chg)
                    status = "error"
                obs = new_obs(Observation)
                set_dict(obs, "__dict__",
                         {"config": cfg, "value": val, "status": status,
                          "charge_s": chg, "result": rec})
                obs_by_row[r] = obs
                memo[id_tab[r]] = obs
            runner.trace.extend(zip(t_after[acc_idx].tolist(), vals,
                                    cfgs_acc))
            budget.spent_seconds = float(spent)
            budget.spent_evals = int(evals)
            runner.fresh_evals += cut
            runner._rows_memo_len = len(memo)
        if exhausted:
            try:
                budget.check()  # same exception/message as the scalar path
            except BudgetExhausted as exc:
                return exc
        return [obs_by_row[r] for r in rows.tolist()]


def replay_many(cols, compiled, rows_matrix, *, seen=None,
                spent0=None, evals0=None, max_seconds=None, max_evals=None,
                mean_charge: float = 0.0,
                tables: "ReplayTables | None" = None):
    """Fused fresh-replay: resolve R concurrent runs' row segments in one
    vmapped dispatch (the workload behind the ``jax_replay`` bench).

    ``rows_matrix`` is (R, N) int rows; per-run scalars broadcast from
    Python numbers or arrive as (R,) arrays. Returns device arrays
    ``(accept, t_after, value, charge, spent, evals, exhausted)`` — each
    run's slice bit-identical to what a ``SimulationRunner`` replaying the
    same segment would commit (tests/test_engine_jax.py pins this). Rows
    must be within-run unique (fresh replay) unless a precomputed ``seen``
    basis makes duplicates revisits; for general logs use ``ReplayEngine``.
    """
    if tables is None:
        tables = replay_tables(cols, compiled)
    rows_matrix = np.asarray(rows_matrix, dtype=np.int64)
    runs, _n = rows_matrix.shape
    with enable_x64():
        rows_d = jnp.asarray(rows_matrix)
        if seen is None:
            fresh = jnp.ones(rows_matrix.shape, dtype=bool)
        else:
            fresh = ~jnp.asarray(seen)[rows_d] if np.asarray(seen).ndim == 1 \
                else ~jnp.take_along_axis(jnp.asarray(seen), rows_d, axis=1)

        def per_run(x, default, dtype):
            if x is None:
                x = default
            arr = jnp.asarray(x, dtype=dtype)
            return jnp.broadcast_to(arr, (runs,))

        out = _replay_vjit(
            rows_d, fresh, tables.col_of_row, tables.time_s, tables.charge_s,
            jnp.float64(mean_charge),
            per_run(spent0, 0.0, jnp.float64),
            per_run(evals0, 0, jnp.int64),
            per_run(max_seconds, _NO_MAX_S, jnp.float64),
            per_run(max_evals, _NO_MAX_E, jnp.int64))
    return out
