"""Ask/tell search driver: the evaluate loop, extracted from the strategies.

The pre-refactor ``Strategy._optimize`` owned its own evaluate loop, which
made mid-run state invisible (no checkpointing inside a tuning run) and
forced every caller to run strategies one at a time. This module inverts
the control flow (paper Sec. III-E: the algorithm never perceives *how* its
evaluations are satisfied):

  * ``SearchState`` — explicit, picklable per-run strategy state. A
    strategy is a pure transition system over it: ``ask(state)`` proposes
    the next batch of configs, ``tell(state, observations)`` folds results
    back in. Pickling a state (plus the runner's ``state_dict``) suspends a
    tuning run mid-generation; unpickling resumes it bit-identically.
  * ``SearchDriver`` — owns budget handling, trace recording, and RNG
    stepping order. One ``step()`` = one ask → ``runner.run_batch`` → tell.
    ``BudgetExhausted`` terminates the run between ask and tell (exactly
    where the legacy imperative loops died), so a strategy never observes a
    partial batch.
  * ``drive_many`` — interleaves N concurrent runs and fuses their asks
    into shared columnar ``run_fused`` calls (see ``runner.run_fused``),
    turning the methodology's repeat grid into cross-run batches.

Two adapters convert imperative search loops into the protocol without
rewriting them as state machines:

  * ``GeneratorBridgeState`` — for strategies written as generators
    (``obs = yield configs``). Pure-Python loops (simulated annealing, the
    greedy local searches) read exactly as before, with each ``runner(x)``
    call replaced by a yield.
  * ``ThreadBridgeState`` — for strategies that drive a foreign callback
    API (``dual_annealing`` wrapping scipy): the legacy ``_optimize`` runs
    on a daemon thread against a proxy runner that rendezvous-hands each
    evaluation request to the ask side.

Neither adapter's runtime (generator frame, thread) can pickle; both
serialize as *replay logs*: the RNG's initial state plus the sequence of
observation batches told so far. Unpickling re-runs the strategy's own
(cheap, deterministic) compute against the recorded observations — no
kernel evaluation is repeated — and lands it in the exact mid-run state.

Out-of-tree ``Strategy`` subclasses that still override ``_optimize`` keep
working through the thread bridge, with a ``ProtocolDeprecationWarning``
(tier-1 turns these into errors unless a test asserts them; see pytest.ini
and docs/api.md for the migration guide).
"""
from __future__ import annotations

import queue
import random
import threading
import warnings
from typing import Callable, Sequence

from .budget import BudgetExhausted
from .runner import Observation, Runner, run_fused
from .searchspace import SearchSpace
from .tunable import Config


class ProtocolDeprecationWarning(DeprecationWarning):
    """Raised-by-default in tier-1: a legacy ``_optimize`` body is being
    adapted through the thread bridge instead of speaking ask/tell."""


class FuseFallbackNotice(UserWarning):
    """A fused drive (device or host) fell back to a slower mode for some
    strategy. Informational, not an error: the fallback is bit-identical,
    only slower — but campaigns that silently degrade from the device path
    to sequential stepping cost orders of magnitude more wall time, so the
    reason is surfaced once per (strategy, reason) instead of never."""


_fuse_noticed: set = set()


def warn_fuse_fallback(strategy_name: str, reason: str, mode: str) -> None:
    """One-time (per process, per (strategy, reason)) notice that a fused
    drive degraded to ``mode`` (``"host"`` or ``"sequential"``)."""
    key = (strategy_name, reason)
    if key in _fuse_noticed:
        return
    _fuse_noticed.add(key)
    warnings.warn(
        f"{strategy_name}: fused drive falling back to {mode} stepping "
        f"({reason})", FuseFallbackNotice, stacklevel=3)


# --------------------------------------------------------------------- state
class SearchState:
    """Explicit per-run strategy state (the object ``ask``/``tell`` act on).

    Base fields: the search ``space``, the run's ``rng``, the ``finished``
    flag, and ``pending`` (configs asked but not yet told — ``None``
    between generations, which is when checkpoints are taken).

    Pickling drops the space (hub spaces may close over live caches) and
    every underscore-prefixed runtime attribute; ``bind(space)`` re-attaches
    the space on resume. Everything else — including the ``random.Random``
    — round-trips.
    """

    def __init__(self, space: SearchSpace, rng: random.Random):
        self.space = space
        self.rng = rng
        self.finished = False
        self.pending: Sequence[Config] | None = None

    # ------------------------------------------------------------ lifecycle
    def bind(self, space: SearchSpace) -> None:
        """Re-attach the (unpickled-away) search space before resuming."""
        self.space = space

    def attach_runner(self, runner: Runner) -> None:
        """Driver hook: bridges keep a transient runner reference so that
        proxied legacy code can still read ``runner.best``/``trace``."""

    def close(self) -> None:
        """Release runtime resources (generator frames, bridge threads)."""

    # -------------------------------------------------------------- pickling
    def __getstate__(self) -> dict:
        return {k: v for k, v in self.__dict__.items()
                if k != "space" and not k.startswith("_")}

    def __setstate__(self, d: dict) -> None:
        self.__dict__.update(d)
        self.space = None  # re-bound via bind()

    # ------------------------------------------------------------- protocol
    # Bridge states implement ask/tell themselves (the base Strategy
    # delegates here); native strategies override Strategy.ask/tell instead
    # and never call these.
    def ask(self) -> Sequence[Config] | None:
        raise NotImplementedError(
            f"{type(self).__name__} does not implement ask(); the strategy "
            "must override Strategy.ask/tell for this state type")

    def tell(self, observations: Sequence[Observation]) -> None:
        raise NotImplementedError


# ------------------------------------------------------------ replay bridges
class _ReplayBridgeState(SearchState):
    """Shared machinery for adapters whose runtime cannot pickle: serialize
    the initial RNG state plus the told-observation log, and rebuild the
    runtime by replaying it."""

    def __init__(self, strategy, space: SearchSpace, rng: random.Random):
        super().__init__(space, rng)
        self.strategy = strategy
        self.rng0 = rng.getstate()
        self.history: list[list[Observation]] = []

    # subclasses: create the runtime positioned at self.history's end and
    # set self.pending to the next asked batch (or finished)
    def _start(self) -> None:
        raise NotImplementedError

    def _running(self) -> bool:
        raise NotImplementedError

    def _advance(self, observations: list[Observation]) -> None:
        """Feed one observation batch to the runtime; update pending."""
        raise NotImplementedError

    def ask(self) -> Sequence[Config] | None:
        if self.finished:
            return None
        if not self._running():
            self._start()
            if self.finished:
                return None
        return self.pending

    def tell(self, observations: Sequence[Observation]) -> None:
        obs = list(observations)
        self.history.append(obs)
        self.pending = None
        self._advance(obs)


class GeneratorBridgeState(_ReplayBridgeState):
    """Adapter for strategies written as generators: ``_generate(space,
    rng)`` yields config batches and receives their observations back
    (``obs = yield [cfg]``). StopIteration means the strategy is done."""

    def _running(self) -> bool:
        return getattr(self, "_gen", None) is not None

    def _start(self) -> None:
        self.rng.setstate(self.rng0)
        self._gen = self.strategy._generate(self.space, self.rng)
        try:
            self.pending = next(self._gen)
            for obs in self.history:  # replay: reposition after unpickle
                self.pending = self._gen.send(obs)
        except StopIteration:
            self.finished = True
            self.pending = None

    def _advance(self, observations: list[Observation]) -> None:
        try:
            self.pending = self._gen.send(observations)
        except StopIteration:
            self.finished = True

    def close(self) -> None:
        gen = getattr(self, "_gen", None)
        if gen is not None:
            gen.close()
            self._gen = None


class _BridgeShutdown(BaseException):
    """Injected into a bridge thread to unwind it when the driver stops
    first (budget exhausted / driver closed). BaseException so legacy
    ``except Exception`` blocks cannot swallow it."""


class _ProxyRunner:
    """What a thread-bridged ``_optimize`` sees as its runner: evaluation
    calls rendezvous with the driver; everything else is delegated
    (read-only) to the real runner, which is only ever mutated while the
    strategy thread is blocked here."""

    def __init__(self, bridge: "_OptimizeThread"):
        self._bridge = bridge

    def run_batch(self, configs: Sequence[Config]) -> list[Observation]:
        bridge = self._bridge
        bridge.requests.put(("ask", list(configs)))
        resp = bridge.responses.get()
        if isinstance(resp, BaseException):
            raise resp
        return resp

    def run(self, config: Config) -> Observation:
        return self.run_batch([config])[0]

    def __call__(self, config: Config) -> float:
        return self.run_batch([config])[0].value

    def __getattr__(self, name: str):
        runner = self._bridge.runner
        if runner is None:
            raise AttributeError(
                f"proxy runner has no {name!r} (no live runner attached)")
        return getattr(runner, name)


class _OptimizeThread:
    """Daemon thread running a legacy imperative search loop, exchanging
    (ask, observations) pairs with the driver through one-shot queues."""

    def __init__(self, fn: Callable, space: SearchSpace, rng: random.Random,
                 runner: Runner | None):
        self.requests: queue.SimpleQueue = queue.SimpleQueue()
        self.responses: queue.SimpleQueue = queue.SimpleQueue()
        self.runner = runner
        self._thread = threading.Thread(
            target=self._main, args=(fn, space, rng), daemon=True,
            name="repro-bridge")
        self._thread.start()

    def _main(self, fn: Callable, space: SearchSpace,
              rng: random.Random) -> None:
        try:
            fn(space, _ProxyRunner(self), rng)
        except _BridgeShutdown:
            return
        except BaseException as e:  # surfaced on the driver side
            self.requests.put(("error", e))
            return
        self.requests.put(("done", None))

    def next_request(self):
        return self.requests.get()

    def respond(self, payload) -> None:
        self.responses.put(payload)

    def shutdown(self) -> None:
        # if the thread is (or will be) blocked awaiting a response, this
        # unwinds it; if it already finished, the token is never read
        self.responses.put(_BridgeShutdown())
        self._thread.join(timeout=10.0)


class ThreadBridgeState(_ReplayBridgeState):
    """Adapter for strategies that drive a foreign synchronous callback API
    (scipy's ``dual_annealing``): the legacy ``_optimize`` runs on a bridge
    thread; each of its runner calls becomes one ask/tell exchange."""

    def attach_runner(self, runner: Runner) -> None:
        self._runner = runner
        bridge = getattr(self, "_bridge", None)
        if bridge is not None:
            bridge.runner = runner

    def _running(self) -> bool:
        return getattr(self, "_bridge", None) is not None

    def _start(self) -> None:
        self.rng.setstate(self.rng0)
        self._bridge = _OptimizeThread(self.strategy._optimize, self.space,
                                       self.rng, getattr(self, "_runner", None))
        for obs in self.history:  # replay: reposition after unpickle
            kind, payload = self._bridge.next_request()
            if kind != "ask":
                raise RuntimeError(
                    f"bridge replay diverged: expected an evaluation "
                    f"request, got {kind!r} — the strategy is not "
                    f"deterministic given (rng, observations)")
            self._bridge.respond(obs)
        self._fetch()

    def _fetch(self) -> None:
        kind, payload = self._bridge.next_request()
        if kind == "ask":
            self.pending = payload
        elif kind == "done":
            self.finished = True
            self.pending = None
        else:  # "error": legacy loops propagate everything but the budget
            self.finished = True
            self.pending = None
            raise payload

    def _advance(self, observations: list[Observation]) -> None:
        self._bridge.respond(observations)
        self._fetch()

    def close(self) -> None:
        bridge = getattr(self, "_bridge", None)
        if bridge is not None:
            bridge.shutdown()
            self._bridge = None


def warn_legacy_optimize(strategy, stacklevel: int = 3) -> None:
    """The one copy of the legacy-``_optimize`` deprecation warning
    (``Strategy.run``'s direct dispatch and the thread-bridge fallback
    both emit it; tier-1 escalates it to an error unless asserted)."""
    warnings.warn(
        f"{type(strategy).__name__} only implements the legacy "
        f"_optimize(space, runner, rng) loop; implement init_state/ask/"
        f"tell (or _generate) for native ask/tell support — see "
        f"docs/api.md.",
        ProtocolDeprecationWarning, stacklevel=stacklevel)


def legacy_state(strategy, space: SearchSpace, rng: random.Random,
                 warn: bool = False) -> ThreadBridgeState:
    """Wrap an imperative ``_optimize`` body as a suspendable SearchState.

    Explicit callers (``dual_annealing``) opt in silently; the base
    ``Strategy.init_state`` fallback for out-of-tree subclasses warns."""
    if warn:
        warn_legacy_optimize(strategy, stacklevel=4)
    return ThreadBridgeState(strategy, space, rng)


# -------------------------------------------------------------------- driver
class SearchDriver:
    """Owns one tuning run: ask → evaluate (budget/trace) → tell.

    The runner keeps the observable run state (memo, budget, trace) exactly
    as before; the driver adds the loop, termination, and suspend/resume.
    """

    def __init__(self, strategy, space: SearchSpace, runner: Runner,
                 rng: random.Random | None = None,
                 state: SearchState | None = None):
        self.strategy = strategy
        self.runner = runner
        if state is None:
            if rng is None:
                raise ValueError("SearchDriver needs an rng or a state")
            state = strategy.init_state(space, rng)
        else:
            state.bind(space)
        self.state = state
        state.attach_runner(runner)
        self.exhausted = False
        # how this run's evaluations were driven: "sequential" (own
        # step()/run() loop) until a drive_many sets "host" or "device"
        self.fuse = "sequential"

    def step(self) -> bool:
        """One ask/evaluate/tell round; False when the run is over.

        ``BudgetExhausted`` from the runner ends the run *between* ask and
        tell — the strategy never observes a partially evaluated batch,
        matching where the legacy imperative loops stopped.
        """
        state = self.state
        if state.finished:
            return False
        configs = self.strategy.ask(state)
        if not configs:
            state.finished = True
            return False
        try:
            observations = self.runner.run_batch(configs)
        except BudgetExhausted:
            state.finished = True
            self.exhausted = True
            state.close()
            return False
        self.strategy.tell(state, observations)
        return True

    def run(self, checkpoint: Callable[["SearchDriver"], None] | None = None
            ) -> Observation | None:
        """Drive to completion; returns the best observation (None if no ok
        config was found). ``checkpoint`` fires after every completed
        generation (ask+tell round) with the driver — serialize
        ``snapshot()`` there to make the run suspendable."""
        try:
            while self.step():
                if checkpoint is not None:
                    checkpoint(self)
        finally:
            self.state.close()
        return self.runner.best

    # ------------------------------------------------------ suspend / resume
    def snapshot(self) -> dict:
        """Picklable mid-run checkpoint: strategy state + runner state."""
        return {"state": self.state, "runner": self.runner.state_dict()}

    @classmethod
    def resume(cls, strategy, space: SearchSpace, runner: Runner,
               snapshot: dict) -> "SearchDriver":
        """Rebuild a driver from ``snapshot()`` output: the runner (fresh,
        same budget limits and cache) is loaded with the checkpointed memo/
        trace/budget, and the strategy state is re-bound to ``space``."""
        runner.load_state_dict(snapshot["runner"])
        return cls(strategy, space, runner, state=snapshot["state"])


# ---------------------------------------------------------------- drive_many
def drive_many(drivers: Sequence[SearchDriver],
               engine: "str | None" = None,
               fuse: "str | None" = None) -> list[Observation | None]:
    """Interleave N tuning runs, fusing concurrent asks into shared batch
    resolutions (``runner.run_fused``) against the columnar engine.

    Each round every still-active driver asks once; asks whose runners
    share a cache resolve as one fused gather, then each driver is told its
    own observations. Per-run observable state is bit-identical to driving
    each run to completion on its own: runs share no mutable state beyond
    the (memoized, value-identical) space caches, and ``run_fused``
    preserves per-runner evaluation order exactly.

    ``engine`` overrides the row-resolution engine of every participating
    ``SimulationRunner`` for the drive (``"numpy"``/``"scalar"``/``"jax"``
    — see ``SimulationRunner``); observable per-run state is engine-
    independent because the jax replay path is bit-identical to numpy.

    ``fuse`` selects the drive mechanism: ``"host"`` (default) is the
    per-round interleave above; ``"device"`` routes eligible runs — array-
    native strategies on jax-backed ``SimulationRunner``s — through the
    device-resident campaign executor (``engine_jax.campaign``: whole runs
    per vmapped dispatch, bit-identical committed state) and drives the
    rest on the host after a one-time ``FuseFallbackNotice`` naming the
    strategy and reason. The chosen mode is recorded per driver as
    ``driver.fuse``.
    """
    if fuse not in (None, "host", "device"):
        raise ValueError(f"unknown fuse mode {fuse!r}; "
                         f"expected 'host' or 'device'")
    if engine is None and fuse == "device":
        engine = "jax"  # the device path is jax-backed by definition
    if engine is not None:
        from .runner import SimulationRunner
        if engine == "vectorized":
            engine = "numpy"
        if engine not in SimulationRunner.ENGINES:
            raise ValueError(f"unknown engine {engine!r}; expected one of "
                             f"{SimulationRunner.ENGINES}")
        for d in drivers:
            r = d.runner
            if isinstance(r, SimulationRunner):
                r.engine = engine
                r.columnar = engine != "scalar"
    host_drivers: Sequence[SearchDriver] = drivers
    if fuse == "device":
        from . import engine_jax
        fused: list[SearchDriver] = []
        host_drivers = []
        for d in drivers:
            reason = (engine_jax.fuse_reason(d)
                      if engine_jax.engine_available() else
                      "jax engine unavailable "
                      f"({engine_jax.unavailable_reason()})")
            if reason is None:
                d.fuse = "device"
                fused.append(d)
            else:
                warn_fuse_fallback(
                    getattr(d.strategy, "name", type(d.strategy).__name__),
                    reason, "host")
                host_drivers.append(d)
        if fused:
            engine_jax.drive_fused(fused)
    for d in host_drivers:
        d.fuse = "host"
    active = [d for d in host_drivers if not d.state.finished]
    try:
        while active:
            batch: list[tuple[SearchDriver, list]] = []
            for d in active:
                configs = d.strategy.ask(d.state)
                if not configs:
                    d.state.finished = True
                    continue
                batch.append((d, configs))
            if not batch:
                break
            results = run_fused([(d.runner, configs)
                                 for d, configs in batch])
            survivors: list[SearchDriver] = []
            for (d, _configs), res in zip(batch, results):
                if isinstance(res, BudgetExhausted):
                    d.state.finished = True
                    d.exhausted = True
                    d.state.close()
                else:
                    d.strategy.tell(d.state, res)
                    survivors.append(d)
            active = survivors
    finally:
        for d in host_drivers:
            d.state.close()
    return [d.runner.best for d in drivers]
