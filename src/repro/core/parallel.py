"""Parallel, resumable campaign execution (paper Sec. III-C/E at scale).

The paper's headline results require thousands of simulated tuning campaigns:
+94.8 % from exhaustive hyperparameter tuning (Sec. IV-B, Table III) and
+204.7 % from meta-strategies (Sec. IV-C, Table IV). The simulation mode
already removes the hardware from the loop (Sec. III-C, ~130× cheaper than
live tuning — Fig. 9); this module removes the single-process bottleneck and
makes long campaigns interruptible:

  * ``CampaignExecutor`` — fans independent scoring tasks (one hyperparameter
    configuration, or one (space, repeat) cell of the methodology's inner
    loop) out over a ``concurrent.futures`` worker pool.
  * ``CampaignJournal`` — an append-only JSONL checkpoint. Every completed
    ``AggregateReport`` is persisted the moment it finishes, so an
    interrupted ``exhaustive_hypertune``/``meta_hypertune`` resumes without
    re-scoring anything.

Determinism: every task seeds its own RNG from ``(seed, space, repeat)``
(see ``methodology.run_repeat``), and partial results are reduced in the
same fixed enumeration order as the serial loop — so parallel campaigns are
bit-identical to serial ones regardless of worker count, backend, or task
completion order.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import pickle
import time
from concurrent.futures import (FIRST_COMPLETED, ProcessPoolExecutor,
                                ThreadPoolExecutor, wait)
from typing import Any, Callable, Iterator, Mapping, Sequence

import numpy as np

from .methodology import AggregateReport, SpaceScorer, evaluate_strategy
from .strategies import get_strategy

JOURNAL_FORMAT = "repro-campaign"
JOURNAL_VERSION = 1


# ------------------------------------------------------------- task payloads
@dataclasses.dataclass(frozen=True)
class StrategyFactory:
    """Picklable ``make_strategy`` for ``methodology.evaluate_strategy``.

    The serial API accepts any zero-argument callable (often a lambda);
    process workers need a payload that survives pickling, so the factory
    stores the registry name plus sorted hyperparameter items and rebuilds
    the strategy on call — the same late construction per repeat that the
    methodology requires (fresh strategy state per run, Sec. III-B).
    """

    name: str
    hyperparams: tuple  # sorted ((key, value), ...) pairs

    @staticmethod
    def create(name: str, hyperparams: Mapping) -> "StrategyFactory":
        return StrategyFactory(name, tuple(sorted(hyperparams.items())))

    def __call__(self):
        return get_strategy(self.name, **dict(self.hyperparams))


def score_hyperconfig_task(scorers: Sequence[SpaceScorer], strategy_name: str,
                           hyperparams: Mapping, repeats: int,
                           seed: int) -> AggregateReport:
    """Score one hyperparameter configuration (one cell of the paper's
    Table III grid) with the methodology — the unit of work an exhaustive
    campaign fans out. Module-level (not a closure) so process-pool workers
    can receive it by reference; ``scorers`` comes first so campaigns can
    ship it once per worker via ``CampaignExecutor.map(shared=scorers)``."""
    return evaluate_strategy(StrategyFactory.create(strategy_name, hyperparams),
                             scorers, repeats=repeats, seed=seed)


# ----------------------------------------------------- process-pool plumbing
# Campaign-constant context (e.g. the scorer list with its megabyte-scale
# baseline arrays) is pickled once per worker process through the pool
# initializer rather than once per task — the difference between shipping a
# few MB and a few GB over the pipe for a Table III-sized grid.
_SHARED: Any = None


def _init_shared(payload: bytes) -> None:
    global _SHARED
    _SHARED = pickle.loads(payload)


def _call_with_shared(fn: Callable, args: tuple) -> Any:
    return fn(_SHARED, *args)


# Chunked task wrappers: one pool submission evaluates a whole slice of the
# task grid. The vectorized scoring engine made individual (space, repeat)
# cells cheap enough that per-task IPC (submit + pickle + result wakeup)
# dominates small tasks on a process pool; chunking amortizes it without
# changing results (cells are still reduced in index order by the caller).
def _run_chunk(fn: Callable, argtuples: Sequence[tuple]) -> list:
    return [fn(*args) for args in argtuples]


def _run_chunk_shared(fn: Callable, shared: Any,
                      argtuples: Sequence[tuple]) -> list:
    return [fn(shared, *args) for args in argtuples]


def _run_chunk_global(fn: Callable, argtuples: Sequence[tuple]) -> list:
    return [fn(_SHARED, *args) for args in argtuples]


# ---------------------------------------------------------------- executor
class CampaignExecutor:
    """Deterministic worker pool for campaign tasks (paper Sec. III-C/E).

    ``workers <= 1`` (the default) runs tasks inline — serial execution is
    just the degenerate pool, so call sites need no branching. Backends:

      * ``"thread"``  — ``ThreadPoolExecutor``; always safe (shared memory,
        no pickling), speedup limited to the numpy portions of scoring.
      * ``"process"`` — ``ProcessPoolExecutor``; true parallelism, requires
        picklable tasks (hub caches loaded from disk and ``StrategyFactory``
        payloads are; ad-hoc lambdas are not).
      * ``"auto"``    — probe-pickle the first task: processes when the
        payload survives, threads otherwise.

    Results are yielded as ``(index, result)`` in completion order; callers
    that need serial-identical output reduce them in index order (see
    ``hypertuner.exhaustive_hypertune``), which together with per-task
    seeding keeps parallel scores bit-identical to serial ones.
    """

    def __init__(self, workers: int = 1, backend: str = "auto"):
        if backend not in ("auto", "serial", "thread", "process"):
            raise ValueError(f"unknown backend {backend!r}")
        self.workers = max(1, int(workers))
        self.backend = backend
        # pools are cached across map() calls (meta campaigns call map once
        # per hyperparameter evaluation); the process pool is keyed by its
        # shared payload, since workers are initialized with it
        self._thread_pool: ThreadPoolExecutor | None = None
        self._proc_pool: ProcessPoolExecutor | None = None
        self._proc_key: str | None = None
        self._auto_cache: dict[int, str] = {}  # id(fn) -> resolved backend

    # ------------------------------------------------------------- plumbing
    @property
    def parallel(self) -> bool:
        return self.workers > 1 and self.backend != "serial"

    def _resolve_backend(self, fn: Callable, argtuples: Sequence[tuple],
                         shared: Any) -> str:
        if not self.parallel or not argtuples:
            return "serial"
        if self.backend in ("thread", "process"):
            return self.backend
        hit = self._auto_cache.get(id(fn))
        if hit is None:  # auto: processes iff the payload pickles
            try:
                pickle.dumps((fn, shared, argtuples[0]))
                hit = "process"
            except Exception:
                hit = "thread"
            self._auto_cache[id(fn)] = hit
        return hit

    def _get_process_pool(self, shared: Any) -> ProcessPoolExecutor:
        payload = pickle.dumps(shared)
        key = hashlib.sha1(payload).hexdigest()
        if self._proc_pool is None or self._proc_key != key:
            if self._proc_pool is not None:
                self._proc_pool.shutdown(wait=True, cancel_futures=True)
            self._proc_pool = ProcessPoolExecutor(
                max_workers=self.workers,
                initializer=_init_shared, initargs=(payload,))
            self._proc_key = key
        return self._proc_pool

    def map(self, fn: Callable, argtuples: Sequence[tuple],
            shared: Any = None,
            chunksize: int = 1) -> Iterator[tuple[int, Any]]:
        """Run ``fn(*argtuples[i])`` — or ``fn(shared, *argtuples[i])`` when
        ``shared`` is given — for every i; yield ``(i, result)`` as tasks
        complete (serial: in submission order). ``shared`` is
        campaign-constant context shipped once per worker process instead of
        once per task; repeated ``map`` calls with an identical payload
        reuse the warm pool. ``chunksize > 1`` groups consecutive tasks
        into one pool submission (amortizing IPC for cheap tasks); results
        are still yielded per task with their original indices, so callers'
        index-order reductions — and therefore campaign scores — are
        unchanged at any chunk size. Exceptions propagate; on early
        generator close, unstarted tasks are cancelled — together with
        ``CampaignJournal`` this is what makes campaigns interruptible.
        """
        backend = self._resolve_backend(fn, argtuples, shared)
        if backend == "serial":
            for i, args in enumerate(argtuples):
                yield i, (fn(*args) if shared is None else fn(shared, *args))
            return
        chunksize = max(1, int(chunksize))
        chunks = [(start, argtuples[start:start + chunksize])
                  for start in range(0, len(argtuples), chunksize)]
        if backend == "thread":
            if self._thread_pool is None:
                self._thread_pool = ThreadPoolExecutor(
                    max_workers=self.workers)
            pool = self._thread_pool
            submit = (lambda chunk: pool.submit(_run_chunk, fn, chunk)
                      if shared is None
                      else pool.submit(_run_chunk_shared, fn, shared, chunk))
        else:
            pool = self._get_process_pool(shared)
            submit = (lambda chunk: pool.submit(_run_chunk, fn, chunk)
                      if shared is None
                      else pool.submit(_run_chunk_global, fn, chunk))
        futures = {}
        try:
            futures = {submit(chunk): start for start, chunk in chunks}
            pending = set(futures)
            while pending:
                done, pending = wait(pending, return_when=FIRST_COMPLETED)
                for fut in done:
                    start = futures[fut]
                    for off, res in enumerate(fut.result()):
                        yield start + off, res
        finally:
            for fut in futures:  # no-op for completed futures
                fut.cancel()

    def shutdown(self) -> None:
        """Tear down cached pools (idempotent)."""
        if self._thread_pool is not None:
            self._thread_pool.shutdown(wait=True, cancel_futures=True)
            self._thread_pool = None
        if self._proc_pool is not None:
            self._proc_pool.shutdown(wait=True, cancel_futures=True)
            self._proc_pool = None
            self._proc_key = None

    def __enter__(self) -> "CampaignExecutor":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()


# ----------------------------------------------------------------- journal
class CampaignJournal:
    """Append-only JSONL checkpoint of a hypertuning campaign.

    Line 1 is a header identifying the campaign (mode, strategy, repeats,
    seed, search spaces); each further line is one completed hyperparameter
    evaluation. Records are flushed and fsync'd as they complete, so a
    campaign killed at any point resumes from its last finished
    configuration — the simulated analogue of the paper's concern that
    hyperparameter tuning is "considerably more expensive" than tuning
    itself (Sec. III-C): the expensive thing must never be recomputed.

    A truncated trailing line (interruption mid-write) is ignored on read.
    Resuming with different campaign settings raises, because mixing scores
    across methodologies would silently corrupt the comparison (Sec. III-B
    requires all scores to share baseline, budget, and repeats).

    ``fmt`` is the value of the header's ``format`` field; other append-only
    JSONL files (e.g. ``core.record``'s observation shards) reuse the same
    durability machinery under their own format tag.
    """

    def __init__(self, path: str, fmt: str = JOURNAL_FORMAT):
        self.path = path
        self.fmt = fmt

    # -------------------------------------------------------------- reading
    def read(self) -> tuple[dict | None, list[dict]]:
        """Return ``(header, records)``; ``(None, [])`` if no file yet."""
        if not os.path.exists(self.path):
            return None, []
        header: dict | None = None
        records: list[dict] = []
        with open(self.path, "r", encoding="utf-8", errors="replace") as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    d = json.loads(line)
                except json.JSONDecodeError:
                    if header is None:  # binary/foreign file, not a journal
                        raise ValueError(
                            f"{self.path} is not a {self.fmt} file")
                    # a line torn by an interrupted write (``append`` starts
                    # every record on a fresh line, so complete records are
                    # always intact lines) — skip it, keep later records
                    continue
                if header is None:
                    if d.get("format") != self.fmt:
                        raise ValueError(
                            f"{self.path} is not a {self.fmt} file "
                            f"(found format {d.get('format')!r})")
                    header = d
                else:
                    records.append(d)
        return header, records

    def ensure_header(self, header: Mapping) -> list[dict]:
        """Create the journal (writing ``header``) or validate that the
        existing one matches; returns the completed records to skip."""
        existing, records = self.read()
        if existing is None:
            os.makedirs(os.path.dirname(self.path) or ".", exist_ok=True)
            self.append(dict(header, format=self.fmt,
                             version=JOURNAL_VERSION))
            return []
        volatile = {"format", "version", "created_unix"}
        mismatched = {k: (existing.get(k), v) for k, v in header.items()
                      if k not in volatile and existing.get(k) != v}
        if mismatched:
            raise ValueError(
                f"journal {self.path} was written by a different campaign: "
                f"{mismatched}; use a fresh journal path")
        return records

    # -------------------------------------------------------------- writing
    def append(self, record: Mapping) -> None:
        """Durably append one JSON line (flush + fsync before returning).

        If the file ends mid-line (a write torn by ``kill -9``), a newline
        is inserted first so the new record starts on a fresh line — the
        torn fragment stays behind as one unparseable line that ``read``
        skips, and no later record is ever merged into it."""
        payload = json.dumps(record) + "\n"
        with open(self.path, "ab") as f:
            if f.tell() > 0 and not self._ends_with_newline():
                payload = "\n" + payload
            f.write(payload.encode("utf-8"))
            f.flush()
            os.fsync(f.fileno())

    def _ends_with_newline(self) -> bool:
        with open(self.path, "rb") as f:
            f.seek(-1, os.SEEK_END)
            return f.read(1) == b"\n"


# ----------------------------------------------- report (de)serialization
def report_to_json(report: AggregateReport) -> dict:
    """JSON form of an ``AggregateReport`` for journal records."""
    return {
        "score": report.score,
        "curve": report.curve.tolist(),
        "per_space": {k: v.tolist() for k, v in report.per_space.items()},
        "per_space_score": report.per_space_score,
        "fresh_evals": report.fresh_evals,
        "wall_seconds": report.wall_seconds,
        "simulated_seconds": report.simulated_seconds,
        "fuse": report.fuse,
    }


def report_from_json(d: Mapping) -> AggregateReport:
    """Inverse of ``report_to_json`` (scores round-trip exactly: python
    floats serialize losslessly through JSON)."""
    return AggregateReport(
        score=d["score"], curve=np.array(d["curve"]),
        per_space={k: np.array(v) for k, v in d["per_space"].items()},
        per_space_score=dict(d["per_space_score"]),
        fresh_evals=int(d.get("fresh_evals", 0)),
        wall_seconds=float(d.get("wall_seconds", 0.0)),
        simulated_seconds=float(d.get("simulated_seconds", 0.0)),
        # pre-fused journals carry no drive mode: "sequential" matches how
        # those campaigns actually ran
        fuse=str(d.get("fuse", "sequential")),
    )


def campaign_header(mode: str, strategy: str, scorers: Sequence[SpaceScorer],
                    repeats: int, seed: int, **extra) -> dict:
    """Identity of a campaign: everything that must match for two scores to
    be comparable under the methodology (Sec. III-B)."""
    return {"mode": mode, "strategy": strategy, "repeats": repeats,
            "seed": seed, "spaces": [s.name for s in scorers], **extra,
            "created_unix": time.time()}
