"""Budget accounting in *simulated* time (paper Sec. III-C/E).

All strategies spend a budget of simulated seconds: each fresh evaluation of a
kernel configuration charges its recorded/modelled compile + run (+ framework
overhead) time, exactly as if the tuning run were live. Revisited
configurations are served from the tuner-side memo and charge nothing, matching
Kernel Tuner's cache behaviour that the paper's simulation-mode cost analysis
relies on ("configurations are likely to be revisited").

``BudgetExhausted`` is raised by the runner when the budget is spent; strategies
treat it as the stop signal.
"""
from __future__ import annotations

import dataclasses


class BudgetExhausted(Exception):
    pass


@dataclasses.dataclass
class Budget:
    """Simulated-time and/or evaluation-count budget."""

    max_seconds: float | None = None
    max_evals: int | None = None
    spent_seconds: float = 0.0
    spent_evals: int = 0

    def charge(self, seconds: float, evals: int = 1) -> None:
        self.spent_seconds += float(seconds)
        self.spent_evals += int(evals)

    @property
    def exhausted(self) -> bool:
        if self.max_seconds is not None and self.spent_seconds >= self.max_seconds:
            return True
        if self.max_evals is not None and self.spent_evals >= self.max_evals:
            return True
        return False

    def check(self) -> None:
        if self.exhausted:
            raise BudgetExhausted(
                f"spent {self.spent_seconds:.3f}s/{self.max_seconds}s, "
                f"{self.spent_evals}/{self.max_evals} evals")

    def copy_empty(self) -> "Budget":
        return Budget(max_seconds=self.max_seconds, max_evals=self.max_evals)
