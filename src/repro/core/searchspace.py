"""Constrained discrete search spaces (paper Sec. III-A) — compiled facade.

The space is the Cartesian product of tunable value sets filtered by
constraints. Since the index-native refactor this module is a deprecation
shim: the public constructor and method signatures are unchanged, but every
hot query delegates to a lazily **compiled** array representation
(``core.space.CompiledSpace``) — a validity bitmap plus a
``(n_valid, n_tunables)`` value-index matrix built once by blocked
vectorized enumeration, CSR neighbor tables for both neighbor semantics,
and precomputed single-move repair tables. Integer row indices are the
native config form through the whole simulation hot path; this facade
translates between rows and the value-tuple/config-id forms at the API
boundary.

Key operations used by the optimization strategies (all signatures as
before the refactor, all results bit-identical to the frozen reference in
``core.space.reference``):
  - ``size`` / ``valid_configs``: enumeration of the valid space
  - ``random_config(rng)``: uniform sampling of valid configs (same rng
    draw order as the scalar rejection sampler)
  - ``neighbors(config)``: Hamming-adjacent valid configs, served as one
    CSR slice
  - ``nearest_valid`` / ``decode_batch``: repair through the move tables
  - ``to_indices`` / ``from_indices``: positional encoding used by
    continuous-relaxation strategies (PSO, differential evolution, dual
    annealing).

Index-native callers (the strategies, ``SimulationRunner``) should use
``space.compiled`` directly and exchange ``core.space.RowBatch`` batches;
the tuple-based methods here exist for external code, the scalar reference
engine, and serialization.
"""
from __future__ import annotations

import random
from typing import Iterable, Mapping, Sequence

import numpy as np

from .space import CompiledSpace, compile_space
from .tunable import Config, Constraint, Tunable


class SearchSpace:
    def __init__(self, tunables: Sequence[Tunable], constraints: Sequence[Constraint] = (),
                 name: str = "space"):
        if not tunables:
            raise ValueError("search space needs at least one tunable")
        names = [t.name for t in tunables]
        if len(set(names)) != len(names):
            raise ValueError("duplicate tunable names")
        self.name = name
        self.tunables = tuple(tunables)
        self.constraints = tuple(constraints)
        self._names = tuple(names)
        self._index = {n: i for i, n in enumerate(names)}
        self._compiled: CompiledSpace | None = None
        # config-id memo for the tuple-keyed compat path (scalar engine,
        # out-of-tree callers); row-native code reads ``compiled.ids``
        self._id_cache: dict[Config, str] = {}

    # --------------------------------------------------------------- compiled
    @property
    def compiled(self) -> CompiledSpace:
        """The array-backed form, compiled on first use (and after
        unpickling — the arrays never cross process boundaries)."""
        cs = self._compiled
        if cs is None:
            cs = self._compiled = compile_space(self.tunables,
                                                self.constraints, self.name)
        return cs

    def __getstate__(self) -> dict:
        """Pickle without the compiled arrays or id memo: parallel
        campaigns ship spaces to worker processes once per pool, and
        recompiling there is cheaper than shipping bitmap + CSR tables."""
        state = self.__dict__.copy()
        state["_compiled"] = None
        state["_id_cache"] = {}
        return state

    # ------------------------------------------------------------------ views
    @property
    def names(self) -> tuple:
        return self._names

    def as_dict(self, config: Config) -> dict:
        return dict(zip(self._names, config))

    def from_dict(self, d: Mapping) -> Config:
        return tuple(d[n] for n in self._names)

    @property
    def cartesian_size(self) -> int:
        n = 1
        for t in self.tunables:
            n *= t.cardinality
        return n

    # ------------------------------------------------------------ enumeration
    def is_valid(self, config: Config) -> bool:
        """Validity as one bitmap probe (replaces the per-config dict
        cache; for hub spaces the membership constraint used to cost a
        string join per miss)."""
        if len(config) != len(self.tunables):
            return False
        cs = self.compiled
        idx = cs.vidx_of_config(config)
        if idx is None:  # some value outside its tunable's value set
            return False
        return bool(cs.bitmap[cs.flat_of_vidx(idx)])

    @property
    def valid_configs(self) -> list:
        return list(self.compiled.configs)

    @property
    def size(self) -> int:
        return self.compiled.n_valid

    def config_id(self, config: Config) -> str:
        """Stable string key for caches (T4 data uses stringified configs).

        Memoized per space; row-native code never calls this — it reads the
        precomputed ``compiled.ids`` table at the serialization boundary."""
        key = self._id_cache.get(config)
        if key is None:
            key = self._id_cache[config] = ",".join(str(v) for v in config)
        return key

    def config_ids(self, configs: Sequence[Config]) -> list[str]:
        """Batch ``config_id`` — one call for a whole generation (the
        tuple-keyed ``BatchRunner`` compat path)."""
        cache = self._id_cache
        out = []
        for config in configs:
            key = cache.get(config)
            if key is None:
                key = cache[config] = ",".join(str(v) for v in config)
            out.append(key)
        return out

    def config_from_id(self, key: str) -> Config:
        """Inverse of ``config_id`` via the per-tunable ``str(value) ->
        value`` tables (``Tunable.from_str``) — O(1) per value instead of
        the former O(cardinality) scan (it is called per record on journal
        resume and cache merge)."""
        return tuple(t.from_str(s)
                     for t, s in zip(self.tunables, key.split(",")))

    # --------------------------------------------------------------- sampling
    def random_config(self, rng: random.Random) -> Config:
        """Uniform over *valid* configs (same draws as the scalar sampler:
        rejection first, enumeration fallback)."""
        cs = self.compiled
        return cs.configs[cs.random_row(rng)]

    # ------------------------------------------------------------- neighbors
    def neighbors(self, config: Config, strictly_adjacent: bool = False) -> list:
        """Valid configs differing in exactly one tunable.

        ``strictly_adjacent``: restrict to numerically adjacent values in the
        tunable's declared order (Kernel Tuner's 'adjacent' neighbor method);
        otherwise all alternative values of each tunable are candidates,
        ordered by distance in the value order ('Hamming+ordered'). Served
        as one CSR row slice; invalid starting configs (allowed by the old
        API) fall back to the scalar enumeration.
        """
        cs = self.compiled
        row = cs.row_of_config(config)
        if row >= 0:
            configs = cs.configs
            return [configs[r] for r in
                    cs.neighbors_rows(row, strictly_adjacent).tolist()]
        return self._neighbors_scalar(config, strictly_adjacent)

    def _neighbors_scalar(self, config: Config,
                          strictly_adjacent: bool) -> list:
        """Legacy path for configs outside the compiled rows (invalid or
        out-of-vocabulary starting points)."""
        out: list[Config] = []
        for i, t in enumerate(self.tunables):
            j = t.index_of(config[i])
            if strictly_adjacent:
                cand = [k for k in (j - 1, j + 1) if 0 <= k < t.cardinality]
            else:
                cand = sorted((k for k in range(t.cardinality) if k != j),
                              key=lambda k: abs(k - j))
            for k in cand:
                c = config[:i] + (t.values[k],) + config[i + 1:]
                if self.is_valid(c):
                    out.append(c)
        return out

    # ---------------------------------------------------- index-vector coding
    def to_indices(self, config: Config) -> np.ndarray:
        return np.array([t.index_of(v) for t, v in zip(self.tunables, config)],
                        dtype=np.float64)

    def from_indices(self, x: Iterable) -> Config:
        """Round a continuous index vector to the nearest config (may be
        invalid; strategies repair via ``nearest_valid``)."""
        out = []
        for t, xi in zip(self.tunables, x):
            k = int(round(float(xi)))
            k = max(0, min(t.cardinality - 1, k))
            out.append(t.values[k])
        return tuple(out)

    def decode_batch(self, x: "np.ndarray", rng: random.Random) -> list:
        """Vectorized ``from_indices`` + ``nearest_valid`` over a (P, T)
        index matrix; repairs draw from ``rng`` exactly as the per-particle
        scalar loop did. Index-native callers use
        ``compiled.decode_rows`` and skip the tuple materialization."""
        cs = self.compiled
        configs = cs.configs
        return [configs[r] for r in cs.decode_rows(x, rng).tolist()]

    def nearest_valid(self, config: Config, rng: random.Random) -> Config:
        """Repair an invalid config: breadth-first over single-tunable
        moves (precomputed move tables, memoized outcome), then random
        restart drawing from ``rng`` in the exact scalar order."""
        cs = self.compiled
        idx = cs.vidx_of_config(config)
        if idx is None:
            return self._nearest_valid_oov(config, rng)
        flat = cs.flat_of_vidx(idx)
        if cs.bitmap[flat]:
            return config
        return cs.configs[cs.repair_flat(flat, rng)]

    def _nearest_valid_oov(self, config: Config, rng: random.Random) -> Config:
        """Legacy BFS for configs with out-of-vocabulary values (the move
        tables only cover the Cartesian product; the old code treated an
        unknown value as index 0)."""
        frontier = [config]
        seen = {config}
        for _depth in range(3):
            nxt: list[Config] = []
            for c in frontier:
                for i, t in enumerate(self.tunables):
                    j = t.index_of(c[i]) if c[i] in t.values else 0
                    order = sorted(range(t.cardinality),
                                   key=lambda k: abs(k - j))
                    for k in order:
                        cc = c[:i] + (t.values[k],) + c[i + 1:]
                        if cc in seen:
                            continue
                        seen.add(cc)
                        if self.is_valid(cc):
                            return cc
                        nxt.append(cc)
            frontier = nxt[:256]
        return self.random_config(rng)

    @property
    def bounds(self) -> list:
        """Index-space bounds [(0, card-1), ...] for continuous strategies."""
        return [(0.0, float(t.cardinality - 1)) for t in self.tunables]

    def __repr__(self):
        return (f"SearchSpace({self.name!r}, tunables={len(self.tunables)}, "
                f"cartesian={self.cartesian_size})")
