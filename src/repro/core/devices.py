"""Simulated TPU device models (stand-ins for the paper's six GPUs).

The paper's benchmark hub spans six GPUs (A100, A4000, A6000, MI250X, W6600,
W7800) whose differing compute/bandwidth balances make kernel optima
device-dependent. This container is CPU-only, so the hub here spans six
*TPU-like device models* with the same kind of diversity: peak bf16 FLOP/s,
HBM bandwidth, VMEM capacity, MXU tile, and noise level differ per device.
The production target (v5e) is one of them.

These constants drive the analytical kernel cost model (costmodel.py) that
plays the role of hardware measurement when brute-forcing the hub dataset.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class DeviceModel:
    name: str
    peak_flops: float          # bf16 FLOP/s per chip
    hbm_bw: float              # bytes/s
    vmem_bytes: int            # per-core VMEM
    mxu: int                   # systolic array dim (matmul tile)
    sublane: int               # second-minor tiling (8 for fp32/bf16 rows)
    lane: int                  # minor tiling (128)
    ici_bw: float              # bytes/s per link
    noise_sigma: float         # log-normal measurement noise
    overhead_s: float          # per-launch framework overhead (seconds)
    compile_s: float           # per-config compile time (seconds)

    @property
    def ridge_intensity(self) -> float:
        """FLOP/byte at the compute/memory roofline ridge."""
        return self.peak_flops / self.hbm_bw


# Production target — TPU v5e (the roofline constants from the assignment).
V5E = DeviceModel("tpu_v5e", peak_flops=197e12, hbm_bw=819e9,
                  vmem_bytes=32 * 2**20, mxu=128, sublane=8, lane=128,
                  ici_bw=50e9, noise_sigma=0.03, overhead_s=40e-6, compile_s=0.9)

# Five additional models spanning the compute/bandwidth plane the way the
# paper's GPU set does (ratios chosen to move kernel optima around).
V4 = DeviceModel("tpu_v4", peak_flops=275e12, hbm_bw=1228e9,
                 vmem_bytes=32 * 2**20, mxu=128, sublane=8, lane=128,
                 ici_bw=100e9, noise_sigma=0.025, overhead_s=40e-6, compile_s=1.1)
V5P = DeviceModel("tpu_v5p", peak_flops=459e12, hbm_bw=2765e9,
                  vmem_bytes=48 * 2**20, mxu=128, sublane=8, lane=128,
                  ici_bw=200e9, noise_sigma=0.02, overhead_s=40e-6, compile_s=1.2)
V6E = DeviceModel("tpu_v6e", peak_flops=918e12, hbm_bw=1640e9,
                  vmem_bytes=48 * 2**20, mxu=256, sublane=8, lane=128,
                  ici_bw=90e9, noise_sigma=0.03, overhead_s=40e-6, compile_s=1.0)
LITE_A = DeviceModel("tpu_lite_a", peak_flops=91e12, hbm_bw=307e9,
                     vmem_bytes=16 * 2**20, mxu=128, sublane=8, lane=128,
                     ici_bw=25e9, noise_sigma=0.05, overhead_s=60e-6, compile_s=0.7)
LITE_B = DeviceModel("tpu_lite_b", peak_flops=45e12, hbm_bw=410e9,
                     vmem_bytes=16 * 2**20, mxu=128, sublane=8, lane=128,
                     ici_bw=25e9, noise_sigma=0.06, overhead_s=60e-6, compile_s=0.6)

HUB_DEVICES: tuple = (V5E, V4, V5P, V6E, LITE_A, LITE_B)
DEVICES_BY_NAME = {d.name: d for d in HUB_DEVICES}

# Train/test split mirroring the paper (Sec. IV-A): tuning happens on three
# devices, generalization is evaluated on the other three.
TRAIN_DEVICES = ("tpu_v5e", "tpu_v4", "tpu_lite_a")
TEST_DEVICES = ("tpu_v5p", "tpu_v6e", "tpu_lite_b")
