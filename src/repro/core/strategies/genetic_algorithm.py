"""Genetic Algorithm (paper Table III/IV hyperparameters).

Rank-weighted parent selection, four crossover methods matching Kernel
Tuner's (single_point, two_point, uniform, disruptive_uniform), per-gene
mutation with probability 1/mutation_chance, invalid children repaired to the
nearest valid config.

Protocol-native: ``ask`` returns the current population (drawing a fresh
random one at start and after every ``maxiter``-generation restart),
``tell`` breeds the next one. The RNG draw order — breeding draws in tell,
(re)initialization draws in the following ask — interleaves with
evaluations exactly as the pre-refactor loop did, so traces are
bit-identical.

Index-native: genomes are value-*index* tuples of the compiled space.
Crossover and mutation are generic tuple operations, so they work
unchanged on indices (equality per gene is preserved — value<->index is a
bijection per tunable), mutation draws the same ``randrange(cardinality)``,
and repair runs over the precomputed move tables. ``ask`` gathers the
population's rows in one vectorized lookup and hands the runner a
``RowBatch``.

Hyperparameters:
  method:          crossover operator
  popsize:         population size           {10, 20, 30} / {2 … 50}
  maxiter:         number of generations     {50, 100, 150} / {10 … 200}
  mutation_chance: inverse mutation rate     {5, 10, 20} / {5 … 100}
"""
from __future__ import annotations

import random

from ..driver import SearchState
from ..searchspace import SearchSpace
from ..space import CompiledSpace, RowBatch
from .base import Strategy


def _single_point(a: tuple, b: tuple, rng: random.Random) -> tuple:
    if len(a) < 2:
        return a, b
    p = rng.randrange(1, len(a))
    return a[:p] + b[p:], b[:p] + a[p:]


def _two_point(a: tuple, b: tuple, rng: random.Random) -> tuple:
    if len(a) < 3:
        return _single_point(a, b, rng)
    p, q = sorted(rng.sample(range(1, len(a)), 2))
    return (a[:p] + b[p:q] + a[q:], b[:p] + a[p:q] + b[q:])


def _uniform(a: tuple, b: tuple, rng: random.Random) -> tuple:
    c1, c2 = list(a), list(b)
    for i in range(len(a)):
        if rng.random() < 0.5:
            c1[i], c2[i] = c2[i], c1[i]
    return tuple(c1), tuple(c2)


def _disruptive_uniform(a: tuple, b: tuple, rng: random.Random) -> tuple:
    """Swap *every* differing gene with p=0.5 but guarantee at least half of
    the differing genes swap (Kernel Tuner's disruptive variant: maximizes
    mixing of dissimilar parents)."""
    diff = [i for i in range(len(a)) if a[i] != b[i]]
    rng.shuffle(diff)
    k = max((len(diff) + 1) // 2, min(1, len(diff)))
    c1, c2 = list(a), list(b)
    for i in diff[:k]:
        c1[i], c2[i] = c2[i], c1[i]
    return tuple(c1), tuple(c2)


CROSSOVERS = {
    "single_point": _single_point,
    "two_point": _two_point,
    "uniform": _uniform,
    "disruptive_uniform": _disruptive_uniform,
}


class _GAState(SearchState):
    def __init__(self, space: SearchSpace, rng: random.Random):
        super().__init__(space, rng)
        self.pop: list | None = None  # index-tuple genomes; None = restart
        self.gen = 0


class GeneticAlgorithm(Strategy):
    name = "genetic_algorithm"
    DEFAULTS = {"method": "uniform", "popsize": 20, "maxiter": 100,
                "mutation_chance": 10}
    HYPERPARAM_SPACE = {
        "method": tuple(CROSSOVERS),
        "popsize": (10, 20, 30),
        "maxiter": (50, 100, 150),
        "mutation_chance": (5, 10, 20),
    }
    EXTENDED_SPACE = {
        "method": tuple(CROSSOVERS),
        "popsize": tuple(range(2, 51, 2)),
        "maxiter": tuple(range(10, 201, 10)),
        "mutation_chance": tuple(range(5, 101, 5)),
    }

    def init_state(self, space: SearchSpace, rng: random.Random) -> _GAState:
        return _GAState(space, rng)

    def ask(self, state: _GAState):
        cs = state.space.compiled
        if state.pop is None:
            popsize = int(self.hp("popsize"))
            idx_tab = cs.idx_tuples
            state.pop = [idx_tab[cs.random_row(state.rng)]
                         for _ in range(popsize)]
            state.gen = 0
        # the whole generation is evaluated in one batch (one vectorized
        # row gather on a simulation runner); population order is
        # preserved, so the trace — and every downstream score — matches
        # the former one-config-at-a-time loop
        return RowBatch(cs, cs.rows_of_vidx(state.pop))

    def tell(self, state: _GAState, observations) -> None:
        popsize = int(self.hp("popsize"))
        generations = int(self.hp("maxiter"))
        p_mut = 1.0 / float(self.hp("mutation_chance"))
        crossover = CROSSOVERS[str(self.hp("method"))]
        rng, pop = state.rng, state.pop
        cs = state.space.compiled

        scored = sorted(((self.fitness(o.value), i, c)
                         for i, (o, c) in enumerate(zip(observations, pop))),
                        key=lambda t: (t[0], t[1]))
        ranked = [c for _, _, c in scored]
        # rank weights: best gets weight popsize, worst gets 1
        weights = list(range(popsize, 0, -1))
        children: list[tuple] = [ranked[0]]  # elitism: keep the best
        while len(children) < popsize:
            a, b = rng.choices(ranked, weights=weights, k=2)
            c1, c2 = crossover(a, b, rng)
            for child in (c1, c2):
                child = self._mutate(child, cs, rng, p_mut)
                child = cs.idx_tuples[cs.repair_vidx(child, rng)]
                children.append(child)
                if len(children) >= popsize:
                    break
        state.gen += 1
        if state.gen >= generations:
            # restart: the bred children are discarded and the next ask
            # draws a fresh random population — the same draws, in the same
            # order, as the pre-refactor restart loop
            state.pop = None
        else:
            state.pop = children

    @staticmethod
    def _mutate(genome: tuple, cs: CompiledSpace, rng: random.Random,
                p_mut: float) -> tuple:
        out = list(genome)
        for i, card in enumerate(cs.cards):
            if rng.random() < p_mut:
                out[i] = rng.randrange(card)
        return tuple(out)
