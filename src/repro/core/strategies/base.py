"""Strategy base class: the ask/tell protocol.

A strategy explores one SearchSpace until the budget is exhausted
(``BudgetExhausted`` from the runner) or its own termination criterion
fires. Strategies are pure-Python orchestration — every objective
evaluation goes through a runner, so live/simulated execution is
indistinguishable to the algorithm (paper Sec. III-E).

Since the ask/tell redesign a strategy is a *transition system* over an
explicit, picklable ``core.driver.SearchState``:

  * ``init_state(space, rng)`` builds the run's state;
  * ``ask(state)`` proposes the next batch of configs (``None``/empty when
    the strategy is done);
  * ``tell(state, observations)`` folds the batch's results back in.

The evaluate loop itself lives in ``core.driver.SearchDriver``; it owns
budget handling, trace recording, and the RNG stepping order.
``Strategy.run`` is a thin compatibility wrapper around the driver and is
bit-identical to the pre-refactor imperative loops (pinned by
tests/test_protocol.py against a frozen reference and recorded fixtures).

Three ways to implement a strategy:

  * natively (GA, PSO, DE, random search): override ``init_state``/``ask``/
    ``tell``;
  * as a generator (simulated annealing, the greedy local searches):
    subclass ``GeneratorStrategy`` and write ``_generate(space, rng)`` with
    ``obs = yield configs`` where the old loop called the runner;
  * legacy (out-of-tree subclasses, ``dual_annealing``'s scipy wrapper):
    keep ``_optimize(space, runner, rng)``; it is adapted through the
    thread bridge — with a ``ProtocolDeprecationWarning`` unless the class
    opts in by overriding ``init_state`` itself.

Hyperparameters: each strategy declares ``DEFAULTS`` plus two hyperparameter
spaces — ``HYPERPARAM_SPACE`` (the paper's Table III, exhaustive-tuning sized)
and ``EXTENDED_SPACE`` (Table IV, meta-strategy sized). The hypertuner treats
these as ordinary SearchSpaces: tuning the tuner reuses the same machinery.
"""
from __future__ import annotations

import random
from typing import Mapping, Sequence

from ..budget import BudgetExhausted
from ..driver import (GeneratorBridgeState, SearchDriver, SearchState,
                      legacy_state, warn_legacy_optimize)
from ..runner import Observation, Runner
from ..searchspace import SearchSpace
from ..tunable import Config

# Objective values can be inf (failed configs); strategies that do arithmetic
# on fitness use this finite stand-in.
FAILURE_FITNESS = 1e12


class Strategy:
    name: str = "base"
    DEFAULTS: dict = {}
    HYPERPARAM_SPACE: dict = {}
    EXTENDED_SPACE: dict = {}

    def __init__(self, **hyperparams):
        unknown = set(hyperparams) - set(self.DEFAULTS)
        if unknown:
            raise ValueError(f"{self.name}: unknown hyperparameters {sorted(unknown)}")
        self.hyperparams = {**self.DEFAULTS, **hyperparams}

    # ------------------------------------------------------ ask/tell protocol
    def init_state(self, space: SearchSpace, rng: random.Random) -> SearchState:
        """Build this run's explicit state. The default adapts a legacy
        ``_optimize`` through the thread bridge (with a deprecation
        warning); protocol-native strategies override this."""
        if type(self)._optimize is not Strategy._optimize:
            return legacy_state(self, space, rng, warn=True)
        raise NotImplementedError(
            f"{type(self).__name__} implements neither init_state/ask/tell "
            f"nor the legacy _optimize loop")

    def ask(self, state: SearchState) -> Sequence[Config] | None:
        """Next batch of configs to evaluate (None/empty = done). The base
        delegates to bridge states; native strategies override."""
        return state.ask()

    def tell(self, state: SearchState,
             observations: Sequence[Observation]) -> None:
        """Fold one evaluated batch (in ask order) back into the state."""
        state.tell(observations)

    # ------------------------------------------------------------ compat api
    def run(self, space: SearchSpace, runner: Runner,
            rng: random.Random) -> Observation | None:
        """Optimize; returns the best observation found (None if nothing ok).

        Thin wrapper over ``core.driver.SearchDriver`` — the runner records
        the full trace; callers read ``runner.trace``.

        Strategies that only implement the legacy imperative ``_optimize``
        loop (``dual_annealing`` wrapping scipy, out-of-tree subclasses)
        dispatch to it directly here: running their loop over the thread
        bridge would pay a thread rendezvous per evaluation for no benefit
        when nobody is stepping the run. The result is bit-identical
        (``tests/test_protocol.py``); the bridge path stays available
        through an explicit ``SearchDriver`` for suspension, fused
        driving, and meta checkpoints.
        """
        if type(self)._optimize is not Strategy._optimize:
            if type(self).init_state is Strategy.init_state:
                warn_legacy_optimize(self, stacklevel=2)
            try:
                self._optimize(space, runner, rng)
            except BudgetExhausted:
                pass
            return runner.best
        return SearchDriver(self, space, runner, rng).run()

    def _optimize(self, space: SearchSpace, runner: Runner,
                  rng: random.Random) -> None:
        """Deprecated pre-ask/tell entry point; see ``init_state``."""
        raise NotImplementedError

    # ------------------------------------------------------------- helpers
    @staticmethod
    def fitness(value: float) -> float:
        return FAILURE_FITNESS if value == float("inf") else value

    def hp(self, key: str):
        return self.hyperparams[key]

    def __repr__(self):
        hp = ",".join(f"{k}={v}" for k, v in sorted(self.hyperparams.items()))
        return f"{self.name}({hp})"


class GeneratorStrategy(Strategy):
    """Base for strategies written as imperative generators.

    ``_generate(space, rng)`` yields batches of configs and receives their
    observations back: ``obs = yield [cfg]`` replaces the old
    ``runner(cfg)``. Returning (StopIteration) ends the run. State is a
    ``GeneratorBridgeState`` — suspendable via its replay log even though
    generator frames cannot pickle.
    """

    def init_state(self, space: SearchSpace,
                   rng: random.Random) -> SearchState:
        return GeneratorBridgeState(self, space, rng)

    def _generate(self, space: SearchSpace, rng: random.Random):
        raise NotImplementedError


def _escape_id(part) -> str:
    """Escape ``%``/``,``/``=`` so string-valued hyperparameters cannot
    collide in journal ids (e.g. ``{'a': '1,b=2'}`` vs ``{'a': 1, 'b': 2}``);
    ids of ordinary numeric/word values are unchanged."""
    s = str(part)
    if "%" in s or "," in s or "=" in s:
        s = s.replace("%", "%25").replace(",", "%2C").replace("=", "%3D")
    return s


def hyperparam_id(hp: Mapping) -> str:
    """Stable journal/ranking key for one hyperparameter configuration.

    Values containing the separator characters are escaped (see
    ``_escape_id``); journals written before the escaping existed resume
    cleanly because readers recompute ids from each record's stored
    ``hyperparams`` dict rather than trusting the stored id.
    """
    return ",".join(f"{_escape_id(k)}={_escape_id(hp[k])}" for k in sorted(hp))
