"""Strategy base class.

A strategy explores one SearchSpace through a Runner until the budget is
exhausted (``BudgetExhausted`` from the runner) or its own termination
criterion fires. Strategies are pure-Python orchestration — every objective
evaluation goes through the runner, so live/simulated execution is
indistinguishable to the algorithm (paper Sec. III-E).

Hyperparameters: each strategy declares ``DEFAULTS`` plus two hyperparameter
spaces — ``HYPERPARAM_SPACE`` (the paper's Table III, exhaustive-tuning sized)
and ``EXTENDED_SPACE`` (Table IV, meta-strategy sized). The hypertuner treats
these as ordinary SearchSpaces: tuning the tuner reuses the same machinery.
"""
from __future__ import annotations

import random
from typing import Mapping

from ..budget import BudgetExhausted
from ..runner import Observation, Runner
from ..searchspace import SearchSpace

# Objective values can be inf (failed configs); strategies that do arithmetic
# on fitness use this finite stand-in.
FAILURE_FITNESS = 1e12


class Strategy:
    name: str = "base"
    DEFAULTS: dict = {}
    HYPERPARAM_SPACE: dict = {}
    EXTENDED_SPACE: dict = {}

    def __init__(self, **hyperparams):
        unknown = set(hyperparams) - set(self.DEFAULTS)
        if unknown:
            raise ValueError(f"{self.name}: unknown hyperparameters {sorted(unknown)}")
        self.hyperparams = {**self.DEFAULTS, **hyperparams}

    # ------------------------------------------------------------------ api
    def run(self, space: SearchSpace, runner: Runner, rng: random.Random) -> Observation | None:
        """Optimize; returns the best observation found (None if nothing ok).

        The runner records the full trace; callers read ``runner.trace``.
        """
        try:
            self._optimize(space, runner, rng)
        except BudgetExhausted:
            pass
        return runner.best

    def _optimize(self, space: SearchSpace, runner: Runner, rng: random.Random) -> None:
        raise NotImplementedError

    # ------------------------------------------------------------- helpers
    @staticmethod
    def fitness(value: float) -> float:
        return FAILURE_FITNESS if value == float("inf") else value

    def hp(self, key: str):
        return self.hyperparams[key]

    def __repr__(self):
        hp = ",".join(f"{k}={v}" for k, v in sorted(self.hyperparams.items()))
        return f"{self.name}({hp})"


def hyperparam_id(hp: Mapping) -> str:
    return ",".join(f"{k}={hp[k]}" for k in sorted(hp))
