"""Additional strategies beyond the paper's four evaluated algorithms.

Kernel Tuner ships 20+ strategies (paper Table I); we implement four more
here so the hypertuner has a broader pool for meta-strategy experiments:
Differential Evolution, Basin Hopping, Greedy Iterated Local Search, and
Multi-start Local Search. Each declares hyperparameter spaces so they are
first-class citizens of the "tuning the tuner" pipeline.

DE is protocol-native (its generation stepping maps directly onto
ask/tell); the three local searches are generators (``GeneratorStrategy``):
imperative walks with each runner call replaced by a yield. GreedyILS and
MLS scan whole neighborhoods with best-improvement, so they yield the full
neighbor list as one batch (observably identical to the former per-neighbor
loop under the BatchRunner contract — and one vectorized gather on a
simulation runner); BasinHopping's descent is first-improvement and must
keep yielding one config at a time.

All four are index-native: walks live on compiled-space rows (whole
neighborhoods are CSR slices wrapped in ``RowBatch``es), perturbations
operate on value-index tuples, and repair runs over the precomputed move
tables — with every rng draw at the same stream position as the scalar
implementation.
"""
from __future__ import annotations

import math
import random

import numpy as np

from ..driver import SearchState
from ..searchspace import SearchSpace
from ..space import RowBatch
from .base import GeneratorStrategy, Strategy


class _DEState(SearchState):
    def __init__(self, space: SearchSpace, rng: random.Random):
        super().__init__(space, rng)
        # same rng-stream position as the pre-refactor loop's seeding draw
        self.np_rng = np.random.default_rng(rng.getrandbits(64))
        self.lo = np.zeros(len(space.tunables))
        self.hi = np.array([t.cardinality - 1 for t in space.tunables],
                           dtype=float)
        self.pop: np.ndarray | None = None  # None = (re)initialize on ask
        self.fit: np.ndarray | None = None  # None = initial batch pending
        self.i = 0    # member index (immediate updating)
        self.it = 0   # generation index
        self.asked: tuple | None = None  # (kind, trial(s), configs)


class DifferentialEvolution(Strategy):
    """DE/rand/1/bin over the continuous index space.

    ``updating`` controls selection semantics (mirrors scipy's
    ``differential_evolution``): ``"immediate"`` (default) updates the
    population member-by-member within a generation — each ask is a single
    trial, so later mutants see this generation's accepted trials (the
    original, order-dependent behaviour, bit-identical to the pre-refactor
    loop); ``"deferred"`` builds every trial vector from the generation's
    snapshot and asks the whole generation as one batch (one vectorized
    lookup on a simulation runner). It is a DEFAULTS-only knob, not part of
    ``HYPERPARAM_SPACE`` — adding it to the grid would change every
    exhaustive campaign's enumeration.
    """

    name = "differential_evolution"
    DEFAULTS = {"popsize": 20, "maxiter": 100, "F": 0.8, "CR": 0.9,
                "updating": "immediate"}
    HYPERPARAM_SPACE = {
        "popsize": (10, 20, 30),
        "maxiter": (50, 100, 150),
        "F": (0.4, 0.8, 1.2),
        "CR": (0.5, 0.7, 0.9),
    }
    EXTENDED_SPACE = {
        "popsize": tuple(range(4, 51, 2)),
        "maxiter": tuple(range(10, 201, 10)),
        "F": tuple(round(0.2 + 0.1 * i, 1) for i in range(15)),
        "CR": tuple(round(0.1 + 0.1 * i, 1) for i in range(9)),
    }

    def init_state(self, space: SearchSpace, rng: random.Random) -> _DEState:
        return _DEState(space, rng)

    def _make_trial(self, state: _DEState, i: int,
                    snapshot: np.ndarray) -> np.ndarray:
        popsize = max(4, int(self.hp("popsize")))
        F, CR = float(self.hp("F")), float(self.hp("CR"))
        np_rng = state.np_rng
        a, b, c = np_rng.choice(
            [j for j in range(popsize) if j != i], 3, replace=False)
        mutant = np.clip(snapshot[a] + F * (snapshot[b] - snapshot[c]),
                         state.lo, state.hi)
        cross = np_rng.uniform(size=len(state.lo)) < CR
        cross[np_rng.integers(len(state.lo))] = True
        return np.where(cross, mutant, snapshot[i])

    def ask(self, state: _DEState):
        rng = state.rng
        cs = state.space.compiled
        popsize = max(4, int(self.hp("popsize")))
        if state.pop is None:  # start / restart: fresh random population
            state.pop = np.stack([cs.x_of_row(cs.random_row(rng))
                                  for _ in range(popsize)])
            state.fit = None
            rows = cs.decode_rows(state.pop, rng)
            state.asked = ("init", None, rows)
            return RowBatch(cs, rows)
        if str(self.hp("updating")) == "deferred":
            # whole-generation ask: trials come from this generation's
            # snapshot, selection applies in tell
            trials = [self._make_trial(state, i, state.pop)
                      for i in range(popsize)]
            rows = cs.decode_rows(np.asarray(trials), rng)
            state.asked = ("deferred", trials, rows)
            return RowBatch(cs, rows)
        # immediate updating: one trial per ask, built against the current
        # (already part-updated) population
        trial = self._make_trial(state, state.i, state.pop)
        row = cs.repair_x(trial, rng)
        state.asked = ("immediate", trial, row)
        return RowBatch(cs, (row,))

    def tell(self, state: _DEState, observations) -> None:
        popsize = max(4, int(self.hp("popsize")))
        maxiter = int(self.hp("maxiter"))
        kind, trial, _cfgs = state.asked
        state.asked = None
        if kind == "init":
            state.fit = np.array([self.fitness(o.value)
                                  for o in observations])
            state.i = 0
            state.it = 0
            return
        if kind == "deferred":
            fs = [self.fitness(o.value) for o in observations]
            for i, (t, f) in enumerate(zip(trial, fs)):
                if f <= state.fit[i]:
                    state.pop[i], state.fit[i] = t, f
            state.it += 1
            if state.it >= maxiter:
                state.pop = None
            return
        f = self.fitness(observations[0].value)
        if f <= state.fit[state.i]:
            state.pop[state.i], state.fit[state.i] = trial, f
        state.i += 1
        if state.i >= popsize:
            state.i = 0
            state.it += 1
            if state.it >= maxiter:
                state.pop = None


class BasinHopping(GeneratorStrategy):
    name = "basin_hopping"
    DEFAULTS = {"T": 1.0, "stepsize": 2, "local_iters": 32}
    HYPERPARAM_SPACE = {
        "T": (0.5, 1.0, 1.5),
        "stepsize": (1, 2, 4),
        "local_iters": (16, 32, 64),
    }
    EXTENDED_SPACE = {
        "T": tuple(round(0.1 * i, 1) for i in range(1, 21)),
        "stepsize": (1, 2, 3, 4, 6, 8),
        "local_iters": (8, 16, 24, 32, 48, 64, 96, 128),
    }

    def _greedy_descent(self, start, cs, max_iters):
        # first-improvement: each neighbor must be observed before deciding
        # whether to evaluate the next, so this yields one row at a time
        cur = start
        f_cur = self.fitness((yield RowBatch(cs, (start,)))[0].value)
        for _ in range(max_iters):
            improved = False
            for n in cs.neighbors_rows(cur, strictly_adjacent=True).tolist():
                f = self.fitness((yield RowBatch(cs, (n,)))[0].value)
                if f < f_cur:
                    cur, f_cur, improved = n, f, True
                    break
            if not improved:
                break
        return cur, f_cur

    def _generate(self, space: SearchSpace, rng: random.Random):
        T = float(self.hp("T"))
        step = int(self.hp("stepsize"))
        local_iters = int(self.hp("local_iters"))
        cs = space.compiled
        cur, f_cur = yield from self._greedy_descent(
            cs.random_row(rng), cs, local_iters)
        while True:
            # hop: jump `step` positions in value-order on a few tunables
            jumped = list(cs.idx_tuples[cur])
            for i, card in enumerate(cs.cards):
                if rng.random() < 0.5:
                    j = jumped[i] + rng.choice((-step, step))
                    jumped[i] = max(0, min(card - 1, j))
            start = cs.repair_vidx(tuple(jumped), rng)
            cand, f_cand = yield from self._greedy_descent(start, cs,
                                                           local_iters)
            d_rel = (f_cand - f_cur) / max(abs(f_cur), 1e-30)
            if d_rel <= 0 or rng.random() < math.exp(-d_rel / max(T, 1e-9)):
                cur, f_cur = cand, f_cand


class GreedyILS(GeneratorStrategy):
    name = "greedy_ils"
    DEFAULTS = {"perturbation": 2, "restart_chance": 0.05}
    HYPERPARAM_SPACE = {
        "perturbation": (1, 2, 4),
        "restart_chance": (0.0, 0.05, 0.2),
    }
    EXTENDED_SPACE = {
        "perturbation": (1, 2, 3, 4, 6, 8),
        "restart_chance": (0.0, 0.01, 0.02, 0.05, 0.1, 0.2, 0.4),
    }

    def _generate(self, space: SearchSpace, rng: random.Random):
        k = int(self.hp("perturbation"))
        p_restart = float(self.hp("restart_chance"))
        cs = space.compiled
        cur = cs.random_row(rng)
        f_cur = self.fitness((yield RowBatch(cs, (cur,)))[0].value)
        while True:
            # greedy descent to local optimum (best-improvement: the whole
            # neighborhood is one ask — one CSR slice, one row gather)
            while True:
                nbrs = cs.neighbors_rows(cur)
                best_n, best_f = None, f_cur
                if len(nbrs):
                    obs = yield RowBatch(cs, nbrs)
                    for n, o in zip(nbrs.tolist(), obs):
                        f = self.fitness(o.value)
                        if f < best_f:
                            best_n, best_f = n, f
                if best_n is None:
                    break
                cur, f_cur = best_n, best_f
            # perturb k random tunables (or restart)
            if rng.random() < p_restart:
                cur = cs.random_row(rng)
            else:
                out = list(cs.idx_tuples[cur])
                idxs = rng.sample(range(cs.n_tunables),
                                  min(k, cs.n_tunables))
                for i in idxs:
                    out[i] = rng.randrange(cs.cards[i])
                cur = cs.repair_vidx(tuple(out), rng)
            f_cur = self.fitness((yield RowBatch(cs, (cur,)))[0].value)


class MultiStartLocalSearch(GeneratorStrategy):
    name = "mls"
    DEFAULTS = {"adjacent_only": True}
    HYPERPARAM_SPACE = {"adjacent_only": (True, False)}
    EXTENDED_SPACE = {"adjacent_only": (True, False)}

    def _generate(self, space: SearchSpace, rng: random.Random):
        adjacent = bool(self.hp("adjacent_only"))
        cs = space.compiled
        while True:
            cur = cs.random_row(rng)
            f_cur = self.fitness((yield RowBatch(cs, (cur,)))[0].value)
            while True:
                nbrs = cs.neighbors_rows(cur, strictly_adjacent=adjacent)
                best_n, best_f = None, f_cur
                if len(nbrs):
                    obs = yield RowBatch(cs, nbrs)
                    for n, o in zip(nbrs.tolist(), obs):
                        f = self.fitness(o.value)
                        if f < best_f:
                            best_n, best_f = n, f
                if best_n is None:
                    break
                cur, f_cur = best_n, best_f
