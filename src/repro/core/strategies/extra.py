"""Additional strategies beyond the paper's four evaluated algorithms.

Kernel Tuner ships 20+ strategies (paper Table I); we implement four more
here so the hypertuner has a broader pool for meta-strategy experiments:
Differential Evolution, Basin Hopping, Greedy Iterated Local Search, and
Multi-start Local Search. Each declares hyperparameter spaces so they are
first-class citizens of the "tuning the tuner" pipeline.
"""
from __future__ import annotations

import random

import numpy as np

from ..runner import Runner
from ..searchspace import SearchSpace
from .base import Strategy


class DifferentialEvolution(Strategy):
    """DE/rand/1/bin over the continuous index space.

    ``updating`` controls selection semantics (mirrors scipy's
    ``differential_evolution``): ``"immediate"`` (default) updates the
    population member-by-member within a generation — the original,
    order-dependent behaviour, kept as the default so existing campaigns
    replay bit-identically; ``"deferred"`` builds every trial vector from
    the generation's snapshot and evaluates the whole generation as one
    ask/tell batch (one vectorized lookup on a simulation runner). It is a
    DEFAULTS-only knob, not part of ``HYPERPARAM_SPACE`` — adding it to the
    grid would change every exhaustive campaign's enumeration.
    """

    name = "differential_evolution"
    DEFAULTS = {"popsize": 20, "maxiter": 100, "F": 0.8, "CR": 0.9,
                "updating": "immediate"}
    HYPERPARAM_SPACE = {
        "popsize": (10, 20, 30),
        "maxiter": (50, 100, 150),
        "F": (0.4, 0.8, 1.2),
        "CR": (0.5, 0.7, 0.9),
    }
    EXTENDED_SPACE = {
        "popsize": tuple(range(4, 51, 2)),
        "maxiter": tuple(range(10, 201, 10)),
        "F": tuple(round(0.2 + 0.1 * i, 1) for i in range(15)),
        "CR": tuple(round(0.1 + 0.1 * i, 1) for i in range(9)),
    }

    def _optimize(self, space: SearchSpace, runner: Runner, rng: random.Random) -> None:
        popsize = max(4, int(self.hp("popsize")))
        maxiter = int(self.hp("maxiter"))
        F, CR = float(self.hp("F")), float(self.hp("CR"))
        deferred = str(self.hp("updating")) == "deferred"
        np_rng = np.random.default_rng(rng.getrandbits(64))
        lo = np.zeros(len(space.tunables))
        hi = np.array([t.cardinality - 1 for t in space.tunables], dtype=float)

        def eval_idx(x) -> float:
            cfg = space.nearest_valid(space.from_indices(x), rng)
            return self.fitness(runner(cfg))

        def eval_batch(xs) -> list:
            # decode + repair vectorized (same rng draw order as the
            # per-member loop: evaluation draws nothing), one ask/tell batch
            cfgs = space.decode_batch(np.asarray(xs), rng)
            return [self.fitness(o.value) for o in runner.run_batch(cfgs)]

        def make_trial(i: int, snapshot: np.ndarray) -> np.ndarray:
            a, b, c = np_rng.choice(
                [j for j in range(popsize) if j != i], 3, replace=False)
            mutant = np.clip(snapshot[a] + F * (snapshot[b] - snapshot[c]),
                             lo, hi)
            cross = np_rng.uniform(size=len(lo)) < CR
            cross[np_rng.integers(len(lo))] = True
            return np.where(cross, mutant, snapshot[i])

        while True:
            pop = np.stack([space.to_indices(space.random_config(rng))
                            for _ in range(popsize)])
            fit = np.array(eval_batch(pop))
            for _ in range(maxiter):
                if deferred:
                    # whole-generation ask/tell: trials come from this
                    # generation's snapshot, selection applies afterwards
                    trials = [make_trial(i, pop) for i in range(popsize)]
                    fs = eval_batch(trials)
                    for i, (trial, f) in enumerate(zip(trials, fs)):
                        if f <= fit[i]:
                            pop[i], fit[i] = trial, f
                else:
                    # immediate updating: later mutants see this
                    # generation's accepted trials (order-dependent — the
                    # original semantics, bit-identical to the seed repo)
                    for i in range(popsize):
                        trial = make_trial(i, pop)
                        f = eval_idx(trial)
                        if f <= fit[i]:
                            pop[i], fit[i] = trial, f


class BasinHopping(Strategy):
    name = "basin_hopping"
    DEFAULTS = {"T": 1.0, "stepsize": 2, "local_iters": 32}
    HYPERPARAM_SPACE = {
        "T": (0.5, 1.0, 1.5),
        "stepsize": (1, 2, 4),
        "local_iters": (16, 32, 64),
    }
    EXTENDED_SPACE = {
        "T": tuple(round(0.1 * i, 1) for i in range(1, 21)),
        "stepsize": (1, 2, 3, 4, 6, 8),
        "local_iters": (8, 16, 24, 32, 48, 64, 96, 128),
    }

    def _greedy_descent(self, start, space, runner, max_iters):
        cur, f_cur = start, self.fitness(runner(start))
        for _ in range(max_iters):
            improved = False
            for n in space.neighbors(cur, strictly_adjacent=True):
                f = self.fitness(runner(n))
                if f < f_cur:
                    cur, f_cur, improved = n, f, True
                    break
            if not improved:
                break
        return cur, f_cur

    def _optimize(self, space: SearchSpace, runner: Runner, rng: random.Random) -> None:
        import math
        T = float(self.hp("T"))
        step = int(self.hp("stepsize"))
        local_iters = int(self.hp("local_iters"))
        cur, f_cur = self._greedy_descent(space.random_config(rng), space,
                                          runner, local_iters)
        while True:
            # hop: jump `step` positions in value-order on a few tunables
            jumped = list(cur)
            for i, t in enumerate(space.tunables):
                if rng.random() < 0.5:
                    j = t.index_of(jumped[i]) + rng.choice((-step, step))
                    j = max(0, min(t.cardinality - 1, j))
                    jumped[i] = t.values[j]
            start = space.nearest_valid(tuple(jumped), rng)
            cand, f_cand = self._greedy_descent(start, space, runner, local_iters)
            d_rel = (f_cand - f_cur) / max(abs(f_cur), 1e-30)
            if d_rel <= 0 or rng.random() < math.exp(-d_rel / max(T, 1e-9)):
                cur, f_cur = cand, f_cand


class GreedyILS(Strategy):
    name = "greedy_ils"
    DEFAULTS = {"perturbation": 2, "restart_chance": 0.05}
    HYPERPARAM_SPACE = {
        "perturbation": (1, 2, 4),
        "restart_chance": (0.0, 0.05, 0.2),
    }
    EXTENDED_SPACE = {
        "perturbation": (1, 2, 3, 4, 6, 8),
        "restart_chance": (0.0, 0.01, 0.02, 0.05, 0.1, 0.2, 0.4),
    }

    def _optimize(self, space: SearchSpace, runner: Runner, rng: random.Random) -> None:
        k = int(self.hp("perturbation"))
        p_restart = float(self.hp("restart_chance"))
        cur = space.random_config(rng)
        f_cur = self.fitness(runner(cur))
        while True:
            # greedy descent to local optimum (best-improvement)
            while True:
                nbrs = space.neighbors(cur)
                best_n, best_f = None, f_cur
                for n in nbrs:
                    f = self.fitness(runner(n))
                    if f < best_f:
                        best_n, best_f = n, f
                if best_n is None:
                    break
                cur, f_cur = best_n, best_f
            # perturb k random tunables (or restart)
            if rng.random() < p_restart:
                cur = space.random_config(rng)
            else:
                out = list(cur)
                idxs = rng.sample(range(len(space.tunables)),
                                  min(k, len(space.tunables)))
                for i in idxs:
                    t = space.tunables[i]
                    out[i] = t.values[rng.randrange(t.cardinality)]
                cur = space.nearest_valid(tuple(out), rng)
            f_cur = self.fitness(runner(cur))


class MultiStartLocalSearch(Strategy):
    name = "mls"
    DEFAULTS = {"adjacent_only": True}
    HYPERPARAM_SPACE = {"adjacent_only": (True, False)}
    EXTENDED_SPACE = {"adjacent_only": (True, False)}

    def _optimize(self, space: SearchSpace, runner: Runner, rng: random.Random) -> None:
        adjacent = bool(self.hp("adjacent_only"))
        while True:
            cur = space.random_config(rng)
            f_cur = self.fitness(runner(cur))
            while True:
                nbrs = space.neighbors(cur, strictly_adjacent=adjacent)
                best_n, best_f = None, f_cur
                for n in nbrs:
                    f = self.fitness(runner(n))
                    if f < best_f:
                        best_n, best_f = n, f
                if best_n is None:
                    break
                cur, f_cur = best_n, best_f
