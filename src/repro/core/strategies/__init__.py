"""Strategy registry.

The four paper-evaluated algorithms (Table III/IV) plus random search (the
methodology baseline) and four extra strategies. ``get_strategy`` builds a
configured instance; ``PAPER_STRATEGIES`` is the evaluation set of Sec. IV.
"""
from __future__ import annotations

from .base import GeneratorStrategy, Strategy, hyperparam_id
from .dual_annealing import DualAnnealing
from .extra import (BasinHopping, DifferentialEvolution, GreedyILS,
                    MultiStartLocalSearch)
from .genetic_algorithm import GeneticAlgorithm
from .particle_swarm import ParticleSwarm
from .random_search import RandomSearch
from .simulated_annealing import SimulatedAnnealing

STRATEGIES: dict[str, type[Strategy]] = {
    cls.name: cls
    for cls in (
        RandomSearch,
        SimulatedAnnealing,
        DualAnnealing,
        GeneticAlgorithm,
        ParticleSwarm,
        DifferentialEvolution,
        BasinHopping,
        GreedyILS,
        MultiStartLocalSearch,
    )
}

# The algorithms evaluated in the paper (Sec. IV-A, Table III).
PAPER_STRATEGIES = ("dual_annealing", "genetic_algorithm", "pso",
                    "simulated_annealing")


def get_strategy(name: str, **hyperparams) -> Strategy:
    try:
        cls = STRATEGIES[name]
    except KeyError:
        raise KeyError(f"unknown strategy {name!r}; known: {sorted(STRATEGIES)}")
    return cls(**hyperparams)


__all__ = ["Strategy", "GeneratorStrategy", "STRATEGIES", "PAPER_STRATEGIES",
           "get_strategy", "hyperparam_id", "RandomSearch", "SimulatedAnnealing",
           "DualAnnealing", "GeneticAlgorithm", "ParticleSwarm",
           "DifferentialEvolution", "BasinHopping", "GreedyILS",
           "MultiStartLocalSearch"]
