"""Dual Annealing (paper Table III hyperparameters).

Wraps ``scipy.optimize.dual_annealing`` over the continuous index space, as
Kernel Tuner does. The single tuned hyperparameter is the local-search
``method`` (paper Table III: COBYLA, L-BFGS-B, SLSQP, CG, Powell,
Nelder-Mead, BFGS, trust-constr). Positions are rounded/repaired to valid
configs inside the objective; failures get a large finite penalty so the
numerical local phases stay well-defined.

scipy owns the control flow (it calls the objective synchronously), so this
strategy cannot be inverted into a native state machine; it opts into the
``core.driver`` thread bridge explicitly — the legacy ``_optimize`` loop
runs on a bridge thread and every objective call becomes one ask/tell
exchange. The run is still suspendable: the bridge state serializes as a
replay log (initial RNG state + observations told so far).

It is also the one strategy that stays on the value-tuple runner path
after the index-native refactor: scipy hands back float vectors one at a
time, so there is no batch to express as rows — but the objective's
round+repair now resolves through the compiled space's move tables
(``compiled.repair_x``), the former per-config scan-and-BFS hot spot.
"""
from __future__ import annotations

import random

import numpy as np
import scipy.optimize

from ..budget import BudgetExhausted
from ..driver import SearchState, legacy_state
from ..runner import Runner
from ..searchspace import SearchSpace
from .base import FAILURE_FITNESS, Strategy

METHODS = ("COBYLA", "L-BFGS-B", "SLSQP", "CG", "Powell", "Nelder-Mead",
           "BFGS", "trust-constr")


class DualAnnealing(Strategy):
    name = "dual_annealing"
    DEFAULTS = {"method": "Powell"}
    HYPERPARAM_SPACE = {"method": METHODS}
    EXTENDED_SPACE = {"method": METHODS}

    def init_state(self, space: SearchSpace,
                   rng: random.Random) -> SearchState:
        # explicit thread-bridge opt-in: no deprecation warning
        return legacy_state(self, space, rng)

    def _optimize(self, space: SearchSpace, runner: Runner, rng: random.Random) -> None:
        method = str(self.hp("method"))
        bounds = space.bounds
        # degenerate 1-value dims break scipy bounds; widen epsilon
        bounds = [(lo, hi if hi > lo else lo + 1e-6) for lo, hi in bounds]
        cs = space.compiled
        configs = cs.configs

        def objective(x: np.ndarray) -> float:
            # round+repair through the compiled move tables (bit-identical
            # to from_indices + nearest_valid, minus the per-call BFS)
            cfg = configs[cs.repair_x(x, rng)]
            v = runner(cfg)  # raises BudgetExhausted when spent
            return FAILURE_FITNESS if v == float("inf") else v

        while True:  # restart until the budget stops us
            try:
                scipy.optimize.dual_annealing(
                    objective, bounds,
                    minimizer_kwargs={"method": method},
                    seed=rng.getrandbits(32),
                    maxiter=1000,
                )
            except BudgetExhausted:
                raise
            except Exception:
                # some local methods can fail on the rounded landscape
                # (e.g. singular Hessian approximations) — restart
                continue
