"""Particle Swarm Optimization (paper Table III/IV hyperparameters).

Standard PSO over the continuous index space of the tunables; positions are
rounded to configs (repaired when invalid) for evaluation. The paper found
the inertia ``w`` to have no meaningful effect (Kruskal-Wallis / mutual
information sensitivity test, Sec. IV-A) and excludes it from tuning; it
remains available as a hyperparameter with its Kernel Tuner default.

Hyperparameters:
  popsize: swarm size                {10, 20, 30} / {2 … 50}
  maxiter: iterations                {50, 100, 150} / {10 … 200}
  c1:      cognitive coefficient     {1.0, 2.0, 3.0} / {1.0 … 3.5}
  c2:      social coefficient        {0.5, 1.0, 1.5} / {0.5 … 2.0}
  w:       inertia (not tuned)       default 0.5
"""
from __future__ import annotations

import random

import numpy as np

from ..runner import Runner
from ..searchspace import SearchSpace
from .base import Strategy


class ParticleSwarm(Strategy):
    name = "pso"
    DEFAULTS = {"popsize": 20, "maxiter": 100, "c1": 2.0, "c2": 1.0, "w": 0.5}
    HYPERPARAM_SPACE = {
        "popsize": (10, 20, 30),
        "maxiter": (50, 100, 150),
        "c1": (1.0, 2.0, 3.0),
        "c2": (0.5, 1.0, 1.5),
    }
    EXTENDED_SPACE = {
        "popsize": tuple(range(2, 51, 2)),
        "maxiter": tuple(range(10, 201, 10)),
        "c1": tuple(round(1.0 + 0.25 * i, 2) for i in range(11)),
        "c2": tuple(round(0.5 + 0.25 * i, 2) for i in range(7)),
    }

    def _optimize(self, space: SearchSpace, runner: Runner, rng: random.Random) -> None:
        popsize = int(self.hp("popsize"))
        maxiter = int(self.hp("maxiter"))
        c1, c2, w = float(self.hp("c1")), float(self.hp("c2")), float(self.hp("w"))
        np_rng = np.random.default_rng(rng.getrandbits(64))

        lo = np.zeros(len(space.tunables))
        hi = np.array([t.cardinality - 1 for t in space.tunables], dtype=float)
        span = np.maximum(hi - lo, 1.0)

        while True:  # restart loop until budget exhausted
            pos = np.stack([space.to_indices(space.random_config(rng))
                            for _ in range(popsize)])
            vel = np_rng.uniform(-1, 1, pos.shape) * span * 0.25
            pbest = pos.copy()
            pbest_f = np.full(popsize, np.inf)
            gbest, gbest_f = pos[0].copy(), np.inf
            for _ in range(maxiter):
                # ask/tell: decode + repair the whole swarm in one vectorized
                # call (same rng draw order as the former interleaved loop —
                # evaluation draws nothing), then evaluate it as one batch
                cfgs = space.decode_batch(pos, rng)
                obs = runner.run_batch(cfgs)
                for i, (o, cfg) in enumerate(zip(obs, cfgs)):
                    f = self.fitness(o.value)
                    if f < pbest_f[i]:
                        pbest_f[i] = f
                        pbest[i] = space.to_indices(cfg)
                    if f < gbest_f:
                        gbest_f = f
                        gbest = space.to_indices(cfg)
                r1 = np_rng.uniform(size=pos.shape)
                r2 = np_rng.uniform(size=pos.shape)
                vel = w * vel + c1 * r1 * (pbest - pos) + c2 * r2 * (gbest - pos)
                vel = np.clip(vel, -span, span)
                pos = np.clip(pos + vel, lo, hi)
