"""Particle Swarm Optimization (paper Table III/IV hyperparameters).

Standard PSO over the continuous index space of the tunables; positions are
rounded to configs (repaired when invalid) for evaluation. The paper found
the inertia ``w`` to have no meaningful effect (Kruskal-Wallis / mutual
information sensitivity test, Sec. IV-A) and excludes it from tuning; it
remains available as a hyperparameter with its Kernel Tuner default.

Protocol-native: ``ask`` decodes the swarm's positions to one config batch
(initializing positions/velocities at start and after each restart);
``tell`` updates personal/global bests and steps velocities. Decode repairs
draw from the run RNG in ask and velocity updates draw from the numpy
generator in tell — the same interleaving as the pre-refactor loop, so
traces are bit-identical.

Index-native: positions decode to compiled-space *rows*
(``compiled.decode_rows``: one whole-matrix round/clip, repair through the
move tables), the ask is a ``RowBatch``, and best-position reads come
straight from the value-index matrix (``x_of_row`` == the old
``to_indices`` of the decoded config).

Hyperparameters:
  popsize: swarm size                {10, 20, 30} / {2 … 50}
  maxiter: iterations                {50, 100, 150} / {10 … 200}
  c1:      cognitive coefficient     {1.0, 2.0, 3.0} / {1.0 … 3.5}
  c2:      social coefficient        {0.5, 1.0, 1.5} / {0.5 … 2.0}
  w:       inertia (not tuned)       default 0.5
"""
from __future__ import annotations

import random

import numpy as np

from ..driver import SearchState
from ..searchspace import SearchSpace
from ..space import RowBatch
from .base import Strategy


class _PSOState(SearchState):
    def __init__(self, space: SearchSpace, rng: random.Random):
        super().__init__(space, rng)
        # drawn here — at the same point in the rng stream as the
        # pre-refactor loop drew it (top of _optimize)
        self.np_rng = np.random.default_rng(rng.getrandbits(64))
        self.lo = np.zeros(len(space.tunables))
        self.hi = np.array([t.cardinality - 1 for t in space.tunables],
                           dtype=float)
        self.span = np.maximum(self.hi - self.lo, 1.0)
        self.pos: np.ndarray | None = None  # None = (re)initialize on ask
        self.vel = self.pbest = self.pbest_f = self.gbest = None
        self.gbest_f = np.inf
        self.it = 0
        self.asked: np.ndarray | None = None  # decoded rows of the open ask


class ParticleSwarm(Strategy):
    name = "pso"
    DEFAULTS = {"popsize": 20, "maxiter": 100, "c1": 2.0, "c2": 1.0, "w": 0.5}
    HYPERPARAM_SPACE = {
        "popsize": (10, 20, 30),
        "maxiter": (50, 100, 150),
        "c1": (1.0, 2.0, 3.0),
        "c2": (0.5, 1.0, 1.5),
    }
    EXTENDED_SPACE = {
        "popsize": tuple(range(2, 51, 2)),
        "maxiter": tuple(range(10, 201, 10)),
        "c1": tuple(round(1.0 + 0.25 * i, 2) for i in range(11)),
        "c2": tuple(round(0.5 + 0.25 * i, 2) for i in range(7)),
    }

    def init_state(self, space: SearchSpace, rng: random.Random) -> _PSOState:
        return _PSOState(space, rng)

    def ask(self, state: _PSOState):
        rng = state.rng
        cs = state.space.compiled
        if state.pos is None:  # start / post-restart initialization
            popsize = int(self.hp("popsize"))
            state.pos = np.stack([cs.x_of_row(cs.random_row(rng))
                                  for _ in range(popsize)])
            state.vel = (state.np_rng.uniform(-1, 1, state.pos.shape)
                         * state.span * 0.25)
            state.pbest = state.pos.copy()
            state.pbest_f = np.full(popsize, np.inf)
            state.gbest, state.gbest_f = state.pos[0].copy(), np.inf
            state.it = 0
        # decode + repair the whole swarm in one vectorized call (repairs
        # draw from rng exactly as the per-particle loop did)
        state.asked = cs.decode_rows(state.pos, rng)
        return RowBatch(cs, state.asked)

    def tell(self, state: _PSOState, observations) -> None:
        cs = state.space.compiled
        c1, c2 = float(self.hp("c1")), float(self.hp("c2"))
        w = float(self.hp("w"))
        for i, (o, row) in enumerate(zip(observations,
                                         state.asked.tolist())):
            f = self.fitness(o.value)
            if f < state.pbest_f[i]:
                state.pbest_f[i] = f
                state.pbest[i] = cs.x_of_row(row)
            if f < state.gbest_f:
                state.gbest_f = f
                state.gbest = cs.x_of_row(row)
        state.asked = None
        np_rng, pos = state.np_rng, state.pos
        r1 = np_rng.uniform(size=pos.shape)
        r2 = np_rng.uniform(size=pos.shape)
        vel = (w * state.vel + c1 * r1 * (state.pbest - pos)
               + c2 * r2 * (state.gbest - pos))
        vel = np.clip(vel, -state.span, state.span)
        state.vel = vel
        state.pos = np.clip(pos + vel, state.lo, state.hi)
        state.it += 1
        if state.it >= int(self.hp("maxiter")):
            state.pos = None  # restart from fresh random positions
