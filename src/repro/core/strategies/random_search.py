"""Random search — the methodology's baseline strategy (paper Sec. III-B)."""
from __future__ import annotations

import random

from ..runner import Runner
from ..searchspace import SearchSpace
from .base import Strategy


class RandomSearch(Strategy):
    name = "random_search"
    DEFAULTS: dict = {}

    def _optimize(self, space: SearchSpace, runner: Runner, rng: random.Random) -> None:
        # Sample *without replacement* over valid configs (Kernel Tuner
        # semantics: the tuner cache makes revisits free, so random search is
        # effectively a random permutation of the space). The whole
        # permutation goes through the runner as ONE batch: a vectorized
        # runner resolves it in a single columnar gather, and budget
        # exhaustion stops it at exactly the same config as the scalar loop.
        order = list(space.valid_configs)
        rng.shuffle(order)
        runner.run_batch(order)
