"""Random search — the methodology's baseline strategy (paper Sec. III-B)."""
from __future__ import annotations

import random

from ..driver import SearchState
from ..searchspace import SearchSpace
from ..space import RowBatch
from .base import Strategy


class _RandomSearchState(SearchState):
    def __init__(self, space: SearchSpace, rng: random.Random):
        super().__init__(space, rng)
        self.asked = False


def _rng_permutation(n: int, rng: random.Random) -> list:
    """Fisher–Yates permutation of ``range(n)`` drawing the *exact*
    ``getrandbits`` stream of ``rng.shuffle(list(range(n)))``.

    ``random.shuffle`` pays a ``_randbelow`` method call (bit_length +
    rejection loop behind a function frame) per element; at campaign scale
    the permutation draw is the whole cost of a random-search ask, and in
    the device-fused path it is the *floor* of the end-to-end wall. This
    inlines the rejection sampling and hoists ``bit_length`` out of the
    loop by walking bands of constant ``k = (i+1).bit_length()`` — ~3x
    less per-draw overhead, bit-identical permutations
    (tests/test_strategies.py pins the stream equivalence).
    """
    order = list(range(n))
    grb = rng.getrandbits
    m = n  # draws _randbelow(m) for m = n .. 2, exactly like shuffle
    while m > 1:
        k = m.bit_length()
        band_lo = max(1 << (k - 1), 2)
        while m >= band_lo:
            r = grb(k)
            while r >= m:
                r = grb(k)
            i = m - 1
            order[i], order[r] = order[r], order[i]
            m -= 1
    return order


class RandomSearch(Strategy):
    name = "random_search"
    DEFAULTS: dict = {}

    def init_state(self, space: SearchSpace,
                   rng: random.Random) -> _RandomSearchState:
        return _RandomSearchState(space, rng)

    def ask(self, state: _RandomSearchState):
        # Sample *without replacement* over valid configs (Kernel Tuner
        # semantics: the tuner cache makes revisits free, so random search
        # is effectively a random permutation of the space). The whole
        # permutation is ONE ask — index-native: shuffling the row range
        # draws from rng exactly like shuffling the config list (Fisher-
        # Yates only reads the length), and the RowBatch resolves as one
        # columnar row gather, with budget exhaustion stopping at exactly
        # the same config as the scalar loop.
        if state.asked:
            return None  # the permutation survived the budget: we are done
        state.asked = True
        cs = state.space.compiled
        return RowBatch(cs, _rng_permutation(cs.n_valid, state.rng))

    def tell(self, state: _RandomSearchState, observations) -> None:
        pass  # best-so-far tracking lives in the runner's trace
