"""Random search — the methodology's baseline strategy (paper Sec. III-B)."""
from __future__ import annotations

import random

from ..driver import SearchState
from ..searchspace import SearchSpace
from ..space import RowBatch
from .base import Strategy


class _RandomSearchState(SearchState):
    def __init__(self, space: SearchSpace, rng: random.Random):
        super().__init__(space, rng)
        self.asked = False


class RandomSearch(Strategy):
    name = "random_search"
    DEFAULTS: dict = {}

    def init_state(self, space: SearchSpace,
                   rng: random.Random) -> _RandomSearchState:
        return _RandomSearchState(space, rng)

    def ask(self, state: _RandomSearchState):
        # Sample *without replacement* over valid configs (Kernel Tuner
        # semantics: the tuner cache makes revisits free, so random search
        # is effectively a random permutation of the space). The whole
        # permutation is ONE ask — index-native: shuffling the row range
        # draws from rng exactly like shuffling the config list (Fisher-
        # Yates only reads the length), and the RowBatch resolves as one
        # columnar row gather, with budget exhaustion stopping at exactly
        # the same config as the scalar loop.
        if state.asked:
            return None  # the permutation survived the budget: we are done
        state.asked = True
        cs = state.space.compiled
        order = list(range(cs.n_valid))
        state.rng.shuffle(order)
        return RowBatch(cs, order)

    def tell(self, state: _RandomSearchState, observations) -> None:
        pass  # best-so-far tracking lives in the runner's trace
