"""Simulated Annealing (paper Table III/IV hyperparameters).

Classic SA over the neighbor graph of the search space: accept worse moves
with probability exp(-Δrel / T); geometric cooling T ← α·T; restart from a
random config whenever T reaches T_min (budget permitting). Δrel is the
*relative* objective difference so that temperature values are comparable
across search spaces whose objectives differ by orders of magnitude.

Written as a generator (``GeneratorStrategy``): the walk reads exactly like
the pre-refactor imperative loop with each runner call replaced by a yield;
the generator bridge turns it into ask/tell and keeps the run suspendable
through its replay log.

Index-native: the walk lives entirely on compiled-space rows — neighbors
are one CSR slice per move and the yields are ``RowBatch``es, so no value
tuple or config-id string is ever built inside the loop. The rng stream is
unchanged (the neighbor pick indexes the same-length, same-order list the
scalar space produced).

Hyperparameters (matching the paper):
  T:        initial temperature            {0.5, 1.0, 1.5} / {0.1 … 2.0}
  T_min:    restart temperature            {1e-4, 1e-3, 1e-2} / {1e-4 … 0.1}
  alpha:    cooling rate                   {0.9925, 0.995, 0.9975}
  maxiter:  moves attempted per temperature {1, 2, 3} / {1 … 10}
"""
from __future__ import annotations

import math
import random

from ..searchspace import SearchSpace
from ..space import RowBatch
from .base import GeneratorStrategy


class SimulatedAnnealing(GeneratorStrategy):
    name = "simulated_annealing"
    DEFAULTS = {"T": 1.0, "T_min": 0.001, "alpha": 0.995, "maxiter": 2}
    HYPERPARAM_SPACE = {
        "T": (0.5, 1.0, 1.5),
        "T_min": (0.0001, 0.001, 0.01),
        "alpha": (0.9925, 0.995, 0.9975),
        "maxiter": (1, 2, 3),
    }
    EXTENDED_SPACE = {
        "T": tuple(round(0.1 * i, 1) for i in range(1, 21)),
        "T_min": tuple(round(0.0001 + 0.001 * i, 4) for i in range(100)),
        "alpha": (0.9925, 0.995, 0.9975),
        "maxiter": tuple(range(1, 11)),
    }

    def _generate(self, space: SearchSpace, rng: random.Random):
        T0 = float(self.hp("T"))
        T_min = float(self.hp("T_min"))
        alpha = float(self.hp("alpha"))
        maxiter = int(self.hp("maxiter"))
        cs = space.compiled

        while True:  # restart loop; terminated by BudgetExhausted
            current = cs.random_row(rng)
            f_cur = self.fitness((yield RowBatch(cs, (current,)))[0].value)
            T = T0
            while T > T_min:
                for _ in range(maxiter):
                    nbrs = cs.neighbors_rows(current)
                    if not len(nbrs):
                        current = cs.random_row(rng)
                        f_cur = self.fitness(
                            (yield RowBatch(cs, (current,)))[0].value)
                        continue
                    cand = int(nbrs[rng.randrange(len(nbrs))])
                    f_new = self.fitness(
                        (yield RowBatch(cs, (cand,)))[0].value)
                    d_rel = (f_new - f_cur) / max(abs(f_cur), 1e-30)
                    if d_rel <= 0 or rng.random() < math.exp(-d_rel / max(T, 1e-9)):
                        current, f_cur = cand, f_new
                T *= alpha
