"""Runners: how a strategy's config evaluations are satisfied.

Three runners implement the same protocol (paper Fig. 1 / Sec. III-E):

  * ``SimulationRunner`` — the paper's simulation mode. Replays a T4 cache:
    returns the recorded result and charges the *recorded* compile/run times
    to a simulated-time budget. "From the point of view of the optimization
    algorithm, there is no perceivable difference between live tuning and the
    simulation mode."
  * ``CostModelRunner`` — computes results on the fly from the analytical
    cost model (used to brute-force the hub; identical values to the cache
    since the model is deterministic).
  * ``LiveRunner`` — times an actual callable (used for Pallas interpret-mode
    kernels on CPU, and on-device when real hardware is present).

All runners memoize: re-evaluating a config returns the cached observation and
charges nothing (Kernel Tuner cache semantics; see budget.py).

Observations carry their full ``CachedResult`` detail (raw repeats,
compile/run split), so any runner can be wrapped in a
``core.record.RecordingRunner`` to persist a live run as a replayable cache
— and because the charge is always ``result.charge_s``, the replay's
simulated-time axis matches the recording bit-for-bit.

Every fresh evaluation is appended to ``trace`` as
``(cumulative_simulated_seconds, objective_value, config)`` — the methodology
computes best-so-far performance curves from this.

Batch evaluation (the ``BatchRunner`` protocol): every runner answers
``run_batch(configs)`` — bit-identical to calling ``run`` in a loop, same
memoization, budget accounting, trace order, and ``BudgetExhausted`` point.
The base implementation *is* that loop (the scalar reference path);
``SimulationRunner`` overrides it to resolve the whole batch through the
cache's columnar view (``cache.CacheColumns``) in one vectorized gather, so
population strategies can evaluate an entire generation per call.

Runners are single-run state (memo, budget, trace) and are NOT shared across
threads: parallel campaigns (``core.parallel``) construct one runner per
(space, repeat) task — see ``methodology.run_repeat``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Protocol, Sequence, runtime_checkable

from .budget import Budget, BudgetExhausted
from .cache import CacheFile, CachedResult
from .costmodel import KernelWorkload, estimate
from .devices import DeviceModel
from .searchspace import SearchSpace
from .tunable import Config

INVALID = float("inf")


@dataclasses.dataclass(frozen=True)
class Observation:
    config: Config
    value: float               # objective (mean time_s); inf when failed
    status: str                # "ok" | "error"
    charge_s: float            # simulated seconds charged
    # full T4-style detail (raw repeats, compile/run split) — what a
    # RecordingRunner persists so a live run replays bit-identically
    result: CachedResult | None = None


@runtime_checkable
class BatchRunner(Protocol):
    """Anything a strategy can hand a whole generation of configs to.

    Contract: ``run_batch(configs)`` is observably identical to
    ``[run(c) for c in configs]`` — same evaluation order, same memo
    hits, same budget charges and trace entries, and ``BudgetExhausted``
    raised at exactly the same element (results for earlier elements stay
    committed to memo/trace). Implementations are free to *resolve* the
    batch however they like (``SimulationRunner`` gathers it from columnar
    arrays in one shot) as long as the observable sequence matches.
    """

    def run(self, config: Config) -> Observation: ...

    def run_batch(self, configs: Sequence[Config]) -> list[Observation]: ...


class Runner:
    """Base: memoization, budget accounting, trace recording."""

    def __init__(self, space: SearchSpace, budget: Budget):
        self.space = space
        self.budget = budget
        self.memo: dict[str, Observation] = {}
        self.trace: list[tuple[float, float, Config]] = []
        self.fresh_evals = 0
        self.wall_start = time.perf_counter()

    # subclasses implement this
    def _evaluate(self, config: Config) -> "CachedResult | tuple[float, str, float]":
        """Returns a full ``CachedResult`` (preferred: recordable and
        replayable with exact time accounting) or a bare
        ``(value, status, charge_seconds)`` tuple for objectives with no
        compile/run split (e.g. the meta level's campaign scores)."""
        raise NotImplementedError

    def _evaluate_keyed(self, key: str,
                        config: Config) -> tuple[CachedResult, float, str, float]:
        """``(result, value, status, charge)`` for one fresh evaluation.

        The key (already computed by ``run``/``run_batch`` for memoization)
        is passed down so lookup-style runners need not re-derive it.
        """
        out = self._evaluate(config)
        if isinstance(out, CachedResult):
            return out, out.time_s, out.status, out.charge_s
        value, status, charge = out
        # degenerate detail: the whole charge attributed to compile
        return CachedResult(status, value, (), charge), value, status, charge

    def _commit(self, key: str, config: Config, result: CachedResult,
                value: float, status: str, charge: float) -> Observation:
        """Account one fresh evaluation (budget, memo, trace) — the single
        bookkeeping path shared by ``run`` and ``run_batch``."""
        self.budget.charge(charge)
        self.fresh_evals += 1
        obs = Observation(config, value, status, charge, result)
        self.memo[key] = obs
        self.trace.append((self.budget.spent_seconds, value, config))
        return obs

    def run(self, config: Config) -> Observation:
        key = self.space.config_id(config)
        hit = self.memo.get(key)
        if hit is not None:
            return hit
        self.budget.check()  # raises BudgetExhausted when spent
        return self._commit(key, config, *self._evaluate_keyed(key, config))

    def run_batch(self, configs: Sequence[Config]) -> list[Observation]:
        """Evaluate ``configs`` in order (the scalar reference loop).

        See ``BatchRunner``: subclasses that override this must preserve
        loop-of-``run`` observable behaviour exactly.
        """
        return [self.run(c) for c in configs]

    def __call__(self, config: Config) -> float:
        return self.run(config).value

    @property
    def best(self) -> Observation | None:
        ok = [o for o in self.memo.values() if o.status == "ok"]
        return min(ok, key=lambda o: o.value) if ok else None

    @property
    def wall_seconds(self) -> float:
        return time.perf_counter() - self.wall_start


class SimulationRunner(Runner):
    """Replays a T4 cache; the engine behind every simulated campaign.

    ``columnar=True`` (the default) resolves evaluations through the
    cache's array-backed view: single evaluations skip the results-dict hop
    and the per-visit ``charge_s`` re-summation, and ``run_batch`` gathers
    a whole generation's values/charges in one fancy-indexed numpy read.
    ``columnar=False`` keeps the original scalar dict path — the reference
    the parity suite and the regression benchmark compare against. Both
    paths are bit-identical by construction (the columns are built with the
    scalar path's own fixed-order reductions).
    """

    def __init__(self, cache: CacheFile, budget: Budget,
                 columnar: bool = True):
        super().__init__(cache.space, budget)
        self.cache = cache
        self.columnar = columnar

    def _evaluate(self, config: Config) -> CachedResult:
        try:
            return self.cache.lookup(config)
        except KeyError:
            # config outside the brute-forced/recorded set: treat as a
            # failed compile costing an average evaluation
            return CachedResult("error", INVALID, (),
                                self.cache.mean_eval_charge())

    def _evaluate_keyed(self, key: str,
                        config: Config) -> tuple[CachedResult, float, str, float]:
        if not self.columnar:
            return super()._evaluate_keyed(key, config)
        cols = self.cache.columns
        row = cols.index.get(key, -1)
        if row < 0:
            # mean_eval_charge (not cols.mean_charge) so an empty cache
            # raises its clear "record the space first" error, not a
            # ZeroDivisionError
            charge = self.cache.mean_eval_charge()
            return CachedResult("error", INVALID, (), charge), \
                INVALID, "error", charge
        result = cols.records[row]
        # result.time_s/status are the authoritative Python scalars; the
        # charge comes from the precomputed column (same value, no re-sum)
        return result, result.time_s, result.status, cols.charge_list[row]

    # gather granularity: a strategy may hand over far more configs than the
    # budget allows (random search batches the whole space permutation);
    # chunks grow geometrically so a budget-capped run wastes at most one
    # small chunk of key work past the exhaustion point, while full-space
    # replays still amortize into large chunks
    BATCH_CHUNK_MIN = 64
    BATCH_CHUNK_MAX = 2048

    def run_batch(self, configs: Sequence[Config]) -> list[Observation]:
        if not self.columnar:
            return super().run_batch(configs)
        cols = self.cache.columns
        space = self.space
        memo = self.memo
        budget = self.budget
        trace = self.trace
        records = cols.records
        time_list, charge_list = cols.time_list, cols.charge_list
        index_get = cols.index.get
        memo_get = memo.get
        append = trace.append
        new_obs = Observation.__new__
        out: list[Observation] = []
        # budget accounting is mirrored in locals (same left-to-right float
        # accumulation as Budget.charge, minus per-eval attribute churn) and
        # synced back even when BudgetExhausted aborts the batch mid-way
        max_s, max_e = budget.max_seconds, budget.max_evals
        spent_s, spent_e = budget.spent_seconds, budget.spent_evals
        fresh = self.fresh_evals
        mean_charge: float | None = None
        try:
            start, step = 0, self.BATCH_CHUNK_MIN
            while start < len(configs):
                chunk = configs[start:start + step]
                start += step
                step = min(step * 2, self.BATCH_CHUNK_MAX)
                for key, config in zip(space.config_ids(chunk), chunk):
                    obs = memo_get(key)
                    if obs is None:
                        if (max_s is not None and spent_s >= max_s) or \
                           (max_e is not None and spent_e >= max_e):
                            # sync, then raise through Budget.check so the
                            # exception (and its message) match the scalar
                            # path exactly
                            budget.spent_seconds = spent_s
                            budget.spent_evals = spent_e
                            budget.check()
                        row = index_get(key, -1)
                        if row >= 0:
                            result = records[row]
                            status = result.status
                            value = time_list[row]
                            charge = charge_list[row]
                        else:
                            # outside the recorded set: a failed compile at
                            # the mean charge, like the scalar path (and
                            # the same clear error on an empty cache)
                            if mean_charge is None:
                                mean_charge = self.cache.mean_eval_charge()
                            charge = mean_charge
                            result = CachedResult("error", INVALID, (), charge)
                            status, value = "error", INVALID
                        spent_s += charge
                        spent_e += 1
                        fresh += 1
                        # frozen-dataclass fast construction: __init__ pays
                        # object.__setattr__ per field, which dominates the
                        # commit at replay rates; filling __dict__ directly
                        # builds an identical instance (__eq__/fields/hash
                        # semantics unchanged)
                        obs = new_obs(Observation)
                        obs.__dict__.update(config=config, value=value,
                                            status=status, charge_s=charge,
                                            result=result)
                        memo[key] = obs
                        append((spent_s, value, config))
                    out.append(obs)
        finally:
            budget.spent_seconds = spent_s
            budget.spent_evals = spent_e
            self.fresh_evals = fresh
        return out


class CostModelRunner(Runner):
    def __init__(self, space: SearchSpace, workload: KernelWorkload,
                 device: DeviceModel, budget: Budget):
        super().__init__(space, budget)
        self.workload = workload
        self.device = device

    def _evaluate(self, config: Config) -> CachedResult:
        cid = self.space.config_id(config)
        est = estimate(self.workload, self.space.as_dict(config), self.device, cid)
        return CachedResult(est.status, est.time_s, tuple(est.times_s),
                            est.compile_s, self.device.overhead_s)


class LiveRunner(Runner):
    """Times ``fn(config_dict)``; exceptions are runtime failures."""

    def __init__(self, space: SearchSpace, fn: Callable, budget: Budget,
                 repeats: int = 3):
        super().__init__(space, budget)
        self.fn = fn
        self.repeats = repeats

    def _evaluate(self, config: Config) -> CachedResult:
        d = self.space.as_dict(config)
        t0 = time.perf_counter()
        try:
            self.fn(d)  # warmup/compile
            compile_s = time.perf_counter() - t0
            times = []
            for _ in range(self.repeats):
                t1 = time.perf_counter()
                self.fn(d)
                times.append(time.perf_counter() - t1)
            return CachedResult("ok", sum(times) / len(times), tuple(times),
                                compile_s)
        except Exception:
            # a failed compile/run still cost the measured wall time
            return CachedResult("error", INVALID, (),
                                time.perf_counter() - t0)
