"""Runners: how a strategy's config evaluations are satisfied.

Three runners implement the same protocol (paper Fig. 1 / Sec. III-E):

  * ``SimulationRunner`` — the paper's simulation mode. Replays a T4 cache:
    returns the recorded result and charges the *recorded* compile/run times
    to a simulated-time budget. "From the point of view of the optimization
    algorithm, there is no perceivable difference between live tuning and the
    simulation mode."
  * ``CostModelRunner`` — computes results on the fly from the analytical
    cost model (used to brute-force the hub; identical values to the cache
    since the model is deterministic).
  * ``LiveRunner`` — times an actual callable (used for Pallas interpret-mode
    kernels on CPU, and on-device when real hardware is present).

All runners memoize: re-evaluating a config returns the cached observation and
charges nothing (Kernel Tuner cache semantics; see budget.py).

Observations carry their full ``CachedResult`` detail (raw repeats,
compile/run split), so any runner can be wrapped in a
``core.record.RecordingRunner`` to persist a live run as a replayable cache
— and because the charge is always ``result.charge_s``, the replay's
simulated-time axis matches the recording bit-for-bit.

Every fresh evaluation is appended to ``trace`` as
``(cumulative_simulated_seconds, objective_value, config)`` — the methodology
computes best-so-far performance curves from this.

Runners are single-run state (memo, budget, trace) and are NOT shared across
threads: parallel campaigns (``core.parallel``) construct one runner per
(space, repeat) task — see ``methodology.run_repeat``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

from .budget import Budget, BudgetExhausted
from .cache import CacheFile, CachedResult
from .costmodel import KernelWorkload, estimate
from .devices import DeviceModel
from .searchspace import SearchSpace
from .tunable import Config

INVALID = float("inf")


@dataclasses.dataclass(frozen=True)
class Observation:
    config: Config
    value: float               # objective (mean time_s); inf when failed
    status: str                # "ok" | "error"
    charge_s: float            # simulated seconds charged
    # full T4-style detail (raw repeats, compile/run split) — what a
    # RecordingRunner persists so a live run replays bit-identically
    result: CachedResult | None = None


class Runner:
    """Base: memoization, budget accounting, trace recording."""

    def __init__(self, space: SearchSpace, budget: Budget):
        self.space = space
        self.budget = budget
        self.memo: dict[str, Observation] = {}
        self.trace: list[tuple[float, float, Config]] = []
        self.fresh_evals = 0
        self.wall_start = time.perf_counter()

    # subclasses implement this
    def _evaluate(self, config: Config) -> "CachedResult | tuple[float, str, float]":
        """Returns a full ``CachedResult`` (preferred: recordable and
        replayable with exact time accounting) or a bare
        ``(value, status, charge_seconds)`` tuple for objectives with no
        compile/run split (e.g. the meta level's campaign scores)."""
        raise NotImplementedError

    def run(self, config: Config) -> Observation:
        key = self.space.config_id(config)
        hit = self.memo.get(key)
        if hit is not None:
            return hit
        self.budget.check()  # raises BudgetExhausted when spent
        out = self._evaluate(config)
        if isinstance(out, CachedResult):
            result = out
            value, status, charge = out.time_s, out.status, out.charge_s
        else:
            value, status, charge = out
            # degenerate detail: the whole charge attributed to compile
            result = CachedResult(status, value, (), charge)
        self.budget.charge(charge)
        self.fresh_evals += 1
        obs = Observation(config, value, status, charge, result)
        self.memo[key] = obs
        self.trace.append((self.budget.spent_seconds, value, config))
        return obs

    def __call__(self, config: Config) -> float:
        return self.run(config).value

    @property
    def best(self) -> Observation | None:
        ok = [o for o in self.memo.values() if o.status == "ok"]
        return min(ok, key=lambda o: o.value) if ok else None

    @property
    def wall_seconds(self) -> float:
        return time.perf_counter() - self.wall_start


class SimulationRunner(Runner):
    def __init__(self, cache: CacheFile, budget: Budget):
        super().__init__(cache.space, budget)
        self.cache = cache

    def _evaluate(self, config: Config) -> CachedResult:
        try:
            return self.cache.lookup(config)
        except KeyError:
            # config outside the brute-forced/recorded set: treat as a
            # failed compile costing an average evaluation
            return CachedResult("error", INVALID, (),
                                self.cache.mean_eval_charge())


class CostModelRunner(Runner):
    def __init__(self, space: SearchSpace, workload: KernelWorkload,
                 device: DeviceModel, budget: Budget):
        super().__init__(space, budget)
        self.workload = workload
        self.device = device

    def _evaluate(self, config: Config) -> CachedResult:
        cid = self.space.config_id(config)
        est = estimate(self.workload, self.space.as_dict(config), self.device, cid)
        return CachedResult(est.status, est.time_s, tuple(est.times_s),
                            est.compile_s, self.device.overhead_s)


class LiveRunner(Runner):
    """Times ``fn(config_dict)``; exceptions are runtime failures."""

    def __init__(self, space: SearchSpace, fn: Callable, budget: Budget,
                 repeats: int = 3):
        super().__init__(space, budget)
        self.fn = fn
        self.repeats = repeats

    def _evaluate(self, config: Config) -> CachedResult:
        d = self.space.as_dict(config)
        t0 = time.perf_counter()
        try:
            self.fn(d)  # warmup/compile
            compile_s = time.perf_counter() - t0
            times = []
            for _ in range(self.repeats):
                t1 = time.perf_counter()
                self.fn(d)
                times.append(time.perf_counter() - t1)
            return CachedResult("ok", sum(times) / len(times), tuple(times),
                                compile_s)
        except Exception:
            # a failed compile/run still cost the measured wall time
            return CachedResult("error", INVALID, (),
                                time.perf_counter() - t0)
