"""Runners: how a strategy's config evaluations are satisfied.

Three runners implement the same protocol (paper Fig. 1 / Sec. III-E):

  * ``SimulationRunner`` — the paper's simulation mode. Replays a T4 cache:
    returns the recorded result and charges the *recorded* compile/run times
    to a simulated-time budget. "From the point of view of the optimization
    algorithm, there is no perceivable difference between live tuning and the
    simulation mode."
  * ``CostModelRunner`` — computes results on the fly from the analytical
    cost model (used to brute-force the hub; identical values to the cache
    since the model is deterministic).
  * ``LiveRunner`` — times an actual callable (used for Pallas interpret-mode
    kernels on CPU, and on-device when real hardware is present).

All runners memoize: re-evaluating a config returns the cached observation and
charges nothing (Kernel Tuner cache semantics; see budget.py).

Observations carry their full ``CachedResult`` detail (raw repeats,
compile/run split), so any runner can be wrapped in a
``core.record.RecordingRunner`` to persist a live run as a replayable cache
— and because the charge is always ``result.charge_s``, the replay's
simulated-time axis matches the recording bit-for-bit.

Every fresh evaluation is appended to ``trace`` as
``(cumulative_simulated_seconds, objective_value, config)`` — the methodology
computes best-so-far performance curves from this.

Batch evaluation (the ``BatchRunner`` protocol): every runner answers
``run_batch(configs)`` — bit-identical to calling ``run`` in a loop, same
memoization, budget accounting, trace order, and ``BudgetExhausted`` point.
The base implementation *is* that loop (the scalar reference path);
``SimulationRunner`` overrides it to resolve the whole batch through the
cache's columnar view (``cache.CacheColumns``) in one vectorized gather, so
population strategies can evaluate an entire generation per call.

Runners are single-run state (memo, budget, trace) and are NOT shared across
threads: parallel campaigns (``core.parallel``) construct one runner per
(space, repeat) task — see ``methodology.run_repeat``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Protocol, Sequence, runtime_checkable

import numpy as np

from .budget import Budget, BudgetExhausted
from .cache import CacheFile, CachedResult
from .costmodel import KernelWorkload, estimate
from .devices import DeviceModel
from .searchspace import SearchSpace
from .tunable import Config

INVALID = float("inf")


@dataclasses.dataclass(frozen=True)
class Observation:
    config: Config
    value: float               # objective (mean time_s); inf when failed
    status: str                # "ok" | "error"
    charge_s: float            # simulated seconds charged
    # full T4-style detail (raw repeats, compile/run split) — what a
    # RecordingRunner persists so a live run replays bit-identically
    result: CachedResult | None = None


@runtime_checkable
class BatchRunner(Protocol):
    """Anything a strategy can hand a whole generation of configs to.

    Contract: ``run_batch(configs)`` is observably identical to
    ``[run(c) for c in configs]`` — same evaluation order, same memo
    hits, same budget charges and trace entries, and ``BudgetExhausted``
    raised at exactly the same element (results for earlier elements stay
    committed to memo/trace). Implementations are free to *resolve* the
    batch however they like (``SimulationRunner`` gathers it from columnar
    arrays in one shot) as long as the observable sequence matches.
    """

    def run(self, config: Config) -> Observation: ...

    def run_batch(self, configs: Sequence[Config]) -> list[Observation]: ...


class Runner:
    """Base: memoization, budget accounting, trace recording."""

    def __init__(self, space: SearchSpace, budget: Budget):
        self.space = space
        self.budget = budget
        self.memo: dict[str, Observation] = {}
        self.trace: list[tuple[float, float, Config]] = []
        self.fresh_evals = 0
        self.wall_start = time.perf_counter()

    # subclasses implement this
    def _evaluate(self, config: Config) -> "CachedResult | tuple[float, str, float]":
        """Returns a full ``CachedResult`` (preferred: recordable and
        replayable with exact time accounting) or a bare
        ``(value, status, charge_seconds)`` tuple for objectives with no
        compile/run split (e.g. the meta level's campaign scores)."""
        raise NotImplementedError

    def _evaluate_keyed(self, key: str,
                        config: Config) -> tuple[CachedResult, float, str, float]:
        """``(result, value, status, charge)`` for one fresh evaluation.

        The key (already computed by ``run``/``run_batch`` for memoization)
        is passed down so lookup-style runners need not re-derive it.
        """
        out = self._evaluate(config)
        if isinstance(out, CachedResult):
            return out, out.time_s, out.status, out.charge_s
        value, status, charge = out
        # degenerate detail: the whole charge attributed to compile
        return CachedResult(status, value, (), charge), value, status, charge

    def _commit(self, key: str, config: Config, result: CachedResult,
                value: float, status: str, charge: float) -> Observation:
        """Account one fresh evaluation (budget, memo, trace) — the single
        bookkeeping path shared by ``run`` and ``run_batch``."""
        self.budget.charge(charge)
        self.fresh_evals += 1
        obs = Observation(config, value, status, charge, result)
        self.memo[key] = obs
        self.trace.append((self.budget.spent_seconds, value, config))
        return obs

    def run(self, config: Config) -> Observation:
        key = self.space.config_id(config)
        hit = self.memo.get(key)
        if hit is not None:
            return hit
        self.budget.check()  # raises BudgetExhausted when spent
        return self._commit(key, config, *self._evaluate_keyed(key, config))

    def run_batch(self, configs: Sequence[Config]) -> list[Observation]:
        """Evaluate ``configs`` in order (the scalar reference loop).

        See ``BatchRunner``: subclasses that override this must preserve
        loop-of-``run`` observable behaviour exactly.
        """
        return [self.run(c) for c in configs]

    def __call__(self, config: Config) -> float:
        return self.run(config).value

    # ------------------------------------------------------ suspend / resume
    def state_dict(self) -> dict:
        """Picklable snapshot of the observable run state (memo, trace,
        budget spend, fresh-eval count) — what a ``core.driver`` checkpoint
        persists alongside the strategy's ``SearchState``."""
        return {"memo": dict(self.memo), "trace": list(self.trace),
                "fresh_evals": self.fresh_evals,
                "spent_seconds": self.budget.spent_seconds,
                "spent_evals": self.budget.spent_evals}

    def load_state_dict(self, d: dict) -> None:
        """Restore a ``state_dict`` snapshot onto this (freshly built)
        runner; budget *limits* stay as constructed, only the spend is
        restored."""
        self.memo = dict(d["memo"])
        self.trace = list(d["trace"])
        self.fresh_evals = int(d["fresh_evals"])
        self.budget.spent_seconds = float(d["spent_seconds"])
        self.budget.spent_evals = int(d["spent_evals"])

    @property
    def best(self) -> Observation | None:
        ok = [o for o in self.memo.values() if o.status == "ok"]
        return min(ok, key=lambda o: o.value) if ok else None

    @property
    def wall_seconds(self) -> float:
        return time.perf_counter() - self.wall_start


class SimulationRunner(Runner):
    """Replays a T4 cache; the engine behind every simulated campaign.

    ``columnar=True`` (the default) resolves evaluations through the
    cache's array-backed view: single evaluations skip the results-dict hop
    and the per-visit ``charge_s`` re-summation, and ``run_batch`` gathers
    a whole generation's values/charges in one fancy-indexed numpy read.
    ``columnar=False`` keeps the original scalar dict path — the reference
    the parity suite and the regression benchmark compare against. Both
    paths are bit-identical by construction (the columns are built with the
    scalar path's own fixed-order reductions).
    """

    def __init__(self, cache: CacheFile, budget: Budget,
                 columnar: bool = True):
        super().__init__(cache.space, budget)
        self.cache = cache
        self.columnar = columnar

    def _evaluate(self, config: Config) -> CachedResult:
        try:
            return self.cache.lookup(config)
        except KeyError:
            # config outside the brute-forced/recorded set: treat as a
            # failed compile costing an average evaluation
            return CachedResult("error", INVALID, (),
                                self.cache.mean_eval_charge())

    def _evaluate_keyed(self, key: str,
                        config: Config) -> tuple[CachedResult, float, str, float]:
        if not self.columnar:
            return super()._evaluate_keyed(key, config)
        cols = self.cache.columns
        row = cols.index.get(key, -1)
        if row < 0:
            # mean_eval_charge (not cols.mean_charge) so an empty cache
            # raises its clear "record the space first" error, not a
            # ZeroDivisionError
            charge = self.cache.mean_eval_charge()
            return CachedResult("error", INVALID, (), charge), \
                INVALID, "error", charge
        result = cols.records[row]
        # result.time_s/status are the authoritative Python scalars; the
        # charge comes from the precomputed column (same value, no re-sum)
        return result, result.time_s, result.status, cols.charge_list[row]

    def _fused_state(self) -> tuple:
        """Per-runner row-indexed mirrors of the memo for ``run_fused``:
        ``(seen, obs_by_row)`` boolean/object arrays over the cache's rows.
        Rebuilt whenever the memo changed outside a fused call (tracked by
        length — the memo only ever grows) or the columnar view was
        invalidated, so mixed ``run_batch``/fused usage stays coherent."""
        cols = self.cache.columns
        st = getattr(self, "_fused", None)
        if (st is None or st[2] is not cols
                or len(self.memo) != getattr(self, "_fused_memo_len", -1)):
            seen = np.zeros(len(cols), dtype=bool)
            obs_by_row = np.empty(len(cols), dtype=object)
            index_get = cols.index.get
            for key, obs in self.memo.items():
                row = index_get(key, -1)
                if row >= 0:
                    seen[row] = True
                    obs_by_row[row] = obs
            st = (seen, obs_by_row, cols)
            self._fused = st
            self._fused_memo_len = len(self.memo)
        return st

    # gather granularity: a strategy may hand over far more configs than the
    # budget allows (random search batches the whole space permutation);
    # chunks grow geometrically so a budget-capped run wastes at most one
    # small chunk of key work past the exhaustion point, while full-space
    # replays still amortize into large chunks
    BATCH_CHUNK_MIN = 64
    BATCH_CHUNK_MAX = 2048

    def run_batch(self, configs: Sequence[Config]) -> list[Observation]:
        if not self.columnar:
            return super().run_batch(configs)
        cols = self.cache.columns
        space = self.space
        memo = self.memo
        budget = self.budget
        trace = self.trace
        records = cols.records
        time_list, charge_list = cols.time_list, cols.charge_list
        index_get = cols.index.get
        memo_get = memo.get
        append = trace.append
        new_obs = Observation.__new__
        out: list[Observation] = []
        # budget accounting is mirrored in locals (same left-to-right float
        # accumulation as Budget.charge, minus per-eval attribute churn) and
        # synced back even when BudgetExhausted aborts the batch mid-way
        max_s, max_e = budget.max_seconds, budget.max_evals
        spent_s, spent_e = budget.spent_seconds, budget.spent_evals
        fresh = self.fresh_evals
        mean_charge: float | None = None
        try:
            start, step = 0, self.BATCH_CHUNK_MIN
            while start < len(configs):
                chunk = configs[start:start + step]
                start += step
                step = min(step * 2, self.BATCH_CHUNK_MAX)
                for key, config in zip(space.config_ids(chunk), chunk):
                    obs = memo_get(key)
                    if obs is None:
                        if (max_s is not None and spent_s >= max_s) or \
                           (max_e is not None and spent_e >= max_e):
                            # sync, then raise through Budget.check so the
                            # exception (and its message) match the scalar
                            # path exactly
                            budget.spent_seconds = spent_s
                            budget.spent_evals = spent_e
                            budget.check()
                        row = index_get(key, -1)
                        if row >= 0:
                            result = records[row]
                            status = result.status
                            value = time_list[row]
                            charge = charge_list[row]
                        else:
                            # outside the recorded set: a failed compile at
                            # the mean charge, like the scalar path (and
                            # the same clear error on an empty cache)
                            if mean_charge is None:
                                mean_charge = self.cache.mean_eval_charge()
                            charge = mean_charge
                            result = CachedResult("error", INVALID, (), charge)
                            status, value = "error", INVALID
                        spent_s += charge
                        spent_e += 1
                        fresh += 1
                        # frozen-dataclass fast construction: __init__ pays
                        # object.__setattr__ per field, which dominates the
                        # commit at replay rates; filling __dict__ directly
                        # builds an identical instance (__eq__/fields/hash
                        # semantics unchanged)
                        obs = new_obs(Observation)
                        obs.__dict__.update(config=config, value=value,
                                            status=status, charge_s=charge,
                                            result=result)
                        memo[key] = obs
                        append((spent_s, value, config))
                    out.append(obs)
        finally:
            budget.spent_seconds = spent_s
            budget.spent_evals = spent_e
            self.fresh_evals = fresh
        return out


# one fused gather's key budget: cross-run generation batches (a few dozen
# runs x a population each) fit comfortably; a whole-space ask replicated
# across many runs would precompute millions of keys that a budget-capped
# run never reaches, so oversized fusions fall back to the per-runner
# chunked path (observably identical either way)
FUSED_KEY_MAX = 8192


def _run_fused_fallback(batches: "Sequence[tuple[Runner, Sequence[Config]]]"
                        ) -> list:
    out: list = []
    for runner, configs in batches:
        try:
            out.append(runner.run_batch(configs))
        except BudgetExhausted as e:
            out.append(e)
    return out


# below this segment size the vectorized per-segment commit loses to plain
# bytecode: numpy's per-call overhead (~1-2us x ~14 calls) outweighs the
# per-evaluation savings for population-sized asks
FUSED_VECTOR_MIN_SEG = 64


def _commit_segment_loop(runner: "SimulationRunner", configs, seg_keys,
                         cols) -> "list[Observation] | BudgetExhausted":
    """One runner's segment through the tight scalar commit loop — the
    body of ``SimulationRunner.run_batch`` minus per-call key computation
    and chunking (keys arrive precomputed from the fused batch)."""
    memo = runner.memo
    memo_get = memo.get
    budget = runner.budget
    append = runner.trace.append
    records = cols.records
    time_list, charge_list = cols.time_list, cols.charge_list
    index_get = cols.index.get
    new_obs = Observation.__new__
    # budget mirror: same left-to-right float accumulation as Budget.charge,
    # synced back even when BudgetExhausted aborts the segment mid-way
    max_s, max_e = budget.max_seconds, budget.max_evals
    spent_s, spent_e = budget.spent_seconds, budget.spent_evals
    fresh = runner.fresh_evals
    mean_charge: float | None = None
    obs_list: list[Observation] = []
    out_append = obs_list.append
    result: object = obs_list
    try:
        for key, config in zip(seg_keys, configs):
            obs = memo_get(key)
            if obs is None:
                if (max_s is not None and spent_s >= max_s) or \
                   (max_e is not None and spent_e >= max_e):
                    budget.spent_seconds = spent_s
                    budget.spent_evals = spent_e
                    budget.check()
                row = index_get(key, -1)
                if row >= 0:
                    rec = records[row]
                    status = rec.status
                    value = time_list[row]
                    charge = charge_list[row]
                else:
                    # outside the recorded set: a failed compile at the
                    # mean charge, exactly like run_batch
                    if mean_charge is None:
                        mean_charge = runner.cache.mean_eval_charge()
                    charge = mean_charge
                    rec = CachedResult("error", INVALID, (), charge)
                    status, value = "error", INVALID
                spent_s += charge
                spent_e += 1
                fresh += 1
                obs = new_obs(Observation)
                obs.__dict__.update(config=config, value=value,
                                    status=status, charge_s=charge,
                                    result=rec)
                memo[key] = obs
                append((spent_s, value, config))
            out_append(obs)
    except BudgetExhausted as e:
        result = e
    finally:
        budget.spent_seconds = spent_s
        budget.spent_evals = spent_e
        runner.fresh_evals = fresh
    return result


def _commit_segment_vectorized(runner: "SimulationRunner", configs, seg_keys,
                               cols) -> "list[Observation] | BudgetExhausted":
    """One runner's large segment as whole-array operations: row gather,
    bitmap freshness (within-segment first occurrence x rows this runner
    has already evaluated), a cumulative-sum budget seeded with the exact
    running spend (the same left-to-right float additions as the scalar
    loop, so exhaustion points and trace times match to the last bit), and
    bulk zip-built trace extension. Only fresh evaluations construct
    Observations in Python; revisits gather from the runner's row-indexed
    object array."""
    index_get = cols.index.get
    n = len(configs)
    rows = np.fromiter((index_get(k, -1) for k in seg_keys),
                       dtype=np.int64, count=n)
    if rows.min() < 0:
        # out-of-recorded-set configs take the keyed imputed-miss path
        return _commit_segment_loop(runner, configs, seg_keys, cols)
    seen_rows, obs_by_row, _ = runner._fused_state()
    order = np.argsort(rows, kind="stable")
    sorted_rows = rows[order]
    first_sorted = np.empty(n, dtype=bool)
    first_sorted[:1] = True
    first_sorted[1:] = sorted_rows[1:] != sorted_rows[:-1]
    first_occ = np.empty(n, dtype=bool)
    first_occ[order] = first_sorted
    fresh_idx = np.nonzero(first_occ & ~seen_rows[rows])[0]
    n_fresh = len(fresh_idx)
    budget = runner.budget
    max_s, max_e = budget.max_seconds, budget.max_evals
    cut = n_fresh
    run_cs = None
    if n_fresh:
        fresh_rows = rows[fresh_idx]
        # seeded sequential cumsum: run_cs[j] is bit-identical to the
        # scalar loop's spend after j fresh evaluations
        run_cs = np.empty(n_fresh + 1, dtype=np.float64)
        run_cs[0] = budget.spent_seconds
        run_cs[1:] = cols.charge_s[fresh_rows]
        np.cumsum(run_cs, out=run_cs)
        if max_s is not None:
            # exhaustion raises at the first fresh attempt whose spend-so-
            # far already reaches the cap; run_cs[:-1] is non-decreasing
            cut = min(cut, int(np.searchsorted(run_cs[:n_fresh], max_s,
                                               side="left")))
        if max_e is not None:
            cut = min(cut, max(0, max_e - budget.spent_evals))
    exhausted = cut < n_fresh
    if cut:
        acc = fresh_idx[:cut]
        acc_rows = rows[acc]
        seen_rows[acc_rows] = True
        vals = cols.time_s[acc_rows].tolist()
        chgs = cols.charge_s[acc_rows].tolist()
        cfgs_acc = [configs[j] for j in acc.tolist()]
        records = cols.records
        new_obs = Observation.__new__
        memo = runner.memo
        obs_acc = []
        for j, row, cfg, value, charge in zip(acc.tolist(),
                                              acc_rows.tolist(),
                                              cfgs_acc, vals, chgs):
            rec = records[row]
            obs = new_obs(Observation)
            obs.__dict__.update(config=cfg, value=value, status=rec.status,
                                charge_s=charge, result=rec)
            obs_acc.append(obs)
            memo[seg_keys[j]] = obs
        obs_by_row[acc_rows] = obs_acc
        runner.trace.extend(zip(run_cs[1:cut + 1].tolist(), vals, cfgs_acc))
        budget.spent_seconds = float(run_cs[cut])
        budget.spent_evals += cut
        runner.fresh_evals += cut
        runner._fused_memo_len = len(memo)
    if exhausted:
        try:
            budget.check()  # same exception/message as the scalar path
        except BudgetExhausted as exc:
            return exc
    return obs_by_row[rows].tolist()


def run_fused(batches: "Sequence[tuple[Runner, Sequence[Config]]]"
              ) -> list:
    """Resolve several runners' batches in one shared gather.

    ``batches`` is ``[(runner, configs), ...]`` — one entry per concurrent
    tuning run (see ``driver.drive_many``). Returns one element per entry:
    the ``list[Observation]`` that ``runner.run_batch(configs)`` would have
    returned, or the ``BudgetExhausted`` it would have raised (with the
    runner's committed state — memo, trace, budget — identical in both
    cases, partial results included).

    When every runner is a columnar ``SimulationRunner`` over the *same*
    cache, the fusion computes config ids for the whole concatenation in
    one batched call and commits per runner without any per-run
    ``run_batch`` call overhead — population-sized segments through a
    tight scalar loop, large segments (``FUSED_VECTOR_MIN_SEG``+) through
    whole-array commits (``_commit_segment_vectorized``). Runners are
    independent (own memo/budget/trace), so per-runner observable order is
    preserved exactly; anything non-fusable falls back to per-runner
    ``run_batch`` calls (observably identical either way).
    """
    if not batches:
        return []
    first = batches[0][0]
    fusable = isinstance(first, SimulationRunner) and first.columnar
    if fusable:
        cache = first.cache
        fusable = all(isinstance(r, SimulationRunner) and r.columnar
                      and r.cache is cache for r, _ in batches)
    total = 0
    for _, configs in batches:
        total += len(configs)
    if not fusable or total == 0 or total > FUSED_KEY_MAX:
        return _run_fused_fallback(batches)
    space = first.space
    cols = first.cache.columns
    all_cfgs: list = []
    for _, configs in batches:
        all_cfgs.extend(configs)
    keys = space.config_ids(all_cfgs)
    out: list = []
    pos = 0
    for runner, configs in batches:
        seg_keys = keys[pos:pos + len(configs)]
        pos += len(configs)
        commit = (_commit_segment_vectorized
                  if len(configs) >= FUSED_VECTOR_MIN_SEG
                  else _commit_segment_loop)
        out.append(commit(runner, configs, seg_keys, cols))
    return out


class CostModelRunner(Runner):
    def __init__(self, space: SearchSpace, workload: KernelWorkload,
                 device: DeviceModel, budget: Budget):
        super().__init__(space, budget)
        self.workload = workload
        self.device = device

    def _evaluate(self, config: Config) -> CachedResult:
        cid = self.space.config_id(config)
        est = estimate(self.workload, self.space.as_dict(config), self.device, cid)
        return CachedResult(est.status, est.time_s, tuple(est.times_s),
                            est.compile_s, self.device.overhead_s)


class LiveRunner(Runner):
    """Times ``fn(config_dict)``; exceptions are runtime failures."""

    def __init__(self, space: SearchSpace, fn: Callable, budget: Budget,
                 repeats: int = 3):
        super().__init__(space, budget)
        self.fn = fn
        self.repeats = repeats

    def _evaluate(self, config: Config) -> CachedResult:
        d = self.space.as_dict(config)
        t0 = time.perf_counter()
        try:
            self.fn(d)  # warmup/compile
            compile_s = time.perf_counter() - t0
            times = []
            for _ in range(self.repeats):
                t1 = time.perf_counter()
                self.fn(d)
                times.append(time.perf_counter() - t1)
            return CachedResult("ok", sum(times) / len(times), tuple(times),
                                compile_s)
        except Exception:
            # a failed compile/run still cost the measured wall time
            return CachedResult("error", INVALID, (),
                                time.perf_counter() - t0)
