"""Runners: how a strategy's config evaluations are satisfied.

Three runners implement the same protocol (paper Fig. 1 / Sec. III-E):

  * ``SimulationRunner`` — the paper's simulation mode. Replays a T4 cache:
    returns the recorded result and charges the *recorded* compile/run times
    to a simulated-time budget. "From the point of view of the optimization
    algorithm, there is no perceivable difference between live tuning and the
    simulation mode."
  * ``CostModelRunner`` — computes results on the fly from the analytical
    cost model (used to brute-force the hub; identical values to the cache
    since the model is deterministic).
  * ``LiveRunner`` — times an actual callable (used for Pallas interpret-mode
    kernels on CPU, and on-device when real hardware is present).

All runners memoize: re-evaluating a config returns the cached observation and
charges nothing (Kernel Tuner cache semantics; see budget.py).

Observations carry their full ``CachedResult`` detail (raw repeats,
compile/run split), so any runner can be wrapped in a
``core.record.RecordingRunner`` to persist a live run as a replayable cache
— and because the charge is always ``result.charge_s``, the replay's
simulated-time axis matches the recording bit-for-bit.

Every fresh evaluation is appended to ``trace`` as
``(cumulative_simulated_seconds, objective_value, config)`` — the methodology
computes best-so-far performance curves from this.

Batch evaluation (the ``BatchRunner`` protocol): every runner answers
``run_batch(configs)`` — bit-identical to calling ``run`` in a loop, same
memoization, budget accounting, trace order, and ``BudgetExhausted`` point.
The base implementation *is* that loop (the scalar reference path);
``SimulationRunner`` overrides it to resolve the whole batch through the
cache's columnar view (``cache.CacheColumns``) in one vectorized gather, so
population strategies can evaluate an entire generation per call.

Index-native batches: strategies ask in ``core.space.RowBatch`` form —
integer rows of the compiled space instead of value tuples. A columnar
``SimulationRunner`` resolves those by pure row indexing (``_run_rows``:
row -> cache column via ``CacheColumns.rows_for_space``, O(1) gathers, no
tuple hashing or string-id probes); every other runner just iterates the
batch and receives ordinary value tuples. Config-id strings and value
tuples materialize only on *fresh* commits — the memo/trace/recording
boundary.

Runners are single-run state (memo, budget, trace) and are NOT shared across
threads: parallel campaigns (``core.parallel``) construct one runner per
(space, repeat) task — see ``methodology.run_repeat``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Protocol, Sequence, runtime_checkable

import numpy as np

from .budget import Budget, BudgetExhausted
from .cache import CacheFile, CachedResult
from .costmodel import KernelWorkload, estimate
from .devices import DeviceModel
from .searchspace import SearchSpace
from .space import RowBatch
from .tunable import Config

INVALID = float("inf")


@dataclasses.dataclass(frozen=True)
class Observation:
    config: Config
    value: float               # objective (mean time_s); inf when failed
    status: str                # "ok" | "error"
    charge_s: float            # simulated seconds charged
    # full T4-style detail (raw repeats, compile/run split) — what a
    # RecordingRunner persists so a live run replays bit-identically
    result: CachedResult | None = None


@runtime_checkable
class BatchRunner(Protocol):
    """Anything a strategy can hand a whole generation of configs to.

    Contract: ``run_batch(configs)`` is observably identical to
    ``[run(c) for c in configs]`` — same evaluation order, same memo
    hits, same budget charges and trace entries, and ``BudgetExhausted``
    raised at exactly the same element (results for earlier elements stay
    committed to memo/trace). Implementations are free to *resolve* the
    batch however they like (``SimulationRunner`` gathers it from columnar
    arrays in one shot) as long as the observable sequence matches.
    """

    def run(self, config: Config) -> Observation: ...

    def run_batch(self, configs: Sequence[Config]) -> list[Observation]: ...


class Runner:
    """Base: memoization, budget accounting, trace recording."""

    def __init__(self, space: SearchSpace, budget: Budget):
        self.space = space
        self.budget = budget
        self.memo: dict[str, Observation] = {}
        self.trace: list[tuple[float, float, Config]] = []
        self.fresh_evals = 0
        self.wall_start = time.perf_counter()
        # row-native mirror of the memo (SimulationRunner fast path);
        # declared here so load_state_dict can invalidate it uniformly
        self._rows_st: tuple | None = None
        self._rows_memo_len = -1

    # subclasses implement this
    def _evaluate(self, config: Config) -> "CachedResult | tuple[float, str, float]":
        """Returns a full ``CachedResult`` (preferred: recordable and
        replayable with exact time accounting) or a bare
        ``(value, status, charge_seconds)`` tuple for objectives with no
        compile/run split (e.g. the meta level's campaign scores)."""
        raise NotImplementedError

    def _evaluate_keyed(self, key: str,
                        config: Config) -> tuple[CachedResult, float, str, float]:
        """``(result, value, status, charge)`` for one fresh evaluation.

        The key (already computed by ``run``/``run_batch`` for memoization)
        is passed down so lookup-style runners need not re-derive it.
        """
        out = self._evaluate(config)
        if isinstance(out, CachedResult):
            return out, out.time_s, out.status, out.charge_s
        value, status, charge = out
        # degenerate detail: the whole charge attributed to compile
        return CachedResult(status, value, (), charge), value, status, charge

    def _commit(self, key: str, config: Config, result: CachedResult,
                value: float, status: str, charge: float) -> Observation:
        """Account one fresh evaluation (budget, memo, trace) — the single
        bookkeeping path shared by ``run`` and ``run_batch``."""
        self.budget.charge(charge)
        self.fresh_evals += 1
        obs = Observation(config, value, status, charge, result)
        self.memo[key] = obs
        self.trace.append((self.budget.spent_seconds, value, config))
        return obs

    def run(self, config: Config) -> Observation:
        key = self.space.config_id(config)
        hit = self.memo.get(key)
        if hit is not None:
            return hit
        self.budget.check()  # raises BudgetExhausted when spent
        return self._commit(key, config, *self._evaluate_keyed(key, config))

    def run_batch(self, configs: Sequence[Config]) -> list[Observation]:
        """Evaluate ``configs`` in order (the scalar reference loop).

        See ``BatchRunner``: subclasses that override this must preserve
        loop-of-``run`` observable behaviour exactly.
        """
        return [self.run(c) for c in configs]

    def __call__(self, config: Config) -> float:
        return self.run(config).value

    # ------------------------------------------------------ suspend / resume
    def state_dict(self) -> dict:
        """Picklable snapshot of the observable run state (memo, trace,
        budget spend, fresh-eval count) — what a ``core.driver`` checkpoint
        persists alongside the strategy's ``SearchState``."""
        return {"memo": dict(self.memo), "trace": list(self.trace),
                "fresh_evals": self.fresh_evals,
                "spent_seconds": self.budget.spent_seconds,
                "spent_evals": self.budget.spent_evals}

    def load_state_dict(self, d: dict) -> None:
        """Restore a ``state_dict`` snapshot onto this (freshly built)
        runner; budget *limits* stay as constructed, only the spend is
        restored."""
        self.memo = dict(d["memo"])
        self.trace = list(d["trace"])
        self.fresh_evals = int(d["fresh_evals"])
        self.budget.spent_seconds = float(d["spent_seconds"])
        self.budget.spent_evals = int(d["spent_evals"])
        # the restored memo is a different dict (possibly of the same
        # length); a length check alone cannot catch that
        self._rows_st = None

    @property
    def best(self) -> Observation | None:
        ok = [o for o in self.memo.values() if o.status == "ok"]
        return min(ok, key=lambda o: o.value) if ok else None

    @property
    def wall_seconds(self) -> float:
        return time.perf_counter() - self.wall_start


class SimulationRunner(Runner):
    """Replays a T4 cache; the engine behind every simulated campaign.

    ``columnar=True`` (the default) resolves evaluations through the
    cache's array-backed view: single evaluations skip the results-dict hop
    and the per-visit ``charge_s`` re-summation, and ``run_batch`` gathers
    a whole generation's values/charges in one fancy-indexed numpy read.
    ``columnar=False`` keeps the original scalar dict path — the reference
    the parity suite and the regression benchmark compare against. Both
    paths are bit-identical by construction (the columns are built with the
    scalar path's own fixed-order reductions).

    ``engine`` names the row-resolution backend explicitly: ``"numpy"``
    (alias ``"vectorized"``; == ``columnar=True``), ``"scalar"``
    (== ``columnar=False``), or ``"jax"`` — the jitted device path of
    ``core.engine_jax``, whose replay-from-log commits are bit-identical to
    the numpy engine (tests/test_engine_jax.py). When jax or a usable
    backend is missing, ``"jax"`` degrades to the numpy path transparently
    — safe precisely because the two are bit-identical, so a process-pool
    worker without an accelerator produces the same campaign.
    """

    ENGINES = ("numpy", "scalar", "jax")

    def __init__(self, cache: CacheFile, budget: Budget,
                 columnar: bool = True, engine: "str | None" = None):
        if engine is None:
            engine = "numpy" if columnar else "scalar"
        elif engine == "vectorized":
            engine = "numpy"
        if engine not in self.ENGINES:
            raise ValueError(f"unknown engine {engine!r}; "
                             f"expected one of {self.ENGINES}")
        super().__init__(cache.space, budget)
        self.cache = cache
        self.columnar = engine != "scalar"
        self.engine = engine
        self._jax_eng: object = None  # lazy ReplayEngine / False once probed

    def _jax_engine(self):
        """The bound ``engine_jax.ReplayEngine``, or None when jax cannot
        dispatch (import failure, no backend) — callers then fall through
        to the bit-identical numpy path."""
        eng = self._jax_eng
        if eng is None:
            from . import engine_jax
            eng = self._jax_eng = (engine_jax.ReplayEngine(self)
                                   if engine_jax.engine_available()
                                   else False)
        return eng or None

    def __getstate__(self) -> dict:
        """Drop the probed engine handle: it captures whether *this*
        process can dispatch jax (and, once bound, a jax-importing
        ``ReplayEngine``), so a pickled runner must re-probe in the
        receiving process — which may have a different backend."""
        return {**self.__dict__, "_jax_eng": None}

    def _evaluate(self, config: Config) -> CachedResult:
        try:
            return self.cache.lookup(config)
        except KeyError:
            # config outside the brute-forced/recorded set: treat as a
            # failed compile costing an average evaluation
            return CachedResult("error", INVALID, (),
                                self.cache.mean_eval_charge())

    def _evaluate_keyed(self, key: str,
                        config: Config) -> tuple[CachedResult, float, str, float]:
        if not self.columnar:
            return super()._evaluate_keyed(key, config)
        cols = self.cache.columns
        row = cols.index.get(key, -1)
        if row < 0:
            # mean_eval_charge (not cols.mean_charge) so an empty cache
            # raises its clear "record the space first" error, not a
            # ZeroDivisionError
            charge = self.cache.mean_eval_charge()
            return CachedResult("error", INVALID, (), charge), \
                INVALID, "error", charge
        result = cols.records[row]
        # result.time_s/status are the authoritative Python scalars; the
        # charge comes from the precomputed column (same value, no re-sum)
        return result, result.time_s, result.status, cols.charge_list[row]

    # ------------------------------------------------------- row-native path
    def _row_state(self) -> tuple:
        """Row-indexed mirrors of the run state for the index-native path:
        ``(seen, obs_by_row, col_of_row, col_list, cols)`` over the
        *space's* valid rows (``space.compiled``). ``col_of_row`` bridges
        space rows to cache-column rows (built once per columns view at the
        string boundary; -1 = not recorded). Rebuilt whenever the memo
        changed outside this path (tracked by length — the memo only grows
        — plus an explicit reset in ``load_state_dict``) or the columnar
        view was invalidated, so mixed scalar/keyed/row usage stays
        coherent."""
        cols = self.cache.columns
        st = self._rows_st
        if (st is None or st[4] is not cols
                or len(self.memo) != self._rows_memo_len):
            cs = self.space.compiled
            seen = np.zeros(cs.n_valid, dtype=bool)
            # a plain list, not an object ndarray: int indexing is ~2x
            # cheaper and it is probed once per evaluation
            obs_by_row: list = [None] * cs.n_valid
            if self.memo:
                # re-seed from the memo (resume, or keyed/scalar calls in
                # between); keys outside the space's rows stay keyed-only
                row_get = cs.id_to_row.get
                for key, obs in self.memo.items():
                    row = row_get(key, -1)
                    if row >= 0:
                        seen[row] = True
                        obs_by_row[row] = obs
            col_of_row = cols.rows_for_space(cs)
            st = (seen, obs_by_row, col_of_row,
                  cols.rows_for_space_list(cs), cols)
            self._rows_st = st
            self._rows_memo_len = len(self.memo)
        return st

    # below this batch size the whole-array commit loses to plain bytecode:
    # numpy's per-call overhead (argsort/cumsum/fancy gathers) outweighs
    # the per-evaluation savings for population- and neighborhood-sized
    # asks (measured crossover ~64, same as the old keyed path)
    ROWS_VECTOR_MIN = 64
    # chunk bounds for oversized row asks (see _run_rows)
    ROWS_CHUNK_MIN = 512
    ROWS_CHUNK_MAX = 4096

    def _run_rows(self, rows) -> "list[Observation] | BudgetExhausted":
        """Resolve a batch of space rows (any int sequence); returns the
        observation list or the ``BudgetExhausted`` the equivalent ``run``
        loop would have raised (committed state identical either way)."""
        n = len(rows)
        if n == 0:
            return []
        if self.engine == "jax":
            eng = self._jax_engine()
            if eng is not None:
                # every batch with a fresh row dispatches on the device
                # (single rows included — uniform coverage for the parity
                # suite); fully-memoized batches short-circuit inside
                return eng.commit_rows(rows)
        if n == 1:
            # the single-move shape (simulated annealing, basin hopping,
            # the thread bridge): skip every batch prologue
            st = self._row_state()
            r = rows[0]
            obs = st[1][r]
            return [obs] if obs is not None else self._commit_row(r, st)
        if n <= 256 and self.memo:
            # revisit fast path: local searches re-ask mostly-seen configs
            # (single moves, whole neighborhoods); a fully memoized batch
            # needs no budget/trace work at all — just the row gather.
            # Fresh runners (empty memo) and huge asks (a whole-space
            # permutation) skip the speculative gather — nothing can hit,
            # or the vectorized commit's zero-fresh path handles it in
            # whole-array ops.
            obs_by_row = self._row_state()[1]
            out = [obs_by_row[r] for r in
                   (rows.tolist() if isinstance(rows, np.ndarray)
                    else rows)]
            if None not in out:
                return out
        if n >= self.ROWS_VECTOR_MIN:
            if n <= self.ROWS_CHUNK_MIN:
                return self._commit_rows_vectorized(rows)
            # geometric chunking, like the keyed path: a strategy may hand
            # over far more rows than the budget allows (random search
            # batches the whole space permutation); whole-array commits on
            # rows past the exhaustion point would be pure waste
            arr = np.asarray(rows, dtype=np.int64)
            out: list[Observation] = []
            start, step = 0, self.ROWS_CHUNK_MIN
            while start < n:
                res = self._commit_rows_vectorized(arr[start:start + step])
                if isinstance(res, BudgetExhausted):
                    return res
                out.extend(res)
                start += step
                step = min(step * 2, self.ROWS_CHUNK_MAX)
            return out
        return self._commit_rows_loop(rows)

    def _commit_row(self, r, st) -> "list[Observation] | BudgetExhausted":
        """Commit one fresh row — the scalar ``run`` commit sequence
        (pre-check, charge, memo, trace) by row index."""
        seen, obs_by_row, _col_arr, col_list, cols = st
        budget = self.budget
        if budget.exhausted:
            try:
                budget.check()  # same exception/message as the scalar path
            except BudgetExhausted as e:
                return e
        col = col_list[r]
        if col >= 0:
            rec = cols.records[col]
            status = rec.status
            value = cols.time_list[col]
            charge = cols.charge_list[col]
        else:
            charge = self.cache.mean_eval_charge()
            rec = CachedResult("error", INVALID, (), charge)
            status, value = "error", INVALID
        budget.spent_seconds += charge
        budget.spent_evals += 1
        self.fresh_evals += 1
        cs = self.space.compiled
        config = cs.configs[r]
        obs = Observation.__new__(Observation)
        object.__setattr__(obs, "__dict__",
                           {"config": config, "value": value,
                            "status": status, "charge_s": charge,
                            "result": rec})
        obs_by_row[r] = obs
        seen[r] = True
        self.memo[cs.ids[r]] = obs
        self._rows_memo_len += 1
        self.trace.append((budget.spent_seconds, value, config))
        return [obs]

    def _commit_rows_loop(self, rows) -> "list[Observation] | BudgetExhausted":
        """Small-batch commit: the tight scalar loop of ``run_batch`` with
        every per-evaluation key computation and hash probe replaced by
        integer row indexing. Strings/value tuples appear only on *fresh*
        commits (memo key, trace entry) — the serialization boundary."""
        seen, obs_by_row, _col_arr, col_list, cols = self._row_state()
        cs = self.space.compiled
        ids, cfgs = cs.ids, cs.configs
        memo = self.memo
        budget = self.budget
        append = self.trace.append
        records = cols.records
        time_list, charge_list = cols.time_list, cols.charge_list
        new_obs = Observation.__new__
        set_dict = object.__setattr__  # frozen dataclass: bypass __setattr__
        # budget accounting mirrored in locals (same left-to-right float
        # accumulation as Budget.charge), synced back even when
        # BudgetExhausted aborts the batch mid-way
        max_s, max_e = budget.max_seconds, budget.max_evals
        spent_s, spent_e = budget.spent_seconds, budget.spent_evals
        fresh = self.fresh_evals
        mean_charge: float | None = None
        out: list[Observation] = []
        out_append = out.append
        result: object = out
        try:
            for r in (rows.tolist() if isinstance(rows, np.ndarray)
                      else rows):
                obs = obs_by_row[r]
                if obs is None:
                    if (max_s is not None and spent_s >= max_s) or \
                       (max_e is not None and spent_e >= max_e):
                        budget.spent_seconds = spent_s
                        budget.spent_evals = spent_e
                        budget.check()  # same exception as the scalar path
                    col = col_list[r]
                    if col >= 0:
                        rec = records[col]
                        status = rec.status
                        value = time_list[col]
                        charge = charge_list[col]
                    else:
                        # valid in the space but not recorded: a failed
                        # compile at the mean charge, like the keyed path
                        if mean_charge is None:
                            mean_charge = self.cache.mean_eval_charge()
                        charge = mean_charge
                        rec = CachedResult("error", INVALID, (), charge)
                        status, value = "error", INVALID
                    spent_s += charge
                    spent_e += 1
                    fresh += 1
                    config = cfgs[r]
                    # frozen-dataclass fast construction: one dict display
                    # replaces per-field object.__setattr__ (identical
                    # instance: __eq__/fields/hash semantics unchanged)
                    obs = new_obs(Observation)
                    set_dict(obs, "__dict__",
                             {"config": config, "value": value,
                              "status": status, "charge_s": charge,
                              "result": rec})
                    obs_by_row[r] = obs
                    seen[r] = True
                    memo[ids[r]] = obs
                    append((spent_s, value, config))
                out_append(obs)
        except BudgetExhausted as e:
            result = e
        finally:
            budget.spent_seconds = spent_s
            budget.spent_evals = spent_e
            self.fresh_evals = fresh
            self._rows_memo_len = len(memo)
        return result

    def _commit_rows_vectorized(self, rows
                                ) -> "list[Observation] | BudgetExhausted":
        """Large-batch commit as whole-array operations: one gather through
        ``col_of_row``, bitmap freshness (within-batch first occurrence x
        already-seen rows), a cumulative-sum budget seeded with the exact
        running spend (the same left-to-right float additions as the scalar
        loop, so exhaustion points and trace times match to the last bit),
        and bulk zip-built trace extension. Only fresh evaluations construct
        Observations in Python; revisits gather from the row-indexed object
        array."""
        rows = np.asarray(rows, dtype=np.int64)
        seen, obs_by_row, col_of_row, _col_list, cols = self._row_state()
        col_rows = col_of_row[rows]
        if col_rows.min() < 0:
            # unrecorded rows take the imputed-miss path of the loop commit
            return self._commit_rows_loop(rows)
        n = len(rows)
        order = np.argsort(rows, kind="stable")
        sorted_rows = rows[order]
        first_sorted = np.empty(n, dtype=bool)
        first_sorted[:1] = True
        first_sorted[1:] = sorted_rows[1:] != sorted_rows[:-1]
        first_occ = np.empty(n, dtype=bool)
        first_occ[order] = first_sorted
        fresh_idx = np.nonzero(first_occ & ~seen[rows])[0]
        n_fresh = len(fresh_idx)
        budget = self.budget
        max_s, max_e = budget.max_seconds, budget.max_evals
        cut = n_fresh
        run_cs = None
        if n_fresh:
            # seeded sequential cumsum: run_cs[j] is bit-identical to the
            # scalar loop's spend after j fresh evaluations
            run_cs = np.empty(n_fresh + 1, dtype=np.float64)
            run_cs[0] = budget.spent_seconds
            run_cs[1:] = cols.charge_s[col_rows[fresh_idx]]
            np.cumsum(run_cs, out=run_cs)
            if max_s is not None:
                # exhaustion raises at the first fresh attempt whose spend-
                # so-far already reaches the cap; run_cs is non-decreasing
                cut = min(cut, int(np.searchsorted(run_cs[:n_fresh], max_s,
                                                   side="left")))
            if max_e is not None:
                cut = min(cut, max(0, max_e - budget.spent_evals))
        exhausted = cut < n_fresh
        if cut:
            acc = fresh_idx[:cut]
            acc_rows = rows[acc]
            acc_cols = col_rows[acc]
            seen[acc_rows] = True
            vals = cols.time_s[acc_cols].tolist()
            chgs = cols.charge_s[acc_cols].tolist()
            cs = self.space.compiled
            cfg_tab, id_tab = cs.configs, cs.ids
            cfgs_acc = [cfg_tab[r] for r in acc_rows.tolist()]
            records = cols.records
            new_obs = Observation.__new__
            set_dict = object.__setattr__
            memo = self.memo
            for r, col, cfg, value, charge in zip(acc_rows.tolist(),
                                                  acc_cols.tolist(),
                                                  cfgs_acc, vals, chgs):
                rec = records[col]
                obs = new_obs(Observation)
                set_dict(obs, "__dict__",
                         {"config": cfg, "value": value,
                          "status": rec.status, "charge_s": charge,
                          "result": rec})
                obs_by_row[r] = obs
                memo[id_tab[r]] = obs
            self.trace.extend(zip(run_cs[1:cut + 1].tolist(), vals, cfgs_acc))
            budget.spent_seconds = float(run_cs[cut])
            budget.spent_evals += cut
            self.fresh_evals += cut
            self._rows_memo_len = len(memo)
        if exhausted:
            try:
                budget.check()  # same exception/message as the scalar path
            except BudgetExhausted as exc:
                return exc
        return [obs_by_row[r] for r in rows.tolist()]

    # gather granularity: a strategy may hand over far more configs than the
    # budget allows (random search batches the whole space permutation);
    # chunks grow geometrically so a budget-capped run wastes at most one
    # small chunk of key work past the exhaustion point, while full-space
    # replays still amortize into large chunks
    BATCH_CHUNK_MIN = 64
    BATCH_CHUNK_MAX = 2048

    def run_batch(self, configs: Sequence[Config]) -> list[Observation]:
        if (self.columnar and isinstance(configs, RowBatch)
                and configs.compiled is self.space.compiled):
            res = self._run_rows(configs.rows)
            if isinstance(res, BudgetExhausted):
                raise res
            return res
        if not self.columnar:
            return super().run_batch(configs)
        cols = self.cache.columns
        space = self.space
        memo = self.memo
        budget = self.budget
        trace = self.trace
        records = cols.records
        time_list, charge_list = cols.time_list, cols.charge_list
        index_get = cols.index.get
        memo_get = memo.get
        append = trace.append
        new_obs = Observation.__new__
        out: list[Observation] = []
        # budget accounting is mirrored in locals (same left-to-right float
        # accumulation as Budget.charge, minus per-eval attribute churn) and
        # synced back even when BudgetExhausted aborts the batch mid-way
        max_s, max_e = budget.max_seconds, budget.max_evals
        spent_s, spent_e = budget.spent_seconds, budget.spent_evals
        fresh = self.fresh_evals
        mean_charge: float | None = None
        try:
            start, step = 0, self.BATCH_CHUNK_MIN
            while start < len(configs):
                chunk = configs[start:start + step]
                start += step
                step = min(step * 2, self.BATCH_CHUNK_MAX)
                for key, config in zip(space.config_ids(chunk), chunk):
                    obs = memo_get(key)
                    if obs is None:
                        if (max_s is not None and spent_s >= max_s) or \
                           (max_e is not None and spent_e >= max_e):
                            # sync, then raise through Budget.check so the
                            # exception (and its message) match the scalar
                            # path exactly
                            budget.spent_seconds = spent_s
                            budget.spent_evals = spent_e
                            budget.check()
                        row = index_get(key, -1)
                        if row >= 0:
                            result = records[row]
                            status = result.status
                            value = time_list[row]
                            charge = charge_list[row]
                        else:
                            # outside the recorded set: a failed compile at
                            # the mean charge, like the scalar path (and
                            # the same clear error on an empty cache)
                            if mean_charge is None:
                                mean_charge = self.cache.mean_eval_charge()
                            charge = mean_charge
                            result = CachedResult("error", INVALID, (), charge)
                            status, value = "error", INVALID
                        spent_s += charge
                        spent_e += 1
                        fresh += 1
                        # frozen-dataclass fast construction: __init__ pays
                        # object.__setattr__ per field, which dominates the
                        # commit at replay rates; filling __dict__ directly
                        # builds an identical instance (__eq__/fields/hash
                        # semantics unchanged)
                        obs = new_obs(Observation)
                        obs.__dict__.update(config=config, value=value,
                                            status=status, charge_s=charge,
                                            result=result)
                        memo[key] = obs
                        append((spent_s, value, config))
                    out.append(obs)
        finally:
            budget.spent_seconds = spent_s
            budget.spent_evals = spent_e
            self.fresh_evals = fresh
        return out


def run_fused(batches: "Sequence[tuple[Runner, Sequence[Config]]]"
              ) -> list:
    """Resolve several runners' batches back-to-back without loop overhead.

    ``batches`` is ``[(runner, configs), ...]`` — one entry per concurrent
    tuning run (see ``driver.drive_many``). Returns one element per entry:
    the ``list[Observation]`` that ``runner.run_batch(configs)`` would have
    returned, or the ``BudgetExhausted`` it would have raised (with the
    runner's committed state — memo, trace, budget — identical in both
    cases, partial results included).

    Since the index-native refactor the shared work the fusion used to do
    — batching config-id computation across runs — no longer exists:
    strategies ask in ``RowBatch`` form, and a columnar runner resolves
    rows with no key work at all (``SimulationRunner._run_rows``:
    population-sized segments through a tight integer loop, large segments
    through whole-array commits). Anything else — thread-bridged legacy
    asks, scalar runners, plain config lists — goes through its runner's
    own ``run_batch``, observably identical either way. Runners are
    independent (own memo/budget/trace), so per-runner observable order is
    preserved exactly.
    """
    out: list = []
    for runner, configs in batches:
        if (isinstance(configs, RowBatch)
                and isinstance(runner, SimulationRunner) and runner.columnar
                and configs.compiled is runner.space.compiled):
            out.append(runner._run_rows(configs.rows))
        else:
            try:
                out.append(runner.run_batch(configs))
            except BudgetExhausted as e:
                out.append(e)
    return out


class CostModelRunner(Runner):
    def __init__(self, space: SearchSpace, workload: KernelWorkload,
                 device: DeviceModel, budget: Budget):
        super().__init__(space, budget)
        self.workload = workload
        self.device = device

    def _evaluate(self, config: Config) -> CachedResult:
        cid = self.space.config_id(config)
        est = estimate(self.workload, self.space.as_dict(config), self.device, cid)
        return CachedResult(est.status, est.time_s, tuple(est.times_s),
                            est.compile_s, self.device.overhead_s)


class LiveRunner(Runner):
    """Times ``fn(config_dict)``; exceptions are runtime failures."""

    def __init__(self, space: SearchSpace, fn: Callable, budget: Budget,
                 repeats: int = 3):
        super().__init__(space, budget)
        self.fn = fn
        self.repeats = repeats

    def _evaluate(self, config: Config) -> CachedResult:
        d = self.space.as_dict(config)
        t0 = time.perf_counter()
        try:
            self.fn(d)  # warmup/compile
            compile_s = time.perf_counter() - t0
            times = []
            for _ in range(self.repeats):
                t1 = time.perf_counter()
                self.fn(d)
                times.append(time.perf_counter() - t1)
            return CachedResult("ok", sum(times) / len(times), tuple(times),
                                compile_s)
        except Exception:
            # a failed compile/run still cost the measured wall time
            return CachedResult("error", INVALID, (),
                                time.perf_counter() - t0)
