"""Record → merge → replay: producing FAIR simulation caches from live runs.

The paper's two headline artifacts are a FAIR dataset of recorded tuning
runs (Sec. III-D) and a simulation mode that replays them at two orders of
magnitude lower cost (Sec. III-C). The seed repo could only *consume*
caches; this module closes the loop and *produces* them from any runner:

  * ``ObservationShard`` — an append-only JSONL file of observations, one
    per fresh evaluation, durably fsync'd as it is measured (the
    ``CampaignJournal`` machinery under a ``repro-shard`` format tag). A
    recording killed at any point keeps everything measured so far.
  * ``RecordingRunner`` — wraps any runner (``LiveRunner`` for Pallas
    interpret/on-device kernels, ``CostModelRunner`` for device models) and
    appends every fresh observation's full ``CachedResult`` to a shard.
    Because the runner protocol already charges exactly
    ``compile + Σ(repeats) + overhead``, a recorded run replays through
    ``SimulationRunner`` with a bit-identical trajectory.
  * ``merge_shards`` — folds the shards of parallel workers into one
    canonical ``CacheFile`` (T4-mini), the unit the simulation mode and the
    hypertuning campaigns consume.

Worker task functions (``record_shard_task``, ``bruteforce_shard_task``)
are module-level and driven by picklable ``RecordSpec`` payloads so a
``CampaignExecutor`` can fan recording out over process pools — each worker
owns one shard file, and the merge step reconciles them afterwards.
"""
from __future__ import annotations

import dataclasses
import random
import zlib
from typing import Mapping, Sequence

from .budget import Budget, BudgetExhausted
from .cache import (CachedResult, CacheFile, membership_space,
                    result_from_json, result_to_json)
from .devices import DEVICES_BY_NAME
from .parallel import CampaignJournal
from .runner import CostModelRunner, LiveRunner, Observation, Runner
from .searchspace import SearchSpace
from .strategies import get_strategy

SHARD_FORMAT = "repro-shard"

# header fields that must agree for shards to describe the same measurement
# campaign: the space itself plus everything that changes what one
# evaluation *means* (problem sizes, repeat count, live vs cost model)
SHARD_IDENTITY = ("kernel", "device", "tunables", "problem", "repeats",
                  "runner")


class ObservationShard:
    """One worker's crash-safe JSONL slice of a recording campaign.

    Line 1 identifies what was measured (kernel, device, tunables, problem
    sizes, runner kind); every further line is one config's ``CachedResult``.
    Appends are flushed + fsync'd (CampaignJournal semantics): a recording
    interrupted mid-measurement loses at most the in-flight config, and a
    torn trailing line is skipped on read.
    """

    def __init__(self, path: str):
        self.path = path
        self._journal = CampaignJournal(path, fmt=SHARD_FORMAT)

    @staticmethod
    def header(kernel: str, device: str, space: SearchSpace,
               **extra) -> dict:
        return {
            "kernel": kernel,
            "device": device,
            "tunables": {t.name: list(t.values) for t in space.tunables},
            "constraints": [c.description for c in space.constraints],
            **extra,
        }

    def ensure_header(self, header: Mapping) -> dict:
        """Create or validate the shard; returns already-recorded results
        keyed by config id (resume support: they pre-seed the runner memo)."""
        records = self._journal.ensure_header(header)
        return {d["id"]: result_from_json(d) for d in records}

    def read(self) -> tuple[dict | None, dict]:
        """Return ``(header, {config_id: CachedResult})``."""
        header, records = self._journal.read()
        results: dict[str, CachedResult] = {}
        for d in records:
            if "id" in d:  # ignore foreign/unknown record shapes
                results[d["id"]] = result_from_json(d)
        return header, results

    def append(self, key: str, result: CachedResult) -> None:
        self._journal.append({"id": key, **result_to_json(result)})


# -------------------------------------------------------------- recording
class RecordingRunner:
    """Transparent recorder around any runner.

    Strategies see the wrapped runner unchanged (memo, budget, trace all
    delegate), but every *fresh* evaluation — the only kind that measures
    anything — is appended to the shard the moment it completes. Memoized
    revisits and budget exhaustion pass through unrecorded.
    """

    def __init__(self, inner: Runner, shard: ObservationShard):
        self.inner = inner
        self.shard = shard
        self.recorded = 0

    def preload(self, results: Mapping[str, CachedResult]) -> None:
        """Seed the wrapped runner's memo with already-recorded observations
        (resuming an interrupted recording: re-visiting them is free and
        re-measures nothing). Unknown config ids are skipped — the space may
        have been narrowed since the shard was written."""
        for key, r in results.items():
            try:
                config = self.inner.space.config_from_id(key)
            except KeyError:
                continue
            self.inner.memo[key] = Observation(config, r.time_s, r.status,
                                               r.charge_s, r)

    def run(self, config) -> Observation:
        key = self.inner.space.config_id(config)
        fresh = key not in self.inner.memo
        obs = self.inner.run(config)
        if fresh:
            self.shard.append(key, obs.result)
            self.recorded += 1
        return obs

    def run_batch(self, configs) -> list:
        """Batch evaluation with recording. Must be defined here (not left to
        ``__getattr__`` delegation): forwarding ``run_batch`` straight to the
        wrapped runner would evaluate configs without appending them to the
        shard — a recording that silently loses every batched strategy's
        observations. Live runs measure one config at a time anyway, so the
        loop *is* the batch; each observation is durably recorded the moment
        it is measured."""
        return [self.run(c) for c in configs]

    def __call__(self, config) -> float:
        return self.run(config).value

    def __getattr__(self, name):
        return getattr(self.inner, name)


# ---------------------------------------------------------------- merging
def merge_shards(paths: Sequence[str], space: SearchSpace | None = None,
                 meta: Mapping | None = None) -> CacheFile:
    """Fold observation shards into one canonical ``CacheFile``.

    Shards must agree on their measurement identity (``SHARD_IDENTITY``:
    kernel, device, tunables, problem sizes, repeats, runner kind) —
    merging measurements of different spaces, workloads, or machines would
    corrupt the replay. Duplicate config ids are resolved by runner kind:

      * **live** runners produce noisy timings, and independently-seeded
        workers legitimately revisit the same config — the observation from
        the lowest (worker, path) wins, deterministically, so the merge is
        idempotent and independent of the order shards are listed in;
      * any other runner is expected to be deterministic — a conflicting
        duplicate means the shards come from different recordings, which is
        an error (identical duplicates still fold away).

    ``space`` defaults to a space reconstructed from the shard header's
    tunables with membership as the validity predicate, exactly like
    ``CacheFile.load``; pass the kernel's real space to keep functional
    constraints for replay.
    """
    if not paths:
        raise ValueError("no shards to merge")
    header0: dict | None = None
    # config id -> ((worker, path) provenance rank, result)
    merged: dict[str, tuple[tuple, CachedResult]] = {}
    n_read = 0
    for path in paths:
        header, results = ObservationShard(path).read()
        if header is None:
            continue  # header never written: an empty, freshly-crashed shard
        identity = {k: header.get(k) for k in SHARD_IDENTITY}
        if header0 is None:
            header0 = dict(header, **identity)
        else:
            prior = {k: header0.get(k) for k in identity}
            if identity != prior:
                diff = {k: (identity[k], prior[k]) for k in identity
                        if identity[k] != prior[k]}
                raise ValueError(
                    f"shard {path} was recorded for a different space or "
                    f"workload: {diff}")
        reconcile = header.get("runner") == "live"
        rank = (header.get("worker", 1 << 30), path)
        for key, r in results.items():
            prior_rank_r = merged.get(key)
            if prior_rank_r is None:
                merged[key] = (rank, r)
            elif prior_rank_r[1] == r:
                # equal duplicate: still adopt the lower rank, so a later
                # conflicting shard resolves identically whatever order the
                # equal copies were listed in
                merged[key] = (min(rank, prior_rank_r[0]), r)
            else:
                if not reconcile:
                    raise ValueError(
                        f"shards disagree on config {key!r} (is {path} from "
                        f"a different recording run?)")
                if rank < prior_rank_r[0]:
                    merged[key] = (rank, r)
        n_read += 1
    if header0 is None:
        raise ValueError(f"none of {list(paths)} contains a recorded shard")
    if space is None:
        space = membership_space(header0["kernel"], header0["device"],
                                 header0["tunables"], merged.keys())
    cache_meta = {
        "recorded": True,
        "runner": header0.get("runner", "unknown"),
        "problem": header0.get("problem", {}),
        "repeats": header0.get("repeats"),
        "n_shards": n_read,
        "n_configs": len(merged),
        "n_ok": sum(1 for _, r in merged.values() if r.status == "ok"),
        **dict(meta or {}),
    }
    cache = CacheFile(header0["kernel"], header0["device"], space, {},
                      cache_meta)
    for key, (_, r) in merged.items():
        cache.insert(key, r)
    return cache


# ------------------------------------------------------- parallel plumbing
@dataclasses.dataclass(frozen=True)
class RecordSpec:
    """Picklable description of one recording campaign: everything a worker
    process needs to rebuild the space and runner from the kernel registry
    and write its shard. ``problem`` overrides the kernel's smoke problem
    sizes; ``device`` selects the cost model's device when
    ``runner == "costmodel"`` and is a label otherwise."""

    kernel: str
    runner: str = "live"            # "live" (Pallas interpret) | "costmodel"
    device: str = "cpu_interpret"
    problem: tuple = ()             # sorted ((key, value), ...)
    strategy: str = "random_search"
    hyperparams: tuple = ()         # sorted ((key, value), ...)
    repeats: int = 3                # observations per fresh live evaluation
    max_evals: int | None = 64      # per-worker fresh-eval budget
    max_seconds: float | None = None
    seed: int = 0

    @staticmethod
    def create(kernel: str, **kw) -> "RecordSpec":
        kw["problem"] = tuple(sorted(dict(kw.get("problem") or {}).items()))
        kw["hyperparams"] = tuple(
            sorted(dict(kw.get("hyperparams") or {}).items()))
        return RecordSpec(kernel=kernel, **kw)

    @property
    def problem_dict(self) -> dict:
        return dict(self.problem)

    def kernel_spec(self):
        from ..kernels import get_kernel
        return get_kernel(self.kernel)

    def build(self) -> tuple[SearchSpace, "object"]:
        """Resolve (space, kernel spec) from the registry."""
        spec = self.kernel_spec()
        return spec.space(self.problem_dict), spec

    def make_runner(self, space: SearchSpace, budget: Budget) -> Runner:
        if self.runner == "live":
            spec = self.kernel_spec()
            fn = spec.make_live(self.problem_dict)
            return LiveRunner(space, fn, budget, repeats=self.repeats)
        if self.runner == "costmodel":
            try:
                device = DEVICES_BY_NAME[self.device]
            except KeyError:
                raise ValueError(
                    f"unknown device model {self.device!r}; known: "
                    f"{sorted(DEVICES_BY_NAME)}")
            spec = self.kernel_spec()
            return CostModelRunner(space, spec.workload(self.problem_dict),
                                   device, budget)
        if self.runner == "surrogate":
            try:
                device = DEVICES_BY_NAME[self.device]
            except KeyError:
                raise ValueError(
                    f"unknown device model {self.device!r}; known: "
                    f"{sorted(DEVICES_BY_NAME)}")
            # late: scenarios sits above core in the layer diagram
            from ..scenarios.surrogate import SurrogateRunner
            spec = self.kernel_spec()
            return SurrogateRunner(space, spec.workload(self.problem_dict),
                                   device, budget)
        raise ValueError(f"unknown runner kind {self.runner!r}")

    def shard_header(self, space: SearchSpace, worker: int,
                     n_workers: int) -> dict:
        return ObservationShard.header(
            self.kernel, self.device, space, runner=self.runner,
            problem=self.problem_dict, repeats=self.repeats,
            strategy=self.strategy, hyperparams=dict(self.hyperparams),
            seed=self.seed, worker=worker, n_workers=n_workers)


def registry_space(kernel: str, problem: Mapping | None) -> SearchSpace | None:
    """The kernel's real search space (functional constraints intact) for
    the recorded problem sizes, or None for kernels not in the registry —
    merges of foreign shards fall back to the membership space."""
    from ..kernels import get_kernel
    try:
        spec = get_kernel(kernel)
    except KeyError:
        return None
    return spec.space(problem or {})


def shard_path(prefix: str, worker: int) -> str:
    return f"{prefix}.shard-{worker:02d}.jsonl"


def record_shard_task(spec: RecordSpec, worker: int, n_workers: int,
                      prefix: str) -> dict:
    """One worker of a strategy-sampled recording: run the configured
    strategy (seeded per worker, so workers explore different regions)
    against a live/cost-model runner, appending every fresh observation to
    this worker's shard. Returns a summary dict."""
    space, _ = spec.build()
    shard = ObservationShard(shard_path(prefix, worker))
    existing = shard.ensure_header(
        spec.shard_header(space, worker, n_workers))
    budget = Budget(max_seconds=spec.max_seconds, max_evals=spec.max_evals)
    runner = spec.make_runner(space, budget)
    rec = RecordingRunner(runner, shard)
    rec.preload(existing)
    rng = random.Random((spec.seed * 1_000_003 + worker)
                        ^ zlib.crc32(spec.kernel.encode()))
    strategy = get_strategy(spec.strategy, **dict(spec.hyperparams))
    strategy.run(space, rec, rng)
    return {"worker": worker, "path": shard.path, "resumed": len(existing),
            "recorded": rec.recorded,
            "measured_seconds": budget.spent_seconds}


def bruteforce_shard_task(spec: RecordSpec, worker: int, n_workers: int,
                          prefix: str) -> dict:
    """One worker of an exhaustive recording: evaluate the worker's
    round-robin slice of the valid space (``configs[worker::n_workers]``) —
    no strategy, no sampling, every config exactly once."""
    space, _ = spec.build()
    shard = ObservationShard(shard_path(prefix, worker))
    existing = shard.ensure_header(
        spec.shard_header(space, worker, n_workers))
    budget = Budget(max_seconds=spec.max_seconds, max_evals=spec.max_evals)
    runner = spec.make_runner(space, budget)
    rec = RecordingRunner(runner, shard)
    rec.preload(existing)
    try:
        for config in space.valid_configs[worker::n_workers]:
            rec.run(config)
    except BudgetExhausted:
        pass  # partial shards are still mergeable/replayable
    return {"worker": worker, "path": shard.path, "resumed": len(existing),
            "recorded": rec.recorded,
            "measured_seconds": budget.spent_seconds}
