"""Tuning the tuner (paper Eq. 4, Sec. III-B/E, IV-B/C/D).

Two modes:

  * ``exhaustive_hypertune`` — enumerate a hyperparameter grid (the paper's
    Table III), score every configuration with the methodology across the
    training search spaces, and rank. This quantifies the impact of
    hyperparameter tuning (paper Sec. IV-B: +94.8 % average).
  * ``meta_hypertune`` — treat the hyperparameter space as an ordinary
    SearchSpace and explore it with any registered strategy ("the same
    optimization strategies that are already included" — Sec. IV-C), enabling
    the extended, non-exhaustive tuning of Table IV (+204.7 %).

The bridge is ``FunctionRunner``: a Runner whose objective is the *negated*
aggregate performance score (strategies minimize), and
``results_to_cache``: exhaustive results repackaged as a synthetic T4 cache
so that meta-strategies can themselves be scored with the methodology
(paper Fig. 6) — the recursion that gives the paper its title.

Campaign execution is delegated to ``core.parallel``: both modes accept a
``CampaignExecutor`` (worker-pool fan-out, bit-identical to serial) and a
``CampaignJournal`` (JSONL checkpointing + resume); see that module and the
``python -m repro hypertune|meta`` CLI.
"""
from __future__ import annotations

import base64
import dataclasses
import math
import pickle
import random
import time
from typing import Callable, Mapping, Sequence

from .budget import Budget
from .cache import CachedResult, CacheFile
from .driver import SearchDriver
from .methodology import AggregateReport, SpaceScorer, evaluate_strategy
from .parallel import (CampaignExecutor, CampaignJournal, StrategyFactory,
                       campaign_header, report_from_json, report_to_json,
                       score_hyperconfig_task)
from .runner import Runner
from .searchspace import SearchSpace
from .strategies import STRATEGIES, get_strategy
from .strategies.base import hyperparam_id
from .tunable import Config, tunables_from_dict

# mid-run checkpoints larger than this are skipped (the campaign still
# resumes through its memoized per-evaluation records, just replaying the
# meta-strategy's cheap compute): replay-bridge states grow with the told
# history, and a scipy-driven meta-strategy can ask tens of thousands of
# times per run
MAX_CHECKPOINT_BYTES = 1 << 20


def hyperparam_searchspace(strategy_name: str, extended: bool = False) -> SearchSpace:
    """The strategy's hyperparameter grid as an ordinary ``SearchSpace`` —
    which means it compiles through the same ``core.space`` path as kernel
    spaces: meta-strategies walk hyperparameter neighborhoods as CSR row
    slices and sample/repair through the same move tables (constraint-free
    grids compile to an all-valid bitmap in one vectorized pass)."""
    cls = STRATEGIES[strategy_name]
    grid = cls.EXTENDED_SPACE if extended else cls.HYPERPARAM_SPACE
    if not grid:
        raise ValueError(f"{strategy_name} exposes no hyperparameters")
    return SearchSpace(tunables_from_dict(grid), (),
                       name=f"hp[{strategy_name}{'-ext' if extended else ''}]")


@dataclasses.dataclass
class HyperConfigResult:
    hyperparams: dict
    report: AggregateReport

    @property
    def score(self) -> float:
        return self.report.score


@dataclasses.dataclass
class HyperTuningResult:
    strategy: str
    results: dict                  # hp_id -> HyperConfigResult
    wall_seconds: float
    simulated_seconds: float       # what live tuning would have cost

    def ranked(self) -> list:
        return sorted(self.results.values(), key=lambda r: -r.score)

    @property
    def best(self) -> HyperConfigResult:
        return self.ranked()[0]

    @property
    def worst(self) -> HyperConfigResult:
        return self.ranked()[-1]

    def closest_to_mean(self) -> HyperConfigResult:
        """The paper's 'average' configuration: closest score to the mean."""
        rs = list(self.results.values())
        mean = sum(r.score for r in rs) / len(rs)
        return min(rs, key=lambda r: abs(r.score - mean))

    @property
    def scores(self) -> list:
        return [r.score for r in self.results.values()]


def score_hyperconfig(strategy_name: str, hyperparams: Mapping,
                      scorers: Sequence[SpaceScorer], repeats: int = 25,
                      seed: int = 0, executor: CampaignExecutor | None = None
                      ) -> AggregateReport:
    """Score one hyperparameter configuration with the methodology (Eq. 3).

    ``executor`` optionally fans the (space × repeat) grid out in parallel —
    use it when scoring a *single* configuration; campaign-level callers
    should parallelize over configurations instead (one task per config)."""
    return evaluate_strategy(StrategyFactory.create(strategy_name, hyperparams),
                             scorers, repeats=repeats, seed=seed,
                             executor=executor)


def exhaustive_hypertune(strategy_name: str, scorers: Sequence[SpaceScorer],
                         repeats: int = 25, seed: int = 0,
                         progress: Callable[[str], None] | None = None,
                         executor: CampaignExecutor | None = None,
                         journal: CampaignJournal | None = None
                         ) -> HyperTuningResult:
    """Enumerate and score the full hyperparameter grid (paper Table III).

    ``executor`` fans configurations out over a worker pool; results are
    assembled in grid-enumeration order, so parallel campaigns are
    bit-identical to serial ones (Sec. III-C determinism). ``journal``
    checkpoints every completed configuration to JSONL; an interrupted
    campaign restarted with the same journal resumes where it left off,
    re-scoring nothing."""
    space = hyperparam_searchspace(strategy_name)
    t0 = time.perf_counter()
    hp_list = [space.as_dict(cfg) for cfg in space.valid_configs]
    ids = [hyperparam_id(hp) for hp in hp_list]
    done: dict[str, HyperConfigResult] = {}
    prior_wall = 0.0  # campaign wall already spent before this (resumed) run
    if journal is not None:
        header = campaign_header("exhaustive", strategy_name, scorers,
                                 repeats, seed)
        for rec in journal.ensure_header(header):
            if rec.get("type") == "checkpoint":
                continue
            # journal-compat shim: recompute the id from the stored
            # hyperparams rather than trusting rec["hp_id"], so journals
            # written before hyperparam_id escaped ,/=/% resume cleanly
            done[hyperparam_id(rec["hyperparams"])] = HyperConfigResult(
                rec["hyperparams"], report_from_json(rec["report"]))
            prior_wall = max(prior_wall, rec.get("done_wall", 0.0))
        if done and progress:
            progress(f"resumed {len(done)}/{space.size} configs from "
                     f"{journal.path}")
    pending = [(i, hp) for i, hp in enumerate(hp_list) if ids[i] not in done]
    n_done = len(done)
    executor = executor or CampaignExecutor()
    tasks = [(strategy_name, hp, repeats, seed) for _, hp in pending]
    for t_idx, report in executor.map(score_hyperconfig_task, tasks,
                                      shared=tuple(scorers)):
        i, hp = pending[t_idx]
        done[ids[i]] = HyperConfigResult(hp, report)
        if journal is not None:
            # done_wall is cumulative across resumes, so wall-clock stays
            # the true campaign cost (fig9's speedup claim depends on it)
            journal.append({"hp_id": ids[i], "hyperparams": hp,
                            "report": report_to_json(report),
                            "done_wall": prior_wall
                            + time.perf_counter() - t0})
        n_done += 1
        if progress:
            progress(f"[{n_done}/{space.size}] {strategy_name} "
                     f"{ids[i]} -> {report.score:+.4f}")
    results = {ids[i]: done[ids[i]] for i in range(len(hp_list))}
    simulated = sum(r.report.simulated_seconds for r in results.values())
    return HyperTuningResult(strategy_name, results,
                             prior_wall + time.perf_counter() - t0, simulated)


# --------------------------------------------------------------------- meta
class FunctionRunner(Runner):
    """Runner over an arbitrary objective; used for the meta level where one
    'evaluation' is a full (simulated) tuning campaign of a hyperparameter
    configuration. The charge is that campaign's simulated tuning cost, so
    meta-traces live on the same simulated-time axis as everything else."""

    def __init__(self, space: SearchSpace, fn: Callable[[Config], tuple],
                 budget: Budget):
        super().__init__(space, budget)
        self.fn = fn

    def _evaluate(self, config: Config) -> tuple:
        value, charge = self.fn(config)
        status = "ok" if math.isfinite(value) else "error"
        return value, status, charge


@dataclasses.dataclass
class MetaTuningResult:
    strategy: str
    meta_strategy: str
    best_hyperparams: dict
    best_score: float
    evaluated: dict                # hp_id -> score
    trace: list                    # FunctionRunner trace (simulated time axis)
    wall_seconds: float
    simulated_seconds: float = 0.0  # what live tuning would have cost
    # drive mode of the inner campaigns ("device"/"host"/"sequential"/
    # "mixed"); None when every evaluation was journal-memoized
    fuse: str | None = None


def meta_hypertune(strategy_name: str, meta_strategy_name: str,
                   scorers: Sequence[SpaceScorer], extended: bool = True,
                   max_hp_evals: int = 50, repeats: int = 25, seed: int = 0,
                   meta_hyperparams: Mapping | None = None,
                   progress: Callable[[str], None] | None = None,
                   executor: CampaignExecutor | None = None,
                   journal: CampaignJournal | None = None
                   ) -> MetaTuningResult:
    """Optimize hyperparameters with a strategy as the meta-strategy (Eq. 4).

    The meta-level is inherently sequential (each proposal depends on the
    previous observation), so ``executor`` parallelizes *within* one
    hyperparameter evaluation (the methodology's space × repeat grid).

    ``journal`` makes the campaign resumable at two granularities. Every
    completed hyperparameter evaluation is memoized (the objective is
    deterministic given ``(hyperparams, repeats, seed)``), and after each
    one the meta-strategy's ``SearchState`` + runner state are checkpointed
    as a pickled snapshot record. A resumed campaign restores the latest
    snapshot and continues *inside* the tuning run — no meta-strategy
    replay at all; if no usable snapshot exists (old journal, or the
    replay log outgrew ``MAX_CHECKPOINT_BYTES``), it falls back to
    replaying the meta-strategy's cheap compute against the memoized
    evaluations, recomputing nothing either way (paper Sec. IV-C)."""
    space = hyperparam_searchspace(strategy_name, extended=extended)
    evaluated: dict[str, float] = {}
    memo: dict[str, tuple[float, float]] = {}
    prior_wall = 0.0  # campaign wall already spent before this (resumed) run
    snapshot_b64: str | None = None
    if journal is not None:
        header = campaign_header("meta", strategy_name, scorers, repeats,
                                 seed, meta_strategy=meta_strategy_name,
                                 extended=extended,
                                 max_hp_evals=max_hp_evals,
                                 **({"meta_hyperparams":
                                     [[k, v] for k, v in
                                      sorted(meta_hyperparams.items())]}
                                    if meta_hyperparams else {}))
        for rec in journal.ensure_header(header):
            if rec.get("type") == "checkpoint":
                snapshot_b64 = rec["snapshot"]
                continue
            # journal-compat shim: ids recomputed from the stored dict (see
            # exhaustive_hypertune)
            memo[hyperparam_id(rec["hyperparams"])] = (
                rec["score"], rec["simulated_seconds"])
            prior_wall = max(prior_wall, rec.get("done_wall", 0.0))
        if memo and progress:
            progress(f"resumed {len(memo)} evaluations from {journal.path}"
                     + (" (with mid-run state snapshot)"
                        if snapshot_b64 else ""))
    t0 = time.perf_counter()
    fuse_modes: set = set()

    def objective(cfg: Config) -> tuple:
        hp = space.as_dict(cfg)
        hp_id = hyperparam_id(hp)
        if hp_id in memo:
            score, simulated = memo[hp_id]
        else:
            report = score_hyperconfig(strategy_name, hp, scorers, repeats,
                                       seed, executor=executor)
            score, simulated = report.score, report.simulated_seconds
            fuse_modes.add(report.fuse)
            memo[hp_id] = (score, simulated)
            if journal is not None:
                journal.append({"hp_id": hp_id, "hyperparams": hp,
                                "score": score,
                                "simulated_seconds": simulated,
                                "done_wall": prior_wall
                                + time.perf_counter() - t0})
        evaluated[hp_id] = score
        if progress:
            progress(f"meta[{meta_strategy_name}] {strategy_name} "
                     f"{hp_id} -> {score:+.4f}")
        # minimize negated score; charge the simulated cost of the campaign
        return -score, simulated

    runner = FunctionRunner(space, objective, Budget(max_evals=max_hp_evals))
    meta = get_strategy(meta_strategy_name, **(meta_hyperparams or {}))
    if snapshot_b64 is not None:
        snap = pickle.loads(base64.b64decode(snapshot_b64))
        evaluated.update(snap.get("evaluated", {}))
        driver = SearchDriver.resume(meta, space, runner, snap)
    else:
        driver = SearchDriver(meta, space, runner, random.Random(seed))

    last_fresh = runner.fresh_evals

    def checkpoint(d: SearchDriver) -> None:
        # one snapshot per completed hyperparameter evaluation; generations
        # that only revisit memoized configs advance nothing worth saving
        nonlocal last_fresh
        if journal is None or runner.fresh_evals == last_fresh:
            return
        last_fresh = runner.fresh_evals
        snap = d.snapshot()
        snap["evaluated"] = dict(evaluated)
        payload = pickle.dumps(snap, protocol=pickle.HIGHEST_PROTOCOL)
        if len(payload) > MAX_CHECKPOINT_BYTES:
            return  # resume will fall back to memoized-evaluation replay
        journal.append({"type": "checkpoint", "fresh_evals": last_fresh,
                        "snapshot": base64.b64encode(payload).decode()})

    best = driver.run(checkpoint=checkpoint if journal is not None else None)
    if best is None:
        raise RuntimeError("meta-strategy found no valid hyperparameters")
    return MetaTuningResult(
        strategy_name, meta_strategy_name,
        space.as_dict(best.config), -best.value, evaluated,
        list(runner.trace), prior_wall + time.perf_counter() - t0,
        simulated_seconds=runner.budget.spent_seconds,
        fuse=(fuse_modes.pop() if len(fuse_modes) == 1
              else "mixed" if fuse_modes else None))


# ------------------------------------------------- meta-level methodology
def results_to_cache(result: HyperTuningResult,
                     mean_campaign_seconds: float | None = None) -> CacheFile:
    """Repackage exhaustive hypertuning results as a synthetic T4 cache whose
    objective is the negated score — so meta-strategies can be scored with
    the same methodology (paper Fig. 6). Every 'config' charges the mean
    campaign cost (each hyperparameter evaluation costs about the same)."""
    space = hyperparam_searchspace(result.strategy)
    cs = space.compiled
    n = max(1, len(result.results))
    charge = (mean_campaign_seconds
              if mean_campaign_seconds is not None
              else result.simulated_seconds / n)
    cached = {}
    for hp_id, r in result.results.items():
        # row-native id: one flat-index lookup into the precomputed id
        # table instead of a per-config string join
        row = cs.row_of_config(space.from_dict(r.hyperparams))
        key = (cs.ids[row] if row >= 0
               else space.config_id(space.from_dict(r.hyperparams)))
        # objective = -score (dimensionless); the *charge* (time axis) is the
        # campaign cost, carried entirely by compile_s so that
        # charge_s == campaign seconds exactly.
        cached[key] = CachedResult(status="ok", time_s=-r.score,
                                   times_s=(), compile_s=charge)
    return CacheFile(f"hp_{result.strategy}", "meta", space, cached,
                     meta={"level": "hyperparameter", "strategy": result.strategy})
