"""Performance-score methodology (paper Sec. III-B, Eqs. 2–3).

Implements the community methodology the paper builds on [29]:

  * a *calculated* random-search baseline in the **time domain**: the mean
    best-so-far over a fixed set of virtual random-search runs (sampling
    without replacement, each draw charging that configuration's own
    recorded compile+run time). A draw-count-domain hypergeometric
    expectation is optimistic here because objective value and evaluation
    cost are positively correlated (slow kernels also take longer to
    measure); the time-domain curve is the honest baseline. It is
    deterministic: the virtual runs use a fixed seed.
  * a per-space *budget*: the simulated time at which the baseline reaches
    the cutoff fraction (default 95 %) of the median→optimum distance;
  * per-run performance curves ``P_t`` (Eq. 2) sampled at equidistant
    simulated-time points, averaged over repeats;
  * aggregation across search spaces into one score (Eq. 3): 0 ⇔ baseline,
    1 ⇔ optimum found immediately, negative ⇔ worse than baseline.
"""
from __future__ import annotations

import dataclasses
import math
import random
import time
import zlib
from typing import Callable, Sequence

import numpy as np

from .budget import Budget
from .cache import CacheFile
from .runner import SimulationRunner
from .strategies.base import Strategy

DEFAULT_CUTOFF = 0.95
DEFAULT_SAMPLES = 50
BASELINE_RUNS = 1000
BASELINE_SEED = 0xB0B
HARD_TIME_CAP_EVALS = 3000  # tractability cap: budget ≤ cap × mean_charge
ENGINES = ("vectorized", "scalar", "jax")
# Baseline vectorization: batching virtual runs into (block, |space|)
# matrices beats the per-run loop only while the block's working set stays
# cache-resident — for large spaces the per-run arrays already amortize the
# numpy call overhead and batching just burns memory bandwidth (measured:
# 1.7× at 256 configs, 0.8× at 10k). Above the cutover the vectorized
# builder delegates to the per-run path (bit-identical either way).
_BASELINE_VECTOR_MAX_N = 1536
_BASELINE_BLOCK_ELEMS = 1 << 14


@dataclasses.dataclass
class SpaceScorer:
    """Precomputed scoring context for one search space (one cache file).

    ``engine`` selects between the array-backed fast path (``"vectorized"``,
    the default: batched baseline construction, ``np.searchsorted`` curve
    sampling, columnar ``SimulationRunner``), the original per-evaluation
    ``"scalar"`` path, and the jitted ``"jax"`` replay path (device-resident
    row resolution; scoring/baselines stay the vectorized numpy code). All
    three produce bit-identical scores — the scalar path is kept as the
    parity reference and the regression benchmark's denominator, not as a
    fallback (see ``core.engine_jax`` for the jax parity contract).
    """

    cache: CacheFile
    values: np.ndarray        # sorted finite objective values (ascending)
    n_total: int              # |space| incl. runtime failures
    mean_charge: float        # simulated seconds per fresh evaluation
    optimum: float
    median: float
    budget_s: float
    n_budget: int             # ≈ budget_s / mean_charge (informational)
    # virtual random-search runs: improvement step functions
    _imp_times: np.ndarray    # (R, K) padded with +inf
    _imp_values: np.ndarray   # (R, K) padded with worst value
    engine: str = "vectorized"

    @property
    def name(self) -> str:
        return f"{self.cache.kernel}@{self.cache.device}"

    # ----------------------------------------------------------- baseline
    def baseline_at_time(self, t) -> np.ndarray:
        """S_baseline(t): mean best-so-far of the virtual runs at time(s) t.

        Runs with no finite observation by t impute the worst finite value.
        """
        t_arr = np.atleast_1d(np.asarray(t, dtype=np.float64))
        # count improvements with time <= t per run: (R, T)
        counts = (self._imp_times[:, :, None] <= t_arr[None, None, :]).sum(axis=1)
        idx = np.maximum(counts - 1, 0)
        vals = np.take_along_axis(self._imp_values, idx, axis=1)
        vals = np.where(counts > 0, vals, self.values[-1])
        out = vals.mean(axis=0)
        return out if np.ndim(t) else float(out[0])

    # ------------------------------------------------------------- scoring
    def sample_times(self, n_samples: int = DEFAULT_SAMPLES) -> np.ndarray:
        return np.linspace(self.budget_s / n_samples, self.budget_s, n_samples)

    def score_trace(self, trace: Sequence[tuple], times: np.ndarray,
                    baseline: np.ndarray | None = None) -> np.ndarray:
        """P_t (Eq. 2) for one run's trace [(cum_seconds, value, config)...].

        Before the first finite observation the run scores 0 (== baseline).
        Vectorized: the best-so-far step function comes from
        ``np.minimum.accumulate`` over the trace's value column, and all
        sample points resolve through one ``np.searchsorted`` over the
        improvement times — bit-identical to the scalar loop (same float64
        arithmetic per sample).
        """
        if self.engine == "scalar":
            return self._score_trace_scalar(trace, times, baseline)
        # improvement extraction stays a single sequential pass (a handful
        # of appends; vectorizing it would re-read every trace tuple into
        # arrays and lose on long traces) — the per-sample loop is what
        # vectorizes, collapsing 50 searchsorted calls into one
        best = math.inf
        ts_list, bs_list = [], []
        for t_cum, value, _cfg in trace:
            if value < best:
                best = value
                ts_list.append(t_cum)
                bs_list.append(best)
        return self.score_improvements(
            np.asarray(ts_list, dtype=np.float64),
            np.asarray(bs_list, dtype=np.float64), times, baseline)

    def score_improvements(self, ts: np.ndarray, bs: np.ndarray,
                           times: np.ndarray,
                           baseline: np.ndarray | None = None) -> np.ndarray:
        """``score_trace`` for a run already reduced to its improvement
        step function ``(ts, bs)`` — the form the device-fused campaign
        path hands over (``FusedRun.improvements``), skipping the Python
        trace entirely. Same float64 arithmetic per sample as
        ``score_trace``; the two agree bit-for-bit on every trace."""
        if baseline is None:
            baseline = self.baseline_at_time(times)
        out = np.zeros(len(times))
        if not len(ts):
            return out
        k = np.searchsorted(ts, times, side="right") - 1
        bk = bs[np.maximum(k, 0)]
        sb = np.asarray(baseline, dtype=np.float64)
        denom = sb - self.optimum
        with np.errstate(divide="ignore", invalid="ignore"):
            score = (sb - bk) / denom
        score = np.where(denom <= 0,
                         np.where(bk <= self.optimum, 1.0, 0.0), score)
        valid = (k >= 0) & np.isfinite(bk)
        return np.where(valid, score, 0.0)

    def _score_trace_scalar(self, trace: Sequence[tuple], times: np.ndarray,
                            baseline: np.ndarray | None = None) -> np.ndarray:
        """The original per-sample loop — parity reference for
        ``score_trace`` (kept verbatim; see tests/test_engine_parity.py)."""
        if baseline is None:
            baseline = self.baseline_at_time(times)
        best = math.inf
        ts, bs = [], []
        for t_cum, value, _cfg in trace:
            if value < best:
                best = value
                ts.append(t_cum)
                bs.append(best)
        out = np.zeros(len(times))
        for j, t in enumerate(times):
            k = np.searchsorted(ts, t, side="right") - 1
            if k < 0 or not math.isfinite(bs[k]):
                out[j] = 0.0
                continue
            sb = baseline[j]
            denom = sb - self.optimum
            if denom <= 0:
                out[j] = 1.0 if bs[k] <= self.optimum else 0.0
            else:
                out[j] = (sb - bs[k]) / denom
        return out


def _virtual_random_runs(values: np.ndarray, charges: np.ndarray,
                         n_runs: int, seed: int) -> tuple:
    """Improvement step functions of ``n_runs`` virtual random-search runs
    (without replacement, per-config charges). Returns padded (times, bests).

    Vectorized: runs are processed in blocks as one (block, |space|)
    cumulative-time / running-min computation. Only the permutation *draws*
    stay a loop — ``rng.permutation`` must be called once per run in the
    original order so the RNG stream (and therefore every baseline, budget,
    and downstream score) is bit-identical to the scalar path.
    """
    if len(values) > _BASELINE_VECTOR_MAX_N:
        return _virtual_random_runs_scalar(values, charges, n_runs, seed)
    rng = np.random.default_rng(seed)
    n = len(values)
    block = max(16, _BASELINE_BLOCK_ELEMS // max(n, 1))
    finite = np.isfinite(values)
    worst = values[finite].max()
    blocks: list[tuple[np.ndarray, np.ndarray]] = []
    for start in range(0, n_runs, block):
        r = min(block, n_runs - start)
        perms = np.empty((r, n), dtype=np.intp)
        for i in range(r):
            perms[i] = rng.permutation(n)  # same draw order as scalar
        v = values[perms]                                      # (r, n)
        t = np.cumsum(charges[perms], axis=1)                  # sequential
        run_min = np.fmin.accumulate(
            np.where(np.isfinite(v), v, np.inf), axis=1)
        # improvement points: first occurrence of each new minimum
        is_imp = np.empty((r, n), dtype=bool)
        is_imp[:, 0] = True
        is_imp[:, 1:] = run_min[:, 1:] < run_min[:, :-1]
        is_imp &= np.isfinite(run_min)
        k = int(is_imp.sum(axis=1).max())
        times = np.full((r, k), np.inf)
        bests = np.full((r, k), worst)
        rows, src = np.nonzero(is_imp)
        dest = (np.cumsum(is_imp, axis=1) - 1)[rows, src]
        times[rows, dest] = t[rows, src]
        bests[rows, dest] = run_min[rows, src]
        blocks.append((times, bests))
    k = max(b.shape[1] for b, _ in blocks)
    all_t = np.full((n_runs, k), np.inf)
    all_b = np.full((n_runs, k), worst)
    row = 0
    for times, bests in blocks:
        r, kc = times.shape
        all_t[row:row + r, :kc] = times
        all_b[row:row + r, :kc] = bests
        row += r
    return all_t, all_b


def _virtual_random_runs_scalar(values: np.ndarray, charges: np.ndarray,
                                n_runs: int, seed: int) -> tuple:
    """The original one-run-at-a-time builder — parity reference for
    ``_virtual_random_runs`` (kept verbatim)."""
    rng = np.random.default_rng(seed)
    n = len(values)
    imp_t: list[np.ndarray] = []
    imp_v: list[np.ndarray] = []
    finite = np.isfinite(values)
    worst = values[finite].max()
    for _ in range(n_runs):
        perm = rng.permutation(n)
        v = values[perm]
        t = np.cumsum(charges[perm])
        run_min = np.fmin.accumulate(np.where(np.isfinite(v), v, np.inf))
        # improvement points: first occurrence of each new minimum
        is_imp = np.ones(n, bool)
        is_imp[1:] = run_min[1:] < run_min[:-1]
        is_imp &= np.isfinite(run_min)
        imp_t.append(t[is_imp])
        imp_v.append(run_min[is_imp])
    k = max(len(a) for a in imp_t)
    times = np.full((n_runs, k), np.inf)
    bests = np.full((n_runs, k), worst)
    for i, (a, b) in enumerate(zip(imp_t, imp_v)):
        times[i, :len(a)] = a
        bests[i, :len(b)] = b
    return times, bests


def make_scorer(cache: CacheFile, cutoff: float = DEFAULT_CUTOFF,
                n_baseline_runs: int = BASELINE_RUNS,
                hard_cap: int = HARD_TIME_CAP_EVALS,
                engine: str = "vectorized") -> SpaceScorer:
    if engine not in ENGINES:
        raise ValueError(f"unknown engine {engine!r}; known: {ENGINES}")
    if engine != "scalar":
        # columnar view: same contents, same insertion order as the scalar
        # comprehension below, built once and shared with the runners
        cols = cache.columns
        all_values, all_charges = cols.time_s, cols.charge_s
        runs_builder = _virtual_random_runs
    else:
        all_values = np.array([r.time_s for r in cache.results.values()],
                              dtype=np.float64)
        all_charges = np.array([r.charge_s for r in cache.results.values()],
                               dtype=np.float64)
        runs_builder = _virtual_random_runs_scalar
    values = np.sort(all_values[np.isfinite(all_values)])
    if values.size == 0:
        raise ValueError(f"cache {cache.kernel}@{cache.device} has no ok results")
    n_total = len(cache.results)
    mean_charge = float(all_charges.mean())
    optimum = float(values[0])
    median = float(np.median(values))
    seed = BASELINE_SEED ^ zlib.crc32(f"{cache.kernel}@{cache.device}".encode())
    imp_t, imp_v = runs_builder(all_values, all_charges,
                                n_baseline_runs, seed)
    scorer = SpaceScorer(cache, values, n_total, mean_charge, optimum, median,
                         budget_s=0.0, n_budget=0, _imp_times=imp_t,
                         _imp_values=imp_v, engine=engine)
    # budget: first time the baseline crosses median - cutoff*(median - opt),
    # by bisection (the baseline is monotone non-increasing in t)
    target = median - cutoff * (median - optimum)
    lo, hi = float(all_charges.min()), float(hard_cap * mean_charge)
    if scorer.baseline_at_time(hi) > target:
        budget = hi  # cap reached; effective cutoff < requested
    else:
        for _ in range(48):
            mid = 0.5 * (lo + hi)
            if scorer.baseline_at_time(mid) <= target:
                hi = mid
            else:
                lo = mid
        budget = hi
    scorer.budget_s = budget
    scorer.n_budget = max(1, int(round(budget / mean_charge)))
    return scorer


@dataclasses.dataclass
class AggregateReport:
    """Result of evaluating one strategy (with fixed hyperparameters)."""

    score: float                       # Eq. 3 aggregate
    curve: np.ndarray                  # mean P_t over spaces (len n_samples)
    per_space: dict                    # name -> mean P_t curve
    per_space_score: dict              # name -> float
    fresh_evals: int = 0
    wall_seconds: float = 0.0
    simulated_seconds: float = 0.0
    # how the in-process grid executed: "device" (engine_jax fused
    # campaigns), "host" (interleaved drive_many), "sequential" (one cell
    # at a time), or "mixed" when spaces took different paths. Purely
    # informational — scores are bit-identical across all of them.
    fuse: str = "sequential"


@dataclasses.dataclass
class RepeatResult:
    """One (space, repeat) cell of the evaluation grid — the unit of work a
    ``core.parallel.CampaignExecutor`` can fan out."""

    curve: np.ndarray          # P_t (Eq. 2) sampled at this space's times
    fresh_evals: int
    wall_seconds: float
    simulated_seconds: float


def run_repeat(scorer: SpaceScorer, make_strategy: Callable[[], Strategy],
               repeat: int, seed: int, times: np.ndarray,
               baseline: np.ndarray) -> RepeatResult:
    """Run one repeat of one space (one cell of Eq. 3's average) and score
    its trace per Eq. 2. Self-contained and deterministic: the RNG is seeded
    from ``(seed, repeat, space name)`` with a process-independent hash
    (crc32 — str hash is randomized per interpreter), so cells compute
    bit-identical curves whether executed serially, on a thread pool, or in
    another process (paper Sec. III-C: simulation results are exactly
    reproducible)."""
    rng = _repeat_rng(scorer, repeat, seed)
    runner = SimulationRunner(scorer.cache,
                              Budget(max_seconds=scorer.budget_s),
                              engine=scorer.engine)
    strategy = make_strategy()
    strategy.run(scorer.cache.space, runner, rng)
    return RepeatResult(scorer.score_trace(runner.trace, times, baseline),
                        runner.fresh_evals, runner.wall_seconds,
                        runner.budget.spent_seconds)


def _repeat_rng(scorer: SpaceScorer, repeat: int, seed: int) -> random.Random:
    """The (space, repeat) cell's RNG — one definition shared by the
    sequential and fused drive paths so they are bit-identical."""
    return random.Random((seed * 1_000_003 + repeat)
                         ^ zlib.crc32(scorer.name.encode()))


def run_repeats_fused(scorer: SpaceScorer,
                      make_strategy: Callable[[], Strategy],
                      repeats: int, seed: int, times: np.ndarray,
                      baseline: np.ndarray
                      ) -> tuple[list[RepeatResult], str]:
    """All of one space's repeats as concurrent, ask-fused tuning runs.

    Builds one ``SearchDriver`` per repeat (same per-cell RNG seeding as
    ``run_repeat``) and interleaves them with ``driver.drive_many``: each
    round's asks resolve as one shared columnar gather instead of
    ``repeats`` separate ``run_batch`` calls. Per-run observable state —
    and therefore every curve and score — is bit-identical to the
    sequential loop; only wall time changes. Per-cell ``wall_seconds`` is
    an even share of the fused wall (runs overlap, so a per-runner clock
    would multiple-count).

    Returns ``(cells, mode)`` where ``mode`` is ``"host"``, or
    ``"sequential"`` when the strategy cannot be driven ask/tell-wise —
    announced once per (strategy, reason) with a ``FuseFallbackNotice``.
    """
    from .driver import (SearchDriver, ThreadBridgeState, drive_many,
                         warn_fuse_fallback)
    t0 = time.perf_counter()
    drivers = []
    for r in range(repeats):
        strategy = make_strategy()
        if not hasattr(strategy, "init_state"):
            # duck-typed strategy exposing only run(space, runner, rng):
            # no ask/tell to fuse — drive the cells sequentially
            warn_fuse_fallback(
                getattr(strategy, "name", type(strategy).__name__),
                "duck-typed strategy exposes only run(space, runner, rng); "
                "no ask/tell protocol to fuse", "sequential")
            return [run_repeat(scorer, make_strategy, rr, seed, times,
                               baseline) for rr in range(repeats)], \
                "sequential"
        runner = SimulationRunner(scorer.cache,
                                  Budget(max_seconds=scorer.budget_s),
                                  engine=scorer.engine)
        driver = SearchDriver(strategy, scorer.cache.space, runner,
                              _repeat_rng(scorer, r, seed))
        if r == 0 and isinstance(driver.state, ThreadBridgeState):
            # thread-bridged loops (dual_annealing wrapping scipy) pay a
            # thread rendezvous per evaluation when driven ask/tell-wise;
            # their direct legacy dispatch in Strategy.run is bit-identical
            # and much faster, so those cells run sequentially
            driver.state.close()
            warn_fuse_fallback(
                getattr(strategy, "name", type(strategy).__name__),
                "thread-bridged legacy loop pays a thread rendezvous per "
                "evaluation when driven ask/tell-wise", "sequential")
            return [run_repeat(scorer, make_strategy, rr, seed, times,
                               baseline) for rr in range(repeats)], \
                "sequential"
        drivers.append(driver)
    drive_many(drivers)
    wall_share = (time.perf_counter() - t0) / max(1, repeats)
    return [RepeatResult(scorer.score_trace(d.runner.trace, times, baseline),
                         d.runner.fresh_evals, wall_share,
                         d.runner.budget.spent_seconds)
            for d in drivers], "host"


def run_repeats_device(scorer: SpaceScorer,
                       make_strategy: Callable[[], Strategy],
                       repeats: int, seed: int, times: np.ndarray,
                       baseline: np.ndarray
                       ) -> "list[RepeatResult] | None":
    """All of one space's repeats as one device-resident fused campaign
    (``engine_jax.campaign``): the strategies' ask/tell trajectories step
    on the host against a value table while every run's budget-replay-
    commit resolves in a handful of vmapped device dispatches. Curves and
    scores are bit-identical to the sequential/host paths (the trajectory
    is budget-independent; see the campaign module docstring).

    Returns ``None`` — after a one-time ``FuseFallbackNotice`` — when the
    grid is not device-fusable (strategy outside the array-native
    allowlist, no jax backend, empty cache); the caller then takes the
    host drive.
    """
    from . import engine_jax
    from .driver import SearchDriver, warn_fuse_fallback
    probe = make_strategy()
    name = getattr(probe, "name", type(probe).__name__)
    if not engine_jax.engine_available():
        warn_fuse_fallback(
            name, "jax engine unavailable "
            f"({engine_jax.unavailable_reason()})", "host")
        return None
    if name not in engine_jax.FUSED_STRATEGIES:
        warn_fuse_fallback(
            name, f"strategy {name!r} is not array-native "
            "(trajectory not host-replayable from values alone)", "host")
        return None
    t0 = time.perf_counter()
    drivers = []
    for r in range(repeats):
        runner = SimulationRunner(scorer.cache,
                                  Budget(max_seconds=scorer.budget_s),
                                  engine="jax")
        drivers.append(SearchDriver(make_strategy(), scorer.cache.space,
                                    runner, _repeat_rng(scorer, r, seed)))
    reason = engine_jax.fuse_reason(drivers[0])
    if reason is not None:
        for d in drivers:
            d.state.close()
        warn_fuse_fallback(name, reason, "host")
        return None
    runs = engine_jax.drive_fused(drivers, materialize=False)
    wall_share = (time.perf_counter() - t0) / max(1, repeats)
    # scores straight from the committed improvement arrays: no Python
    # trace materializes on the scores-only path (score_improvements is
    # bit-identical to score_trace on the equivalent trace)
    return [RepeatResult(scorer.score_improvements(*run.improvements(),
                                                   times, baseline),
                         run.fresh_evals, wall_share, run.spent)
            for run in runs]


def _repeat_cell(ctx: tuple, si: int, r: int) -> RepeatResult:
    """Executor task: ``ctx`` is the campaign-constant context shipped once
    per worker (see ``CampaignExecutor.map(shared=...)``)."""
    scorers, make_strategy, seed, times, baselines = ctx
    return run_repeat(scorers[si], make_strategy, r, seed, times[si],
                      baselines[si])


def evaluate_strategy(make_strategy: Callable[[], Strategy],
                      scorers: Sequence[SpaceScorer],
                      repeats: int = 25,
                      n_samples: int = DEFAULT_SAMPLES,
                      seed: int = 0,
                      executor=None,
                      drive: str = "auto") -> AggregateReport:
    """Run a strategy ``repeats`` times on every space in simulation mode and
    aggregate performance curves per Eq. 3.

    ``executor``: optional ``core.parallel.CampaignExecutor``; the
    (space × repeat) grid is fanned out and reduced in fixed space-major
    order, so the aggregate is bit-identical to the serial loop.

    ``drive`` selects how the in-process grid executes: ``"device"``
    drives each space's repeats as one device-resident fused campaign
    (``run_repeats_device``; falls back with a ``FuseFallbackNotice`` when
    ineligible), ``"fused"`` drives them as interleaved host ask/tell runs
    with cross-run batch fusion (``run_repeats_fused``), ``"sequential"``
    runs one cell at a time (``run_repeat``), and ``"auto"`` (default)
    fuses in-process grids on the host — on the device when the scorer's
    engine is ``"jax"``. Scores are bit-identical across all of them —
    the drive only changes wall time; the chosen mode is surfaced as
    ``AggregateReport.fuse``.
    """
    if drive not in ("auto", "device", "fused", "sequential"):
        raise ValueError(f"unknown drive mode {drive!r}")
    names = [s.name for s in scorers]
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate space names in scorers: {names}")
    times = [s.sample_times(n_samples) for s in scorers]
    baselines = [s.baseline_at_time(t) for s, t in zip(scorers, times)]
    cells_idx = [(si, r) for si in range(len(scorers)) for r in range(repeats)]
    cells: list[RepeatResult | None] = [None] * len(cells_idx)
    modes: list[str] = []
    if executor is not None and executor.parallel:
        ctx = (tuple(scorers), make_strategy, seed, times, baselines)
        # chunk the (space × repeat) grid: vectorized cells are cheap, so
        # amortize pool IPC while keeping ≥ ~4 chunks per worker in flight.
        # Cells are never journaled individually (checkpointing happens one
        # level up, per hyperparameter configuration), so chunking does not
        # coarsen campaign resume granularity.
        chunksize = max(1, len(cells_idx) // (executor.workers * 4))
        for i, res in executor.map(_repeat_cell, cells_idx, shared=ctx,
                                   chunksize=chunksize):
            cells[i] = res
        modes.append("sequential")
    else:
        for si, scorer in enumerate(scorers):
            res: "list[RepeatResult] | None" = None
            mode = "sequential"
            if scorer.engine != "scalar" and (
                    drive == "device"
                    or (drive == "auto" and scorer.engine == "jax")):
                res = run_repeats_device(scorer, make_strategy, repeats,
                                         seed, times[si], baselines[si])
                mode = "device"
            if res is None and drive != "sequential" \
                    and scorer.engine != "scalar":
                res, mode = run_repeats_fused(
                    scorer, make_strategy, repeats, seed, times[si],
                    baselines[si])
            if res is None:
                res = [run_repeat(scorer, make_strategy, r, seed, times[si],
                                  baselines[si]) for r in range(repeats)]
                mode = "sequential"
            cells[si * repeats:(si + 1) * repeats] = res
            modes.append(mode)
    per_space: dict[str, np.ndarray] = {}
    per_space_score: dict[str, float] = {}
    fresh = 0
    wall = 0.0
    simulated = 0.0
    for si, scorer in enumerate(scorers):
        acc = np.zeros(n_samples)
        for r in range(repeats):
            cell = cells[si * repeats + r]
            acc += cell.curve
            fresh += cell.fresh_evals
            wall += cell.wall_seconds
            simulated += cell.simulated_seconds
        curve = acc / repeats
        per_space[scorer.name] = curve
        per_space_score[scorer.name] = float(curve.mean())
    mean_curve = np.mean(np.stack(list(per_space.values())), axis=0)
    fuse = modes[0] if len(set(modes)) == 1 else "mixed"
    return AggregateReport(float(mean_curve.mean()), mean_curve, per_space,
                           per_space_score, fresh, wall, simulated, fuse)
