"""T4-style results cache: the on-disk form of a brute-forced search space.

The paper stores hub results in the community T4 JSON format (FAIR sharing of
data in autotuning research [42]); files are compressed for portability
(Sec. III-D: "output files are compressed and decompressed automatically").
We implement a faithful, self-describing subset ("T4-mini"): per-config status,
raw repeated observations, mean objective, and compile time, plus enough
metadata to reconstruct the search space.

The cache is what the simulation mode replays (Sec. III-C): every segment of a
live evaluation (compile, run, overhead) is recorded so a tuning run can be
replayed with exact simulated-time accounting.
"""
from __future__ import annotations

import dataclasses
import gzip
import json
import os
from typing import Mapping, Sequence

import numpy as np

try:  # optional: zstd gives the best ratio, but the stdlib must suffice
    import zstandard
except ImportError:  # pragma: no cover - depends on environment
    zstandard = None

from .searchspace import SearchSpace
from .tunable import Config, Constraint, Tunable

_ZSTD_MAGIC = b"\x28\xb5\x2f\xfd"
_GZIP_MAGIC = b"\x1f\x8b"


def _compress(payload: bytes, path: str) -> bytes:
    """Compress per extension. Without ``zstandard``, ``.zst`` files are
    written gzip-compressed instead — ``_decompress`` sniffs magic bytes, so
    the fallback stays round-trippable and portable."""
    if path.endswith(".zst"):
        if zstandard is not None:
            return zstandard.ZstdCompressor(level=9).compress(payload)
        return gzip.compress(payload, compresslevel=9)
    if path.endswith(".gz"):
        return gzip.compress(payload, compresslevel=9)
    return payload


def _decompress(payload: bytes, path: str) -> bytes:
    """Decompress by magic bytes (extension-agnostic: a ``.zst`` file written
    by the gzip fallback still loads)."""
    if payload[:4] == _ZSTD_MAGIC:
        if zstandard is None:
            raise RuntimeError(
                f"{path} is zstd-compressed but the 'zstandard' module is not "
                f"installed; install it or re-save the cache as .json/.json.gz")
        return zstandard.ZstdDecompressor().decompress(payload)
    if payload[:2] == _GZIP_MAGIC:
        return gzip.decompress(payload)
    return payload


class _Membership:
    """Picklable membership predicate for caches loaded from disk.

    Static constraints excluded configs from the brute force entirely, so
    membership in the results *is* the original validity predicate. A class
    (rather than a closure) so that reconstructed spaces — and the scorers
    built on them — can cross process boundaries in parallel campaigns."""

    def __init__(self, names: tuple, present: frozenset):
        self.names = names
        self.present = present

    def __call__(self, conf: Mapping) -> bool:
        return ",".join(str(conf[n]) for n in self.names) in self.present


@dataclasses.dataclass(frozen=True)
class CachedResult:
    status: str          # "ok" | "error"
    time_s: float        # mean objective (inf for error)
    times_s: tuple       # raw observations
    compile_s: float
    overhead_s: float = 0.0

    @property
    def charge_s(self) -> float:
        """Simulated seconds a live evaluation of this config would cost:
        compile + one execution of every recorded repeat + overhead."""
        return self.compile_s + sum(self.times_s) + self.overhead_s


def result_to_json(r: CachedResult) -> dict:
    """The T4-mini JSON form of one result (shared by cache files and
    recording shards: one schema, one reader/writer pair)."""
    return {
        "status": r.status,
        "time_s": (r.time_s if r.time_s != float("inf") else None),
        "times_s": list(r.times_s),
        "compile_s": r.compile_s,
        "overhead_s": r.overhead_s,
    }


def result_from_json(d: Mapping) -> CachedResult:
    return CachedResult(
        status=d["status"],
        time_s=(float("inf") if d["time_s"] is None else d["time_s"]),
        times_s=tuple(d["times_s"]),
        compile_s=d["compile_s"],
        overhead_s=d.get("overhead_s", 0.0),
    )


def membership_space(kernel: str, device: str,
                     tunables: Mapping[str, Sequence],
                     present: Sequence[str]) -> SearchSpace:
    """Reconstruct a search space whose validity predicate is membership in
    the recorded/brute-forced result set. Static constraints excluded
    configs from the brute force entirely, so membership in the results
    *is* the original validity predicate (runtime failures are present with
    status "error" — they belong to the space)."""
    tun = tuple(Tunable(n, tuple(v)) for n, v in tunables.items())
    member = Constraint(_Membership(tuple(tunables.keys()),
                                    frozenset(present)),
                        "config present in recorded results")
    return SearchSpace(tun, (member,), name=f"{kernel}@{device}")


class CacheColumns:
    """Columnar (array-backed) view of a cache's results.

    The simulation hot path — millions of replayed evaluations per
    hypertuning campaign — is dominated by per-evaluation dict lookups,
    attribute access, and the ``CachedResult.charge_s`` sum recomputed on
    every visit. This view lays the same data out once as contiguous numpy
    arrays plus a config-id hash table, so whole batches of configs resolve
    in one fancy-indexed gather (see ``runner.SimulationRunner.run_batch``
    and ``methodology.make_scorer``).

    Invariants:
      * row order == ``results`` insertion order (the same order the scalar
        path iterates, so fixed-order reductions stay bit-identical);
      * ``charge_s``/``run_s`` are computed with the *scalar* left-to-right
        reductions of ``CachedResult`` — never a numpy pairwise sum — so a
        gathered charge equals the scalar path's to the last bit;
      * the view is immutable; ``CacheFile`` invalidates and rebuilds it on
        mutation (``insert``) so a stale view can never serve lookups.
    """

    __slots__ = ("keys", "index", "records", "time_s", "charge_s",
                 "time_list", "charge_list", "_mean_charge", "_detail",
                 "_space_rows", "_jax")

    def __init__(self, results: Mapping[str, CachedResult]):
        self.keys = tuple(results.keys())
        self.index = {k: i for i, k in enumerate(self.keys)}
        self.records = tuple(results.values())
        # Python-float mirrors of the hot columns: small batches (a
        # population generation) resolve faster through plain list indexing
        # than through numpy's per-call overhead; the arrays win for big
        # gathers. Both views hold bit-identical values.
        self.time_list = [r.time_s for r in self.records]
        self.charge_list = [r.charge_s for r in self.records]  # fixed-order
        self.time_s = np.array(self.time_list, dtype=np.float64)
        self.charge_s = np.array(self.charge_list, dtype=np.float64)
        self._mean_charge: float | None = None
        # compile/run-split detail columns are built on first access — the
        # replay/scoring hot paths never touch them, and every insert
        # invalidation triggers a rebuild of this object
        self._detail: tuple | None = None
        self._space_rows: tuple | None = None  # (compiled, row map) memo
        # device-array mirror (core.engine_jax.ReplayTables), never pickled
        self._jax: tuple | None = None

    def __getstate__(self) -> dict:
        """Columns rarely pickle (``CacheFile`` drops them), but when they
        do, the space-keyed memos stay behind: ``_space_rows`` drags a
        whole ``CompiledSpace`` along and ``_jax`` holds device arrays
        that must not cross process boundaries."""
        return {k: getattr(self, k) for k in self.__slots__
                if k not in ("_space_rows", "_jax")}

    def __setstate__(self, state: dict) -> None:
        for k, v in state.items():
            setattr(self, k, v)
        self._space_rows = None
        self._jax = None

    def __len__(self) -> int:
        return len(self.keys)

    def _detail_columns(self) -> tuple:
        if self._detail is None:
            n = len(self.records)
            compile_s = np.empty(n, dtype=np.float64)
            run_s = np.empty(n, dtype=np.float64)
            overhead_s = np.empty(n, dtype=np.float64)
            ok = np.empty(n, dtype=bool)
            for i, r in enumerate(self.records):
                compile_s[i] = r.compile_s
                run_s[i] = sum(r.times_s)  # scalar fixed-order reduction
                overhead_s[i] = r.overhead_s
                ok[i] = r.status == "ok"
            self._detail = (compile_s, run_s, overhead_s, ok)
        return self._detail

    @property
    def compile_s(self) -> np.ndarray:
        return self._detail_columns()[0]

    @property
    def run_s(self) -> np.ndarray:
        return self._detail_columns()[1]

    @property
    def overhead_s(self) -> np.ndarray:
        return self._detail_columns()[2]

    @property
    def ok(self) -> np.ndarray:
        return self._detail_columns()[3]

    @property
    def mean_charge(self) -> float:
        """Bit-identical to the scalar ``CacheFile.mean_eval_charge`` (a
        Python left-to-right sum over insertion order, not ``np.mean``)."""
        if self._mean_charge is None:
            self._mean_charge = sum(self.charge_list) / len(self.charge_list)
        return self._mean_charge

    def rows_for(self, keys: Sequence[str]) -> np.ndarray:
        """Row indices for a batch of config-id keys; -1 marks keys outside
        the recorded set (the replay treats those as failed compiles)."""
        idx = self.index
        return np.fromiter((idx.get(k, -1) for k in keys),
                           dtype=np.int64, count=len(keys))

    def rows_for_space(self, compiled) -> np.ndarray:
        """Space-row -> cache-row map for a ``core.space.CompiledSpace``:
        the bridge that lets the index-native hot path gather results by
        integer row with no per-evaluation string-id hash probe. Built once
        per (columns, compiled space) pair — config-id strings survive only
        in this one-time boundary translation (and the cache file itself).
        Space rows absent from the recorded set map to -1 (imputed-miss
        semantics, like ``rows_for``)."""
        cached = self._space_rows
        if cached is not None and cached[0] is compiled:
            return cached[1]
        rows = self.rows_for(compiled.ids)
        # plain-list mirror rides along: small-batch commits index it with
        # Python ints (see SimulationRunner._commit_rows_loop), and building
        # it once here keeps short-lived runners (a 25-repeat grid's worth)
        # from each paying an O(n_valid) tolist
        rows.flags.writeable = False
        self._space_rows = (compiled, rows, rows.tolist())
        return rows

    def rows_for_space_list(self, compiled) -> list:
        """The ``rows_for_space`` map as a plain list (same cache entry)."""
        cached = self._space_rows
        if cached is None or cached[0] is not compiled:
            self.rows_for_space(compiled)
            cached = self._space_rows
        return cached[2]


class CacheFile:
    """In-memory view of one brute-forced search space (kernel × device)."""

    def __init__(self, kernel: str, device: str, space: SearchSpace,
                 results: Mapping[str, CachedResult], meta: dict | None = None):
        self.kernel = kernel
        self.device = device
        self.space = space
        self.results = dict(results)
        self.meta = dict(meta or {})
        self._columns: CacheColumns | None = None

    # ------------------------------------------------------------------- api
    def lookup(self, config: Config) -> CachedResult:
        return self.results[self.space.config_id(config)]

    @property
    def columns(self) -> CacheColumns:
        """The columnar view, built lazily and rebuilt after mutation.

        The length guard also catches direct ``results`` dict additions, so
        code that bypasses ``insert`` still never sees stale arrays."""
        cols = self._columns
        if cols is None or len(cols) != len(self.results):
            cols = self._columns = CacheColumns(self.results)
        return cols

    def invalidate_columns(self) -> None:
        """Drop the columnar view; the next ``columns`` access rebuilds it."""
        self._columns = None

    def insert(self, key: str, result: CachedResult,
               overwrite: bool = False) -> None:
        """Add one observation under its ``space.config_id`` key.

        Recorded caches are built incrementally (shards of a live tuning run
        fold in one observation at a time); re-inserting an existing key with
        a different result raises unless ``overwrite`` — silently keeping one
        of two conflicting measurements would corrupt the replay.

        Any columnar view is invalidated: a cache mutated after its arrays
        were built must never serve stale lookups.
        """
        prior = self.results.get(key)
        if prior is not None and prior != result and not overwrite:
            raise ValueError(
                f"cache {self.kernel}@{self.device} already holds a "
                f"different result for config {key!r}")
        self.results[key] = result
        self._columns = None

    def __getstate__(self) -> dict:
        """Pickle without the columnar arrays: parallel campaigns ship caches
        to worker processes once per pool (``parallel.CampaignExecutor``),
        and the view rebuilds lazily on first use — shipping it would roughly
        double the payload for no benefit."""
        state = self.__dict__.copy()
        state["_columns"] = None
        return state

    @property
    def ok_values(self) -> list:
        return [r.time_s for r in self.results.values() if r.status == "ok"]

    @property
    def optimum(self) -> float:
        vals = self.ok_values
        if not vals:
            raise ValueError(
                f"cache {self.kernel}@{self.device} has no successful "
                f"results ({len(self.results)} recorded, all "
                f"{'errors' if self.results else 'missing'}); "
                "a partial recording must cover at least one ok config "
                "before it can be replayed")
        return min(vals)

    def mean_eval_charge(self) -> float:
        """Average simulated cost of one fresh evaluation — used for the
        calculated random-search baseline's time axis. Served from the
        columnar view (the scalar path recomputed the whole sum on every
        out-of-space lookup); the reduction order is unchanged."""
        if not self.results:
            raise ValueError(
                f"cache {self.kernel}@{self.device} is empty (no recorded "
                "evaluations); record or brute-force the space first")
        return self.columns.mean_charge

    # -------------------------------------------------------------------- io
    def to_json(self) -> dict:
        return {
            "format": "T4-mini",
            "format_version": "1.0",
            "kernel": self.kernel,
            "device": self.device,
            "objective": "time_s",
            "tunables": {t.name: list(t.values) for t in self.space.tunables},
            "constraints": [c.description for c in self.space.constraints],
            "meta": self.meta,
            "results": {key: result_to_json(r)
                        for key, r in self.results.items()},
        }

    def save(self, path: str) -> None:
        """Write .json or .json.zst depending on extension; atomic rename."""
        payload = _compress(json.dumps(self.to_json()).encode(), path)
        tmp = path + ".tmp"
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(tmp, "wb") as f:
            f.write(payload)
        os.replace(tmp, path)

    @staticmethod
    def load(path: str, space: SearchSpace | None = None) -> "CacheFile":
        with open(path, "rb") as f:
            payload = f.read()
        d = json.loads(_decompress(payload, path))
        if d.get("format") != "T4-mini":
            raise ValueError(f"unknown cache format {d.get('format')!r}")
        if space is None:
            space = membership_space(d["kernel"], d["device"], d["tunables"],
                                     d["results"].keys())
        results = {key: result_from_json(r)
                   for key, r in d["results"].items()}
        return CacheFile(d["kernel"], d["device"], space, results, d.get("meta"))
