"""Analytical TPU kernel cost model — the hub's stand-in for hardware.

The paper brute-forces each (kernel × device) search space on real hardware
(Table II: 962 h total). Here the measurement role is played by a roofline
cost model over the simulated device models: per config we derive

    t = max(flops / (peak × eff(config)), hbm_bytes(config) / bw) + overhead

with ``eff`` capturing MXU/VPU utilization losses from tile misalignment and
pipeline underutilization, plus per-tile grid launch overhead. Configs whose
working set exceeds VMEM *fail at compile time* (status "error"), like real
auto-tuning failures. Deterministic log-normal noise seeded by
(device, kernel, config) provides the 32 raw observations stored in the T4
data, so the statistical pipeline matches the paper's exactly.

The resulting spaces keep the structural properties the paper's method relies
on: discontinuous, non-convex, device-dependent optima, partial invalidity.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Callable, Mapping

import numpy as np

from .devices import DeviceModel

N_OBSERVATIONS = 32  # per-config repeats stored in the hub (paper Sec. III-D)


@dataclasses.dataclass(frozen=True)
class KernelWorkload:
    """Analytic description of one kernel instance (problem sizes bound).

    The callables receive the config as a dict {tunable: value}.
      flops:       useful FLOPs of the whole problem (config-independent
                   unless the config changes the algorithm, e.g. split-k)
      hbm_bytes:   HBM traffic given the tiling (captures reuse)
      vmem_bytes:  per-core working set given the tiling (VMEM gate)
      grid_size:   number of Pallas program instances (launch/loop overhead)
      compute_eff: 0..1 utilization multiplier from alignment/shape effects
    """

    name: str
    flops: Callable[[Mapping], float]
    hbm_bytes: Callable[[Mapping, DeviceModel], float]
    vmem_bytes: Callable[[Mapping], float]
    grid_size: Callable[[Mapping], float]
    compute_eff: Callable[[Mapping, DeviceModel], float]


@dataclasses.dataclass(frozen=True)
class CostEstimate:
    status: str                # "ok" | "error"
    time_s: float              # mean of observations (inf when error)
    times_s: tuple             # raw observations
    compile_s: float
    compute_s: float = 0.0
    memory_s: float = 0.0
    reason: str = ""


def _seed_for(device: DeviceModel, kernel: str, config_id: str) -> int:
    h = hashlib.sha256(f"{device.name}|{kernel}|{config_id}".encode()).digest()
    return int.from_bytes(h[:8], "little")


def alignment_eff(dim: int, align: int, floor: float = 0.25) -> float:
    """Utilization multiplier for a dim padded up to a multiple of ``align``.

    dim=align → 1.0; dim=align+1 → ≈0.5 (half the padded tile wasted); small
    dims bottom out at ``floor`` (VPU still does something useful).
    """
    if dim <= 0:
        return floor
    padded = -(-dim // align) * align
    return max(floor, dim / padded)


def dma_eff(block_bytes: float, floor: float = 0.08) -> float:
    """HBM streaming efficiency as a function of the DMA block size.

    Small blocks underutilize the HBM channels (request overhead, no
    prefetch depth); full efficiency needs ~MiB-scale transfers. This is the
    term that makes near-optimal configurations *sparse* — as in real
    auto-tuning spaces, only a narrow band of tilings streams at full
    bandwidth.
    """
    full = 2.0 * 2**20
    return max(floor, min(1.0, (block_bytes / full) ** 0.6))


def estimate(workload: KernelWorkload, config: Mapping, device: DeviceModel,
             config_id: str) -> CostEstimate:
    vmem = workload.vmem_bytes(config)
    compile_s = device.compile_s
    if vmem > device.vmem_bytes:
        # compile-time failure: charged at compile cost, no runtime
        return CostEstimate("error", float("inf"), (), compile_s,
                            reason=f"VMEM overflow: {vmem/2**20:.1f} MiB")

    flops = workload.flops(config)
    bytes_hbm = workload.hbm_bytes(config, device)
    eff = max(1e-3, min(1.0, workload.compute_eff(config, device)))
    grid = max(1.0, workload.grid_size(config))

    compute_s = flops / (device.peak_flops * eff)
    memory_s = bytes_hbm / device.hbm_bw
    # per-tile fixed cost (control, DMA issue): 120 ns per program instance,
    # partially hidden behind the dominant term.
    launch_s = grid * 120e-9
    base = max(compute_s, memory_s) + 0.35 * min(compute_s, memory_s) + launch_s
    base += device.overhead_s

    rng = np.random.default_rng(_seed_for(device, workload.name, config_id))
    times = base * rng.lognormal(mean=0.0, sigma=device.noise_sigma,
                                 size=N_OBSERVATIONS)
    times = tuple(float(t) for t in times)
    return CostEstimate("ok", float(np.mean(times)), times, compile_s,
                        compute_s=compute_s, memory_s=memory_s)
