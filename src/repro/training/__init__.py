"""repro subpackage."""
