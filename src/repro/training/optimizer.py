"""AdamW with warmup-cosine schedule and global-norm clipping (pure JAX).

Parameters are fp32 masters; first/second moments fp32. ``mu_dtype=bf16`` is
available as a memory trick for the largest models (halves optimizer HBM at
negligible quality cost — a standard large-scale deployment option).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    mu_dtype: str = "float32"      # "bfloat16" halves optimizer memory


def schedule(cfg: OptimizerConfig, step) -> jax.Array:
    step = jnp.asarray(step, jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    progress = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    progress = jnp.clip(progress, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * progress))
    return cfg.peak_lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(cfg: OptimizerConfig, params) -> dict:
    mu_dt = jnp.dtype(cfg.mu_dtype)
    return {
        "mu": jax.tree.map(lambda p: jnp.zeros_like(p, dtype=mu_dt), params),
        "nu": jax.tree.map(lambda p: jnp.zeros_like(p), params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(cfg: OptimizerConfig, params, grads, opt_state):
    """One AdamW step. Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    c1 = 1 - b1 ** step.astype(jnp.float32)
    c2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu_new = b1 * mu.astype(jnp.float32) + (1 - b1) * g
        nu_new = b2 * nu + (1 - b2) * g * g
        mhat = mu_new / c1
        vhat = nu_new / c2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p
        return (p - lr * delta, mu_new.astype(mu.dtype), nu_new)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(opt_state["mu"])
    flat_nu = jax.tree.leaves(opt_state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in
           zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_mu = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_nu = jax.tree.unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"mu": new_mu, "nu": new_nu, "step": step}, metrics
