"""Train-step factory: loss, microbatch gradient accumulation, remat.

``make_train_step`` closes over the arch/optimizer configs and returns a
pure function (state, batch) -> (state, metrics) suitable for jit with
donated state. Microbatching is a ``lax.scan`` over batch splits (the
standard accumulate-then-update schedule); remat policy is applied per
layer inside the model's layer scan.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..models.transformer import forward, init_params
from .optimizer import OptimizerConfig, adamw_update, init_opt_state


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    microbatches: int = 1
    remat: str = "full"           # none | dots | full
    z_loss: float = 1e-4          # logit norm regularizer (stability)


def cross_entropy(logits: jax.Array, targets: jax.Array,
                  z_loss: float = 0.0) -> jax.Array:
    """Mean next-token CE (fp32). logits: (B,S,V); targets: (B,S) int32.

    The gold logit is extracted with an iota-mask reduction rather than
    ``take_along_axis``: a gather across a vocab-sharded logits tensor makes
    GSPMD replicate the whole (B,S,V) fp32 array per chip ("involuntary full
    rematerialization"); the masked reduction stays sharded and fuses.
    """
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                          logits.ndim - 1)
    gold = jnp.sum(jnp.where(vocab_iota == targets[..., None], logits, 0.0),
                   axis=-1)
    loss = (lse - gold).mean()
    if z_loss:
        loss = loss + z_loss * jnp.square(lse).mean()
    return loss


def init_train_state(cfg: ArchConfig, opt_cfg: OptimizerConfig, key) -> dict:
    params = init_params(cfg, key)
    return {"params": params, "opt": init_opt_state(opt_cfg, params)}


def _split_batch(batch: dict, n: int) -> dict:
    """(B, ...) -> (n, B/n, ...) for every leaf."""
    def r(x):
        b = x.shape[0]
        assert b % n == 0, f"batch {b} not divisible by {n} microbatches"
        return x.reshape((n, b // n) + x.shape[1:])
    return jax.tree.map(r, batch)


def chunked_cross_entropy(cfg: ArchConfig, params, x: jax.Array,
                          targets: jax.Array, z_loss: float = 0.0,
                          chunk: int = 512) -> jax.Array:
    """CE computed per sequence chunk so the (B,S,V) logits never
    materialize — essential when the vocab does not divide the model axis
    (e.g. mamba2's 50280) and the logits would otherwise be replicated in
    fp32 per chip. The chunk body is checkpointed; backward recomputes each
    chunk's logits."""
    from ..models.transformer import _unembed

    b, s, d = x.shape
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)), constant_values=-1)
    n = (s + pad) // chunk
    xc = x.reshape(b, n, chunk, d).transpose(1, 0, 2, 3)
    tc_ = targets.reshape(b, n, chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def body(acc, inp):
        xb, tb = inp
        logits = _unembed(cfg, params, xb)          # (B, chunk, V) fp32
        lse = jax.nn.logsumexp(logits, axis=-1)
        iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
        gold = jnp.sum(jnp.where(iota == tb[..., None], logits, 0.0), axis=-1)
        valid = (tb >= 0).astype(jnp.float32)
        loss_sum = jnp.sum((lse - gold) * valid)
        if z_loss:
            loss_sum = loss_sum + z_loss * jnp.sum(jnp.square(lse) * valid)
        return (acc[0] + loss_sum, acc[1] + valid.sum()), None

    (loss_sum, count), _ = jax.lax.scan(body, (0.0, 0.0), (xc, tc_))
    return loss_sum / jnp.maximum(count, 1.0)


def make_loss_fn(cfg: ArchConfig, tc: TrainConfig) -> Callable:
    def loss_fn(params, batch):
        tokens = batch["tokens"]
        model_batch = dict(batch)
        model_batch["tokens"] = tokens[:, :-1]
        if "positions" in model_batch:
            model_batch["positions"] = model_batch["positions"][:, :-1]
        x = forward(cfg, params, model_batch, remat=tc.remat,
                    pre_logits=True)
        return chunked_cross_entropy(cfg, params, x, tokens[:, 1:], tc.z_loss)
    return loss_fn


def make_train_step(cfg: ArchConfig, opt_cfg: OptimizerConfig,
                    tc: TrainConfig = TrainConfig()) -> Callable:
    loss_fn = make_loss_fn(cfg, tc)
    grad_fn = jax.value_and_grad(loss_fn)

    def train_step(state: dict, batch: dict):
        params = state["params"]
        if tc.microbatches > 1:
            micro = _split_batch(batch, tc.microbatches)

            def acc_body(carry, mb):
                loss_acc, grad_acc = carry
                loss, grads = grad_fn(params, mb)
                grad_acc = jax.tree.map(jnp.add, grad_acc, grads)
                return (loss_acc + loss, grad_acc), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(acc_body, (0.0, zeros), micro)
            inv = 1.0 / tc.microbatches
            loss = loss * inv
            grads = jax.tree.map(lambda g: g * inv, grads)
        else:
            loss, grads = grad_fn(params, batch)
        new_params, new_opt, metrics = adamw_update(
            opt_cfg, params, grads, state["opt"])
        metrics["loss"] = loss
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step
