"""Roofline analysis from compiled dry-run artifacts (deliverable g).

Three terms per (arch × shape × mesh):

  compute    = HLO_FLOPs_per_chip / peak_FLOP/s
  memory     = HLO_bytes_per_chip / HBM_bw
  collective = wire_bytes_per_chip / link_bw

``cost_analysis()`` on a GSPMD-partitioned module reports **per-device**
FLOPs/bytes (verified experimentally: global HLO flops / n_chips). Collective
bytes are not in cost_analysis, so the post-optimization HLO text is parsed:
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute instruction contributes ring-model bytes-on-wire
((g-1)/g × payload for AG/RS/A2A, 2(g-1)/g for AR, 1 hop for permute).

MODEL_FLOPS uses the standard 6·N·T (train) / 2·N·T (inference) parameter
term plus the attention term; the ratio MODEL_FLOPS / (chips × HLO_FLOPs)
exposes remat/padding/dispatch overhead in the compiled module.
"""
from __future__ import annotations

import dataclasses
import re

from ..configs import ArchConfig, ShapeConfig

# TPU v5e constants (assignment-specified)
PEAK_FLOPS = 197e12       # bf16 / chip
HBM_BW = 819e9            # bytes/s / chip
LINK_BW = 50e9            # bytes/s / ICI link

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[^\]]*\]\S*))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_COMP_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{")
_CALL_RE = re.compile(
    r"(?:condition|body|to_apply|calls|true_computation|false_computation)"
    r"=%([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _max_element_bytes(type_str: str) -> float:
    """Largest tuple element (== the full buffer for -start variants)."""
    best = 0.0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        best = max(best, n * _DTYPE_BYTES.get(dt, 4))
    return best


@dataclasses.dataclass
class CollectiveSummary:
    counts: dict              # per type, trip-count-weighted dynamic counts
    wire_bytes: dict          # per type, aggregate across chips
    total_wire_bytes: float

    def to_json(self) -> dict:
        return {"counts": self.counts, "wire_bytes": self.wire_bytes,
                "total_wire_bytes": self.total_wire_bytes}


def _split_computations(hlo_text: str) -> dict:
    comps: dict = {}
    entry = None
    name = None
    for line in hlo_text.splitlines():
        m = _COMP_HEADER_RE.match(line.strip())
        if m and ("->" in line):
            name = m.group(1)
            comps[name] = []
            if line.strip().startswith("ENTRY"):
                entry = name
        elif name is not None:
            comps[name].append(line)
    return comps, entry


def _trip_count(cond_lines) -> int:
    """Loop bound heuristic: max integer constant in the condition
    computation (scan conditions compare the counter to the bound)."""
    best = 1
    for line in cond_lines:
        for c in _CONST_RE.findall(line):
            best = max(best, int(c))
    return best


def parse_collectives(hlo_text: str, n_chips: int) -> CollectiveSummary:
    """Trip-count-aware collective accounting.

    HLO prints a while-loop body once, but its collectives execute once per
    iteration; this walks the call graph from ENTRY multiplying by loop trip
    counts (parsed from the loop conditions) so scan-over-layers schedules
    are charged correctly.
    """
    comps, entry = _split_computations(hlo_text)
    counts: dict = {}
    wire: dict = {}

    def visit(name: str, mult: float, stack: frozenset) -> None:
        if name not in comps or name in stack:
            return
        lines = comps[name]
        stack = stack | {name}
        for line in lines:
            m = _COLL_RE.search(line)
            if m:
                type_str, op = m.group(1), m.group(2)
                payload = _max_element_bytes(type_str)
                g = n_chips
                gm = _GROUPS_RE.search(line)
                if gm:
                    g = len(gm.group(1).split(","))
                else:
                    gi = _GROUPS_IOTA_RE.search(line)
                    if gi:
                        g = int(gi.group(2))
                g = max(g, 1)
                if op == "all-reduce":
                    per_chip = 2 * (g - 1) / g * payload
                elif op == "collective-permute":
                    per_chip = payload
                else:  # all-gather / reduce-scatter / all-to-all
                    per_chip = (g - 1) / g * payload
                counts[op] = counts.get(op, 0) + mult
                wire[op] = wire.get(op, 0.0) + per_chip * n_chips * mult
            # nested computations
            if " while(" in line:
                calls = dict()
                for kind, callee in re.findall(
                        r"(condition|body)=%([\w.\-]+)", line):
                    calls[kind] = callee
                trips = _trip_count(comps.get(calls.get("condition"), []))
                if "body" in calls:
                    visit(calls["body"], mult * trips, stack)
            else:
                for callee in _CALL_RE.findall(line):
                    visit(callee, mult, stack)
                bm = _BRANCHES_RE.search(line)
                if bm:
                    for callee in re.findall(r"%([\w.\-]+)", bm.group(1)):
                        visit(callee, mult, stack)

    if entry:
        visit(entry, 1.0, frozenset())
    total = sum(wire.values())
    return CollectiveSummary({k: round(v, 1) for k, v in counts.items()},
                             wire, total)


# ------------------------------------------------------------ analytic cost
def _fwd_flops(cfg: ArchConfig, b: int, s: int) -> float:
    """Forward FLOPs of one teacher-forced pass (global, all layers)."""
    d, dh = cfg.d_model, cfg.d_head
    t = b * s
    # attention projections (q, k, v, o)
    proj = 2 * t * d * (2 * cfg.n_heads * dh + 2 * cfg.n_kv_heads * dh)
    # attention core (QKᵀ + PV), causal ⇒ ×0.5; local layers see the window
    if cfg.is_attention_free:
        core = 0.0
    elif cfg.global_every and cfg.window:
        w = min(cfg.window, s)
        loc = cfg.n_layers * (1 - 1 / cfg.global_every)
        glo = cfg.n_layers / cfg.global_every
        core = 4 * b * cfg.n_heads * dh * (loc * s * w + glo * s * s * 0.5) \
            / cfg.n_layers
    else:
        w = min(cfg.window or s, s)
        core = 4 * b * cfg.n_heads * dh * s * min(w, s) * (0.5 if not cfg.window else 1.0)
    mlp_mult = 3 if cfg.act in ("swiglu", "geglu") else 2
    if cfg.family == "moe":
        t_eff = t * cfg.top_k * cfg.capacity_factor  # capacity-padded
        ffn = 2 * t_eff * mlp_mult * d * cfg.d_ff_expert + 2 * t * d * cfg.n_experts
    elif cfg.family in ("ssm", "hybrid"):
        ffn = 0.0
    else:
        ffn = 2 * t * mlp_mult * d * cfg.d_ff
    per_layer = proj + core + ffn
    if cfg.family in ("ssm", "hybrid"):
        d_in = cfg.ssm_expand * d
        n = cfg.ssm_state
        q = cfg.ssm_chunk
        nh = cfg.ssm_heads
        p = d_in // max(nh, 1)
        ssd = (2 * t * d * (2 * d_in + 2 * n + nh)       # in_proj
               + 2 * t * d_in * d                         # out_proj
               + (s // max(q, 1)) * b * nh *
               (2 * q * q * n / max(nh, 1) + 2 * q * q * p + 4 * q * n * p))
        if cfg.family == "ssm":
            per_layer = ssd
        else:
            # hybrid: mamba everywhere + one shared attn block per group
            shared = (proj + core + 2 * t * mlp_mult * d * cfg.d_ff)
            n_shared = cfg.n_layers // max(cfg.shared_attn_every, 1)
            return (cfg.n_layers * ssd + n_shared * shared
                    + 2 * t * d * cfg.vocab)
    total_layers = cfg.n_layers + (cfg.n_encoder_layers if cfg.family == "audio" else 0)
    if cfg.family == "audio":  # cross-attention adds one more attn per layer
        per_layer = per_layer + proj / 2 + 4 * b * s * cfg.n_audio_frames * cfg.n_heads * dh / 2
    return total_layers * per_layer + 2 * t * d * cfg.vocab  # + unembed


def analytic_cost(cfg: ArchConfig, shape: ShapeConfig, remat: str = "full",
                  n_chips: int = 1) -> tuple:
    """(flops_per_chip, hbm_bytes_per_chip) — analytic, trip-count-exact.

    Used for the compute/memory roofline terms because XLA's
    ``cost_analysis()`` counts while-loop (scan) bodies once instead of
    ×trip-count; validated against cost_analysis on trip-count-1 configs in
    tests/test_roofline.py.
    """
    b, s = shape.global_batch, shape.seq_len
    p_total = cfg.param_count()
    p_active = cfg.active_param_count()
    if shape.kind == "train":
        fwd = _fwd_flops(cfg, b, s)
        mult = 3.0 + (1.0 if remat == "full" else 0.33 if remat == "dots" else 0.0)
        flops = fwd * mult
        act_bytes = 14 * b * s * cfg.d_model * 2 * max(cfg.n_layers, 1)
        logits_bytes = 4 * b * s * cfg.vocab * 3
        # params: bf16 fwd/bwd/remat reads + fp32 grad w/r + AdamW p/mu/nu r+w
        param_bytes = p_total * (2 * (2 + (1 if remat == "full" else 0))
                                 + 4 * 2 + 4 * 6)
        hbm = act_bytes * 2.5 + logits_bytes + param_bytes
    elif shape.kind == "prefill":
        flops = _fwd_flops(cfg, b, s)
        act_bytes = 14 * b * s * cfg.d_model * 2 * max(cfg.n_layers, 1)
        hbm = act_bytes + p_active * 2 + 4 * b * s * cfg.vocab
    else:  # decode: one token
        d, dh = cfg.d_model, cfg.d_head
        flops = 2.0 * p_active * b
        kv_read = 0.0
        if not cfg.is_attention_free:
            n_kv_layers = (cfg.n_layers if cfg.family != "hybrid"
                           else cfg.n_layers // max(cfg.shared_attn_every, 1))
            if cfg.global_every and cfg.window:
                w = min(cfg.window, s)
                eff_s = (w * (1 - 1 / cfg.global_every)
                         + s / cfg.global_every)
            else:
                eff_s = s
            flops += 4.0 * n_kv_layers * b * eff_s * cfg.n_kv_heads * dh
            kv_read = n_kv_layers * b * eff_s * cfg.n_kv_heads * dh * 2 * 2
        ssm_read = 0.0
        if cfg.ssm_state:
            d_in = cfg.ssm_expand * d
            ssm_read = cfg.n_layers * b * (d_in // max(cfg.ssm_d_head, 1)) \
                * cfg.ssm_state * cfg.ssm_d_head * 4
            flops += cfg.n_layers * b * 6 * d_in * cfg.ssm_state
        hbm = p_active * 2 + kv_read + ssm_read + b * cfg.vocab * 4
    return flops / n_chips, hbm / n_chips


# --------------------------------------------------------------- model flops
def model_flops(cfg: ArchConfig, shape: ShapeConfig) -> float:
    """Useful model FLOPs for the cell: the forward cost with *no* waste
    (capacity factor 1, no remat); ≈ 6·N·T (train) / 2·N·T (inference) plus
    the attention term, with window/hybrid structure accounted for."""
    import dataclasses as _dc
    ideal = (_dc.replace(cfg, capacity_factor=1.0)
             if cfg.family == "moe" else cfg)
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        return 3.0 * _fwd_flops(ideal, b, s)
    if shape.kind == "prefill":
        return _fwd_flops(ideal, b, s)
    # decode: one token against an s-deep cache
    param_term = 2.0 * ideal.active_param_count() * b
    if cfg.is_attention_free:
        attn = 0.0
    else:
        n_attn_layers = (cfg.n_layers // max(cfg.shared_attn_every, 1)
                         if cfg.family == "hybrid" else cfg.n_layers)
        if cfg.global_every and cfg.window:
            eff_s = (min(cfg.window, s) * (1 - 1 / cfg.global_every)
                     + s / cfg.global_every)
        else:
            eff_s = s
        attn = 4.0 * n_attn_layers * b * eff_s * cfg.n_kv_heads * cfg.d_head
    ssm = (cfg.n_layers * b * 6 * cfg.ssm_expand * cfg.d_model * cfg.ssm_state
           if cfg.ssm_state else 0.0)
    return param_term + attn + ssm


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    useful_ratio: float       # MODEL_FLOPS / (chips × HLO_FLOPs)

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


def roofline(flops_per_chip: float, bytes_per_chip: float,
             collective_wire_bytes: float, n_chips: int,
             mflops: float) -> Roofline:
    compute_s = flops_per_chip / PEAK_FLOPS
    memory_s = bytes_per_chip / HBM_BW
    collective_s = collective_wire_bytes / (n_chips * LINK_BW)
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    total_hlo_flops = flops_per_chip * n_chips
    ratio = mflops / total_hlo_flops if total_hlo_flops else 0.0
    return Roofline(compute_s, memory_s, collective_s, dominant, mflops,
                    ratio)
