"""repro subpackage."""
