"""Deprecation warning categories for redesigned API surfaces.

Each retired surface keeps thin delegating shims that emit a *dedicated*
``DeprecationWarning`` subclass, and ``pytest.ini`` escalates exactly those
categories to errors — so no in-tree caller can quietly keep using a
retired entry point, while out-of-tree callers get an ordinary, filterable
deprecation period. (Precedent: ``core.driver.ProtocolDeprecationWarning``
for the pre-ask/tell strategy protocol.)

The classes live in this dependency-free module so that warning filters —
``error::repro.deprecations.HubDeprecationWarning`` — can resolve their
category without importing (and thereby warning through) the shims
themselves.
"""
from __future__ import annotations


class HubDeprecationWarning(DeprecationWarning):
    """The ``core.dataset`` free functions (``build_hub`` / ``load_hub`` /
    ``train_test_caches``) moved to ``repro.hub`` (storage layer) and the
    ``repro.api.Hub`` facade."""


class ServingMovedWarning(DeprecationWarning):
    """``repro.serving`` (LLM token serving) moved to ``repro.inference``;
    ``repro.service`` now unambiguously means the ConfigHub tuning
    service."""
