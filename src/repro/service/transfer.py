"""Nearest-problem-shape transfer for ConfigHub lookups.

When a lookup misses the recorded index exactly, the service answers with
the best config of the *nearest recorded problem* (possibly on another
device) plus a provenance/confidence record — the classic transfer-tuning
fallback of hosted tuners (MindOpt Tuner's cold-start story,
arXiv:2307.08085).

Distance is computed in log-space over the shared numeric problem
dimensions — tile/shape optima track *ratios* (a 4096→8192 GEMM is as far
from 4096 as 4096 is from 2048), so ``ln(a/b)`` is the right metric — with
a constant penalty per non-comparable dimension (missing on one side, or
non-numeric and unequal). The result is deterministic and symmetric:
``shape_distance(a, b) == shape_distance(b, a)``.
"""
from __future__ import annotations

import math
from typing import Mapping

# penalty added per problem dimension that the two shapes cannot compare
# numerically; deliberately >= 1 so "same dims, 2x scale" (distance ln 2)
# always beats "different dims entirely"
UNSHARED_PENALTY = 1.0

# a transfer from another device is trusted less than one from another
# problem shape on the same device: optima move with the compute/bandwidth
# balance (paper Sec. II) even when the shape matches exactly
CROSS_DEVICE_PENALTY = 0.5


def shape_distance(a: Mapping, b: Mapping) -> float:
    """Normalized distance between two problem-size dicts (0.0 = identical).

    RMS of ``ln(a[k]/b[k])`` over the dimensions both shapes share with
    positive numeric values, plus ``UNSHARED_PENALTY`` for every dimension
    only one side has (or both have but cannot be compared as positive
    numbers and are unequal).
    """
    shared_sq = []
    penalty = 0.0
    for k in sorted(set(a) | set(b)):
        if k not in a or k not in b:
            penalty += UNSHARED_PENALTY
            continue
        va, vb = a[k], b[k]
        numeric = (isinstance(va, (int, float)) and not isinstance(va, bool)
                   and isinstance(vb, (int, float))
                   and not isinstance(vb, bool))
        if numeric and va > 0 and vb > 0:
            shared_sq.append(math.log(va / vb) ** 2)
        elif va == vb:
            shared_sq.append(0.0)
        else:
            penalty += UNSHARED_PENALTY
    base = math.sqrt(sum(shared_sq) / len(shared_sq)) if shared_sq else 0.0
    return base + penalty


def transfer_confidence(distance: float, cross_device: bool) -> float:
    """Confidence in a transferred config, in (0, 1]: 1 at distance 0 on
    the same device, decaying with shape distance and a flat cross-device
    penalty. Exact hits report 1.0 without going through here."""
    return 1.0 / (1.0 + distance
                  + (CROSS_DEVICE_PENALTY if cross_device else 0.0))


def donor_order_key(distance: float, cross_device: bool, pkey: str,
                    device: str) -> tuple:
    """Deterministic total order for donor selection: nearest shape first,
    same-device before cross-device at equal distance, then lexicographic
    (problem_key, device) so ties never depend on index/dict order."""
    return (distance, cross_device, pkey, device)
