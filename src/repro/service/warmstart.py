"""Single-flight warm-start campaigns for cold ConfigHub keys.

A *cold* key — a kernel with nothing recorded anywhere in the hub — cannot
be answered from data. With warm-start enabled, the service launches a
journaled recording campaign for the key **exactly once** (single-flight:
every concurrent lookup of the same cold key joins the one in-flight
campaign) and serves the incumbent best as observations stream into the
campaign's crash-safe shards.

The campaign is ``Tuner.record`` against the cost-model runner for the
requested device model — the same ``CampaignJournal``-backed
``ObservationShard`` machinery as ``python -m repro record``, so a killed
service resumes the recording instead of re-measuring, and the journal is
the single-flight token across restarts too. On completion the merged
cache is registered into the hub (``storage.register_cache``) and live
indexes are invalidated; the next lookup is an exact hit.
"""
from __future__ import annotations

import os
import threading
from typing import Mapping

from ..core import record as rec
from ..hub import storage

# incumbent confidence saturates with recorded ok-observations: 8 ok configs
# -> 0.5, full completion reported by the exact path at 1.0 afterwards
CONFIDENCE_SCALE = 8.0


class WarmStartFlight:
    """One in-flight (or finished) warm-start campaign for a cold key."""

    def __init__(self, kernel: str, device: str, problem: dict,
                 prefix: str, n_workers: int):
        self.kernel = kernel
        self.device = device
        self.problem = problem
        self.prefix = prefix
        self.n_workers = n_workers
        self.done = threading.Event()
        self.error: BaseException | None = None
        self._space = None

    def join(self, timeout: float | None = None) -> bool:
        """Block until the campaign finishes; True when done."""
        return self.done.wait(timeout)

    def incumbent(self) -> tuple[dict | None, float | None, int]:
        """Best (config, value, n_ok) observed so far, read from the
        campaign's journal shards — safe while workers are appending
        (torn trailing lines are skipped by the shard reader)."""
        paths = [p for p in (rec.shard_path(self.prefix, w)
                             for w in range(self.n_workers))
                 if os.path.exists(p)]
        best_cfg, best_val, n_ok = None, None, 0
        if self._space is None:
            self._space = rec.registry_space(self.kernel, self.problem)
        for path in paths:
            try:
                _, results = rec.ObservationShard(path).read()
            except (OSError, ValueError):
                continue
            for cid, r in results.items():
                if r.status != "ok":
                    continue
                n_ok += 1
                if best_val is None or r.time_s < best_val:
                    best_val = r.time_s
                    best_cfg = self._space.as_dict(
                        self._space.config_from_id(cid))
        return best_cfg, best_val, n_ok


class WarmStartManager:
    """Launches at most one journaled recording campaign per cold key.

    ``ensure`` is the single-flight gate: the first caller creates the
    flight (a daemon thread running ``Tuner.record``); every later caller
    of the same (kernel, device, problem) gets the same flight object.
    ``launches`` counts actual campaign starts — the observable the
    single-flight tests assert on.
    """

    def __init__(self, hub, runner: str = "costmodel", max_evals: int = 32,
                 repeats: int = 3, workers: int = 1, seed: int = 0,
                 journal_dir: str | None = None, background: bool = True):
        self._hub = hub
        self.runner = runner
        self.max_evals = max_evals
        self.repeats = repeats
        self.workers = workers
        self.seed = seed
        self.journal_dir = journal_dir or os.path.join(hub.root, ".warmstart")
        self.background = background
        self.launches = 0
        self._flights: dict[tuple, WarmStartFlight] = {}
        self._lock = threading.Lock()

    def can_serve(self, kernel: str, device: str) -> bool:
        """Warm-start needs a registered kernel (to rebuild its space) and
        a known device model (for the model-backed runners)."""
        from ..core.devices import DEVICES_BY_NAME
        from ..kernels import KERNELS
        return kernel in KERNELS and (
            self.runner not in ("costmodel", "surrogate")
            or device in DEVICES_BY_NAME)

    def ensure(self, kernel: str, device: str,
               problem: Mapping | None) -> WarmStartFlight:
        """Get-or-start the flight for a cold key (the single-flight gate)."""
        problem = dict(problem or {})
        key = (kernel, device, storage.problem_key(problem))
        with self._lock:
            flight = self._flights.get(key)
            if flight is not None:
                return flight
            suffix = ("." + key[2].replace("=", "-").replace(",", "_")
                      if key[2] else "")
            prefix = os.path.join(self.journal_dir,
                                  f"{kernel}@{device}{suffix}")
            flight = WarmStartFlight(kernel, device, problem, prefix,
                                     max(1, self.workers))
            self._flights[key] = flight
            self.launches += 1
        thread = threading.Thread(target=self._run, args=(flight,),
                                  name=f"warmstart-{kernel}@{device}",
                                  daemon=True)
        if self.background:
            thread.start()
        else:
            self._run(flight)
        return flight

    def _run(self, flight: WarmStartFlight) -> None:
        from ..api import Tuner
        try:
            out = flight.prefix + ".json.gz"
            with Tuner(workers=self.workers, seed=self.seed) as tuner:
                run = tuner.record(
                    flight.kernel, runner=self.runner, device=flight.device,
                    problem=flight.problem, repeats=self.repeats,
                    max_evals=self.max_evals, out=out)
            storage.register_cache(self._hub.root, run.cache,
                                   problem=flight.problem or None)
            from .hub import notify_cache_merged
            notify_cache_merged(self._hub.root, kernel=flight.kernel)
        except BaseException as e:  # surfaced via flight.error, not lost
            flight.error = e
        finally:
            flight.done.set()

    def serve(self, kernel: str, device: str, problem: dict):
        """The hub's cold-path hook: ensure the flight exists and answer
        from it (completed campaign -> the freshly registered exact entry;
        otherwise the journal's incumbent best)."""
        from .hub import LookupResult
        flight = self.ensure(kernel, device, problem)
        if flight.done.is_set() and flight.error is None:
            # probe the freshly registered entry directly (not via
            # hub.lookup, whose cold path would re-enter this method)
            ikey = (kernel, device, storage.problem_key(problem))
            entry = self._hub._index.get(ikey)
            if entry is not None and entry.n_ok > 0:
                config, value, n_ok = self._hub._best_for(ikey)
                if config is not None:
                    return LookupResult(
                        kernel=kernel, device=device, problem=dict(problem),
                        status="warm", best_config=config, best_value=value,
                        confidence=1.0, source=entry.key, n_configs=n_ok)
            return None
        config, value, n_ok = flight.incumbent()
        return LookupResult(
            kernel=kernel, device=device, problem=dict(problem),
            status="warming", best_config=config, best_value=value,
            confidence=n_ok / (n_ok + CONFIDENCE_SCALE),
            source=f"warmstart:{os.path.basename(flight.prefix)}",
            n_configs=n_ok)
