"""Tuning-as-a-service over the recorded hub (ROADMAP item 1).

``ConfigHub`` answers "best config for (kernel, problem shape, device)" in
microseconds from the FAIR dataset: exact hits from a precomputed
in-memory index, shape misses by nearest-problem transfer with provenance
and confidence, cold keys (optionally) by a single-flight journaled
warm-start campaign. See docs/service.md.

    from repro.service import ConfigHub

    hub = ConfigHub()                       # reads hub/manifest.json once
    r = hub.lookup("gemm", {"m": 4096, "n": 4096, "k": 4096}, "tpu_v5e")
    r.status, r.best_config, r.confidence   # 'exact', {...}, 1.0
"""
from .hub import ConfigHub, LookupResult, notify_cache_merged
from .transfer import shape_distance, transfer_confidence
from .warmstart import WarmStartFlight, WarmStartManager

__all__ = ["ConfigHub", "LookupResult", "notify_cache_merged",
           "shape_distance", "transfer_confidence", "WarmStartFlight",
           "WarmStartManager"]
