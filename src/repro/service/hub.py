"""ConfigHub: microsecond best-config lookups over the recorded hub.

``ConfigHub`` reads ``hub/manifest.json`` once into an in-memory index and
answers ``lookup(kernel, problem, device)`` with the best known kernel
configuration:

  * **exact** — the (kernel, device, problem shape) was recorded: after the
    entry's first (lazy, sha256-verified) materialization, the answer is a
    single dict probe of a precomputed best — no disk I/O on the hot path
    (``disk_loads`` counts materializations, so callers can assert that);
  * **transfer** — shape miss: the nearest recorded problem donates its
    best config, with provenance (donor entry, shape distance) and a
    confidence score (``service.transfer``);
  * **warming / warm** — nothing recorded for the kernel at all: with
    ``warm_start=True`` a journaled recording campaign is launched exactly
    once per cold key (single-flight, ``service.warmstart``) and the
    incumbent best is served while results stream in;
  * **modeled** — no measurement worth serving (no donor, or only a donor
    whose transfer confidence falls below
    ``scenarios.surrogate.MODELED_CONFIDENCE``), but the kernel and device
    are modelable: the roofline surrogate's argmin over the valid space
    answers, with fixed confidence ``MODELED_CONFIDENCE`` and ``model``
    provenance. Computed once per (kernel, device, shape), then a dict
    probe;
  * **cold** — nothing recorded, not modelable, no warm-start:
    ``best_config=None``.

Tier order is confidence order: exact (1.0) beats a near-shape transfer
(``1/(1+d)``), which beats modeled (0.3), which beats a far-shape or
cross-device transfer (held as a last resort ahead of cold), which beats
cold (0.0).

Freshness: ``invalidate()`` drops materialized state and re-reads the
manifest (``merge-cache --hub-root`` and warm-start completion route
through ``notify_cache_merged``), and an optional ``ttl_s`` re-stats an
entry's file when its materialization is older than the TTL, re-loading
only if the file actually changed.
"""
from __future__ import annotations

import dataclasses
import os
import threading
import time
import weakref
from typing import Mapping, Sequence

import numpy as np

from ..hub import storage
from .transfer import donor_order_key, shape_distance, transfer_confidence

# live hubs, by normalized root — merge-cache / warm-start completion push
# invalidations here so long-running services see refreshed recordings
_LIVE_HUBS: "weakref.WeakSet[ConfigHub]" = weakref.WeakSet()


def notify_cache_merged(root: str | None = None, kernel: str | None = None,
                        device: str | None = None) -> int:
    """Invalidate every live ``ConfigHub`` serving ``root`` (all roots when
    None) after a recording was merged/registered. Returns the number of
    hubs notified."""
    root = os.path.abspath(root) if root is not None else None
    n = 0
    for hub in list(_LIVE_HUBS):
        if root is None or os.path.abspath(hub.root) == root:
            hub.invalidate(kernel=kernel, device=device)
            n += 1
    return n


def _modeled_confidence() -> float:
    # lazy: repro.scenarios imports the api facade, which imports this
    # module — only method bodies may cross that boundary
    from ..scenarios.surrogate import MODELED_CONFIDENCE
    return MODELED_CONFIDENCE


@dataclasses.dataclass(frozen=True)
class LookupResult:
    """One service answer, ``TuningRun``-shaped (headline fields + enough
    provenance to audit where the config came from)."""

    kernel: str
    device: str
    problem: dict
    status: str          # exact | transfer | warming | warm | modeled | cold
    best_config: dict | None = None
    best_value: float | None = None  # objective seconds of best_config
    confidence: float = 0.0          # 1.0 exact; see service.transfer
    source: str | None = None        # hub entry key the answer came from
    donor_problem: dict | None = None   # transfer: the donor's shape
    distance: float | None = None       # transfer: shape distance to donor
    n_configs: int = 0               # recorded configs behind the answer
    wall_seconds: float = 0.0
    mode: str = "lookup"
    model: dict | None = None        # modeled: surrogate provenance

    @property
    def found(self) -> bool:
        return self.best_config is not None

    @property
    def tier(self) -> str:
        """The coverage tier this answer belongs to: ``warming``/``warm``
        collapse to ``warm``; every other status is its own tier."""
        return "warm" if self.status in ("warming", "warm") else self.status

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["tier"] = self.tier
        if self.best_value is not None and self.best_value == float("inf"):
            d["best_value"] = None
        return d


class _Entry:
    """One manifest entry in the index: identity + file provenance; the
    expensive parts (cache file, best config) materialize lazily."""

    __slots__ = ("key", "kernel", "device", "pkey", "problem", "path",
                 "sha256", "n_configs", "n_ok")

    def __init__(self, key: str, kernel: str, device: str, pkey: str,
                 problem: dict, entry: Mapping):
        self.key = key
        self.kernel = kernel
        self.device = device
        self.pkey = pkey
        self.problem = problem
        self.path = entry["path"]
        self.sha256 = entry.get("sha256")
        self.n_configs = int(entry.get("n_configs", 0))
        self.n_ok = int(entry.get("n_ok", 0))

    def __getstate__(self) -> dict:
        return {k: getattr(self, k) for k in self.__slots__}

    def __setstate__(self, state: dict) -> None:
        for k, v in state.items():
            setattr(self, k, v)


class ConfigHub:
    """In-memory lookup service over one hub root. Thread-safe; cheap to
    construct (one manifest read, no cache files touched until a lookup
    needs them). Picklable: workers receive the index and any already-
    computed bests, but never locks, columnar arrays, or warm-start state.
    """

    def __init__(self, root: str = storage.DEFAULT_ROOT, verify: bool = True,
                 ttl_s: float | None = None,
                 warm_start: bool | Mapping = False):
        self.root = root
        self.verify = verify
        self.ttl_s = ttl_s
        self.disk_loads = 0          # materializations (exact hits stay flat)
        self._lock = threading.RLock()
        self._manifest: dict | None = None
        self._index: dict[tuple, _Entry] = {}   # (kernel, device, pkey)
        self._best: dict[tuple, tuple] = {}     # key -> (config, value, n_ok)
        self._materialized: dict[tuple, object] = {}  # key -> CacheColumns
        self._stamp: dict[tuple, tuple] = {}    # key -> (mono, mtime_ns, size)
        self._modeled: dict[tuple, object] = {}  # key -> ModeledBest | None
        self._counters = {"exact": 0, "transfer": 0, "warm": 0,
                          "modeled": 0, "cold": 0}
        self._warm = None
        if warm_start:
            from .warmstart import WarmStartManager
            opts = dict(warm_start) if isinstance(warm_start, Mapping) else {}
            self._warm = WarmStartManager(self, **opts)
        self._reload_index()
        _LIVE_HUBS.add(self)

    # ---------------------------------------------------------------- index
    def _reload_index(self) -> None:
        """(Re)build the in-memory index from the manifest."""
        manifest = storage.read_manifest(self.root)
        index: dict[tuple, _Entry] = {}
        for key, raw in manifest["files"].items():
            kernel, device, pkey = storage.split_key(key)
            problem = dict(
                raw.get("problem")
                or manifest.get("kernels", {}).get(kernel, {}).get("problem")
                or storage.hub_default_problem(kernel))
            if pkey == "":
                # the unsuffixed entry is the kernel's default shape; index
                # it under its *resolved* problem key so passing the default
                # shape explicitly still hits exactly
                pkey = storage.problem_key(problem)
            index[(kernel, device, pkey)] = _Entry(key, kernel, device, pkey,
                                                   problem, raw)
        with self._lock:
            self._manifest = manifest
            self._index = index

    def invalidate(self, kernel: str | None = None,
                   device: str | None = None) -> None:
        """Evict materialized/best state (filtered by kernel/device when
        given) and re-read the manifest, picking up new or re-recorded
        entries."""
        with self._lock:
            def hit(k: tuple) -> bool:
                return ((kernel is None or k[0] == kernel)
                        and (device is None or k[1] == device))
            for store in (self._best, self._materialized, self._stamp,
                          self._modeled):
                for k in [k for k in store if hit(k)]:
                    del store[k]
        self._reload_index()

    # ----------------------------------------------------- materialization
    def _resolve_problem(self, kernel: str, problem: Mapping | None) -> dict:
        """Problem dicts are *overrides* of the kernel's hub-default shape
        (the repo-wide convention, e.g. ``record --problem``): unspecified
        dimensions keep their recorded defaults rather than counting as
        missing in the shape distance."""
        return {**storage.hub_default_problem(kernel), **(problem or {})}

    def _file_sig(self, entry: _Entry) -> tuple | None:
        try:
            st = os.stat(os.path.join(self.root, entry.path))
        except OSError:
            return None
        return (st.st_mtime_ns, st.st_size)

    def _best_for(self, ikey: tuple) -> tuple:
        """(best_config, best_value, n_ok) for an indexed entry; loads and
        verifies the cache file once, then serves from memory (TTL-gated
        re-stat when ``ttl_s`` is set)."""
        with self._lock:
            best = self._best.get(ikey)
            if best is not None:
                if self.ttl_s is None:
                    return best
                stamp = self._stamp.get(ikey)
                if stamp and time.monotonic() - stamp[0] < self.ttl_s:
                    return best
                entry = self._index[ikey]
                sig = self._file_sig(entry)
                if stamp and sig == stamp[1:]:
                    self._stamp[ikey] = (time.monotonic(),) + stamp[1:]
                    return best
                # file changed under us: pick up the refreshed recording
                self._best.pop(ikey, None)
                self._materialized.pop(ikey, None)
                self._reload_index()
            entry = self._index[ikey]
            cache = storage.load_cache(self.root, entry.key, self._manifest,
                                       verify=self.verify)
            self.disk_loads += 1
            cols = cache.columns
            ok = cols.ok
            if ok.any():
                row = int(np.argmin(np.where(ok, cols.time_s, np.inf)))
                cid = cols.keys[row]
                config = cache.space.as_dict(cache.space.config_from_id(cid))
                value = float(cols.time_s[row])
            else:
                config, value = None, None
            best = (config, value, int(ok.sum()))
            self._best[ikey] = best
            self._materialized[ikey] = cols
            sig = self._file_sig(entry)
            self._stamp[ikey] = (time.monotonic(),) + (sig or (0, 0))
            return best

    # ---------------------------------------------------------------- lookup
    def lookup(self, kernel: str, problem: Mapping | None = None,
               device: str = "tpu_v5e") -> LookupResult:
        """Best known config for (kernel, problem shape, device); see the
        module docstring for the exact/transfer/warming/cold semantics."""
        t0 = time.perf_counter()
        target = self._resolve_problem(kernel, problem)
        pkey = storage.problem_key(target)
        ikey = (kernel, device, pkey)
        with self._lock:
            entry = self._index.get(ikey)
        if entry is not None and entry.n_ok > 0:
            config, value, n_ok = self._best_for(ikey)
            if config is not None:
                with self._lock:
                    self._counters["exact"] += 1
                return LookupResult(
                    kernel=kernel, device=device, problem=target,
                    status="exact", best_config=config, best_value=value,
                    confidence=1.0, source=entry.key, n_configs=n_ok,
                    wall_seconds=time.perf_counter() - t0)
        transfer_res = None
        donor = self._nearest_donor(kernel, device, target, exclude=ikey)
        if donor is not None:
            d_entry, dist = donor
            config, value, n_ok = self._best_for(
                (d_entry.kernel, d_entry.device, d_entry.pkey))
            if config is not None:
                cross = d_entry.device != device
                confidence = transfer_confidence(dist, cross)
                transfer_res = LookupResult(
                    kernel=kernel, device=device, problem=target,
                    status="transfer", best_config=config, best_value=value,
                    confidence=confidence,
                    source=d_entry.key, donor_problem=dict(d_entry.problem),
                    distance=dist, n_configs=n_ok,
                    wall_seconds=time.perf_counter() - t0)
                # a near-shape donor outranks the surrogate; a far-shape or
                # cross-device one is held back and only serves if the
                # surrogate can't answer either
                if (confidence >= _modeled_confidence()
                        or not self._modelable(kernel, device)):
                    with self._lock:
                        self._counters["transfer"] += 1
                    return transfer_res
        if transfer_res is None:
            if self._warm is not None and self._warm.can_serve(kernel,
                                                               device):
                result = self._warm.serve(kernel, device, target)
                if result is not None:
                    with self._lock:
                        self._counters["warm"] += 1
                    return dataclasses.replace(
                        result, wall_seconds=time.perf_counter() - t0)
        modeled = self._modeled_best(kernel, device, target)
        if modeled is not None:
            with self._lock:
                self._counters["modeled"] += 1
            return LookupResult(
                kernel=kernel, device=device, problem=target,
                status="modeled", best_config=dict(modeled.config),
                best_value=modeled.value,
                confidence=_modeled_confidence(),
                n_configs=modeled.n_ok, model=modeled.provenance(),
                wall_seconds=time.perf_counter() - t0)
        if transfer_res is not None:
            with self._lock:
                self._counters["transfer"] += 1
            return dataclasses.replace(
                transfer_res, wall_seconds=time.perf_counter() - t0)
        with self._lock:
            self._counters["cold"] += 1
        return LookupResult(kernel=kernel, device=device, problem=target,
                            status="cold",
                            wall_seconds=time.perf_counter() - t0)

    # ---------------------------------------------------------- modeled tier
    @staticmethod
    def _modelable(kernel: str, device: str) -> bool:
        """Can the roofline surrogate answer for this (kernel, device)?"""
        from ..core.devices import DEVICES_BY_NAME
        from ..kernels import KERNELS
        return kernel in KERNELS and device in DEVICES_BY_NAME

    def _modeled_best(self, kernel: str, device: str, target: Mapping):
        """The surrogate argmin for one triple, computed once and then a
        dict probe (``ModeledBest`` is plain data, so it ships to workers
        with the rest of the pickled state)."""
        key = (kernel, device, storage.problem_key(target))
        with self._lock:
            if key in self._modeled:
                return self._modeled[key]
        if not self._modelable(kernel, device):
            best = None
        else:
            from ..scenarios.surrogate import best_modeled
            best = best_modeled(kernel, target, device)
        with self._lock:
            self._modeled[key] = best
        return best

    def _nearest_donor(self, kernel: str, device: str, target: Mapping,
                       exclude: tuple) -> tuple[_Entry, float] | None:
        """Deterministic nearest recorded donor for a shape/device miss."""
        with self._lock:
            candidates = [e for k, e in self._index.items()
                          if e.kernel == kernel and k != exclude
                          and e.n_ok > 0]
        if not candidates:
            return None
        scored = [(donor_order_key(shape_distance(target, e.problem),
                                   e.device != device, e.pkey, e.device), e)
                  for e in candidates]
        order, entry = min(scored, key=lambda t: t[0])
        return entry, order[0]

    def lookup_many(self, requests: Sequence[Mapping]) -> list[LookupResult]:
        """Batched lookups for fleet callers: each request is a mapping with
        ``kernel`` and optional ``problem`` / ``device`` keys. Distinct
        entries materialize once; repeated keys amortize to dict probes."""
        return [self.lookup(r["kernel"], r.get("problem"),
                            r.get("device", "tpu_v5e")) for r in requests]

    # ----------------------------------------------------------------- misc
    def warm_up(self, kernels: Sequence[str] | None = None,
                devices: Sequence[str] | None = None) -> int:
        """Eagerly materialize matching index entries (so a service's first
        real lookups are already O(1)); returns how many were loaded."""
        with self._lock:
            keys = [k for k, e in sorted(self._index.items())
                    if (kernels is None or e.kernel in kernels)
                    and (devices is None or e.device in devices)
                    and e.n_ok > 0]
        n = 0
        for k in keys:
            self._best_for(k)
            n += 1
        return n

    def recorded_keys(self) -> frozenset:
        """The (kernel, device, problem_key) triples backed by a measured
        entry (``n_ok > 0``) — what the scenario matrix classifies as
        ``recorded`` coverage."""
        with self._lock:
            return frozenset(k for k, e in self._index.items() if e.n_ok > 0)

    def stats(self) -> dict:
        with self._lock:
            return {
                "root": self.root,
                "entries": len(self._index),
                "kernels": sorted({e.kernel for e in self._index.values()}),
                "devices": sorted({e.device for e in self._index.values()}),
                "materialized": len(self._best),
                "modeled_cached": len(self._modeled),
                "disk_loads": self.disk_loads,
                "lookups": dict(self._counters),
                "warm_campaigns": (self._warm.launches
                                   if self._warm is not None else 0),
            }

    @property
    def warm_start(self):
        """The ``WarmStartManager`` (None unless enabled)."""
        return self._warm

    # -------------------------------------------------------------- pickling
    def __getstate__(self) -> dict:
        """Ship the index and computed bests to workers, but never locks,
        columnar arrays, warm-start threads, or live-hub registration."""
        state = self.__dict__.copy()
        state["_lock"] = None
        state["_materialized"] = {}
        state["_warm"] = None
        return state

    def __setstate__(self, state: dict) -> None:
        self.__dict__.update(state)
        self._lock = threading.RLock()
