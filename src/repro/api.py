"""``repro.api`` — the public facade over the paper's whole workflow.

One object, four verbs (mirroring the session-style facades of
auto-tuning frameworks like Autotune: heterogeneous machinery behind a
single entry point):

    from repro.api import Tuner

    tuner = Tuner(kernels=("gemm", "hotspot"), devices=("tpu_v5e",),
                  repeats=10, workers=4)
    run = tuner.simulate("pso")                      # score one config
    run = tuner.hypertune("pso", journal="pso.jsonl")  # Table III campaign
    run = tuner.meta("pso", "simulated_annealing")   # Eq. 4 meta-tuning
    run = tuner.record("ssd", runner="costmodel")    # produce a new cache

Every verb returns a ``TuningRun`` — one result type carrying the mode's
headline numbers (score / best hyperparameters / best kernel config) plus
the full underlying result object for callers that need the details.

Scoring data resolves lazily from either explicit T4 ``caches`` (paths or
``CacheFile`` objects) or a benchmark-hub selection, exactly like the CLI's
``--cache``/``--kernels``/``--devices``/``--split`` options — indeed
``python -m repro`` is a thin argument parser over this class. Campaign
execution (worker pools, JSONL journals with resume, the ask/tell
``SearchDriver`` underneath every strategy run) is wired through
``core.parallel`` / ``core.driver``; see docs/api.md.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Callable, Mapping, Sequence

from .core.cache import CacheFile
from .core.hypertuner import (HyperTuningResult, MetaTuningResult,
                              exhaustive_hypertune, hyperparam_searchspace,
                              meta_hypertune, score_hyperconfig)
from .core.methodology import (DEFAULT_CUTOFF, AggregateReport, SpaceScorer,
                               make_scorer)
from .core.parallel import CampaignExecutor, CampaignJournal

__all__ = ["Hub", "Tuner", "TuningRun", "describe_space",
           "hyperparam_space_stats", "lint"]


class Hub:
    """First-class facade over the benchmark hub (the FAIR dataset,
    Sec. III-D) and the lookup service built on it.

        hub = Hub()                       # the bundled hub root
        hub.verify()                      # sha256 every indexed file
        caches = hub.caches(split="train")  # scorer inputs, verified
        hub.lookup("gemm", device="tpu_v5e")  # ConfigHub exact/transfer

    Replaces the retired ``core.dataset`` free functions (which now shim
    here behind ``HubDeprecationWarning``). Storage primitives live in
    ``repro.hub.storage``; the lookup service in ``repro.service``.
    """

    def __init__(self, root: str | None = None, verify: bool = True):
        from .hub import storage
        self._storage = storage
        self.root = root or storage.DEFAULT_ROOT
        self.verify_digests = verify
        self._service = None

    @classmethod
    def build(cls, root: str | None = None,
              progress: Callable[[str], None] = print) -> "Hub":
        """Brute-force all hub spaces into ``root`` and return the facade."""
        from .hub import storage
        hub = cls(root)
        storage.build_hub(hub.root, progress)
        return hub

    @property
    def manifest(self) -> dict:
        return self._storage.read_manifest(self.root)

    def verify(self, strict: bool = True) -> dict:
        """sha256-check every indexed file; returns ``{entry: reason}``
        failures (empty = intact). ``strict`` raises ``HubError`` on any."""
        failures = self._storage.verify_manifest(self.root)
        if failures and strict:
            raise self._storage.HubError(
                f"hub at {self.root} failed verification: "
                + "; ".join(f"{k}: {v}" for k, v in sorted(failures.items())))
        return failures

    def load(self, kernels: Sequence[str] | None = None,
             devices: Sequence[str] | None = None) -> dict:
        """``{(kernel, device): CacheFile}`` for the default-shape entries,
        digest-verified per file unless the facade was built with
        ``verify=False``."""
        return self._storage.load_hub(self.root, kernels, devices,
                                      verify=self.verify_digests)

    def caches(self, split: str | None = None,
               kernels: Sequence[str] | None = None,
               devices: Sequence[str] | None = None) -> list[CacheFile]:
        """Cache files as a deterministic list — the scorer-input shape.
        ``split`` ("train"/"test") selects the paper's device split;
        explicit ``devices`` override it."""
        if devices is None and split is not None:
            from .core.devices import TEST_DEVICES, TRAIN_DEVICES
            devices = list(TRAIN_DEVICES if split == "train"
                           else TEST_DEVICES)
        hub = self.load(kernels, devices)
        return [c for _, c in sorted(hub.items())]

    def train_test_caches(self) -> tuple:
        return self._storage.train_test_caches(
            self.root, verify=self.verify_digests)

    def register(self, cache: CacheFile, problem=None) -> str:
        """Save a recorded cache into the hub layout, index it in the
        manifest, and invalidate live lookup services; returns the entry
        key."""
        key = self._storage.register_cache(self.root, cache, problem=problem)
        from .service import notify_cache_merged
        notify_cache_merged(self.root, kernel=cache.kernel)
        return key

    def service(self, ttl_s: float | None = None,
                warm_start: bool | Mapping = False):
        """The ``repro.service.ConfigHub`` over this root (memoized per
        facade; see docs/service.md for lookup semantics)."""
        if self._service is None:
            from .service import ConfigHub
            self._service = ConfigHub(self.root, verify=self.verify_digests,
                                      ttl_s=ttl_s, warm_start=warm_start)
        return self._service

    def lookup(self, kernel: str, problem: Mapping | None = None,
               device: str = "tpu_v5e"):
        """Best known config for (kernel, problem, device) — delegates to
        the memoized service; returns a ``LookupResult``."""
        return self.service().lookup(kernel, problem, device)

    def coverage(self, kernels: Sequence[str] | None = None,
                 devices: Sequence[str] | None = None,
                 with_best: bool = False):
        """Scenario-matrix coverage of this hub: every (kernel, shape,
        device) triple classified ``recorded | modeled | cold`` (a
        ``repro.scenarios.CoverageReport``). ``with_best`` resolves each
        answerable triple's best time through the service — the payload
        the CLI report and the fleet regression gate use."""
        from .scenarios import ScenarioMatrix
        matrix = ScenarioMatrix(kernels=kernels, devices=devices)
        return matrix.coverage(self.service(), with_best=with_best)

    def stats(self) -> dict:
        """Manifest-level summary (entries, kernels, devices, sizes) plus
        the scenario coverage matrix and live service counters when a
        service has been created."""
        m = self.manifest
        out = {
            "root": self.root,
            "version": m.get("version"),
            "entries": len(m["files"]),
            "kernels": sorted({self._storage.split_key(k)[0]
                               for k in m["files"]}),
            "devices": sorted({self._storage.split_key(k)[1]
                               for k in m["files"]}),
            "n_configs": sum(e.get("n_configs", 0)
                             for e in m["files"].values()),
            "n_ok": sum(e.get("n_ok", 0) for e in m["files"].values()),
            "bruteforce_hours": round(sum(
                sum(v.values()) for v in m.get("bruteforce_hours",
                                               {}).values()), 1),
        }
        report = self.coverage()
        out["coverage"] = {"counts": report.counts(),
                           "matrix": report.matrix()}
        if self._service is not None:
            out["service"] = self._service.stats()
        return out


def lint(paths: Sequence[str] | None = None,
         baseline: str | None = None):
    """Run parity-lint (the determinism & pickle-safety static analysis,
    ``repro.analysis``) over ``paths`` (default ``src/repro``) and return
    its ``LintResult`` — the programmatic face of ``python -m repro
    lint``. ``baseline`` is a path to a grandfathered-findings file; see
    docs/static-analysis.md for the rule catalogue."""
    from .analysis import lint_paths
    return lint_paths(list(paths) if paths else ["src/repro"],
                      baseline=baseline)


def describe_space(space) -> dict:
    """Compile one ``SearchSpace`` (if not already compiled) and return its
    stats: cartesian vs valid size, valid fraction, neighbor-degree
    distribution per semantics, compile time. The data behind
    ``python -m repro spaces``."""
    return space.compiled.stats()


def hyperparam_space_stats(extended: bool = False) -> list[dict]:
    """``describe_space`` over every registered strategy's hyperparameter
    grid (Table III, or Table IV with ``extended``) — they compile through
    the same ``core.space`` path as kernel spaces."""
    from .core.hypertuner import hyperparam_searchspace
    from .core.strategies import STRATEGIES
    out = []
    for name, cls in sorted(STRATEGIES.items()):
        grid = cls.EXTENDED_SPACE if extended else cls.HYPERPARAM_SPACE
        if not grid:
            continue
        out.append(describe_space(hyperparam_searchspace(name,
                                                         extended=extended)))
    return out


@dataclasses.dataclass
class TuningRun:
    """Unified result of one ``Tuner`` verb.

    ``mode`` says which verb produced it; the headline fields are filled
    when meaningful for that mode and ``None`` otherwise. The full
    mode-specific result object (``AggregateReport``,
    ``HyperTuningResult``, ``MetaTuningResult``, or the recorded
    ``CacheFile``) rides along for detailed consumers.
    """

    mode: str                      # simulate | hypertune | meta | record
    strategy: str
    score: float | None = None             # Eq. 3 aggregate (best, for
    #                                        campaign modes)
    best_hyperparams: dict | None = None   # hypertune / meta
    best_config: dict | None = None        # record: best kernel config
    best_value: float | None = None        # record: its objective seconds
    n_evaluated: int | None = None         # configs / hp-configs evaluated
    wall_seconds: float = 0.0
    simulated_seconds: float = 0.0         # what live tuning would have cost
    report: AggregateReport | None = None          # simulate
    hypertuning: HyperTuningResult | None = None   # hypertune
    meta: MetaTuningResult | None = None           # meta
    cache: CacheFile | None = None                 # record
    cache_path: str | None = None                  # record
    # how the campaign grid was driven: "device" (fused on the jax
    # engine), "host" (interleaved ask/tell), "sequential", or "mixed"
    # (differed per space). Informational — scores are bit-identical
    # across modes. None for modes without a drive (record).
    fuse: str | None = None

    @property
    def speedup(self) -> float | None:
        """Simulated-vs-wall speedup (the paper's Fig. 9 headline ratio)."""
        if not self.simulated_seconds or not self.wall_seconds:
            return None
        return self.simulated_seconds / self.wall_seconds


class Tuner:
    """Facade over simulation-mode scoring, hypertuning campaigns,
    meta-strategies, and cache recording. See the module docstring.

    Construction is cheap; scorers (including their 1000-run virtual
    baselines) and worker pools are built on first use. Use as a context
    manager — or call ``close()`` — to tear down pooled workers.
    """

    def __init__(self,
                 caches: Sequence[CacheFile | str] | None = None,
                 kernels: Sequence[str] | None = None,
                 devices: Sequence[str] | None = None,
                 split: str = "train",
                 hub_root: str | None = None,
                 engine: str = "vectorized",
                 cutoff: float = DEFAULT_CUTOFF,
                 repeats: int = 25,
                 seed: int = 0,
                 workers: int = 1,
                 backend: str = "auto",
                 progress: Callable[[str], None] | None = None):
        self._caches = list(caches) if caches else None
        self._kernels = list(kernels) if kernels else None
        self._devices = list(devices) if devices else None
        self._split = split
        self._hub_root = hub_root
        self.engine = engine
        self.cutoff = cutoff
        self.repeats = repeats
        self.seed = seed
        self.workers = workers
        self.backend = backend
        self.progress = progress
        self._scorers: list[SpaceScorer] | None = None
        self._executor: CampaignExecutor | None = None
        self._hub: Hub | None = None

    # ----------------------------------------------------------- resources
    @property
    def scorers(self) -> list[SpaceScorer]:
        """The scoring contexts (paper Sec. III-B: one per search space),
        built lazily from the cache/hub selection."""
        if self._scorers is None:
            self._scorers = [make_scorer(c, cutoff=self.cutoff,
                                         engine=self.engine)
                             for c in self._resolve_caches()]
        return self._scorers

    def _resolve_caches(self) -> list[CacheFile]:
        if self._caches is not None:
            return [c if isinstance(c, CacheFile) else CacheFile.load(c)
                    for c in self._caches]
        caches = self.hub.caches(split=self._split, kernels=self._kernels,
                                 devices=self._devices)
        if not caches:
            raise ValueError("no hub spaces matched the selection")
        return caches

    @property
    def hub(self) -> Hub:
        """The ``Hub`` facade for this tuner's ``hub_root``."""
        if self._hub is None:
            self._hub = Hub(self._hub_root)
        return self._hub

    @property
    def executor(self) -> CampaignExecutor:
        if self._executor is None:
            self._executor = CampaignExecutor(self.workers, self.backend)
        return self._executor

    def space_stats(self) -> list[dict]:
        """``describe_space`` for every search space of this tuner's
        cache/hub selection (compiles the spaces; does *not* build scorers,
        so no 1000-run baselines are paid for a stats listing)."""
        return [describe_space(c.space) for c in self._resolve_caches()]

    def close(self) -> None:
        if self._executor is not None:
            self._executor.shutdown()
            self._executor = None

    def __enter__(self) -> "Tuner":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ---------------------------------------------------------------- verbs
    def simulate(self, strategy: str,
                 hyperparams: Mapping | None = None) -> TuningRun:
        """Score one strategy configuration with the methodology
        (Sec. III-B, Eqs. 2–3) across this tuner's spaces."""
        report = score_hyperconfig(strategy, dict(hyperparams or {}),
                                   self.scorers, repeats=self.repeats,
                                   seed=self.seed, executor=self.executor)
        return TuningRun(mode="simulate", strategy=strategy,
                         score=report.score, report=report,
                         n_evaluated=1,
                         wall_seconds=report.wall_seconds,
                         simulated_seconds=report.simulated_seconds,
                         fuse=report.fuse)

    def hypertune(self, strategy: str,
                  journal: str | CampaignJournal | None = None) -> TuningRun:
        """Exhaustive hyperparameter-grid campaign (Sec. IV-B, Table III):
        parallel over this tuner's workers, resumable via ``journal``."""
        res = exhaustive_hypertune(strategy, self.scorers,
                                   repeats=self.repeats, seed=self.seed,
                                   progress=self.progress,
                                   executor=self.executor,
                                   journal=_as_journal(journal))
        best = res.best
        # res.wall_seconds is cumulative across journal resumes — the
        # honest denominator for the Fig. 9 speedup claim
        return TuningRun(mode="hypertune", strategy=strategy,
                         score=best.score,
                         best_hyperparams=dict(best.hyperparams),
                         n_evaluated=len(res.results),
                         wall_seconds=res.wall_seconds,
                         simulated_seconds=res.simulated_seconds,
                         hypertuning=res, fuse=best.report.fuse)

    def meta(self, strategy: str, meta_strategy: str = "simulated_annealing",
             extended: bool = True, max_hp_evals: int = 50,
             meta_hyperparams: Mapping | None = None,
             journal: str | CampaignJournal | None = None) -> TuningRun:
        """Meta-strategy hyperparameter optimization (Sec. IV-C, Eq. 4):
        ``meta_strategy`` explores ``strategy``'s hyperparameter space
        (Table IV when ``extended``), journaled — including mid-run
        ``SearchState`` checkpoints — for resume."""
        res = meta_hypertune(strategy, meta_strategy, self.scorers,
                             extended=extended, max_hp_evals=max_hp_evals,
                             repeats=self.repeats, seed=self.seed,
                             meta_hyperparams=meta_hyperparams,
                             progress=self.progress, executor=self.executor,
                             journal=_as_journal(journal))
        return TuningRun(mode="meta", strategy=strategy,
                         score=res.best_score,
                         best_hyperparams=dict(res.best_hyperparams),
                         n_evaluated=len(res.evaluated),
                         wall_seconds=res.wall_seconds,  # resume-cumulative
                         simulated_seconds=res.simulated_seconds,
                         meta=res, fuse=res.fuse)

    def record(self, kernel: str, runner: str = "live",
               device: str = "cpu_interpret",
               problem: Mapping | None = None,
               strategy: str = "random_search",
               hyperparams: Mapping | None = None,
               repeats: int = 3, max_evals: int | None = 64,
               max_seconds: float | None = None,
               out: str | None = None,
               bruteforce: bool = False) -> TuningRun:
        """Record a registered Pallas kernel into a replayable T4 cache
        (Sec. III-C/D): strategy-sampled by default, exhaustive with
        ``bruteforce=True``; sharded across this tuner's workers, shards
        crash-safe and resumable. Returns the merged cache (saved to
        ``out``) plus the best recorded configuration."""
        from .core import record as rec
        from .kernels import get_kernel

        get_kernel(kernel)  # fail fast on unknown kernels
        t0 = time.perf_counter()
        spec = rec.RecordSpec.create(
            kernel, runner=runner, device=device,
            problem=dict(problem or {}), strategy=strategy,
            hyperparams=dict(hyperparams or {}), repeats=repeats,
            max_evals=max_evals, max_seconds=max_seconds, seed=self.seed)
        out = out or os.path.join("recorded", f"{kernel}@{device}.json.gz")
        prefix = out
        for ext in (".json.zst", ".json.gz", ".json"):
            if prefix.endswith(ext):
                prefix = prefix[:-len(ext)]
                break
        os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
        n = max(1, self.workers)
        task = (rec.bruteforce_shard_task if bruteforce
                else rec.record_shard_task)
        argtuples = [(w, n, prefix) for w in range(n)]
        measured = 0.0
        for _, summary in self.executor.map(task, argtuples, shared=spec):
            measured += summary["measured_seconds"]
            if self.progress:
                self.progress(
                    f"worker {summary['worker']}: {summary['recorded']} "
                    f"recorded (+{summary['resumed']} resumed) "
                    f"-> {summary['path']}")
        space = rec.registry_space(kernel, dict(problem or {}))
        cache = rec.merge_shards(
            [rec.shard_path(prefix, w) for w in range(n)], space=space,
            meta={"mode": "bruteforce" if bruteforce else "record"})
        cache.save(out)
        best_cfg = best_val = None
        ok = [(r.time_s, k) for k, r in cache.results.items()
              if r.status == "ok"]
        if ok:
            best_val, key = min(ok)
            best_cfg = cache.space.as_dict(cache.space.config_from_id(key))
        return TuningRun(mode="record", strategy=strategy,
                         best_config=best_cfg, best_value=best_val,
                         n_evaluated=len(cache.results),
                         wall_seconds=time.perf_counter() - t0,
                         simulated_seconds=measured,
                         cache=cache, cache_path=out)

    def lookup(self, kernel: str, problem: Mapping | None = None,
               device: str = "tpu_v5e"):
        """Best known config for (kernel, problem shape, device) from the
        recorded hub — exact hit, nearest-shape transfer, roofline-modeled
        answer, or cold; returns a ``repro.service.LookupResult``
        (``TuningRun``-shaped: ``mode``, ``best_config``, ``best_value``,
        ``wall_seconds`` plus status/provenance/confidence). See
        docs/service.md."""
        return self.hub.lookup(kernel, problem, device)

    def surrogate(self, kernel: str, problem: Mapping | None = None,
                  device: str = "tpu_v5e", strategy: str | None = None,
                  hyperparams: Mapping | None = None,
                  max_evals: int | None = None,
                  max_seconds: float | None = None) -> TuningRun:
        """Tune a kernel against the roofline surrogate instead of a cache
        or live hardware (docs/scenarios.md) — any (registry kernel,
        device model) pair works, recorded or not.

        With ``strategy=None`` the whole valid space is priced and the
        exact argmin returned (what the hub's ``modeled`` lookup tier
        serves). With a strategy name, that strategy runs against a
        ``SurrogateRunner`` under the given budget — the same ask/tell
        driver path as simulation, just surrogate-priced."""
        from .core.budget import Budget, BudgetExhausted
        from .core.devices import DEVICES_BY_NAME
        from .core.strategies import get_strategy
        from .kernels import get_kernel
        from .scenarios.surrogate import SurrogateRunner, best_modeled

        t0 = time.perf_counter()
        if strategy is None:
            mb = best_modeled(kernel, problem, device)
            if mb is None:
                get_kernel(kernel)  # raise the more precise error
                raise ValueError(
                    f"unknown device model {device!r}; known: "
                    f"{sorted(DEVICES_BY_NAME)}")
            return TuningRun(mode="surrogate", strategy="exhaustive",
                             best_config=dict(mb.config),
                             best_value=mb.value, n_evaluated=mb.n_valid,
                             wall_seconds=time.perf_counter() - t0)
        spec = get_kernel(kernel)
        dev = DEVICES_BY_NAME.get(device)
        if dev is None:
            raise ValueError(f"unknown device model {device!r}; known: "
                             f"{sorted(DEVICES_BY_NAME)}")
        problem = dict(problem or {})
        space = spec.space(problem)
        budget = Budget(max_seconds=max_seconds, max_evals=max_evals or 64)
        runner = SurrogateRunner(space, spec.workload(problem), dev, budget)
        import random
        try:
            get_strategy(strategy, **dict(hyperparams or {})).run(
                space, runner, random.Random(self.seed))
        except BudgetExhausted:
            pass
        best = runner.best
        return TuningRun(
            mode="surrogate", strategy=strategy,
            best_config=(space.as_dict(best.config) if best else None),
            best_value=(best.value if best else None),
            n_evaluated=runner.fresh_evals,
            wall_seconds=time.perf_counter() - t0,
            simulated_seconds=budget.spent_seconds)


def _as_journal(journal: str | CampaignJournal | None
                ) -> CampaignJournal | None:
    if journal is None or isinstance(journal, CampaignJournal):
        return journal
    return CampaignJournal(journal)
