"""parity-lint: static analysis for the repo's determinism contracts.

The simulation mode is only trustworthy because replayed runs are
bit-identical to recorded ones, and the whole house style enforces that
with *runtime* oracles — trace fixtures, engine-parity suites, the bench
score checksum. This package encodes the same contracts as AST rules so a
hazard is caught when it is written, not when a fixture happens to
exercise it:

  * RNG discipline (``rules/rng.py``) — no module-level/time-seeded
    draws in core/, no draws ordered by set iteration;
  * pickle safety (``rules/pickle_safety.py``) — device/columnar mirror
    caches are dropped from pickles; SearchStates stay host-only;
  * f64 budget discipline (``rules/f64.py``) — no parallel scans, no
    float32, explicit reduction dtypes in ``core/engine_jax/``;
  * ask/tell conformance (``rules/protocol.py``) — strategies never call
    the runner; states don't retain runtime across snapshots;
  * ordering (``rules/ordering.py``) — sorted directory enumeration, no
    set-ordered iteration in core/.

Entry points: ``python -m repro lint`` (CI gate), ``repro.api.lint``
(programmatic), ``run_source`` (fixture tests). Deliberate findings live
in the checked-in baseline (``parity-lint-baseline.json``); per-line
escapes use ``# parity-lint: disable=<rule>`` and unused escapes are
themselves findings. docs/static-analysis.md is the rule catalogue.
"""
from __future__ import annotations

from .core import (ERROR, SYNTAX_ERROR, UNUSED_SUPPRESSION, WARNING,
                   Finding, LintResult, Rule, lint_paths, lint_source,
                   run_source)

__all__ = ["Finding", "LintResult", "Rule", "lint_paths", "lint_source",
           "run_source", "default_rules", "ERROR", "WARNING",
           "SYNTAX_ERROR", "UNUSED_SUPPRESSION"]


def default_rules() -> list[Rule]:
    """Fresh instances of every registered rule."""
    from .rules import ALL_RULES
    return [cls() for cls in ALL_RULES]
