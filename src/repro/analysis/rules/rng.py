"""RNG discipline rules.

The bit-parity contract (docs/architecture.md, "RNG parity contract")
requires every random draw in the simulation core to come from the run's
explicitly-seeded ``random.Random``/``np.random.Generator`` in a
deterministic order. Three ways code breaks that statically:

  * drawing from the *module-level* global RNG (``np.random.shuffle``,
    ``random.random``) — shared mutable state whose stream depends on
    whatever else ran in the process;
  * seeding an RNG from wall-clock time / OS entropy — different stream
    every run;
  * drawing inside iteration over a set — per-process hash order decides
    the draw order, so two bit-identical states diverge.
"""
from __future__ import annotations

import ast

from ..core import (ERROR, Rule, call_name, dotted, enclosing, is_set_expr,
                    parent)

# np.random attributes that construct explicitly-seeded objects rather
# than drawing from the module-level global state
_NP_CONSTRUCTORS = frozenset({
    "default_rng", "Generator", "RandomState", "SeedSequence",
    "BitGenerator", "Philox", "PCG64", "PCG64DXSM", "MT19937", "SFC64",
})

# stdlib ``random`` module-level draw/seed functions (random.Random and
# the class names are constructors, fine when explicitly seeded)
_PY_MODULE_DRAWS = frozenset({
    "random", "randint", "randrange", "uniform", "choice", "choices",
    "shuffle", "sample", "seed", "getrandbits", "gauss", "normalvariate",
    "betavariate", "expovariate", "triangular", "vonmisesvariate",
    "paretovariate", "weibullvariate", "lognormvariate", "randbytes",
})

# draw methods on rng-like receivers (random.Random + np Generator)
_RNG_METHODS = frozenset(_PY_MODULE_DRAWS - {"seed"} | {
    "integers", "standard_normal", "normal", "permutation", "permuted",
    "bytes", "exponential",
})

_RNG_RECEIVERS = ("rng", "np_rng", "rnd", "rand", "random_state")

_TIME_SOURCES = frozenset({
    "time.time", "time.time_ns", "time.monotonic", "time.perf_counter",
    "os.urandom", "uuid.uuid4", "secrets.token_bytes", "secrets.randbits",
})


def _is_rng_receiver(recv: ast.AST) -> bool:
    name = dotted(recv)
    if name is None:
        return False
    last = name.rsplit(".", 1)[-1]
    return last in _RNG_RECEIVERS or last.endswith("_rng")


class ModuleLevelDraw(Rule):
    name = "rng-module-draw"
    severity = ERROR
    scope = ("core/",)
    invariant = ("core/ draws only from per-run seeded RNG objects, never "
                 "the np.random / random module-level global state")
    oracle = ("trace fixtures + frozen legacy loops "
              "(tests/test_protocol.py) and the bench score checksum")

    def visit_Call(self, ctx, node):
        name = call_name(node)
        if name is None:
            return
        parts = name.split(".")
        if parts[0] in ("np", "numpy") and len(parts) >= 3 \
                and parts[1] == "random" \
                and parts[2] not in _NP_CONSTRUCTORS:
            yield self.finding(
                ctx, node,
                f"module-level draw {name}() uses numpy's global RNG; "
                f"draw from the run's np.random.Generator instead")
        elif parts[0] == "random" and len(parts) == 2 \
                and parts[1] in _PY_MODULE_DRAWS:
            yield self.finding(
                ctx, node,
                f"module-level draw {name}() uses the shared global RNG; "
                f"draw from the run's random.Random instance instead")


class TimeSeededRng(Rule):
    name = "rng-time-seed"
    severity = ERROR
    scope = ()
    invariant = ("RNGs are seeded from explicit integers derived from "
                 "(seed, space, repeat), never wall clock or OS entropy")
    oracle = ("bit-identical parallel campaigns "
              "(tests/test_parallel.py determinism suite)")

    _CONSTRUCTORS = ("random.Random", "np.random.default_rng",
                     "numpy.random.default_rng", "np.random.RandomState",
                     "numpy.random.RandomState")

    def visit_Call(self, ctx, node):
        name = call_name(node)
        if name is None:
            return
        is_ctor = name in self._CONSTRUCTORS
        is_seed = name.endswith(".seed") or name in (
            "np.random.PRNGKey", "jax.random.PRNGKey")
        if is_ctor and not node.args and not node.keywords:
            yield self.finding(
                ctx, node,
                f"{name}() without a seed draws entropy from the OS — "
                f"every run gets a different stream")
            return
        if not (is_ctor or is_seed):
            return
        for arg in ast.walk(node):
            if isinstance(arg, ast.Call) \
                    and call_name(arg) in _TIME_SOURCES:
                yield self.finding(
                    ctx, node,
                    f"{name}(...) is seeded from {call_name(arg)}() — "
                    f"time/entropy-seeded RNG cannot replay")
                return


class DrawInSetIteration(Rule):
    name = "rng-set-iteration"
    severity = ERROR
    scope = ("core/",)
    invariant = ("RNG draw order never depends on set/dict hash order: no "
                 "draws inside iteration over a set")
    oracle = ("cross-process bit-parity (PYTHONHASHSEED varies per "
              "worker; tests/test_parallel.py)")

    def visit_Call(self, ctx, node):
        if not isinstance(node.func, ast.Attribute):
            return
        if node.func.attr not in _RNG_METHODS \
                or not _is_rng_receiver(node.func.value):
            return
        loop = enclosing(node, ast.For, ast.comprehension)
        # comprehension generators aren't parent-linked the same way; walk
        # For loops here and comprehensions below
        while loop is not None:
            if isinstance(loop, ast.For) and is_set_expr(loop.iter):
                yield self.finding(
                    ctx, node,
                    "RNG draw inside iteration over a set — draw order "
                    "follows hash order and differs between processes; "
                    "iterate a sorted() or list-ordered view")
                return
            loop = enclosing(loop, ast.For)

    def visit_comprehension(self, ctx, node):
        if not is_set_expr(node.iter):
            return
        comp = parent(node)
        if comp is None:
            return
        for sub in ast.walk(comp):
            if isinstance(sub, ast.Call) \
                    and isinstance(sub.func, ast.Attribute) \
                    and sub.func.attr in _RNG_METHODS \
                    and _is_rng_receiver(sub.func.value):
                yield self.finding(
                    ctx, sub,
                    "RNG draw inside a comprehension over a set — draw "
                    "order follows hash order and differs between "
                    "processes; iterate a sorted() view")
                return
