"""Device→host sync discipline for the jitted engine's hot loops.

The fused-campaign throughput budget (docs/performance.md, "host↔device
round-trip budget") hinges on one shape: a handful of vmapped dispatches,
then *one* bulk ``np.asarray`` per output. An implicit element-wise sync —
``np.asarray``/``float()``/``.item()``/``.tolist()`` applied to a jax
array inside a loop body — blocks on the device once per iteration and
silently turns an O(dispatches) campaign back into the O(evaluations)
round-trip pattern the fused executor exists to remove.

The rule is a conservative local dataflow with one structural judgment,
"convert where you dispatch": names assigned from ``jnp.*``/``jax.*``
calls or jitted callables (any callable whose name contains ``jit``) are
device values, and converting one inside a loop is an error **unless** the
value was produced inside the same innermost loop's per-iteration region —
the batched-output idiom of ``campaign._drive_group`` (dispatch in the
loop, one bulk ``np.asarray`` per output right after it) stays clean,
while per-element syncs of device values produced outside the loop (the
``(np.asarray(o) for o in out)`` shape grandfathered in ``replay.py``)
are flagged. A conversion's *result* is a host value: ``spent =
np.asarray(out[4])`` then ``float(spent[i])`` in a loop syncs nothing.
"""
from __future__ import annotations

import ast

from ..core import ERROR, Rule, call_name

# conversion callables that force a device→host transfer per call
_CONVERT_CALLS = frozenset({
    "np.asarray", "numpy.asarray", "np.array", "numpy.array", "float",
})
# conversion methods on array receivers
_CONVERT_METHODS = frozenset({"item", "tolist"})

_DEVICE_ROOTS = ("jnp", "jax")

_LOOPS = (ast.For, ast.While, ast.GeneratorExp, ast.ListComp,
          ast.SetComp, ast.DictComp)


def _is_device_call(node: ast.Call) -> bool:
    name = call_name(node)
    if name is None:
        return False
    root = name.split(".", 1)[0]
    if root in _DEVICE_ROOTS:
        return True
    return "jit" in name.rsplit(".", 1)[-1]


def _is_conversion(node: ast.AST) -> bool:
    """Top-level host conversion: its result lives on the host."""
    if not isinstance(node, ast.Call):
        return False
    if call_name(node) in _CONVERT_CALLS:
        return True
    return (isinstance(node.func, ast.Attribute)
            and node.func.attr in _CONVERT_METHODS)


def _target_names(target: ast.AST):
    if isinstance(target, ast.Name):
        yield target.id
    elif isinstance(target, (ast.Tuple, ast.List)):
        for elt in target.elts:
            yield from _target_names(elt)


def _device_names_in(expr: ast.AST, device: set) -> set:
    return {n.id for n in ast.walk(expr)
            if isinstance(n, ast.Name) and n.id in device}


def _refs_device(expr: ast.AST, device: set) -> bool:
    for node in ast.walk(expr):
        if isinstance(node, ast.Name) and node.id in device:
            return True
        if isinstance(node, ast.Call) and _is_device_call(node):
            return True
    return False


def _walk_function(func: ast.AST):
    """Every node of ``func``'s own body, skipping nested function defs
    (they get their own visit)."""
    stack = list(ast.iter_child_nodes(func))
    while stack:
        node = stack.pop()
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            continue
        yield node
        stack.extend(ast.iter_child_nodes(node))


def _device_assigns(node: ast.AST, device: set):
    """(targets-iterable, value) pairs for assignments whose value is a
    device expression (and not a top-level host conversion)."""
    if isinstance(node, ast.Assign):
        value, targets = node.value, node.targets
    elif isinstance(node, (ast.AnnAssign, ast.AugAssign)):
        value, targets = node.value, [node.target]
    else:
        return
    if value is None or _is_conversion(value) \
            or not _refs_device(value, device):
        return
    for t in targets:
        yield from _target_names(t)


def _collect_device_names(func: ast.AST) -> set:
    """Fixpoint over assignments/loop targets: names holding device
    values. Conversion results are host values and do not propagate."""
    device: set = set()
    for _ in range(3):
        before = len(device)
        for node in _walk_function(func):
            device.update(_device_assigns(node, device))
            if isinstance(node, ast.For) \
                    and _refs_device(node.iter, device):
                device.update(_target_names(node.target))
            elif isinstance(node, ast.comprehension) \
                    and _refs_device(node.iter, device):
                device.update(_target_names(node.target))
        if len(device) == before:
            break
    return device


def _loop_region_defs(loop: ast.AST, device: set) -> set:
    """Device names produced inside ``loop``'s per-iteration region —
    converting these where they were dispatched is the blessed idiom."""
    defs: set = set()
    if isinstance(loop, (ast.For, ast.While)):
        region = list(loop.body) + list(loop.orelse)
        if isinstance(loop, ast.While):
            region.append(loop.test)
        for stmt in region:
            for node in ast.walk(stmt):
                defs.update(_device_assigns(node, device))
    # comprehensions assign nothing: defs stay empty, every outside
    # device name converted per-element is a violation
    return defs


class DeviceSyncInLoop(Rule):
    name = "device-sync-in-loop"
    severity = ERROR
    scope = ("core/engine_jax/",)
    invariant = ("engine_jax hot loops never convert device arrays "
                 "element-wise: np.asarray/float()/.item()/.tolist() on "
                 "a device value inside a loop body is an error unless "
                 "the value was dispatched in that same loop iteration")
    oracle = ("fused_campaign bench floor — ≥10x over the scalar "
              "campaign path (benchmarks/check_regression.py)")

    def _conversion_arg(self, node: ast.Call) -> "ast.AST | None":
        name = call_name(node)
        if name in _CONVERT_CALLS and node.args:
            return node.args[0]
        if isinstance(node.func, ast.Attribute) \
                and node.func.attr in _CONVERT_METHODS and not node.args:
            return node.func.value
        return None

    def _innermost_loop(self, func, node, chain):
        """Nearest enclosing loop of ``node`` within ``func``; a ``for``'s
        iterable and a comprehension's first source evaluate once and do
        not count as being inside that loop."""
        child = node
        for anc in chain:
            if anc is func:
                return None
            if isinstance(anc, (ast.For,)) and child is not anc.iter \
                    and child is not anc.target:
                return anc
            if isinstance(anc, ast.While):
                return anc
            if isinstance(anc, (ast.GeneratorExp, ast.ListComp,
                                ast.SetComp, ast.DictComp)) \
                    and child is not anc.generators[0].iter:
                return anc
            child = anc
        return None

    def _visit_function(self, ctx, func):
        device = _collect_device_names(func)
        if not device:
            return
        # parent chains from the local walk (framework parents exist too,
        # but the local walk already excludes nested functions)
        parents: dict = {}
        for node in _walk_function(func):
            for child in ast.iter_child_nodes(node):
                parents[id(child)] = node
        region_defs: dict = {}
        for node in _walk_function(func):
            if not isinstance(node, ast.Call):
                continue
            arg = self._conversion_arg(node)
            if arg is None:
                continue
            names = _device_names_in(arg, device)
            if not names:
                continue
            chain = []
            cur = parents.get(id(node))
            while cur is not None:
                chain.append(cur)
                cur = parents.get(id(cur))
            chain.append(func)
            loop = self._innermost_loop(func, node, chain)
            if loop is None:
                continue
            if id(loop) not in region_defs:
                region_defs[id(loop)] = _loop_region_defs(loop, device)
            escaped = names - region_defs[id(loop)]
            if not escaped:
                continue  # batched-output idiom: converted where dispatched
            yield self.finding(
                ctx, node,
                f"device→host sync in a loop body: converting "
                f"{', '.join(sorted(escaped))} (a jax value produced "
                f"outside this loop) once per iteration — dispatch once "
                f"and convert the batched output outside the loop (see "
                f"campaign._drive_group)")

    def visit_FunctionDef(self, ctx, node):
        yield from self._visit_function(ctx, node)

    def visit_AsyncFunctionDef(self, ctx, node):  # pragma: no cover
        yield from self._visit_function(ctx, node)
