"""Ask/tell protocol conformance rules.

Since the PR-4 inversion, strategies are transition systems: the
``SearchDriver`` owns the evaluate loop (ask → ``runner.run_batch`` →
tell), budget handling, and RNG stepping order. A strategy that calls the
runner itself bypasses budget accounting, trace recording, and the fused
``drive_many`` path; a state that retains the space/runner across a
snapshot boundary either bloats the pickle with a live cache or breaks
resume outright.
"""
from __future__ import annotations

import ast

from ..core import ERROR, Rule, dotted
from .pickle_safety import _is_state_class, _self_assign_names

_RUN_METHODS = frozenset({"run", "run_batch", "run_fused",
                          "run_repeats_fused"})

# methods of a state where (re)binding space/runner is the documented
# lifecycle (driver.SearchState): construction, re-binding on resume,
# unpickling
_BIND_METHODS = frozenset({"__init__", "bind", "__setstate__"})


def _is_runner_receiver(recv: ast.AST) -> bool:
    name = dotted(recv)
    if name is None:
        return False
    last = name.rsplit(".", 1)[-1]
    return last in ("runner", "_runner", "inner_runner")


class DirectRunnerCall(Rule):
    name = "protocol-runner-call"
    severity = ERROR
    scope = ("core/strategies/",)
    invariant = ("strategies never call runner.run*() themselves — the "
                 "SearchDriver owns the evaluate loop, budget placement, "
                 "and trace order")
    oracle = ("fused==sequential and fixture/legacy parity "
              "(tests/test_protocol.py); ProtocolDeprecationWarning "
              "escalated to error in tier-1")

    def visit_Call(self, ctx, node):
        if isinstance(node.func, ast.Attribute) \
                and node.func.attr in _RUN_METHODS \
                and _is_runner_receiver(node.func.value):
            yield self.finding(
                ctx, node,
                f"direct runner.{node.func.attr}() call inside a strategy "
                f"module — evaluation must flow through the SearchDriver "
                f"ask/tell loop (return configs from ask(), read results "
                f"in tell())")


class StateRetainsRuntime(Rule):
    name = "protocol-state-retention"
    severity = ERROR
    scope = ("core/",)
    invariant = ("SearchState subclasses only (re)bind space/runner in "
                 "__init__/bind/__setstate__; pickled attributes must "
                 "not smuggle live runtime across snapshot boundaries")
    oracle = ("pickle-resume for all 9 strategies + no-partial-tell "
              "(tests/test_protocol.py); __getstate__ drops the space")

    def visit_ClassDef(self, ctx, node):
        if not _is_state_class(node):
            return
        for attr, assign, method in _self_assign_names(node):
            if attr in ("space", "runner") and method not in _BIND_METHODS:
                yield self.finding(
                    ctx, assign,
                    f"self.{attr} assigned in {node.name}.{method}() — "
                    f"states re-attach runtime via bind()/attach_runner() "
                    f"with underscore (unpickled-away) attributes; a "
                    f"pickleable {attr!r} reference crosses the snapshot "
                    f"boundary")
