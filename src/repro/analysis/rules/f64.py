"""float64 budget-discipline rules for the jitted engine.

Replay is bit-exact because budget spend accumulates left-to-right in
float64 (core/engine_jax/replay.py's ``budget_scan``; the module
docstring is explicit that any parallel scan reassociates the additions
and drifts by ULPs). Statically enforceable corollaries for everything
under ``core/engine_jax/``:

  * no ``jnp.cumsum``/``cumprod``/``associative_scan`` — parallel scans
    reassociate; sequential accumulation must go through ``lax.scan``;
  * no float32 literals/dtypes — the tables are float64 mirrors of the
    cache columns, and a float32 intermediate silently truncates them;
  * reductions spell out their dtype — without one, ``jnp.sum``'s
    accumulator dtype depends on the ambient ``enable_x64`` context.
"""
from __future__ import annotations

import ast

from ..core import ERROR, WARNING, Rule, call_name, dotted

_JNP_ROOTS = ("jnp", "jax.numpy")


def _jnp_call(node: ast.Call, names: tuple) -> str | None:
    full = call_name(node)
    if full is None:
        return None
    for root in _JNP_ROOTS:
        for fn in names:
            if full == f"{root}.{fn}":
                return fn
    return None


def _has_kwarg(node: ast.Call, name: str) -> bool:
    return any(kw.arg == name for kw in node.keywords)


class ParallelScanOnDevice(Rule):
    name = "f64-parallel-scan"
    severity = ERROR
    scope = ("core/engine_jax/",)
    invariant = ("budget/spend accumulation is left-to-right float64 via "
                 "lax.scan; parallel prefix scans reassociate and drift")
    oracle = ("scalar-vs-jax commit parity incl. exhaustion points "
              "(tests/test_engine_jax.py)")

    def visit_Call(self, ctx, node):
        fn = _jnp_call(node, ("cumsum", "cumprod", "nancumsum"))
        full = call_name(node)
        if fn is None and full in ("lax.associative_scan",
                                   "jax.lax.associative_scan"):
            fn = "associative_scan"
        if fn is not None:
            yield self.finding(
                ctx, node,
                f"{full}() is a parallel scan — it reassociates float "
                f"additions and breaks bit-parity with the sequential "
                f"numpy accumulation; use lax.scan (see budget_scan)")


class ReductionWithoutDtype(Rule):
    name = "f64-sum-dtype"
    severity = WARNING
    scope = ("core/engine_jax/",)
    invariant = ("device reductions pin their accumulator dtype; the "
                 "default depends on the ambient enable_x64 context")
    oracle = ("JAX_ENABLE_X64=1 CI row — the suite must pass with x64 on "
              "globally and off")

    def visit_Call(self, ctx, node):
        fn = _jnp_call(node, ("sum", "prod", "nansum", "nanprod", "trace"))
        if fn is not None and not _has_kwarg(node, "dtype"):
            yield self.finding(
                ctx, node,
                f"jnp.{fn}() without an explicit dtype= — the accumulator "
                f"dtype flips with the enable_x64 context; pin it "
                f"(dtype=jnp.float64 for budget/spend, jnp.int* for "
                f"counters)")


class Float32Literal(Rule):
    name = "f64-float32-literal"
    severity = ERROR
    scope = ("core/engine_jax/",)
    invariant = ("the replay tables and commit path are float64 "
                 "end-to-end; a float32 cast silently truncates the "
                 "cache's charge/time columns")
    oracle = ("float64 device mirrors asserted by table construction "
              "under enable_x64 (core/engine_jax/tables.py) + replay "
              "bit-parity tests")

    def visit_Attribute(self, ctx, node):
        if node.attr != "float32":
            return
        name = dotted(node)
        if name in ("jnp.float32", "np.float32", "numpy.float32",
                    "jax.numpy.float32"):
            yield self.finding(
                ctx, node,
                f"{name} in the jitted engine — replay tables are "
                f"float64 by contract; a float32 cast truncates "
                f"charge/time columns and breaks bit-parity")

    def visit_Call(self, ctx, node):
        # dtype="float32" string form
        for kw in node.keywords:
            if kw.arg == "dtype" and isinstance(kw.value, ast.Constant) \
                    and kw.value.value == "float32":
                yield self.finding(
                    ctx, node,
                    'dtype="float32" in the jitted engine — replay '
                    'tables are float64 by contract')
