"""Ordering-hazard rules.

Shard discovery, journal replay, and cache merging are deterministic only
because every enumeration the output depends on has a defined order
(core/record.py merges by explicit (worker, path) rank; the hub loads in
sorted-key order). ``os.listdir``/``glob`` return filesystem order —
which differs between machines and even between runs — and set iteration
follows per-process hash order. Both are fine *inside* a computation
whose result is order-insensitive, but the cheap, always-safe fix is to
sort at the producer, so that is what the rules demand.
"""
from __future__ import annotations

import ast

from ..core import (ERROR, WARNING, Rule, call_name, dotted, enclosing,
                    is_set_expr, parent, wrapped_in_sorted)

_FS_ENUMERATORS = frozenset({
    "os.listdir", "os.scandir", "glob.glob", "glob.iglob",
})
_PATH_METHODS = frozenset({"iterdir", "glob", "rglob"})

_ENV_MUTATORS = frozenset({
    "os.environ.setdefault", "os.environ.update", "os.environ.pop",
    "os.environ.clear", "os.environ.popitem", "os.putenv", "os.unsetenv",
})
_DEF_SCOPES = (ast.FunctionDef, ast.AsyncFunctionDef)


class UnsortedDirectoryIteration(Rule):
    name = "ordering-listdir"
    severity = ERROR
    scope = ()
    invariant = ("directory enumerations are sorted at the call site — "
                 "filesystem order differs across machines, so anything "
                 "derived from it (shard discovery, checkpoint GC, "
                 "journal replay) would too")
    oracle = ("merge idempotence / shard-order independence "
              "(tests/test_record.py) and resumable-campaign tests")

    def visit_Call(self, ctx, node):
        full = call_name(node)
        is_fs = full in _FS_ENUMERATORS
        if not is_fs and isinstance(node.func, ast.Attribute) \
                and node.func.attr in _PATH_METHODS:
            is_fs = True
            full = f"<path>.{node.func.attr}"
        if is_fs and not wrapped_in_sorted(node):
            yield self.finding(
                ctx, node,
                f"{full}(...) without sorted() — filesystem enumeration "
                f"order is not deterministic; wrap the call in sorted()")


class SetOrderedIteration(Rule):
    name = "ordering-set-iteration"
    severity = WARNING
    scope = ("core/",)
    invariant = ("core/ never iterates a set directly — hash order leaks "
                 "into whatever the loop builds (journal lines, cache "
                 "records, reduction order)")
    oracle = ("bit-identical parallel campaigns across worker counts "
              "(tests/test_parallel.py)")

    def _flag(self, ctx, node):
        return self.finding(
            ctx, node,
            "iteration directly over a set — order follows per-process "
            "hash order; iterate sorted(...) (or keep a list/dict, which "
            "preserve insertion order)")

    def visit_For(self, ctx, node):
        if is_set_expr(node.iter) and not wrapped_in_sorted(node.iter):
            yield self._flag(ctx, node.iter)

    def visit_comprehension(self, ctx, node):
        if is_set_expr(node.iter) and not wrapped_in_sorted(node.iter):
            comp = parent(node)
            # building another set/frozenset from a set is order-free
            if isinstance(comp, (ast.SetComp,)):
                return
            yield self._flag(ctx, node.iter)


class ImportTimeEnvMutation(Rule):
    name = "ordering-import-env-mutation"
    severity = ERROR
    scope = ()
    invariant = ("importing a module never mutates the process environment "
                 "— an import-time os.environ write (e.g. XLA_FLAGS) "
                 "changes behavior for every importer depending on import "
                 "*order*, and jax locks some of it in at first backend "
                 "init; environment setup belongs behind main()/CLI entry")
    oracle = ("library importers see an unchanged environment "
              "(launch.dryrun is importable without forcing 512 devices)")

    def _module_level(self, node) -> bool:
        return enclosing(node, *_DEF_SCOPES) is None

    def visit_Assign(self, ctx, node):
        for tgt in node.targets:
            if (isinstance(tgt, ast.Subscript)
                    and dotted(tgt.value) == "os.environ"
                    and self._module_level(node)):
                yield self.finding(
                    ctx, node,
                    "os.environ[...] assigned at module import time — "
                    "move the mutation behind main()/the CLI entry point")
                return

    def visit_Call(self, ctx, node):
        full = call_name(node)
        if full in _ENV_MUTATORS and self._module_level(node):
            yield self.finding(
                ctx, node,
                f"{full}(...) at module import time mutates the process "
                f"environment — move it behind main()/the CLI entry point")
