"""Rule registry: one module per invariant family.

Order matters only for the report (it is re-sorted by position anyway);
the registry is the single place a new rule module plugs in.
"""
from __future__ import annotations

from . import device_sync, f64, ordering, pickle_safety, protocol, rng

ALL_RULES = (
    rng.ModuleLevelDraw,
    rng.TimeSeededRng,
    rng.DrawInSetIteration,
    pickle_safety.DeviceCacheNotDropped,
    pickle_safety.StateDeviceAttr,
    device_sync.DeviceSyncInLoop,
    f64.ParallelScanOnDevice,
    f64.ReductionWithoutDtype,
    f64.Float32Literal,
    protocol.DirectRunnerCall,
    protocol.StateRetainsRuntime,
    ordering.UnsortedDirectoryIteration,
    ordering.SetOrderedIteration,
    ordering.ImportTimeEnvMutation,
)
