"""Pickle-safety rules.

Process-pool campaigns ship scorers, caches, and mid-run ``SearchState``
snapshots through pickle (core/parallel.py, core/driver.py). Two classes
of objects must never reach the pickle stream:

  * memoized device/columnar mirrors (``CacheColumns._jax``,
    ``CompiledSpace._jax``, ``CacheFile._columns``, ``_space_rows``) —
    jax device arrays don't unpickle portably, and a worker must rebuild
    its mirrors against whatever backend it actually has;
  * device arrays inside ``SearchState`` subclasses — states snapshot
    mid-run into journals (``meta_hypertune``) and resume in arbitrary
    processes.
"""
from __future__ import annotations

import ast
import re

from ..core import ERROR, Rule, dotted

# attribute names that hold device/columnar mirror caches by convention
# (CacheColumns._jax, CompiledSpace._jax, SimulationRunner._jax_eng,
# CacheFile._columns, CacheColumns._space_rows)
_CACHE_ATTR = re.compile(r"^(_jax\w*|_columns|_space_rows)$")

_PICKLE_HOOKS = ("__getstate__", "__reduce__", "__reduce_ex__")


def _class_methods(cls: ast.ClassDef) -> list[ast.FunctionDef]:
    return [n for n in cls.body
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]


def _self_assign_names(cls: ast.ClassDef):
    """Yield (attr-name, assignment-node, enclosing-method-name) for every
    ``self.X = ...`` in the class body."""
    for method in _class_methods(cls):
        for node in ast.walk(method):
            targets = []
            if isinstance(node, ast.Assign):
                targets = node.targets
            elif isinstance(node, (ast.AnnAssign, ast.AugAssign)):
                targets = [node.target]
            for t in targets:
                if isinstance(t, ast.Attribute) \
                        and isinstance(t.value, ast.Name) \
                        and t.value.id == "self":
                    yield t.attr, node, method.name


def _slots_names(cls: ast.ClassDef) -> list[str]:
    for node in cls.body:
        if isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name) and t.id == "__slots__" \
                        and isinstance(node.value,
                                       (ast.Tuple, ast.List, ast.Set)):
                    return [e.value for e in node.value.elts
                            if isinstance(e, ast.Constant)
                            and isinstance(e.value, str)]
    return []


def _is_state_class(cls: ast.ClassDef) -> bool:
    """Heuristic: any base whose (dotted-last) name contains 'State' —
    covers SearchState, _ReplayBridgeState, GeneratorBridgeState, ..."""
    for base in cls.bases:
        name = dotted(base)
        if name and "State" in name.rsplit(".", 1)[-1]:
            return True
    return False


class DeviceCacheNotDropped(Rule):
    name = "pickle-device-cache"
    severity = ERROR
    scope = ()
    invariant = ("classes holding device/columnar mirror caches (_jax*, "
                 "_columns, _space_rows) define __getstate__/__reduce__ "
                 "to drop them before pickling")
    oracle = ("device-arrays-never-pickle tests (tests/test_parallel.py) "
              "and process-pool campaign determinism")

    def visit_ClassDef(self, ctx, node):
        cached = sorted(
            {attr for attr, _, _ in _self_assign_names(node)
             if _CACHE_ATTR.match(attr)}
            | {s for s in _slots_names(node) if _CACHE_ATTR.match(s)})
        if not cached:
            return
        methods = {m.name for m in _class_methods(node)}
        if not methods.intersection(_PICKLE_HOOKS):
            yield self.finding(
                ctx, node,
                f"class {node.name} holds mirror cache(s) "
                f"{', '.join(cached)} but defines no "
                f"__getstate__/__reduce__ to drop them — pickling would "
                f"ship device arrays to workers")


class StateDeviceAttr(Rule):
    name = "pickle-state-device-attr"
    severity = ERROR
    scope = ()
    invariant = ("SearchState subclasses never assign jax/device-array "
                 "attributes: states snapshot into journals and resume "
                 "in arbitrary processes")
    oracle = ("pickle-resume conformance for all strategies "
              "(tests/test_protocol.py) incl. cross-engine resume")

    _DEVICE_ROOTS = ("jnp", "jax")

    def _is_device_expr(self, expr: ast.AST) -> bool:
        for node in ast.walk(expr):
            name = None
            if isinstance(node, (ast.Attribute, ast.Name)):
                name = dotted(node)
            if name:
                root = name.split(".", 1)[0]
                if root in self._DEVICE_ROOTS or name.endswith("device_put"):
                    return True
        return False

    def visit_ClassDef(self, ctx, node):
        if not _is_state_class(node):
            return
        for attr, assign, _method in _self_assign_names(node):
            if attr.startswith("_"):
                continue  # underscore attrs are dropped by __getstate__
            value = getattr(assign, "value", None)
            if value is not None and self._is_device_expr(value):
                yield self.finding(
                    ctx, assign,
                    f"state attribute self.{attr} is assigned a "
                    f"jax/device expression — SearchState pickles must "
                    f"stay host-only (convert with np.asarray, or use an "
                    f"underscore attribute rebuilt on bind())")
