"""Lint report rendering: human text and machine-readable JSON.

The JSON form (``python -m repro lint --format json``, and the CI
artifact via ``--report``) is versioned and self-describing: it embeds
the rule catalogue (invariant + runtime oracle per rule) alongside the
findings, so a report is interpretable without the source checkout.
"""
from __future__ import annotations

from .core import ERROR, WARNING, LintResult, Rule

REPORT_FORMAT = "parity-lint-report"
REPORT_VERSION = 1


def to_json(result: LintResult, rules=()) -> dict:
    return {
        "format": REPORT_FORMAT,
        "version": REPORT_VERSION,
        "ok": result.ok,
        "n_files": result.n_files,
        "n_errors": result.count(ERROR),
        "n_warnings": result.count(WARNING),
        "findings": [f.to_json() for f in result.findings],
        "baselined": [f.to_json() for f in result.baselined],
        "stale_baseline": list(result.stale_baseline),
        "rules": [r.describe() for r in rules],
    }


def to_text(result: LintResult) -> str:
    lines = [f.format() for f in result.findings]
    summary = (f"parity-lint: {result.count(ERROR)} error(s), "
               f"{result.count(WARNING)} warning(s) in "
               f"{result.n_files} file(s)")
    if result.baselined:
        summary += f"; {len(result.baselined)} baselined"
    if result.stale_baseline:
        lines.append(f"note: {len(result.stale_baseline)} stale baseline "
                     f"entr{'y' if len(result.stale_baseline) == 1 else 'ies'}"
                     f" no longer match anything — prune the baseline:")
        for e in result.stale_baseline:
            lines.append(f"  {e['path']}: [{e['rule']}] {e['context']}")
    lines.append(summary + (" — clean" if result.ok else ""))
    return "\n".join(lines)


def rule_catalogue(rules) -> str:
    """``--list-rules``: one block per rule, generated from the registry
    (the same data docs/static-analysis.md catalogues)."""
    blocks = []
    for r in sorted(rules, key=lambda r: r.name):
        scope = ", ".join(r.scope) if r.scope else "all linted files"
        blocks.append(f"{r.name} ({r.severity}; scope: {scope})\n"
                      f"  invariant: {r.invariant}\n"
                      f"  oracle:    {r.oracle}")
    return "\n".join(blocks)
