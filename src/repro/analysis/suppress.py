"""Inline suppressions: ``# parity-lint: disable=<rule>[,<rule>...]``.

A directive on a physical line exempts that line from the named rules
(``disable=all`` exempts it from every rule). The directive must sit on
the line the finding is reported at — for multi-line statements that is
the line of the offending expression, which the finding's position names
exactly.

Suppressions are tracked: a directive that never matches a finding is
reported by the framework-owned ``unused-suppression`` rule (see
``core.lint_source``), so exemptions cannot silently outlive the hazard
they were written for.
"""
from __future__ import annotations

import re

DIRECTIVE = re.compile(r"#\s*parity-lint:\s*disable=([A-Za-z0-9_,\- ]+)")


class Suppressions:
    """Per-file directive table with usage tracking."""

    def __init__(self, source: str):
        self.by_line: dict[int, tuple[str, ...]] = {}
        self._used: dict[tuple[int, str], bool] = {}
        for lineno, line in enumerate(source.splitlines(), 1):
            m = DIRECTIVE.search(line)
            if not m:
                continue
            rules = tuple(sorted({r.strip() for r in m.group(1).split(",")
                                  if r.strip()}))
            if rules:
                self.by_line[lineno] = rules
                for rule in rules:
                    self._used[(lineno, rule)] = False

    def suppresses(self, line: int, rule: str) -> bool:
        rules = self.by_line.get(line)
        if not rules:
            return False
        for candidate in (rule, "all"):
            if candidate in rules:
                self._used[(line, candidate)] = True
                return True
        return False

    def unused(self) -> list[tuple[int, str]]:
        return sorted(key for key, used in self._used.items() if not used)
