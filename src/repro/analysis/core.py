"""The parity-lint framework: AST visitor core, rule protocol, driver.

Every invariant this linter encodes is backed by a *runtime* oracle
somewhere in the tree (a trace fixture, a parity test, the bench score
checksum). The oracles catch a determinism hazard only when some fixture
happens to exercise it; the linter catches the hazard the moment it is
written. docs/static-analysis.md catalogues the rules; each ``Rule``
subclass carries its one-line ``invariant`` and a pointer to the
``oracle`` that backs it, so the catalogue can be generated from the
registry (``python -m repro lint --list-rules``).

Mechanics:

  * ``Finding`` — one diagnostic: module-relative path, position, rule id,
    severity (``error``/``warning`` — both gate in CI; severity ranks the
    report), message.
  * ``Rule`` — a visitor: ``visit_<NodeType>`` methods receive every node
    of that type from a single shared walk; ``check_module`` runs once per
    file. ``scope`` restricts a rule to path prefixes relative to the
    ``repro`` package (``core/``, ``core/engine_jax/``, ...).
  * ``lint_source``/``lint_paths`` — the driver: parse, walk once
    dispatching to all applicable rules, apply inline suppressions
    (``# parity-lint: disable=<rule>``), flag unused suppressions, then
    subtract the checked-in baseline (grandfathered findings).

The linter lints itself (``src/repro/analysis`` is inside the default
target), so the framework obeys its own ordering rules — e.g. the file
walk below is sorted.
"""
from __future__ import annotations

import ast
import dataclasses
import os
from typing import Iterable, Sequence

from .suppress import Suppressions

ERROR = "error"
WARNING = "warning"

# framework-owned rule ids (not in the rules/ registry)
SYNTAX_ERROR = "syntax-error"
UNUSED_SUPPRESSION = "unused-suppression"


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    """One diagnostic, ordered by position for deterministic reports."""

    path: str        # module-relative posix path, e.g. "core/record.py"
    line: int
    col: int         # 1-based
    rule: str
    severity: str
    message: str

    def format(self) -> str:
        return (f"{self.path}:{self.line}:{self.col}: {self.severity}: "
                f"{self.message} [{self.rule}]")

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


class FileContext:
    """One parsed file: source lines plus an AST with parent links."""

    def __init__(self, path: str, source: str):
        self.path = path
        self.source = source
        self.lines = source.splitlines()
        self.tree = ast.parse(source)
        for parent in ast.walk(self.tree):
            for child in ast.iter_child_nodes(parent):
                child._pl_parent = parent  # type: ignore[attr-defined]

    def line_text(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1]
        return ""


# ------------------------------------------------------------- AST helpers
def parent(node: ast.AST) -> ast.AST | None:
    return getattr(node, "_pl_parent", None)


def dotted(node: ast.AST) -> str | None:
    """``a.b.c`` for a Name/Attribute chain, else None."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def call_name(node: ast.Call) -> str | None:
    return dotted(node.func)


def enclosing(node: ast.AST, *types) -> ast.AST | None:
    n = parent(node)
    while n is not None:
        if isinstance(n, types):
            return n
        n = parent(n)
    return None


def wrapped_in_sorted(node: ast.AST) -> bool:
    """True when ``node`` is the direct argument of ``sorted(...)``."""
    p = parent(node)
    return (isinstance(p, ast.Call) and isinstance(p.func, ast.Name)
            and p.func.id == "sorted" and bool(p.args)
            and p.args[0] is node)


def is_set_expr(node: ast.AST) -> bool:
    """A set literal, comprehension, or ``set(...)``/``frozenset(...)``."""
    if isinstance(node, (ast.Set, ast.SetComp)):
        return True
    return (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
            and node.func.id in ("set", "frozenset"))


# ------------------------------------------------------------------- rules
class Rule:
    """Base rule. Subclasses define ``visit_<NodeType>`` methods (called
    from the shared walk with ``(ctx, node)``) and/or ``check_module``;
    both return an iterable of ``Finding``."""

    name: str = ""
    severity: str = ERROR
    scope: tuple[str, ...] = ()     # () = every linted file
    invariant: str = ""             # the contract this rule encodes
    oracle: str = ""                # the runtime check that backs it

    def applies_to(self, path: str) -> bool:
        return not self.scope or any(path.startswith(s) for s in self.scope)

    def finding(self, ctx: FileContext, node: ast.AST, message: str,
                severity: str | None = None) -> Finding:
        return Finding(ctx.path, getattr(node, "lineno", 1),
                       getattr(node, "col_offset", 0) + 1, self.name,
                       severity or self.severity, message)

    def check_module(self, ctx: FileContext) -> Iterable[Finding]:
        return ()

    def describe(self) -> dict:
        return {"rule": self.name, "severity": self.severity,
                "scope": list(self.scope) or ["**"],
                "invariant": self.invariant, "oracle": self.oracle}


def _handlers(rules: Sequence[Rule]) -> dict:
    by_type: dict[str, list] = {}
    for rule in rules:
        for attr in dir(rule):
            if attr.startswith("visit_") and hasattr(ast, attr[6:]):
                by_type.setdefault(attr[6:], []).append(getattr(rule, attr))
    return by_type


def lint_source(source: str, path: str,
                rules: Sequence[Rule]) -> list[Finding]:
    """Lint one file's source: parse, dispatch, suppress. Returns findings
    *before* baseline subtraction (the driver owns the baseline)."""
    sup = Suppressions(source)
    try:
        ctx = FileContext(path, source)
    except SyntaxError as exc:
        return [Finding(path, exc.lineno or 1, exc.offset or 1,
                        SYNTAX_ERROR, ERROR,
                        f"file does not parse: {exc.msg}")]
    applicable = [r for r in rules if r.applies_to(path)]
    handlers = _handlers(applicable)
    findings: list[Finding] = []
    for node in ast.walk(ctx.tree):
        for handler in handlers.get(type(node).__name__, ()):
            findings.extend(handler(ctx, node))
    for rule in applicable:
        findings.extend(rule.check_module(ctx))
    kept = [f for f in findings if not sup.suppresses(f.line, f.rule)]
    # an unused disable is itself a finding: it claims an exemption the
    # code no longer needs, and stale exemptions hide future regressions.
    # Deliberately not suppressible — delete the comment instead.
    kept.extend(
        Finding(path, line, 1, UNUSED_SUPPRESSION, WARNING,
                f"suppression 'parity-lint: disable={rule}' matched no "
                f"finding on this line")
        for line, rule in sup.unused())
    return sorted(kept)


# ------------------------------------------------------------------ driver
def module_path(file_path: str, root: str) -> str:
    """Path key for findings/baselines: relative to the ``repro`` package
    when the file lives under one (stable across checkouts), else relative
    to the linted root (fixture trees in tests)."""
    posix = os.path.abspath(file_path).replace(os.sep, "/")
    marker = "/repro/"
    i = posix.rfind(marker)
    if i != -1:
        return posix[i + len(marker):]
    rel = os.path.relpath(file_path, root if os.path.isdir(root)
                          else os.path.dirname(root) or ".")
    return rel.replace(os.sep, "/")


def iter_python_files(root: str) -> Iterable[str]:
    if os.path.isfile(root):
        yield root
        return
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames.sort()            # deterministic walk (our own medicine)
        dirnames[:] = [d for d in dirnames if d != "__pycache__"]
        for name in sorted(filenames):
            if name.endswith(".py"):
                yield os.path.join(dirpath, name)


@dataclasses.dataclass
class LintResult:
    """Outcome of one lint run. ``findings`` is what gates (suppressions
    applied, baseline subtracted); ``baselined`` are the grandfathered
    matches; ``stale_baseline`` are baseline entries that no longer match
    anything (safe to delete from the baseline file)."""

    findings: list[Finding]
    baselined: list[Finding]
    stale_baseline: list[dict]
    n_files: int

    @property
    def ok(self) -> bool:
        return not self.findings

    def count(self, severity: str) -> int:
        return sum(1 for f in self.findings if f.severity == severity)


def lint_paths(paths: Sequence[str], baseline=None,
               rules: Sequence[Rule] | None = None) -> LintResult:
    """Lint every ``.py`` file under ``paths``. ``baseline`` is a
    ``baseline.Baseline``, a path to one, or None."""
    from . import default_rules
    from .baseline import Baseline
    if rules is None:
        rules = default_rules()
    if isinstance(baseline, str):
        baseline = Baseline.load(baseline)
    for p in paths:
        if not os.path.exists(p):
            raise ValueError(f"no such path: {p}")
    raw: list[Finding] = []
    texts: dict[str, list[str]] = {}
    n_files = 0
    for root in paths:
        for file_path in iter_python_files(root):
            n_files += 1
            with open(file_path, "r", encoding="utf-8") as f:
                source = f.read()
            mod = module_path(file_path, root)
            texts[mod] = source.splitlines()
            raw.extend(lint_source(source, mod, rules))

    def line_text(f: Finding) -> str:
        lines = texts.get(f.path, [])
        return lines[f.line - 1] if 1 <= f.line <= len(lines) else ""

    findings, grandfathered = [], []
    for f in sorted(raw):
        if baseline is not None and baseline.match(f, line_text(f)):
            grandfathered.append(f)
        else:
            findings.append(f)
    stale = baseline.stale() if baseline is not None else []
    return LintResult(findings, grandfathered, stale, n_files)


def run_source(source: str, path: str = "module.py",
               rules: Sequence[Rule] | None = None) -> list[Finding]:
    """Lint a source snippet under a pseudo module-relative ``path`` (which
    selects the scoped rules, e.g. ``core/x.py``) — the fixture entry point
    used throughout tests/test_analysis.py."""
    from . import default_rules
    return lint_source(source, path,
                       default_rules() if rules is None else rules)
