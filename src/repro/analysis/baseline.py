"""Checked-in baseline of grandfathered findings.

The baseline exists for findings that are *deliberate* — code documented
to live outside the contract a rule encodes (e.g. the free-running
``engine_jax/strategies.py`` loops are outside the bit-parity contract by
design). Everything else gets fixed, not baselined.

Entries are keyed by ``(rule, path, context)`` where ``context`` is the
stripped source line of the finding — stable under unrelated edits that
shift line numbers, invalidated the moment the offending line itself
changes (which is when a human should re-decide). Matching is
multiset-style: an entry absorbs at most ``count`` findings, so new
duplicates of a grandfathered pattern still gate. Entries that match
nothing are reported as *stale* so the baseline only ever shrinks.
"""
from __future__ import annotations

import json
from collections import Counter

BASELINE_FORMAT = "parity-lint-baseline"
BASELINE_VERSION = 1


def _key(rule: str, path: str, context: str) -> tuple:
    return (rule, path, " ".join(context.split()))


class Baseline:
    def __init__(self, entries=()):
        self._avail: Counter = Counter()
        for e in entries:
            self._avail[_key(e["rule"], e["path"], e.get("context", ""))] \
                += int(e.get("count", 1))

    @staticmethod
    def load(path: str) -> "Baseline":
        with open(path, "r", encoding="utf-8") as f:
            try:
                data = json.load(f)
            except json.JSONDecodeError as exc:
                raise ValueError(f"{path} is not a baseline file: {exc}")
        if not isinstance(data, dict) \
                or data.get("format") != BASELINE_FORMAT:
            raise ValueError(f"{path} is not a {BASELINE_FORMAT} file")
        return Baseline(data.get("entries", ()))

    def match(self, finding, line_text: str) -> bool:
        """Consume one baseline slot for this finding if available."""
        key = _key(finding.rule, finding.path, line_text)
        if self._avail.get(key, 0) > 0:
            self._avail[key] -= 1
            return True
        return False

    def stale(self) -> list[dict]:
        """Entries (or counts) that matched no current finding."""
        return [{"rule": r, "path": p, "context": c, "count": n}
                for (r, p, c), n in sorted(self._avail.items()) if n > 0]


def baseline_dict(findings, line_text_of) -> dict:
    """Serializable baseline covering ``findings`` (``--write-baseline``).
    Identical (rule, path, context) triples fold into one counted entry;
    output order is sorted, so the file is deterministic."""
    counts: Counter = Counter()
    for f in findings:
        counts[_key(f.rule, f.path, line_text_of(f))] += 1
    entries = [{"rule": r, "path": p, "context": c,
                **({"count": n} if n > 1 else {})}
               for (r, p, c), n in sorted(counts.items())]
    return {"format": BASELINE_FORMAT, "version": BASELINE_VERSION,
            "entries": entries}


def write(path: str, findings, line_text_of) -> int:
    data = baseline_dict(findings, line_text_of)
    with open(path, "w", encoding="utf-8") as f:
        json.dump(data, f, indent=2, sort_keys=True)
        f.write("\n")
    return len(data["entries"])
