"""Unified command-line interface: ``python -m repro <subcommand>``.

One entry point for the paper's workflow, replacing the ad-hoc scripts in
``examples/`` and ``benchmarks/`` for everyday use:

  simulate   score one strategy (fixed hyperparameters) with the
             methodology in simulation mode (paper Sec. III-B/C, Eqs. 2–3)
  hypertune  exhaustive hyperparameter-grid campaign (Sec. IV-B,
             Table III) — parallel (``--workers``) and resumable
             (``--journal``)
  meta       meta-strategy hyperparameter optimization (Sec. IV-C,
             Table IV / Eq. 4), journaled for resume
  report     inspect a campaign journal: ranking, optimal-vs-average
             improvement (the 94.8 % metric), wall-clock parallelism

Search spaces come either from the benchmark hub (``--kernels/--devices``
or ``--split``, Sec. III-D) or from explicit T4 cache files (``--cache``).
"""
from __future__ import annotations

import argparse
import ast
import os
import sys
import time
from typing import Sequence

from .core.cache import CacheFile
from .core.hypertuner import (HyperConfigResult, HyperTuningResult,
                              exhaustive_hypertune, hyperparam_searchspace,
                              meta_hypertune, score_hyperconfig)
from .core.methodology import SpaceScorer, make_scorer
from .core.parallel import CampaignExecutor, CampaignJournal, report_from_json
from .core.strategies import STRATEGIES


# ------------------------------------------------------------ shared options
def _add_space_args(p: argparse.ArgumentParser) -> None:
    g = p.add_argument_group("search spaces (scoring data)")
    g.add_argument("--cache", action="append", default=[], metavar="PATH",
                   help="T4 cache file (.json/.json.gz/.json.zst); "
                        "repeatable. Overrides the hub options.")
    g.add_argument("--split", choices=("train", "test"), default="train",
                   help="hub device split (paper Sec. III-D; default train)")
    g.add_argument("--kernels", default=None,
                   help="comma-separated hub kernels (default: all)")
    g.add_argument("--devices", default=None,
                   help="comma-separated hub devices (overrides --split)")
    g.add_argument("--hub-root", default=None,
                   help="hub directory (default: the bundled hub path)")


def _add_exec_args(p: argparse.ArgumentParser) -> None:
    g = p.add_argument_group("execution")
    g.add_argument("--workers", type=int, default=1,
                   help="worker pool size (1 = serial; results are "
                        "bit-identical at any worker count)")
    g.add_argument("--backend", choices=("auto", "thread", "process"),
                   default="auto", help="worker pool backend")
    g.add_argument("--repeats", type=int, default=25,
                   help="methodology repeats per space (paper uses 25)")
    g.add_argument("--seed", type=int, default=0)


def _parse_hyperparams(text: str | None) -> dict:
    """Parse ``k=v,k2=v2`` with Python-literal values (``0.05``, ``True``,
    ``'greedy'``); bare words fall back to strings."""
    out: dict = {}
    for item in filter(None, (text or "").split(",")):
        key, _, raw = item.partition("=")
        if not _:
            raise SystemExit(f"--hyperparams: expected k=v, got {item!r}")
        try:
            out[key.strip()] = ast.literal_eval(raw.strip())
        except (ValueError, SyntaxError):
            out[key.strip()] = raw.strip()
    return out


def build_scorers(args) -> list[SpaceScorer]:
    """Resolve the scoring data (paper Sec. III-B: one scorer per brute-
    forced search space) from ``--cache`` files or the benchmark hub."""
    if args.cache:
        return [make_scorer(CacheFile.load(p)) for p in args.cache]
    from .core.dataset import DEFAULT_ROOT, load_hub
    from .core.devices import TEST_DEVICES, TRAIN_DEVICES
    root = args.hub_root or DEFAULT_ROOT
    kernels = args.kernels.split(",") if args.kernels else None
    if args.devices:
        devices = args.devices.split(",")
    else:
        devices = list(TRAIN_DEVICES if args.split == "train"
                       else TEST_DEVICES)
    hub = load_hub(root, kernels=kernels, devices=devices)
    if not hub:
        raise SystemExit("no hub spaces matched the selection")
    return [make_scorer(c) for _, c in sorted(hub.items())]


def _progress(quiet: bool):
    if quiet:
        return None
    return lambda msg: print(msg, flush=True)


# -------------------------------------------------------------- subcommands
def cmd_simulate(args) -> int:
    """Score one strategy configuration (paper Sec. III-B, Eqs. 2–3)."""
    scorers = build_scorers(args)
    hp = _parse_hyperparams(args.hyperparams)
    with CampaignExecutor(args.workers, args.backend) as ex:
        report = score_hyperconfig(args.strategy, hp, scorers,
                                   repeats=args.repeats, seed=args.seed,
                                   executor=ex)
    for name, score in sorted(report.per_space_score.items()):
        print(f"  {name:28s} {score:+.4f}")
    print(f"aggregate score (Eq. 3): {report.score:+.4f}  "
          f"[{args.strategy} x{args.repeats} repeats, "
          f"{len(scorers)} spaces]")
    print(f"simulated {report.simulated_seconds/3600:.2f} h of tuning in "
          f"{report.wall_seconds:.1f} s wall")
    return 0


def cmd_hypertune(args) -> int:
    """Exhaustive hyperparameter tuning (paper Sec. IV-B, Table III)."""
    scorers = build_scorers(args)
    journal = CampaignJournal(args.journal) if args.journal else None
    t0 = time.perf_counter()
    with CampaignExecutor(args.workers, args.backend) as ex:
        res = exhaustive_hypertune(args.strategy, scorers,
                                   repeats=args.repeats, seed=args.seed,
                                   progress=_progress(args.quiet),
                                   executor=ex, journal=journal)
    wall = time.perf_counter() - t0
    _print_ranking(res.results, args.top)
    best, avg = res.best, res.closest_to_mean()
    rel = (best.score - avg.score) / max(abs(avg.score), 1e-2)
    print(f"optimal vs average config: {best.score:+.4f} vs {avg.score:+.4f}"
          f" ({100*rel:+.1f}%; paper Sec. IV-B reports +94.8% on average)")
    print(f"campaign: {len(res.results)} configs, "
          f"{res.simulated_seconds/3600:.2f} simulated h replayed in "
          f"{wall:.1f} s wall ({args.workers} workers)")
    if journal:
        print(f"journal: {journal.path}")
    return 0


def cmd_meta(args) -> int:
    """Meta-strategy hyperparameter tuning (paper Sec. IV-C, Eq. 4)."""
    scorers = build_scorers(args)
    journal = CampaignJournal(args.journal) if args.journal else None
    with CampaignExecutor(args.workers, args.backend) as ex:
        res = meta_hypertune(args.strategy, args.meta_strategy, scorers,
                             extended=not args.table3_grid,
                             max_hp_evals=args.max_hp_evals,
                             repeats=args.repeats, seed=args.seed,
                             meta_hyperparams=_parse_hyperparams(
                                 args.meta_hyperparams),
                             progress=_progress(args.quiet),
                             executor=ex, journal=journal)
    grid = hyperparam_searchspace(args.strategy,
                                  extended=not args.table3_grid)
    print(f"best hyperparameters for {args.strategy} "
          f"(found by {args.meta_strategy}): {res.best_hyperparams}")
    print(f"score {res.best_score:+.4f} after {len(res.evaluated)} of "
          f"{grid.size} grid points ({res.wall_seconds:.1f} s wall)")
    if journal:
        print(f"journal: {journal.path}")
    return 0


def cmd_report(args) -> int:
    """Summarize a campaign journal (no recomputation)."""
    journal = CampaignJournal(args.journal)
    header, records = journal.read()
    if header is None:
        raise SystemExit(f"no journal at {args.journal}")
    mode = header.get("mode", "?")
    print(f"campaign: {mode} {header.get('strategy')} "
          f"(repeats={header.get('repeats')}, seed={header.get('seed')})")
    print(f"spaces: {', '.join(header.get('spaces', []))}")
    if not records:
        print("no completed evaluations yet")
        return 0
    if mode == "exhaustive":
        results = {r["hp_id"]: HyperConfigResult(
            r["hyperparams"], report_from_json(r["report"]))
            for r in records}
        grid = hyperparam_searchspace(header["strategy"])
        print(f"progress: {len(results)}/{grid.size} configurations")
        _print_ranking(results, args.top)
        res = HyperTuningResult(header["strategy"], results, 0.0, 0.0)
        best, avg = res.best, res.closest_to_mean()
        rel = (best.score - avg.score) / max(abs(avg.score), 1e-2)
        print(f"optimal vs average config: {best.score:+.4f} vs "
              f"{avg.score:+.4f} ({100*rel:+.1f}%)")
        work = sum(r.report.wall_seconds for r in results.values())
    else:
        ranked = sorted(records, key=lambda r: -r["score"])[:args.top]
        for r in ranked:
            print(f"  {r['score']:+.4f}  {r['hp_id']}")
        work = 0.0
    done_wall = max(r.get("done_wall", 0.0) for r in records)
    simulated = sum(r["report"]["simulated_seconds"] if "report" in r
                    else r["simulated_seconds"] for r in records)
    print(f"simulated tuning replayed: {simulated/3600:.2f} h")
    if done_wall:
        rate = 60.0 * len(records) / done_wall
        print(f"campaign wall: {done_wall:.1f} s "
              f"({rate:.1f} configs/min)")
    if work and done_wall:
        print(f"aggregate worker compute: {work:.1f} s -> "
              f"average parallelism {work/done_wall:.2f}x")
    return 0


def _print_ranking(results: dict, top: int) -> None:
    ranked = sorted(results.items(), key=lambda kv: -kv[1].score)
    for hp_id, r in ranked[:top]:
        print(f"  {r.score:+.4f}  {hp_id}")
    if len(ranked) > top:
        print(f"  ... {len(ranked) - top} more "
              f"(worst {ranked[-1][1].score:+.4f})")


# ------------------------------------------------------------------ parser
def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="repro",
        description="Tuning the Tuner — simulation-mode auto-tuning and "
                    "hyperparameter campaigns (parallel + resumable)")
    sub = p.add_subparsers(dest="command", required=True)

    ps = sub.add_parser("simulate", help="score one strategy configuration "
                        "with the methodology (Sec. III-B)")
    ps.add_argument("--strategy", required=True, choices=sorted(STRATEGIES))
    ps.add_argument("--hyperparams", default=None, metavar="K=V,...",
                    help="strategy hyperparameters (default: DEFAULTS)")
    _add_space_args(ps)
    _add_exec_args(ps)
    ps.set_defaults(fn=cmd_simulate)

    ph = sub.add_parser("hypertune", help="exhaustive hyperparameter "
                        "campaign (Table III), parallel + resumable")
    ph.add_argument("--strategy", required=True, choices=sorted(STRATEGIES))
    ph.add_argument("--journal", default=None, metavar="PATH",
                    help="JSONL checkpoint; rerun with the same path to "
                         "resume an interrupted campaign")
    ph.add_argument("--top", type=int, default=5,
                    help="show the N best configurations")
    ph.add_argument("--quiet", action="store_true")
    _add_space_args(ph)
    _add_exec_args(ph)
    ph.set_defaults(fn=cmd_hypertune)

    pm = sub.add_parser("meta", help="meta-strategy hyperparameter "
                        "optimization (Eq. 4, Table IV)")
    pm.add_argument("--strategy", required=True, choices=sorted(STRATEGIES))
    pm.add_argument("--meta-strategy", required=True,
                    choices=sorted(STRATEGIES))
    pm.add_argument("--max-hp-evals", type=int, default=50)
    pm.add_argument("--table3-grid", action="store_true",
                    help="search the small Table III grid instead of the "
                         "extended Table IV space")
    pm.add_argument("--meta-hyperparams", default=None, metavar="K=V,...")
    pm.add_argument("--journal", default=None, metavar="PATH")
    pm.add_argument("--quiet", action="store_true")
    _add_space_args(pm)
    _add_exec_args(pm)
    pm.set_defaults(fn=cmd_meta)

    pr = sub.add_parser("report", help="summarize a campaign journal")
    pr.add_argument("journal", metavar="JOURNAL",
                    help="path to a campaign JSONL journal")
    pr.add_argument("--top", type=int, default=10)
    pr.set_defaults(fn=cmd_report)
    return p


def main(argv: Sequence[str] | None = None) -> int:
    args = build_parser().parse_args(argv)
    try:
        return args.fn(args)
    except ValueError as e:
        # domain errors (journal mismatch, bad cache format, unknown
        # hyperparameters) are user errors, not crashes
        raise SystemExit(f"error: {e}")


if __name__ == "__main__":
    sys.exit(main())
