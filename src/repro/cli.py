"""Unified command-line interface: ``python -m repro <subcommand>``.

One entry point for the paper's workflow, replacing the ad-hoc scripts in
``examples/`` and ``benchmarks/`` for everyday use:

  simulate   score one strategy (fixed hyperparameters) with the
             methodology in simulation mode (paper Sec. III-B/C, Eqs. 2–3)
  hypertune  exhaustive hyperparameter-grid campaign (Sec. IV-B,
             Table III) — parallel (``--workers``) and resumable
             (``--journal``)
  meta       meta-strategy hyperparameter optimization (Sec. IV-C,
             Table IV / Eq. 4), journaled for resume
  report     inspect a campaign journal: ranking, optimal-vs-average
             improvement (the 94.8 % metric), wall-clock parallelism
  spaces     per-space statistics for the selected hub/cache spaces and
             the strategies' hyperparameter grids: cartesian vs valid
             size, valid fraction, neighbor-degree distribution, compile
             time (the ``core.space`` compiled representation)
  record     strategy-sample a registered Pallas kernel (live interpret
             mode or cost model) across parallel workers and emit a
             replayable T4 cache — producing the FAIR data the simulation
             mode consumes (Sec. III-C/D)
  bruteforce exhaustively record a registered kernel's whole valid space
             (the paper's Table II hub-building runs), resumable per shard
  merge-cache fold recording shards (from crashed/partial/parallel runs)
             into one canonical cache file — ``--hub-root`` also registers
             the merge into a hub and evicts stale service index entries
  lookup     best known config for (kernel, problem shape, device) from
             the recorded hub: exact hit, nearest-shape transfer with
             confidence, roofline-modeled answer, or cold
             (docs/service.md, docs/scenarios.md)
  serve      line-oriented lookup service: JSON requests on stdin, one
             ``LookupResult`` JSON per line on stdout
  scenarios  the scenario matrix: every (kernel × shape × device) triple
             with its coverage tier (recorded | modeled | cold), optional
             best times, JSON artifact output, and the recorded best-time
             regression gate (docs/scenarios.md)
  fleet      run/resume the recording fleet over the scenario matrix:
             record → merge → register each runnable triple into the hub,
             journaled so re-runs skip completed work
  hub        hub dataset management: build, info, verify (sha256 every
             indexed file), stats (includes the coverage matrix)
  lint       parity-lint: static analysis of the determinism / pickle /
             f64 / protocol contracts (docs/static-analysis.md); the CI
             gate is ``python -m repro lint src/repro``

Search spaces come either from the benchmark hub (``--kernels/--devices``
or ``--split``, Sec. III-D) or from explicit T4 cache files (``--cache``)
— including caches produced by ``record``/``bruteforce``.
"""
from __future__ import annotations

import argparse
import ast
import os
import sys
from typing import Sequence

from .api import Tuner
from .core.hypertuner import (HyperConfigResult, HyperTuningResult,
                              hyperparam_searchspace)
from .core.parallel import CampaignJournal, report_from_json
from .core.strategies import STRATEGIES


# ------------------------------------------------------------ shared options
def _add_space_args(p: argparse.ArgumentParser) -> None:
    g = p.add_argument_group("search spaces (scoring data)")
    g.add_argument("--cache", action="append", default=[], metavar="PATH",
                   help="T4 cache file (.json/.json.gz/.json.zst); "
                        "repeatable. Overrides the hub options.")
    g.add_argument("--split", choices=("train", "test"), default="train",
                   help="hub device split (paper Sec. III-D; default train)")
    g.add_argument("--kernels", default=None,
                   help="comma-separated hub kernels (default: all)")
    g.add_argument("--devices", default=None,
                   help="comma-separated hub devices (overrides --split)")
    g.add_argument("--hub-root", default=None,
                   help="hub directory (default: the bundled hub path)")
    g.add_argument("--engine", choices=("vectorized", "scalar", "jax"),
                   default="vectorized",
                   help="simulation engine: 'vectorized' resolves lookups "
                        "and scoring through columnar numpy arrays; "
                        "'scalar' is the per-evaluation reference path; "
                        "'jax' replays row batches through the jitted "
                        "device kernel (falls back to 'vectorized' when no "
                        "jax backend is importable). Scores are "
                        "bit-identical across all three (see "
                        "docs/performance.md)")


def _add_exec_args(p: argparse.ArgumentParser) -> None:
    g = p.add_argument_group("execution")
    g.add_argument("--workers", type=int, default=1,
                   help="worker pool size (1 = serial; results are "
                        "bit-identical at any worker count)")
    g.add_argument("--backend", choices=("auto", "thread", "process"),
                   default="auto", help="worker pool backend")
    g.add_argument("--repeats", type=int, default=25,
                   help="methodology repeats per space (paper uses 25)")
    g.add_argument("--seed", type=int, default=0)


def _parse_hyperparams(text: str | None) -> dict:
    """Parse ``k=v,k2=v2`` with Python-literal values (``0.05``, ``True``,
    ``'greedy'``); bare words fall back to strings."""
    out: dict = {}
    for item in filter(None, (text or "").split(",")):
        key, _, raw = item.partition("=")
        if not _:
            raise SystemExit(f"--hyperparams: expected k=v, got {item!r}")
        try:
            out[key.strip()] = ast.literal_eval(raw.strip())
        except (ValueError, SyntaxError):
            out[key.strip()] = raw.strip()
    return out


def _progress(quiet: bool):
    if quiet:
        return None
    return lambda msg: print(msg, flush=True)


def tuner_from_args(args) -> Tuner:
    """Build the ``repro.api.Tuner`` facade from the shared CLI options
    (paper Sec. III-B: one scorer per brute-forced search space)."""
    return Tuner(
        caches=args.cache or None,
        kernels=args.kernels.split(",") if args.kernels else None,
        devices=args.devices.split(",") if args.devices else None,
        split=args.split,
        hub_root=args.hub_root,
        engine=getattr(args, "engine", "vectorized"),
        repeats=args.repeats,
        seed=args.seed,
        workers=args.workers,
        backend=args.backend,
        progress=_progress(getattr(args, "quiet", False)),
    )


# -------------------------------------------------------------- subcommands
def cmd_simulate(args) -> int:
    """Score one strategy configuration (paper Sec. III-B, Eqs. 2–3)."""
    with tuner_from_args(args) as tuner:
        run = tuner.simulate(args.strategy,
                             _parse_hyperparams(args.hyperparams))
    report = run.report
    for name, score in sorted(report.per_space_score.items()):
        print(f"  {name:28s} {score:+.4f}")
    print(f"aggregate score (Eq. 3): {run.score:+.4f}  "
          f"[{args.strategy} x{args.repeats} repeats, "
          f"{len(report.per_space_score)} spaces]")
    print(f"simulated {run.simulated_seconds/3600:.2f} h of tuning in "
          f"{report.wall_seconds:.1f} s wall (drive: {run.fuse})")
    return 0


def cmd_hypertune(args) -> int:
    """Exhaustive hyperparameter tuning (paper Sec. IV-B, Table III)."""
    with tuner_from_args(args) as tuner:
        run = tuner.hypertune(args.strategy, journal=args.journal)
    res = run.hypertuning
    _print_ranking(res.results, args.top)
    best, avg = res.best, res.closest_to_mean()
    rel = (best.score - avg.score) / max(abs(avg.score), 1e-2)
    print(f"optimal vs average config: {best.score:+.4f} vs {avg.score:+.4f}"
          f" ({100*rel:+.1f}%; paper Sec. IV-B reports +94.8% on average)")
    print(f"campaign: {run.n_evaluated} configs, "
          f"{run.simulated_seconds/3600:.2f} simulated h replayed in "
          f"{run.wall_seconds:.1f} s wall ({args.workers} workers, "
          f"drive: {run.fuse})")
    if args.journal:
        print(f"journal: {args.journal}")
    return 0


def cmd_meta(args) -> int:
    """Meta-strategy hyperparameter tuning (paper Sec. IV-C, Eq. 4)."""
    with tuner_from_args(args) as tuner:
        run = tuner.meta(args.strategy, args.meta_strategy,
                         extended=not args.table3_grid,
                         max_hp_evals=args.max_hp_evals,
                         meta_hyperparams=_parse_hyperparams(
                             args.meta_hyperparams),
                         journal=args.journal)
    grid = hyperparam_searchspace(args.strategy,
                                  extended=not args.table3_grid)
    print(f"best hyperparameters for {args.strategy} "
          f"(found by {args.meta_strategy}): {run.best_hyperparams}")
    print(f"score {run.score:+.4f} after {run.n_evaluated} of "
          f"{grid.size} grid points ({run.wall_seconds:.1f} s wall"
          + (f", drive: {run.fuse}" if run.fuse else "") + ")")
    if run.speedup:
        print(f"simulated {run.simulated_seconds/3600:.2f} h of tuning "
              f"replayed in {run.wall_seconds:.1f} s wall "
              f"({run.speedup:,.0f}x)")
    if args.journal:
        print(f"journal: {args.journal}")
    return 0


def cmd_report(args) -> int:
    """Summarize a campaign journal (no recomputation)."""
    journal = CampaignJournal(args.journal)
    header, records = journal.read()
    if header is None:
        raise SystemExit(f"no journal at {args.journal}")
    mode = header.get("mode", "?")
    print(f"campaign: {mode} {header.get('strategy')} "
          f"(repeats={header.get('repeats')}, seed={header.get('seed')})")
    print(f"spaces: {', '.join(header.get('spaces', []))}")
    snapshots = [r for r in records if r.get("type") == "checkpoint"]
    records = [r for r in records if r.get("type") != "checkpoint"]
    if not records:
        print("no completed evaluations yet")
        return 0
    if mode == "exhaustive":
        results = {r["hp_id"]: HyperConfigResult(
            r["hyperparams"], report_from_json(r["report"]))
            for r in records}
        grid = hyperparam_searchspace(header["strategy"])
        print(f"progress: {len(results)}/{grid.size} configurations")
        _print_ranking(results, args.top)
        res = HyperTuningResult(header["strategy"], results, 0.0, 0.0)
        best, avg = res.best, res.closest_to_mean()
        rel = (best.score - avg.score) / max(abs(avg.score), 1e-2)
        print(f"optimal vs average config: {best.score:+.4f} vs "
              f"{avg.score:+.4f} ({100*rel:+.1f}%)")
        modes = {r.report.fuse for r in results.values()}
        print(f"drive: {modes.pop() if len(modes) == 1 else 'mixed'}")
        work = sum(r.report.wall_seconds for r in results.values())
    else:
        ranked = sorted(records, key=lambda r: -r["score"])[:args.top]
        for r in ranked:
            print(f"  {r['score']:+.4f}  {r['hp_id']}")
        if snapshots:
            print(f"mid-run state snapshots: {len(snapshots)} "
                  f"(resume continues inside the tuning run)")
        work = 0.0
    done_wall = max(r.get("done_wall", 0.0) for r in records)
    simulated = sum(r["report"]["simulated_seconds"] if "report" in r
                    else r["simulated_seconds"] for r in records)
    print(f"simulated tuning replayed: {simulated/3600:.2f} h")
    if done_wall:
        rate = 60.0 * len(records) / done_wall
        print(f"campaign wall: {done_wall:.1f} s "
              f"({rate:.1f} configs/min)")
        # simulated-vs-wall: the paper's Fig. 9 headline ratio, now
        # reported for meta campaigns too (MetaTuningResult carries
        # simulated_seconds since the api redesign)
        print(f"simulated-vs-wall speedup: {simulated/done_wall:,.0f}x")
    if work and done_wall:
        print(f"aggregate worker compute: {work:.1f} s -> "
              f"average parallelism {work/done_wall:.2f}x")
    return 0


def cmd_spaces(args) -> int:
    """Per-space stats (thin over ``repro.api.describe_space``)."""
    from .api import hyperparam_space_stats

    def row(st: dict) -> str:
        adj, ham = st["degrees"]["strictly_adjacent"], st["degrees"]["hamming"]
        return (f"  {st['name']:32s} {st['cartesian_size']:>9d} "
                f"{st['n_valid']:>8d} {st['valid_fraction']:>6.1%} "
                f"{adj['median']:>5.1f}/{adj['max']:<4d} "
                f"{ham['median']:>6.1f}/{ham['max']:<5d} "
                f"{st['compile_seconds']*1e3:>8.1f}")

    header = (f"  {'space':32s} {'cartesian':>9s} {'valid':>8s} {'frac':>6s} "
              f"{'adj med/max':>10s} {'ham med/max':>12s} {'compile ms':>9s}")
    tuner = tuner_from_args(args)
    print("search spaces (hub/cache selection):")
    print(header)
    for st in tuner.space_stats():
        print(row(st))
    print(f"hyperparameter grids "
          f"({'Table IV extended' if args.extended else 'Table III'}):")
    print(header)
    for st in hyperparam_space_stats(extended=args.extended):
        print(row(st))
    return 0


def _run_recording(args, bruteforce: bool) -> int:
    """``record``/``bruteforce``: fan one shard per worker out through the
    facade, which merges them into the output cache."""
    mode = "bruteforce" if bruteforce else "record"
    from .kernels import get_kernel
    try:
        get_kernel(args.kernel)  # fail fast on unknown kernels
    except KeyError as e:
        raise SystemExit(f"error: {e.args[0] if e.args else e}")
    tuner = Tuner(workers=args.workers, backend=args.backend, seed=args.seed,
                  progress=lambda msg: print(f"  {msg}", flush=True))
    with tuner:
        run = tuner.record(
            args.kernel, runner=args.runner, device=args.device,
            problem=_parse_hyperparams(getattr(args, "problem", None)),
            strategy=getattr(args, "strategy", "random_search"),
            hyperparams=_parse_hyperparams(
                getattr(args, "hyperparams", None)),
            repeats=args.repeats, max_evals=args.max_evals,
            max_seconds=args.seconds, out=args.out,
            bruteforce=bruteforce)
    cache = run.cache
    n_ok = cache.meta["n_ok"]
    total = (cache.space.size if cache.space is not None
             else len(cache.results))
    print(f"{mode}: {len(cache.results)}/{total} configs recorded "
          f"({n_ok} ok) for {args.kernel}@{args.device} "
          f"[{args.runner}] in {run.wall_seconds:.1f} s wall "
          f"({max(1, args.workers)} workers)")
    if run.best_config is not None:
        print(f"best: {run.best_config} ({run.best_value*1e3:.3f} ms)")
    print(f"cache: {run.cache_path}")
    print(f"replay: python -m repro simulate --strategy random_search "
          f"--cache {run.cache_path}")
    return 0


def cmd_record(args) -> int:
    """Strategy-sampled recording of a registered kernel (the affordable
    way to turn a live space into simulation data)."""
    return _run_recording(args, bruteforce=False)


def cmd_bruteforce(args) -> int:
    """Exhaustive recording (paper Table II: brute-forcing the hub)."""
    return _run_recording(args, bruteforce=True)


def cmd_merge_cache(args) -> int:
    """Merge recording shards into one canonical cache file."""
    from .core import record as rec
    header, _ = rec.ObservationShard(args.shards[0]).read()
    if header is None:
        raise SystemExit(f"{args.shards[0]} has no shard header")
    space = rec.registry_space(header.get("kernel", ""),
                               header.get("problem"))
    cache = rec.merge_shards(args.shards, space=space)
    cache.save(args.out)
    print(f"merged {cache.meta['n_shards']} shards -> {args.out}: "
          f"{cache.meta['n_configs']} configs ({cache.meta['n_ok']} ok) "
          f"for {cache.kernel}@{cache.device}")
    if args.hub_root:
        from .api import Hub
        key = Hub(args.hub_root).register(
            cache, problem=header.get("problem") or None)
        print(f"registered in hub {args.hub_root} as {key} "
              f"(live lookup indexes invalidated)")
    return 0


def _lookup_hub(args):
    """A ``ConfigHub`` from the shared lookup/serve options."""
    from .service import ConfigHub
    warm: bool | dict = False
    if getattr(args, "warm_start", False):
        warm = {"max_evals": args.warm_max_evals}
    return ConfigHub(args.hub_root or _default_hub_root(),
                     verify=not args.no_verify,
                     ttl_s=getattr(args, "ttl", None), warm_start=warm)


def _default_hub_root() -> str:
    from .hub import DEFAULT_ROOT
    return DEFAULT_ROOT


def _print_lookup(r, as_json: bool) -> None:
    import json as _json
    if as_json:
        print(_json.dumps(r.to_json()))
        return
    print(f"{r.kernel}@{r.device} "
          f"{'{' + ', '.join(f'{k}={v}' for k, v in r.problem.items()) + '}'}"
          f": {r.status} (confidence {r.confidence:.2f})")
    if r.best_config is not None:
        val = (f"{r.best_value * 1e3:.3f} ms"
               if r.best_value not in (None, float('inf')) else "n/a")
        kind = "modeled" if r.status == "modeled" else "recorded ok"
        print(f"  best: {r.best_config} ({val}, over {r.n_configs} "
              f"{kind} configs)")
    if r.status == "transfer":
        print(f"  donor: {r.source} problem={r.donor_problem} "
              f"shape-distance {r.distance:.3f}")
    elif r.status == "modeled" and r.model:
        print(f"  model: {r.model['model']} on {r.model['device_model']} "
              f"({r.model['dominant']}-bound, "
              f"{r.model['n_ok']}/{r.model['n_valid']} configs feasible)")
    elif r.source:
        print(f"  source: {r.source}")
    print(f"  resolved in {r.wall_seconds * 1e6:.0f} us")


def cmd_lookup(args) -> int:
    """One-shot service lookup against the recorded hub."""
    hub = _lookup_hub(args)
    r = hub.lookup(args.kernel, _parse_hyperparams(args.problem) or None,
                   args.device)
    if args.wait and r.status == "warming" and hub.warm_start is not None:
        flight = hub.warm_start.ensure(args.kernel, args.device, r.problem)
        flight.join(args.wait)
        r = hub.lookup(args.kernel, _parse_hyperparams(args.problem) or None,
                       args.device)
    _print_lookup(r, args.json)
    return 0 if r.found else 3


def serve_requests(hub, lines) -> "object":
    """The ``serve`` loop, factored for tests: yields one result dict per
    input line. A line is a JSON object (one request: ``kernel`` plus
    optional ``problem``/``device``) or a JSON array of them (batched
    through ``lookup_many``). Bad lines yield an ``error`` dict instead of
    killing the service."""
    import json as _json
    for line in lines:
        line = line.strip()
        if not line:
            continue
        try:
            req = _json.loads(line)
            if isinstance(req, list):
                for r in hub.lookup_many(req):
                    yield r.to_json()
            else:
                yield hub.lookup(req["kernel"], req.get("problem"),
                                 req.get("device", "tpu_v5e")).to_json()
        except (ValueError, KeyError, TypeError) as e:
            yield {"error": f"{type(e).__name__}: {e}", "request": line}


def cmd_serve(args) -> int:
    """Stdin/stdout lookup service (one JSON request per line)."""
    import json as _json
    hub = _lookup_hub(args)
    if args.warm_up:
        n = hub.warm_up()
        print(f"warmed {n} hub entries", file=sys.stderr, flush=True)
    print(f"serving lookups over {hub.root} "
          f"(entries: {hub.stats()['entries']}); one JSON request per "
          f"line, e.g. {{\"kernel\": \"gemm\", \"device\": \"tpu_v5e\"}}",
          file=sys.stderr, flush=True)
    for result in serve_requests(hub, sys.stdin):
        print(_json.dumps(result), flush=True)
    stats = hub.stats()
    print(f"served {sum(stats['lookups'].values())} lookups "
          f"({stats['lookups']}); {stats['disk_loads']} cache loads",
          file=sys.stderr)
    return 0


def _build_matrix(args):
    """A ``ScenarioMatrix`` from the shared --kernels/--devices CSVs."""
    from .scenarios import ScenarioMatrix
    return ScenarioMatrix(
        kernels=args.kernels.split(",") if args.kernels else None,
        devices=args.devices.split(",") if args.devices else None)


def cmd_scenarios(args) -> int:
    """Coverage report over the scenario matrix: every (kernel x shape x
    device) triple with its tier, optionally best times and the recorded
    best-time regression gate (docs/scenarios.md)."""
    import json as _json

    from .scenarios import gate_recorded
    from .service import ConfigHub
    matrix = _build_matrix(args)
    hub = ConfigHub(args.hub_root or _default_hub_root(),
                    verify=not args.no_verify)
    with_best = args.best or bool(args.gate) or bool(args.out)
    report = matrix.coverage(hub, with_best=with_best)
    if args.out:
        with open(args.out, "w", encoding="utf-8") as f:
            _json.dump(report.to_json(), f, indent=1)
            f.write("\n")
    if args.json:
        print(_json.dumps(report.to_json(), indent=1))
    else:
        for row in report.rows:
            best = ""
            if row.best_value is not None:
                best = f"  {row.best_value * 1e3:.3f} ms"
            print(f"  {row.scenario.key:58s} {row.tier:8s}{best}")
        counts = report.counts()
        total = sum(counts.values())
        print(f"{total} scenarios: " + ", ".join(
            f"{counts.get(t, 0)} {t}" for t in ("recorded", "modeled",
                                                "cold")))
    if args.gate:
        with open(args.gate, "r", encoding="utf-8") as f:
            baseline = _json.load(f)
        base_best = {r["key"]: r["best_value"]
                     for r in baseline.get("rows", [])
                     if r.get("tier") == "recorded"
                     and r.get("best_value") is not None}
        failures = gate_recorded(report.recorded_best(), base_best,
                                 threshold=args.threshold)
        if failures:
            for msg in failures:
                print(f"  GATE {msg}")
            print(f"{len(failures)} recorded-best regression(s) vs "
                  f"{args.gate}")
            return 1
        print(f"gate ok: {len(base_best)} recorded baselines within "
              f"{args.threshold:.0%}")
    return 0


def cmd_fleet(args) -> int:
    """Run/resume the recording fleet: record -> merge -> register every
    runnable triple of the matrix into the hub, journaled so completed
    scenarios are skipped on re-run."""
    import json as _json

    from .scenarios import run_fleet
    outcome = run_fleet(
        args.hub_root or _default_hub_root(),
        matrix=_build_matrix(args),
        runner=args.runner, strategy=args.strategy,
        max_evals=args.max_evals, repeats=args.repeats,
        workers=args.workers, backend=args.backend, seed=args.seed,
        progress=_progress(args.quiet))
    if args.json:
        print(_json.dumps(outcome.to_json(), indent=1))
    else:
        print(f"fleet: {len(outcome.recorded)} recorded, "
              f"{len(outcome.skipped)} already journaled, "
              f"{len(outcome.covered)} already in hub, "
              f"{len(outcome.unrunnable)} unrunnable with "
              f"runner={args.runner}")
        for key in outcome.recorded:
            print(f"  recorded {key}")
    return 0


def cmd_hub(args) -> int:
    """Hub dataset management (build / info / verify / stats)."""
    import json as _json

    from .api import Hub
    hub = Hub(args.root)
    if args.action == "build":
        Hub.build(args.root)
        m = hub.manifest
        print(f"hub built at {os.path.abspath(hub.root)} in "
              f"{m['build_wall_seconds']:.1f}s wall")
        return 0
    if args.action == "verify":
        failures = hub.verify(strict=False)
        if failures:
            for key, reason in sorted(failures.items()):
                print(f"  FAIL {key}: {reason}")
            print(f"{len(failures)} of {hub.stats()['entries']} entries "
                  f"failed verification")
            return 1
        print(f"ok: all {hub.stats()['entries']} entries verified "
              f"(sha256)")
        return 0
    if args.action == "info":
        print(_json.dumps(hub.manifest, indent=1))
        return 0
    print(_json.dumps(hub.stats(), indent=1))  # stats
    return 0


DEFAULT_BASELINE = "parity-lint-baseline.json"


def cmd_lint(args) -> int:
    """parity-lint: the determinism/pickle-safety static-analysis gate
    (``repro.analysis``; rule catalogue in docs/static-analysis.md)."""
    import json as _json

    from .analysis import baseline as _baseline
    from .analysis import default_rules, lint_paths
    from .analysis.report import rule_catalogue, to_json, to_text

    rules = default_rules()
    if args.list_rules:
        print(rule_catalogue(rules))
        return 0
    paths = args.paths or ["src/repro"]
    for p in paths:
        if not os.path.exists(p):
            raise SystemExit(f"error: no such path: {p}")
    baseline_path = args.baseline
    if baseline_path is None and not args.no_baseline \
            and os.path.exists(DEFAULT_BASELINE):
        baseline_path = DEFAULT_BASELINE
    if args.no_baseline or args.write_baseline:
        baseline_path = None
    result = lint_paths(paths, baseline=baseline_path, rules=rules)
    if args.write_baseline:
        out = args.baseline or DEFAULT_BASELINE
        lines: dict = {}

        def line_text(f):
            if f.path not in lines:
                for root in paths:
                    cand = os.path.join(root, f.path)
                    if os.path.exists(cand):
                        with open(cand, "r", encoding="utf-8") as fh:
                            lines[f.path] = fh.read().splitlines()
                        break
                else:
                    lines[f.path] = []
            text = lines[f.path]
            return text[f.line - 1] if 1 <= f.line <= len(text) else ""

        n = _baseline.write(out, result.findings, line_text)
        print(f"wrote {n} baseline entr{'y' if n == 1 else 'ies'} "
              f"covering {len(result.findings)} finding(s) -> {out}")
        return 0
    if args.report:
        with open(args.report, "w", encoding="utf-8") as f:
            _json.dump(to_json(result, rules), f, indent=2)
            f.write("\n")
    if args.format == "json":
        print(_json.dumps(to_json(result, rules), indent=2))
    else:
        print(to_text(result))
    return 0 if result.ok else 1


def _print_ranking(results: dict, top: int) -> None:
    ranked = sorted(results.items(), key=lambda kv: -kv[1].score)
    for hp_id, r in ranked[:top]:
        print(f"  {r.score:+.4f}  {hp_id}")
    if len(ranked) > top:
        print(f"  ... {len(ranked) - top} more "
              f"(worst {ranked[-1][1].score:+.4f})")


# ------------------------------------------------------------------ parser
def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="repro",
        description="Tuning the Tuner — simulation-mode auto-tuning and "
                    "hyperparameter campaigns (parallel + resumable)")
    sub = p.add_subparsers(dest="command", required=True)

    ps = sub.add_parser("simulate", help="score one strategy configuration "
                        "with the methodology (Sec. III-B)")
    ps.add_argument("--strategy", required=True, choices=sorted(STRATEGIES))
    ps.add_argument("--hyperparams", default=None, metavar="K=V,...",
                    help="strategy hyperparameters (default: DEFAULTS)")
    _add_space_args(ps)
    _add_exec_args(ps)
    ps.set_defaults(fn=cmd_simulate)

    ph = sub.add_parser("hypertune", help="exhaustive hyperparameter "
                        "campaign (Table III), parallel + resumable")
    ph.add_argument("--strategy", required=True, choices=sorted(STRATEGIES))
    ph.add_argument("--journal", default=None, metavar="PATH",
                    help="JSONL checkpoint; rerun with the same path to "
                         "resume an interrupted campaign")
    ph.add_argument("--top", type=int, default=5,
                    help="show the N best configurations")
    ph.add_argument("--quiet", action="store_true")
    _add_space_args(ph)
    _add_exec_args(ph)
    ph.set_defaults(fn=cmd_hypertune)

    pm = sub.add_parser("meta", help="meta-strategy hyperparameter "
                        "optimization (Eq. 4, Table IV)")
    pm.add_argument("--strategy", required=True, choices=sorted(STRATEGIES))
    pm.add_argument("--meta-strategy", required=True,
                    choices=sorted(STRATEGIES))
    pm.add_argument("--max-hp-evals", type=int, default=50)
    pm.add_argument("--table3-grid", action="store_true",
                    help="search the small Table III grid instead of the "
                         "extended Table IV space")
    pm.add_argument("--meta-hyperparams", default=None, metavar="K=V,...")
    pm.add_argument("--journal", default=None, metavar="PATH")
    pm.add_argument("--quiet", action="store_true")
    _add_space_args(pm)
    _add_exec_args(pm)
    pm.set_defaults(fn=cmd_meta)

    pr = sub.add_parser("report", help="summarize a campaign journal")
    pr.add_argument("journal", metavar="JOURNAL",
                    help="path to a campaign JSONL journal")
    pr.add_argument("--top", type=int, default=10)
    pr.set_defaults(fn=cmd_report)

    psp = sub.add_parser("spaces", help="per-space stats: sizes, valid "
                         "fraction, neighbor degrees, compile time")
    psp.add_argument("--extended", action="store_true",
                     help="show the Table IV extended hyperparameter grids "
                          "instead of Table III")
    _add_space_args(psp)
    _add_exec_args(psp)
    psp.set_defaults(fn=cmd_spaces)

    def _add_record_args(pp, bruteforce: bool) -> None:
        pp.add_argument("--kernel", required=True,
                        help="registered kernel (gemm, convolution, "
                             "dedispersion, hotspot, flash_attention, ssd)")
        pp.add_argument("--runner", choices=("live", "costmodel",
                                             "surrogate"),
                        default=("costmodel" if bruteforce else "live"),
                        help="live = Pallas interpret mode on this host; "
                             "costmodel = analytic device model; surrogate "
                             "= deterministic roofline pricing "
                             "(docs/scenarios.md)")
        pp.add_argument("--device",
                        default=("tpu_v5e" if bruteforce else "cpu_interpret"),
                        help="device model for --runner costmodel/"
                             "surrogate; a label recorded in the cache "
                             "otherwise")
        pp.add_argument("--problem", default=None, metavar="K=V,...",
                        help="problem-size overrides (e.g. m=256,n=256,"
                             "k=256); default: the kernel's smoke sizes")
        pp.add_argument("--repeats", type=int, default=3,
                        help="observations per fresh live evaluation")
        if not bruteforce:
            pp.add_argument("--strategy", default="random_search",
                            choices=sorted(STRATEGIES),
                            help="sampling strategy (default random_search)")
            pp.add_argument("--hyperparams", default=None, metavar="K=V,...")
        pp.add_argument("--max-evals", type=int,
                        default=(None if bruteforce else 64),
                        help="fresh-evaluation cap per worker"
                             + (" (default unlimited)" if bruteforce
                                else " (default 64)"))
        pp.add_argument("--seconds", type=float, default=None,
                        help="measured-seconds cap per worker")
        pp.add_argument("--out", default=None, metavar="PATH",
                        help="output cache (.json/.json.gz/.json.zst; "
                             "default recorded/<kernel>@<device>.json.gz). "
                             "Shards land next to it and survive crashes: "
                             "rerun the same command to resume.")
        pp.add_argument("--workers", type=int, default=1,
                        help="parallel recording workers (one shard each)")
        pp.add_argument("--backend", choices=("auto", "thread", "process"),
                        default="auto")
        pp.add_argument("--seed", type=int, default=0)

    prec = sub.add_parser("record", help="record a live/cost-model tuning "
                          "run of a registered kernel into a replayable "
                          "cache (strategy-sampled)")
    _add_record_args(prec, bruteforce=False)
    prec.set_defaults(fn=cmd_record)

    pbf = sub.add_parser("bruteforce", help="exhaustively record a "
                         "registered kernel's valid space (Table II)")
    _add_record_args(pbf, bruteforce=True)
    pbf.set_defaults(fn=cmd_bruteforce)

    pmc = sub.add_parser("merge-cache", help="merge recording shards into "
                         "one canonical T4 cache")
    pmc.add_argument("shards", nargs="+", metavar="SHARD",
                     help="shard JSONL files (from record/bruteforce)")
    pmc.add_argument("--out", required=True, metavar="PATH",
                     help="output cache path (.json/.json.gz/.json.zst)")
    pmc.add_argument("--hub-root", default=None, metavar="DIR",
                     help="also register the merged cache in this hub's "
                          "manifest and invalidate live lookup services")
    pmc.set_defaults(fn=cmd_merge_cache)

    def _add_lookup_args(pp, serve: bool) -> None:
        pp.add_argument("--hub-root", default=None, metavar="DIR",
                        help="hub directory (default: the bundled hub)")
        pp.add_argument("--no-verify", action="store_true",
                        help="skip sha256 verification when materializing "
                             "hub entries")
        pp.add_argument("--ttl", type=float, default=None, metavar="SECONDS",
                        help="re-stat materialized entries older than this "
                             "(default: only explicit invalidation)")
        pp.add_argument("--warm-start", action="store_true",
                        help="launch a journaled recording campaign "
                             "(single-flight) for cold keys")
        pp.add_argument("--warm-max-evals", type=int, default=32,
                        help="fresh-eval budget of a warm-start campaign")
        if not serve:
            pp.add_argument("--kernel", required=True,
                            help="kernel name (hub or registry)")
            pp.add_argument("--device", default="tpu_v5e")
            pp.add_argument("--problem", default=None, metavar="K=V,...",
                            help="problem sizes (default: the kernel's "
                                 "hub shape)")
            pp.add_argument("--json", action="store_true",
                            help="print the LookupResult as JSON")
            pp.add_argument("--wait", type=float, default=None,
                            metavar="SECONDS",
                            help="with --warm-start: block up to SECONDS "
                                 "for the campaign before answering")

    plk = sub.add_parser("lookup", help="best known config for (kernel, "
                         "problem, device) from the recorded hub")
    _add_lookup_args(plk, serve=False)
    plk.set_defaults(fn=cmd_lookup)

    psv = sub.add_parser("serve", help="lookup service: JSON requests on "
                         "stdin, LookupResult JSON lines on stdout")
    _add_lookup_args(psv, serve=True)
    psv.add_argument("--warm-up", action="store_true",
                     help="materialize every hub entry before serving")
    psv.set_defaults(fn=cmd_serve)

    psc = sub.add_parser("scenarios", help="coverage over the scenario "
                         "matrix: every (kernel x shape x device) triple, "
                         "recorded | modeled | cold")
    psc.add_argument("--kernels", default=None,
                     help="comma-separated kernels (default: all registered)")
    psc.add_argument("--devices", default=None,
                     help="comma-separated devices (default: hub devices "
                          "+ cpu_interpret)")
    psc.add_argument("--hub-root", default=None, metavar="DIR",
                     help="hub directory (default: the bundled hub)")
    psc.add_argument("--no-verify", action="store_true",
                     help="skip sha256 verification of hub entries")
    psc.add_argument("--best", action="store_true",
                     help="resolve and show the best time per triple")
    psc.add_argument("--json", action="store_true",
                     help="print the coverage report as JSON")
    psc.add_argument("--out", default=None, metavar="PATH",
                     help="also write the JSON report to PATH (the CI "
                          "artifact / gate baseline)")
    psc.add_argument("--gate", default=None, metavar="BASELINE",
                     help="fail if any recorded best time regressed vs "
                          "this earlier coverage JSON")
    psc.add_argument("--threshold", type=float, default=0.2,
                     help="allowed recorded-best slowdown for --gate "
                          "(default 0.2 = 20%%)")
    psc.set_defaults(fn=cmd_scenarios)

    pfl = sub.add_parser("fleet", help="run/resume the recording fleet "
                         "over the scenario matrix (journaled)")
    pfl.add_argument("--kernels", default=None,
                     help="comma-separated kernels (default: all registered)")
    pfl.add_argument("--devices", default=None,
                     help="comma-separated devices (default: hub devices "
                          "+ cpu_interpret)")
    pfl.add_argument("--hub-root", default=None, metavar="DIR",
                     help="hub directory to register into (default: the "
                          "bundled hub)")
    pfl.add_argument("--runner", choices=("live", "costmodel", "surrogate"),
                     default="costmodel",
                     help="recorder per triple (live runs cpu_interpret "
                          "scenarios only; default costmodel)")
    pfl.add_argument("--strategy", default="random_search",
                     choices=sorted(STRATEGIES))
    pfl.add_argument("--max-evals", type=int, default=64,
                     help="fresh-evaluation cap per scenario (default 64)")
    pfl.add_argument("--repeats", type=int, default=3,
                     help="observations per fresh evaluation (default 3)")
    pfl.add_argument("--workers", type=int, default=1)
    pfl.add_argument("--backend", choices=("auto", "thread", "process"),
                     default="auto")
    pfl.add_argument("--seed", type=int, default=0)
    pfl.add_argument("--json", action="store_true",
                     help="print the fleet outcome as JSON")
    pfl.add_argument("--quiet", action="store_true")
    pfl.set_defaults(fn=cmd_fleet)

    phub = sub.add_parser("hub", help="hub dataset management: build, "
                          "info, verify (sha256), stats")
    phub.add_argument("action", choices=("build", "info", "verify", "stats"))
    phub.add_argument("--root", default=None,
                      help="hub directory (default: the bundled hub)")
    phub.set_defaults(fn=cmd_hub)

    pl = sub.add_parser("lint", help="parity-lint: determinism & "
                        "pickle-safety static analysis (the CI gate)")
    pl.add_argument("paths", nargs="*", metavar="PATH",
                    help="files/directories to lint (default: src/repro)")
    pl.add_argument("--baseline", default=None, metavar="PATH",
                    help=f"baseline of grandfathered findings (default: "
                         f"{DEFAULT_BASELINE} when present)")
    pl.add_argument("--no-baseline", action="store_true",
                    help="ignore any baseline file: report everything")
    pl.add_argument("--write-baseline", action="store_true",
                    help="write the current findings as the new baseline "
                         "(to --baseline or the default path) and exit 0")
    pl.add_argument("--format", choices=("text", "json"), default="text",
                    help="stdout format (json is the machine-readable "
                         "report, incl. the rule catalogue)")
    pl.add_argument("--report", default=None, metavar="PATH",
                    help="also write the JSON report to PATH (the CI "
                         "artifact), regardless of --format")
    pl.add_argument("--list-rules", action="store_true",
                    help="print the rule catalogue (invariant + runtime "
                         "oracle per rule) and exit")
    pl.set_defaults(fn=cmd_lint)
    return p


def main(argv: Sequence[str] | None = None) -> int:
    args = build_parser().parse_args(argv)
    try:
        return args.fn(args)
    except ValueError as e:
        # domain errors (journal mismatch, bad cache format, unknown
        # hyperparameters) are user errors, not crashes; this includes
        # json.JSONDecodeError (a ValueError) from malformed inputs
        raise SystemExit(f"error: {e}")
    except OSError as e:
        # missing/unreadable caches, journals, baselines, shard files:
        # one-line error, not a traceback
        raise SystemExit(f"error: {e}")


if __name__ == "__main__":
    sys.exit(main())
