"""Benchmark hub: FAIR on-disk storage for recorded tuning data.

``repro.hub.storage`` is the data layer (build / load / verify /
register); ``repro.api.Hub`` is the user-facing facade; ``repro.service``
serves lookups over it. ``python -m repro hub build|info|verify`` is the
CLI entry point.
"""
from .storage import (DEFAULT_ROOT, HUB_VERSION, HubError, build_hub,
                      entry_key, hub_default_problem, load_cache, load_hub,
                      problem_key, read_manifest,
                      record_framework_smoke, register_cache, split_key,
                      train_test_caches, verify_manifest, write_manifest)

__all__ = [
    "DEFAULT_ROOT", "HUB_VERSION", "HubError", "build_hub", "entry_key",
    "hub_default_problem", "load_cache", "load_hub", "problem_key",
    "read_manifest", "record_framework_smoke", "register_cache",
    "split_key", "train_test_caches",
    "verify_manifest", "write_manifest",
]
