"""Config: see class docstring comments inline."""
from .base import ArchConfig

CONFIG = ArchConfig(
    # [audio] enc-dec, conv frontend stubbed — arXiv:2212.04356
    name="whisper-small", family="audio", n_layers=12, d_model=768,
    n_heads=12, n_kv_heads=12, d_ff=3072, vocab=51865,
    n_encoder_layers=12, n_audio_frames=1500,
    rope_theta=1e4, norm="layernorm_np", act="gelu", tie_embeddings=True)
