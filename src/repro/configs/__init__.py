"""Assigned architecture configs (10) + input-shape registry.

One module per architecture (``configs/<id>.py``, exact dims from public
literature — sources in each file); reduced smoke-test variants come from
``ArchConfig.tiny()``. The shape registry defines the four assignment shapes
and the per-cell support rules.
"""
from __future__ import annotations

import dataclasses

from .base import ArchConfig
from .gemma3_1b import CONFIG as GEMMA3_1B
from .grok_1_314b import CONFIG as GROK1_314B
from .mamba2_130m import CONFIG as MAMBA2_130M
from .olmo_1b import CONFIG as OLMO_1B
from .phi3_mini_3_8b import CONFIG as PHI3_MINI
from .qwen2_vl_2b import CONFIG as QWEN2_VL_2B
from .qwen3_moe_235b_a22b import CONFIG as QWEN3_MOE
from .starcoder2_7b import CONFIG as STARCODER2_7B
from .whisper_small import CONFIG as WHISPER_SMALL
from .zamba2_1_2b import CONFIG as ZAMBA2_1B

ARCHS: dict[str, ArchConfig] = {
    c.name: c for c in (
        MAMBA2_130M, STARCODER2_7B, PHI3_MINI, GEMMA3_1B, OLMO_1B,
        GROK1_314B, QWEN3_MOE, WHISPER_SMALL, QWEN2_VL_2B, ZAMBA2_1B,
    )
}


def get_config(name: str) -> ArchConfig:
    try:
        return ARCHS[name]
    except KeyError:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")


# --------------------------------------------------------------------------
# Input shapes (assignment: 4 per arch, 40 cells)
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def cell_supported(arch: ArchConfig, shape: ShapeConfig) -> tuple:
    """(supported, reason). long_500k needs sub-quadratic attention; whisper's
    decoder is bounded by construction (448 tokens) so 500k is out of family.
    """
    if shape.name == "long_500k":
        if arch.family == "audio":
            return False, "whisper decoder is 448-token by construction"
        if not arch.sub_quadratic:
            return False, "pure full-attention arch (skip per assignment)"
    return True, ""
