"""Config: see class docstring comments inline."""
from .base import ArchConfig

CONFIG = ArchConfig(
    # [dense] RoPE SwiGLU GQA — arXiv:2404.14219
    name="phi3-mini-3.8b", family="dense", n_layers=32, d_model=3072,
    n_heads=32, n_kv_heads=32, d_head=96, d_ff=8192, vocab=32064,
    rope_theta=1e4, norm="rmsnorm", act="swiglu", tie_embeddings=False)
