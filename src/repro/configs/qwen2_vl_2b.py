"""Config: see class docstring comments inline."""
from .base import ArchConfig

CONFIG = ArchConfig(
    # [vlm] M-RoPE, dynamic resolution (patch frontend stubbed) —
    # arXiv:2409.12191
    name="qwen2-vl-2b", family="vlm", n_layers=28, d_model=1536,
    n_heads=12, n_kv_heads=2, d_head=128, d_ff=8960, vocab=151936,
    m_rope=True, rope_theta=1e6, norm="rmsnorm", act="swiglu",
    tie_embeddings=True, n_patches=256)
