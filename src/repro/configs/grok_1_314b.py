"""Config: see class docstring comments inline."""
from .base import ArchConfig

CONFIG = ArchConfig(
    # [moe] 8 experts top-2 — hf:xai-org/grok-1
    name="grok-1-314b", family="moe", n_layers=64, d_model=6144,
    n_heads=48, n_kv_heads=8, d_head=128, d_ff=0, vocab=131072,
    n_experts=8, top_k=2, d_ff_expert=32768, rope_theta=1e4,
    norm="rmsnorm", act="geglu", tie_embeddings=True, logits_softcap=30.0)
