"""Config: see class docstring comments inline."""
from .base import ArchConfig

CONFIG = ArchConfig(
    # [dense] GQA + RoPE — arXiv:2402.19173
    name="starcoder2-7b", family="dense", n_layers=32, d_model=4608,
    n_heads=36, n_kv_heads=4, d_head=128, d_ff=18432, vocab=49152,
    rope_theta=1e5, norm="layernorm_np", act="gelu", tie_embeddings=False)
