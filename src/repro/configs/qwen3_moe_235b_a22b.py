"""qwen3-moe-235b-a22b — [moe] 128 experts top-8 (assigned dims; pool source
hf:Qwen/Qwen3-30B-A3B / Qwen3 family)."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-235b-a22b", family="moe", n_layers=94, d_model=4096,
    n_heads=64, n_kv_heads=4, d_head=128, d_ff=0, vocab=151936,
    n_experts=128, top_k=8, d_ff_expert=1536, rope_theta=1e6,
    norm="rmsnorm", act="swiglu", tie_embeddings=False)
