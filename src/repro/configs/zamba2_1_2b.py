"""Config: see class docstring comments inline."""
from .base import ArchConfig

CONFIG = ArchConfig(
    # [hybrid] Mamba2 + shared attention blocks — arXiv:2411.15242
    name="zamba2-1.2b", family="hybrid", n_layers=38, d_model=2048,
    n_heads=32, n_kv_heads=32, d_head=64, d_ff=8192, vocab=32000,
    ssm_state=64, ssm_heads=64, ssm_d_head=64, ssm_expand=2,
    shared_attn_every=6, norm="rmsnorm", act="swiglu", tie_embeddings=True)
