"""Config: see class docstring comments inline."""
from .base import ArchConfig

CONFIG = ArchConfig(
    # [dense] 5:1 local:global, 128k — hf:google/gemma-3-1b-pt
    name="gemma3-1b", family="dense", n_layers=26, d_model=1152,
    n_heads=4, n_kv_heads=1, d_head=256, d_ff=6912, vocab=262144,
    rope_theta=1e6, window=512, global_every=6, norm="rmsnorm", act="geglu",
    tie_embeddings=True, scale_embed=True)
