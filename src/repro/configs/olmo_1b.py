"""Config: see class docstring comments inline."""
from .base import ArchConfig

CONFIG = ArchConfig(
    # [dense] non-parametric LN — arXiv:2402.00838
    name="olmo-1b", family="dense", n_layers=16, d_model=2048,
    n_heads=16, n_kv_heads=16, d_ff=8192, vocab=50304,
    rope_theta=1e4, norm="layernorm_np", act="swiglu", tie_embeddings=True)
