"""Architecture configuration schema.

One frozen dataclass describes every assigned architecture; family-specific
fields are zero/None when unused. ``tiny()`` derives the reduced smoke-test
variant (same family and wiring, small dims) used by the CPU test suite —
the full configs are exercised only through the dry-run (ShapeDtypeStruct,
no allocation).
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0                # 0 -> d_model // n_heads

    # attention flavour
    rope_theta: float = 10_000.0
    window: Optional[int] = None   # sliding-window size for local layers
    global_every: int = 0          # gemma3: every k-th layer is global
    m_rope: bool = False           # qwen2-vl multimodal rotary
    logits_softcap: float = 0.0

    # norms / activations
    norm: str = "rmsnorm"          # rmsnorm | layernorm_np (olmo)
    act: str = "swiglu"            # swiglu | gelu | geglu
    tie_embeddings: bool = True
    scale_embed: bool = False      # gemma-style sqrt(d) embedding scale

    # MoE
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25

    # SSM (mamba2)
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_d_head: int = 0
    ssm_expand: int = 2
    conv_width: int = 4
    ssm_chunk: int = 128

    # hybrid (zamba2): shared attention block every k mamba layers
    shared_attn_every: int = 0

    # enc-dec (whisper)
    n_encoder_layers: int = 0
    n_audio_frames: int = 1500     # stub frontend sequence length

    # vlm (qwen2-vl)
    n_patches: int = 0             # stub patch-embedding count

    def __post_init__(self):
        if self.d_head == 0:
            object.__setattr__(self, "d_head", self.d_model // self.n_heads)
        if self.n_heads and self.n_heads % max(self.n_kv_heads, 1) != 0:
            raise ValueError(f"{self.name}: n_heads % n_kv_heads != 0")

    # --------------------------------------------------------------- sizes
    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k (SSM / hybrid / mostly-local attention)."""
        return self.family in ("ssm", "hybrid") or self.global_every > 0

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + layer stacks)."""
        d, v = self.d_model, self.vocab
        n = v * d  # embeddings (tied head)
        if not self.tie_embeddings:
            n += v * d
        for _ in range(1):
            pass
        per_attn = d * (self.n_heads * self.d_head) * 2 \
            + d * (self.n_kv_heads * self.d_head) * 2
        mlp_mult = 3 if self.act in ("swiglu", "geglu") else 2
        per_mlp = mlp_mult * d * self.d_ff if self.d_ff else 0
        if self.family == "moe":
            per_moe = self.n_experts * mlp_mult * d * self.d_ff_expert + d * self.n_experts
            n += self.n_layers * (per_attn + per_moe)
        elif self.family == "ssm":
            n += self.n_layers * self._mamba_params()
        elif self.family == "hybrid":
            n += self.n_layers * self._mamba_params()
            n += per_attn + per_mlp  # one shared block
        elif self.family == "audio":
            n += (self.n_layers + self.n_encoder_layers) * (per_attn + per_mlp)
            n += self.n_layers * per_attn  # cross-attention
        else:
            n += self.n_layers * (per_attn + per_mlp)
        return int(n)

    def _mamba_params(self) -> int:
        d = self.d_model
        d_in = self.ssm_expand * d
        nh = self.ssm_heads if self.ssm_heads else d_in // max(self.ssm_d_head, 1)
        return (d * (2 * d_in + 2 * self.ssm_state + nh)  # in_proj
                + d_in * d                                 # out_proj
                + self.conv_width * (d_in + 2 * self.ssm_state)
                + 3 * nh)                                  # A, dt_bias, D

    def active_param_count(self) -> int:
        """Active (per-token) parameters — MoE counts only top_k experts."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        mlp_mult = 3 if self.act in ("swiglu", "geglu") else 2
        per_attn = d * (self.n_heads * self.d_head) * 2 \
            + d * (self.n_kv_heads * self.d_head) * 2
        per_act = self.top_k * mlp_mult * d * self.d_ff_expert + d * self.n_experts
        return int(self.vocab * d + self.n_layers * (per_attn + per_act))

    # ---------------------------------------------------------------- tiny
    def tiny(self) -> "ArchConfig":
        """Reduced same-family config for CPU smoke tests."""
        replace = dict(
            name=self.name + "-tiny",
            n_layers=min(self.n_layers, 4 if self.family not in ("hybrid",) else 5),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 4) if self.n_kv_heads else 0,
            d_head=16,
            d_ff=128,
            vocab=512,
            window=min(self.window, 32) if self.window else None,
            n_audio_frames=24 if self.family == "audio" else self.n_audio_frames,
            n_patches=8 if self.family == "vlm" else self.n_patches,
        )
        if self.n_experts:
            replace.update(n_experts=4, top_k=min(self.top_k, 2), d_ff_expert=64)
        if self.ssm_state:
            replace.update(ssm_state=16, ssm_heads=4, ssm_d_head=32,
                           ssm_chunk=16)
        if self.n_encoder_layers:
            replace.update(n_encoder_layers=2)
        if self.shared_attn_every:
            replace.update(shared_attn_every=2)
        if self.global_every:
            replace.update(global_every=min(self.global_every, 3))
        return dataclasses.replace(self, **replace)
