"""Config: see class docstring comments inline."""
from .base import ArchConfig

CONFIG = ArchConfig(
    # [ssm] SSD — arXiv:2405.21060
    name="mamba2-130m", family="ssm", n_layers=24, d_model=768,
    n_heads=12, n_kv_heads=12, d_ff=0, vocab=50280,
    ssm_state=128, ssm_heads=24, ssm_d_head=64, ssm_expand=2, conv_width=4,
    norm="rmsnorm", act="swiglu", tie_embeddings=True)
