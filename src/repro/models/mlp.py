"""Dense MLP and Mixture-of-Experts layers.

MoE uses *per-row capacity dispatch*: routing/sort/scatter happen
independently per batch row, so under data-parallel sharding the dispatch is
shard-local and GSPMD only needs an all-to-all along the expert axis (the
standard expert-parallel schedule). Tokens beyond an expert's capacity
(capacity_factor × S·K/E) are dropped, as in Switch/GShard.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..distribution.annotate import annotate
from .layers import activation, dense_init


# ------------------------------------------------------------------- dense
def make_mlp(cfg: ArchConfig, key, d: int, ff: int) -> dict:
    ks = jax.random.split(key, 3)
    p = {"wi": dense_init(ks[0], d, ff), "wo": dense_init(ks[1], ff, d)}
    if cfg.act in ("swiglu", "geglu"):
        p["wg"] = dense_init(ks[2], d, ff)
    return p


def apply_mlp(cfg: ArchConfig, p: dict, x: jax.Array) -> jax.Array:
    dt = x.dtype
    up = annotate(x @ p["wi"].astype(dt), "dp", None, "tp")
    gate = (annotate(x @ p["wg"].astype(dt), "dp", None, "tp")
            if "wg" in p else None)
    return activation(cfg, gate, up) @ p["wo"].astype(dt)


# --------------------------------------------------------------------- moe
def make_moe(cfg: ArchConfig, key, d: int | None = None) -> dict:
    d = cfg.d_model if d is None else d
    e, ff = cfg.n_experts, cfg.d_ff_expert
    ks = jax.random.split(key, 4)
    p = {
        "router": dense_init(ks[0], d, e, scale=0.02),
        "wi": jax.random.normal(ks[1], (e, d, ff), jnp.float32) * d ** -0.5,
        "wo": jax.random.normal(ks[2], (e, ff, d), jnp.float32) * ff ** -0.5,
    }
    if cfg.act in ("swiglu", "geglu"):
        p["wg"] = jax.random.normal(ks[3], (e, d, ff), jnp.float32) * d ** -0.5
    return p


def apply_moe(cfg: ArchConfig, p: dict, x: jax.Array) -> jax.Array:
    """x: (B, S, D) -> (B, S, D). Aux-loss-free top-k routing with capacity."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    cap = int(-(-s * k * cfg.capacity_factor // e))
    dt = x.dtype

    logits = (x @ p["router"].astype(dt)).astype(jnp.float32)  # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                     # (B,S,K)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # ---- per-row dispatch (shard-local under data parallelism) ----
    # Positions-within-expert come from a stable sort over the (S·K) index
    # domain — cheap. The actual data movement is K unrolled scatter-adds
    # straight from x (B,S,D): materializing the duplicated (B, S·K, D)
    # token tensor would be K× the activation size (hundreds of GB/chip for
    # qwen3's K=8 at 32k tokens/row).
    flat_e = top_e.reshape(b, s * k)
    order = jnp.argsort(flat_e, axis=-1, stable=True)          # (B, SK)
    sorted_e = jnp.take_along_axis(flat_e, order, axis=-1)
    # position within expert group = index - first index of that expert
    first = jax.vmap(lambda se: jnp.searchsorted(se, se, side="left"))(sorted_e)
    pos_sorted = jnp.arange(s * k)[None, :] - first            # (B, SK)
    bidx = jnp.arange(b)[:, None]
    pos_flat = jnp.zeros((b, s * k), jnp.int32).at[bidx, order].set(pos_sorted)
    pos = pos_flat.reshape(b, s, k)
    keep = pos < cap                                           # (B, S, K)
    pos_c = jnp.minimum(pos, cap - 1)

    buf = annotate(jnp.zeros((b, e, cap, d), dt), "dp", None, None, None)
    bidx2 = jnp.arange(b)[:, None]
    for kk in range(k):
        contrib = annotate(jnp.where(keep[:, :, kk, None], x, 0).astype(dt),
                           "dp", None, None)
        # pin every scatter output: GSPMD otherwise replicates the running
        # buffer (and its gradient) on all chips
        buf = annotate(buf.at[bidx2, top_e[:, :, kk], pos_c[:, :, kk]]
                       .add(contrib), "dp", None, None, None)
    buf = annotate(buf, "dp", "tp", None, None)                # all-to-all

    # ---- expert computation (E sharded over the model axis) ----
    up = jnp.einsum("becd,edf->becf", buf, p["wi"].astype(dt))
    if "wg" in p:
        gate = jnp.einsum("becd,edf->becf", buf, p["wg"].astype(dt))
    else:
        gate = None
    hidden = activation(cfg, gate, up)
    out = jnp.einsum("becf,efd->becd", hidden, p["wo"].astype(dt))

    # ---- combine back (K unrolled gathers, no (B,S·K,D) materialization) --
    y = jnp.zeros((b, s, d), dt)
    for kk in range(k):
        gathered = out[bidx2, top_e[:, :, kk], pos_c[:, :, kk]]  # (B,S,D)
        w = (top_p[:, :, kk, None] * keep[:, :, kk, None]).astype(dt)
        y = y + gathered * w
    return y
