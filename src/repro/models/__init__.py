"""repro subpackage."""
