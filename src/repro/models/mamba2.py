"""Mamba2 (SSD) block: chunked training path + O(1)-state decode path.

Training uses the chunked state-space-dual algorithm (same math as
kernels/ssd.py, which is the fused TPU version): a ``lax.scan`` over
sequence chunks carrying the (B, H, N, P) state, with two MXU-shaped matmuls
per chunk. Decode carries (conv_state, ssm_state) and costs O(N·P) per token
— the reason SSM/hybrid archs run the long_500k cell.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..distribution.annotate import annotate
from .layers import dense_init, rmsnorm


def dims(cfg: ArchConfig) -> tuple:
    d_in = cfg.ssm_expand * cfg.d_model
    nh = cfg.ssm_heads
    hd = d_in // nh
    n = cfg.ssm_state
    return d_in, nh, hd, n


def make_mamba(cfg: ArchConfig, key) -> dict:
    d = cfg.d_model
    d_in, nh, hd, n = dims(cfg)
    conv_ch = d_in + 2 * n
    ks = jax.random.split(key, 6)
    return {
        # projects to [z (d_in), xBC (d_in + 2n), dt (nh)]
        "in_proj": dense_init(ks[0], d, 2 * d_in + 2 * n + nh),
        "conv_w": jax.random.normal(ks[1], (cfg.conv_width, conv_ch),
                                    jnp.float32) * 0.2,
        "conv_b": jnp.zeros((conv_ch,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh).astype(jnp.float32)),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "gate_norm": jnp.zeros((d_in,), jnp.float32),
        "out_proj": dense_init(ks[2], d_in, d),
    }


def _split(cfg: ArchConfig, proj: jax.Array) -> tuple:
    d_in, nh, hd, n = dims(cfg)
    z = proj[..., :d_in]
    xbc = proj[..., d_in:2 * d_in + 2 * n]
    dt = proj[..., 2 * d_in + 2 * n:]
    return z, xbc, dt


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv, width K: x (B,S,C), w (K,C)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i].astype(x.dtype)
              for i in range(k))
    return jax.nn.silu(out + b.astype(x.dtype))


def _ssd_chunked(x, dt, a, bmat, cmat, chunk: int, h0=None):
    """Chunked SSD scan.

    x: (B, S, H, P); dt: (B, S, H); a: (H,) negative; b/c: (B, S, N).
    Returns (y, h_final) with y like x, h (B, H, N, P) fp32.
    """
    bsz, s, nh, p = x.shape
    n = bmat.shape[-1]
    assert s % chunk == 0, "sequence must be chunk-padded"
    nc = s // chunk

    xc = x.reshape(bsz, nc, chunk, nh, p).transpose(1, 0, 2, 3, 4)
    dtc = dt.reshape(bsz, nc, chunk, nh).transpose(1, 0, 2, 3)
    bc = bmat.reshape(bsz, nc, chunk, n).transpose(1, 0, 2, 3)
    cc = cmat.reshape(bsz, nc, chunk, n).transpose(1, 0, 2, 3)

    iot = jnp.arange(chunk)
    tri = iot[:, None] >= iot[None, :]

    def body(h, inp):
        xq, dtq, bq, cq = inp           # (B,Q,H,P), (B,Q,H), (B,Q,N), (B,Q,N)
        dtf = dtq.astype(jnp.float32)
        log_decay = dtf * a             # (B,Q,H)
        cum = jnp.cumsum(log_decay, axis=1)
        # intra-chunk: ((C Bᵀ) ∘ decay-mask) X, per head. The upper triangle
        # would be exp(positive)→inf; clamp BEFORE exp (the where alone
        # still propagates inf×0=NaN through the backward pass).
        li = cum[:, :, None, :] - cum[:, None, :, :]          # (B,Q,Q,H)
        li = jnp.where(tri[None, :, :, None], li, -1e30)
        decay = jnp.exp(li)
        cb = jnp.einsum("bqn,bsn->bqs", cq.astype(jnp.float32),
                        bq.astype(jnp.float32))               # (B,Q,Q)
        w = cb[:, :, :, None] * decay * dtf[:, None, :, :]    # (B,Q,S,H)
        y_intra = jnp.einsum("bqsh,bshp->bqhp", w, xq.astype(jnp.float32))
        # inter-chunk readout from carried state
        y_inter = jnp.exp(cum)[..., None] * jnp.einsum(
            "bqn,bhnp->bqhp", cq.astype(jnp.float32), h)
        # state update
        total = cum[:, -1, :]                                  # (B,H)
        suffix = jnp.exp(total[:, None, :] - cum) * dtf        # (B,Q,H)
        bx = jnp.einsum("bqn,bqh,bqhp->bhnp", bq.astype(jnp.float32),
                        suffix, xq.astype(jnp.float32))
        h = jnp.exp(total)[:, :, None, None] * h + bx
        return h, (y_intra + y_inter).astype(x.dtype)

    if h0 is None:
        h0 = jnp.zeros((bsz, nh, n, p), jnp.float32)
    # checkpoint the chunk body: scan AD would otherwise stash the (Q,Q)
    # decay/weight matrices of every chunk (quadratic-in-S fp32 residuals);
    # with the checkpoint only the carried state per chunk is saved.
    h, yc = jax.lax.scan(jax.checkpoint(body), h0, (xc, dtc, bc, cc))
    y = yc.transpose(1, 0, 2, 3, 4).reshape(bsz, s, nh, p)
    return y, h


def apply_mamba(cfg: ArchConfig, p: dict, x: jax.Array,
                return_cache: bool = False):
    """Full-sequence (training/prefill) path. x: (B, S, D).

    With ``return_cache`` also returns (conv_state, ssm_state) for decode
    continuation: the last (conv_width-1) raw xBC inputs and the final SSD
    state."""
    d_in, nh, hd, n = dims(cfg)
    dt_ = x.dtype
    proj = annotate(x @ p["in_proj"].astype(dt_), "dp", None, "tp")
    z, xbc, dt_raw = _split(cfg, proj)
    xbc_raw = xbc
    xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    xs = xbc[..., :d_in]
    bmat = xbc[..., d_in:d_in + n]
    cmat = xbc[..., d_in + n:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"]).astype(jnp.float32)
    a = -jnp.exp(p["A_log"])
    bsz, s, _ = x.shape
    # pad sequence to chunk multiple
    chunk = cfg.ssm_chunk
    pad = (-s) % chunk
    if pad:
        xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
    xh = xs.reshape(bsz, s + pad, nh, hd)
    y, h_final = _ssd_chunked(xh, dt, a, bmat, cmat, chunk)
    y = y + p["D"].astype(y.dtype)[None, None, :, None] * xh  # skip connection
    y = y.reshape(bsz, s + pad, d_in)[:, :s]
    y = rmsnorm(y * jax.nn.silu(z), p["gate_norm"])            # gated norm
    out = y @ p["out_proj"].astype(dt_)
    if not return_cache:
        return out, None
    cw = cfg.conv_width
    conv_state = xbc_raw[:, s - (cw - 1):s].astype(jnp.bfloat16)
    return out, (conv_state, h_final)


# -------------------------------------------------------------------- decode
def init_mamba_cache(cfg: ArchConfig, batch: int) -> dict:
    d_in, nh, hd, n = dims(cfg)
    conv_ch = d_in + 2 * n
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, conv_ch), jnp.bfloat16),
        "ssm": jnp.zeros((batch, nh, n, hd), jnp.float32),
    }


def decode_mamba(cfg: ArchConfig, p: dict, cache: dict, x: jax.Array) -> tuple:
    """Single-token step. x: (B, 1, D) -> (y, new_cache)."""
    d_in, nh, hd, n = dims(cfg)
    dt_ = x.dtype
    proj = x[:, 0] @ p["in_proj"].astype(dt_)                  # (B, ...)
    z, xbc, dt_raw = _split(cfg, proj)
    # conv update: window = [cache, current]
    win = jnp.concatenate([cache["conv"],
                           xbc[:, None, :].astype(jnp.bfloat16)], axis=1)
    w = p["conv_w"].astype(dt_)
    conv_out = jax.nn.silu((win.astype(dt_) * w[None]).sum(axis=1)
                           + p["conv_b"].astype(dt_))
    xs = conv_out[..., :d_in]
    bvec = conv_out[..., d_in:d_in + n].astype(jnp.float32)
    cvec = conv_out[..., d_in + n:].astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,H)
    a = -jnp.exp(p["A_log"])
    xh = xs.reshape(-1, nh, hd).astype(jnp.float32)
    decay = jnp.exp(dt * a)                                    # (B,H)
    h = (decay[:, :, None, None] * cache["ssm"]
         + dt[:, :, None, None] * bvec[:, None, :, None] * xh[:, :, None, :])
    y = jnp.einsum("bhnp,bn->bhp", h, cvec) + p["D"][None, :, None] * xh
    y = y.reshape(-1, d_in).astype(dt_)
    y = rmsnorm(y * jax.nn.silu(z), p["gate_norm"])
    out = (y @ p["out_proj"].astype(dt_))[:, None, :]
    new_cache = {"conv": win[:, 1:], "ssm": h}
    return out, new_cache
