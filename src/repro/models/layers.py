"""Shared model layers: norms, RoPE (incl. M-RoPE), initializers.

Pure-JAX (no flax): parameters are nested dicts of arrays; apply functions
are stateless. Compute dtype is bf16, norms/softmax accumulate in fp32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig

EPS = 1e-6
COMPUTE_DTYPE = jnp.bfloat16


# ------------------------------------------------------------------- norms
def rmsnorm(x: jax.Array, scale: jax.Array) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + EPS) * (1.0 + scale.astype(jnp.float32))
    return out.astype(x.dtype)


def layernorm_np(x: jax.Array) -> jax.Array:
    """Non-parametric LayerNorm (OLMo): no scale, no bias."""
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + EPS)).astype(x.dtype)


def make_norm(cfg: ArchConfig, key, d: int) -> dict:
    if cfg.norm == "rmsnorm":
        return {"scale": jnp.zeros((d,), jnp.float32)}
    return {}  # layernorm_np has no parameters


def apply_norm(cfg: ArchConfig, p: dict, x: jax.Array) -> jax.Array:
    if cfg.norm == "rmsnorm":
        return rmsnorm(x, p["scale"])
    return layernorm_np(x)


# -------------------------------------------------------------------- rope
def rope_angles(cfg: ArchConfig, positions: jax.Array) -> jax.Array:
    """positions: (..., ) int32 -> angles (..., d_head//2) fp32.

    M-RoPE (qwen2-vl): positions (..., 3) with (t, h, w) components; the
    half-dim frequency slots are split into three sections.
    """
    half = cfg.d_head // 2
    inv_freq = cfg.rope_theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if cfg.m_rope:
        # section split (t, h, w) ≈ (¼, ⅜, ⅜) of the half-dims (qwen2-vl uses
        # [16, 24, 24] for half=64)
        s1 = half // 4
        s2 = s1 + (half - s1) // 2
        sec = jnp.concatenate([jnp.zeros((s1,), jnp.int32),
                               jnp.ones((s2 - s1,), jnp.int32),
                               jnp.full((half - s2,), 2, jnp.int32)])
        pos = jnp.take_along_axis(
            positions[..., None, :].astype(jnp.float32),
            sec[(None,) * (positions.ndim - 1)][..., None], axis=-1)[..., 0]
        return pos * inv_freq  # (..., half)
    return positions[..., None].astype(jnp.float32) * inv_freq


def apply_rope(x: jax.Array, angles: jax.Array) -> jax.Array:
    """x: (B, S, H, D); angles: (B, S, D//2). Rotate-half convention."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    cos = jnp.cos(angles)[:, :, None, :].astype(x.dtype)
    sin = jnp.sin(angles)[:, :, None, :].astype(x.dtype)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


# -------------------------------------------------------------------- init
def dense_init(key, d_in: int, d_out: int, scale: float | None = None) -> jax.Array:
    s = scale if scale is not None else d_in ** -0.5
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * s)


def embed_init(key, vocab: int, d: int) -> jax.Array:
    return jax.random.normal(key, (vocab, d), jnp.float32) * 0.02


def activation(cfg: ArchConfig, gate: jax.Array | None, up: jax.Array) -> jax.Array:
    if cfg.act == "swiglu":
        return jax.nn.silu(gate) * up
    if cfg.act == "geglu":
        return jax.nn.gelu(gate, approximate=True) * up
    return jax.nn.gelu(up, approximate=True)  # plain gelu MLP


def softcap(x: jax.Array, cap: float) -> jax.Array:
    if cap and cap > 0:
        return cap * jnp.tanh(x / cap)
    return x
