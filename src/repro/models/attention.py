"""GQA attention: flash (memory-linear, custom-VJP) training path + decode.

``blockwise_attention`` is the FlashAttention algorithm in plain JAX: a
``lax.scan`` over KV blocks with online-softmax carry, wrapped in a
``jax.custom_vjp`` whose backward recomputes per-block probabilities from
the saved (out, lse) statistics — the standard flash backward. Without the
custom VJP, scan AD would stash every block's probability matrix
(O(S²) fp32, and GSPMD replicates those residual stacks); with it, the
residuals are q/k/v/out/lse — linear in S. On TPU the Pallas kernel
(kernels/flash_attention.py) is the fused drop-in; this is the portable
oracle and the dry-run path.

Sliding-window and global layers differ only in the mask, so a stack mixing
both (gemma3 5:1) stays one homogeneous scan: ``is_global`` is a traced
per-layer flag (passed as a float 0/1 so the custom VJP can treat it as a
regular operand).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

NEG_INF = jnp.float32(-1e30)


def _mask_for(q_pos, kv_pos, *, causal: bool, window, is_global):
    """(Sq, Skv) boolean mask from absolute positions (is_global: 0/1 fp)."""
    m = jnp.ones((q_pos.shape[0], kv_pos.shape[0]), bool)
    if causal:
        m &= q_pos[:, None] >= kv_pos[None, :]
    if window is not None:
        win_ok = (q_pos[:, None] - kv_pos[None, :]) < window
        if is_global is None:
            m &= win_ok
        else:
            m &= win_ok | (is_global > 0.5)
    return m


def _split_blocks(k, block: int):
    b, skv, hkv, d = k.shape
    n_blocks = -(-skv // block)
    pad = n_blocks * block - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return (k.reshape(b, n_blocks, block, hkv, d).transpose(1, 0, 2, 3, 4),
            n_blocks, pad)


def _flash_fwd_scan(q, k, v, is_global, *, causal, window, q_offset,
                    block_kv):
    b, sq, h, d = q.shape
    _, skv, hkv, _ = k.shape
    g = h // hkv
    qg = q.reshape(b, sq, hkv, g, d)
    scale = d ** -0.5
    block = min(block_kv, skv)
    kb, n_blocks, pad = _split_blocks(k, block)
    vb, _, _ = _split_blocks(v, block)
    q_pos = q_offset + jnp.arange(sq)

    def body(carry, inputs):
        m_prev, l_prev, acc = carry
        kblk, vblk, bi = inputs
        kv_pos = bi * block + jnp.arange(block)
        s = jnp.einsum("bqkgd,bskd->bkgqs", qg, kblk,
                       preferred_element_type=jnp.float32) * scale
        mask = _mask_for(q_pos, kv_pos, causal=causal, window=window,
                         is_global=is_global)
        if pad:
            mask &= (kv_pos < skv)[None, :]
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l_prev * alpha + p.sum(axis=-1)
        pv = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(vblk.dtype), vblk,
                        preferred_element_type=jnp.float32)
        acc = acc * alpha[..., None] + pv
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, hkv, g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, sq), jnp.float32)
    acc0 = jnp.zeros((b, hkv, g, sq, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, acc0),
                                  (kb, vb, jnp.arange(n_blocks)))
    l_safe = jnp.maximum(l, 1e-30)
    out = (acc / l_safe[..., None])
    lse = m + jnp.log(l_safe)                       # (b, hkv, g, sq)
    out_q = out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, d).astype(q.dtype)
    return out_q, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7))
def _flash(q, k, v, is_global, causal, window, q_offset, block_kv):
    out, _ = _flash_fwd_scan(q, k, v, is_global, causal=causal,
                             window=window, q_offset=q_offset,
                             block_kv=block_kv)
    return out


def _flash_fwd(q, k, v, is_global, causal, window, q_offset, block_kv):
    out, lse = _flash_fwd_scan(q, k, v, is_global, causal=causal,
                               window=window, q_offset=q_offset,
                               block_kv=block_kv)
    return out, (q, k, v, is_global, out, lse)


def _flash_bwd(causal, window, q_offset, block_kv, res, dout):
    q, k, v, is_global, out, lse = res
    b, sq, h, d = q.shape
    _, skv, hkv, _ = k.shape
    g = h // hkv
    scale = d ** -0.5
    qg = q.reshape(b, sq, hkv, g, d)
    dog = dout.reshape(b, sq, hkv, g, d).transpose(0, 2, 3, 1, 4)  # b,k,g,q,d
    og = out.reshape(b, sq, hkv, g, d).transpose(0, 2, 3, 1, 4)
    # D_i = Σ_d dout·out (flash backward trick)
    delta = jnp.sum(dog.astype(jnp.float32) * og.astype(jnp.float32), -1)
    block = min(block_kv, skv)
    kb, n_blocks, pad = _split_blocks(k, block)
    vb, _, _ = _split_blocks(v, block)
    q_pos = q_offset + jnp.arange(sq)

    def body(dq_acc, inputs):
        kblk, vblk, bi = inputs
        kv_pos = bi * block + jnp.arange(block)
        s = jnp.einsum("bqkgd,bskd->bkgqs", qg, kblk,
                       preferred_element_type=jnp.float32) * scale
        mask = _mask_for(q_pos, kv_pos, causal=causal, window=window,
                         is_global=is_global)
        if pad:
            mask &= (kv_pos < skv)[None, :]
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        p = jnp.exp(s - lse[..., None])                      # (b,k,g,q,s)
        dv_blk = jnp.einsum("bkgqs,bkgqd->bskd", p,
                            dog.astype(jnp.float32))
        dp = jnp.einsum("bkgqd,bskd->bkgqs", dog, vblk,
                        preferred_element_type=jnp.float32)
        ds = p * (dp - delta[..., None]) * scale
        dq_blk = jnp.einsum("bkgqs,bskd->bqkgd", ds, kblk,
                            preferred_element_type=jnp.float32)
        dk_blk = jnp.einsum("bkgqs,bqkgd->bskd", ds, qg,
                            preferred_element_type=jnp.float32)
        return dq_acc + dq_blk, (dk_blk, dv_blk)

    dq0 = jnp.zeros((b, sq, hkv, g, d), jnp.float32)
    dq, (dks, dvs) = jax.lax.scan(body, dq0, (kb, vb, jnp.arange(n_blocks)))
    dk = dks.transpose(1, 0, 2, 3, 4).reshape(b, n_blocks * block, hkv, d)
    dv = dvs.transpose(1, 0, 2, 3, 4).reshape(b, n_blocks * block, hkv, d)
    if pad:
        dk = dk[:, :skv]
        dv = dv[:, :skv]
    dq = dq.reshape(b, sq, h, d).astype(q.dtype)
    return (dq, dk.astype(k.dtype), dv.astype(v.dtype),
            jnp.zeros_like(is_global))


_flash.defvjp(_flash_fwd, _flash_bwd)


def blockwise_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: int | None = None,
                        is_global=None, q_offset: int = 0,
                        block_kv: int = 1024) -> jax.Array:
    """q: (B, Sq, H, D); k/v: (B, Skv, Hkv, D). Returns (B, Sq, H, D)."""
    isg = (jnp.float32(-1.0) if is_global is None
           else jnp.asarray(is_global, jnp.float32))
    return _flash(q, k, v, isg, causal, window, q_offset, block_kv)


def attention_reference(q, k, v, *, causal=True, window=None, is_global=None,
                        q_offset: int = 0) -> jax.Array:
    """Materialized-S² oracle (tests only)."""
    b, sq, h, d = q.shape
    _, skv, hkv, _ = k.shape
    g = h // hkv
    kf = jnp.repeat(k, g, axis=2)
    vf = jnp.repeat(v, g, axis=2)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        kf.astype(jnp.float32)) * d ** -0.5
    q_pos = q_offset + jnp.arange(sq)
    isg = None if is_global is None else jnp.asarray(is_global, jnp.float32)
    mask = _mask_for(q_pos, jnp.arange(skv), causal=causal, window=window,
                     is_global=isg)
    logits = jnp.where(mask[None, None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p,
                      vf.astype(jnp.float32)).astype(q.dtype)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     cache_len, *, window: int | None = None,
                     is_global=None) -> jax.Array:
    """Single-step attention against a cache.

    q: (B, 1, H, D); caches: (B, S_max, Hkv, D); cache_len: (B,) or scalar —
    number of valid cache entries *including* the current token.
    """
    b, _, h, d = q.shape
    _, smax, hkv, _ = k_cache.shape
    g = h // hkv
    qg = q.reshape(b, hkv, g, d)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache,
                   preferred_element_type=jnp.float32) * (d ** -0.5)
    kv_pos = jnp.arange(smax)
    cl = jnp.broadcast_to(jnp.asarray(cache_len), (b,))
    valid = kv_pos[None, :] < cl[:, None]                    # causal+len
    if window is not None:
        win_ok = (cl[:, None] - 1 - kv_pos[None, :]) < window
        if is_global is None:
            valid &= win_ok
        else:
            valid &= win_ok | jnp.asarray(is_global > 0.5)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, 1, h, d).astype(q.dtype)
