"""Model composition for all assigned families.

Families and their layer stacks (all scanned with stacked params):

  dense / vlm   [attn + mlp] × L            (gemma3: per-layer is_global flag
                                             switches the mask, not the code)
  moe           [attn + moe] × L
  ssm           [mamba2] × L
  hybrid        ([mamba2] × k + shared attn block) × groups + tail
                (zamba2: one shared transformer block reused at every site)
  audio         whisper enc-dec: encoder [bi-attn + mlp] × Le over stub audio
                embeddings; decoder [self-attn + cross-attn + mlp] × Ld

Entry points:
  ``init_params``                      parameter pytree (fp32 masters)
  ``forward``                          teacher-forced logits (training)
  ``init_cache`` / ``prefill`` / ``decode_step``   serving; caches are
      stacked per-layer pytrees scanned together with the layer params, so
      the decode HLO stays one compact loop at any depth.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..distribution.annotate import annotate
from .attention import blockwise_attention, decode_attention
from .layers import (COMPUTE_DTYPE, apply_norm, apply_rope, dense_init,
                     embed_init, make_norm, rope_angles, softcap)
from .mamba2 import (apply_mamba, decode_mamba, dims as mamba_dims,
                     init_mamba_cache, make_mamba)
from .mlp import apply_mlp, apply_moe, make_mlp, make_moe

CACHE_DTYPE = jnp.bfloat16


# ----------------------------------------------------------------- attention
def make_attention(cfg: ArchConfig, key) -> dict:
    d, h, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], d, h * dh),
        "wk": dense_init(ks[1], d, hkv * dh),
        "wv": dense_init(ks[2], d, hkv * dh),
        "wo": dense_init(ks[3], h * dh, d, scale=(h * dh) ** -0.5),
    }


def _project_qkv(cfg: ArchConfig, p: dict, x, kv_src=None):
    b, s, _ = x.shape
    kv = x if kv_src is None else kv_src
    skv = kv.shape[1]
    dt = x.dtype
    q = (x @ p["wq"].astype(dt)).reshape(b, s, cfg.n_heads, cfg.d_head)
    k = (kv @ p["wk"].astype(dt)).reshape(b, skv, cfg.n_kv_heads, cfg.d_head)
    v = (kv @ p["wv"].astype(dt)).reshape(b, skv, cfg.n_kv_heads, cfg.d_head)
    q = annotate(q, "dp", None, "tp", None)
    k = annotate(k, "dp", None, "tp", None)
    v = annotate(v, "dp", None, "tp", None)
    return q, k, v


def apply_attention(cfg: ArchConfig, p: dict, x, positions, *, causal=True,
                    window=None, is_global=None, rope=True, kv_src=None,
                    kv_positions=None, block_kv=1024):
    """Full-sequence attention. x: (B,S,D); positions: (B,S[,3])."""
    q, k, v = _project_qkv(cfg, p, x, kv_src)
    if rope:
        ang_q = rope_angles(cfg, positions)
        ang_k = ang_q if kv_src is None else rope_angles(cfg, kv_positions)
        q = apply_rope(q, ang_q)
        k = apply_rope(k, ang_k)
    out = blockwise_attention(q, k, v, causal=causal, window=window,
                              is_global=is_global, block_kv=block_kv)
    b, s, _, _ = q.shape
    return (out.reshape(b, s, -1) @ p["wo"].astype(x.dtype)), (k, v)


def apply_attention_decode(cfg: ArchConfig, p: dict, x, cache_k, cache_v,
                           cache_len, *, window=None, is_global=None,
                           rope=True, cross=False):
    """Single-step attention. x: (B,1,D); caches (B,Smax,Hkv,Dh).

    Self-attention writes the current token's K/V at index cache_len;
    cross-attention reads the (static) encoder projection cache.
    """
    q, k, v = _project_qkv(cfg, p, x)
    b = x.shape[0]
    if rope:
        pos = jnp.broadcast_to(jnp.asarray(cache_len), (b,))[:, None]
        if cfg.m_rope:
            pos = jnp.repeat(pos[..., None], 3, axis=-1)
        ang = rope_angles(cfg, pos)
        q = apply_rope(q, ang)
        if not cross:
            k = apply_rope(k, ang)
    if cross:
        new_k, new_v = cache_k, cache_v
        total_len = cache_k.shape[1]  # full encoder output is valid
    else:
        idx = jnp.broadcast_to(jnp.asarray(cache_len), (b,))
        new_k = jax.vmap(lambda c, n, i: jax.lax.dynamic_update_slice(
            c, n.astype(c.dtype), (i, 0, 0)))(cache_k, k, idx)
        new_v = jax.vmap(lambda c, n, i: jax.lax.dynamic_update_slice(
            c, n.astype(c.dtype), (i, 0, 0)))(cache_v, v, idx)
        total_len = idx + 1
    out = decode_attention(q, new_k.astype(q.dtype), new_v.astype(q.dtype),
                           total_len, window=window, is_global=is_global)
    return (out.reshape(b, 1, -1) @ p["wo"].astype(x.dtype)), new_k, new_v


# -------------------------------------------------------------- layer bodies
def make_block(cfg: ArchConfig, key, kind: str) -> dict:
    """kind: dense | moe | mamba | encdec (decoder w/ cross-attn) | bidi."""
    ks = jax.random.split(key, 6)
    if kind == "mamba":
        return {"norm": make_norm(cfg, ks[0], cfg.d_model),
                "mamba": make_mamba(cfg, ks[1])}
    p = {"norm1": make_norm(cfg, ks[0], cfg.d_model),
         "attn": make_attention(cfg, ks[1]),
         "norm2": make_norm(cfg, ks[2], cfg.d_model)}
    if kind == "moe":
        p["moe"] = make_moe(cfg, ks[3])
    else:
        p["mlp"] = make_mlp(cfg, ks[3], cfg.d_model, cfg.d_ff)
    if kind == "encdec":
        p["norm_x"] = make_norm(cfg, ks[4], cfg.d_model)
        p["xattn"] = make_attention(cfg, ks[5])
    return p


def _apply_ffn(cfg: ArchConfig, p: dict, x):
    z = apply_norm(cfg, p["norm2"], x)
    if "moe" in p:
        return x + apply_moe(cfg, p["moe"], z)
    return x + apply_mlp(cfg, p["mlp"], z)


def apply_block(cfg: ArchConfig, p: dict, x, positions, *, is_global=None,
                causal=True, enc_out=None, enc_positions=None,
                collect=False):
    """One block, full-sequence. Returns (x, kv_or_None)."""
    x = annotate(x, "dp", "sp", None)
    if "mamba" in p:
        h, mcache = apply_mamba(cfg, p["mamba"],
                                apply_norm(cfg, p["norm"], x),
                                return_cache=collect)
        return x + h, mcache
    h, (k, v) = apply_attention(cfg, p["attn"],
                                apply_norm(cfg, p["norm1"], x), positions,
                                causal=causal, window=cfg.window,
                                is_global=is_global)
    x = x + h
    if "xattn" in p:
        h, _ = apply_attention(cfg, p["xattn"],
                               apply_norm(cfg, p["norm_x"], x), positions,
                               causal=False, rope=False, kv_src=enc_out,
                               kv_positions=enc_positions)
        x = x + h
    x = _apply_ffn(cfg, p, x)
    return x, ((k.astype(CACHE_DTYPE), v.astype(CACHE_DTYPE))
               if collect else None)


# -------------------------------------------------------------------- init
def _stack(cfg: ArchConfig, key, n: int, kind: str) -> dict:
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: make_block(cfg, k, kind))(keys)


def init_params(cfg: ArchConfig, key) -> dict:
    ks = jax.random.split(key, 8)
    params: dict = {"embed": embed_init(ks[0], cfg.vocab, cfg.d_model),
                    "final_norm": make_norm(cfg, ks[1], cfg.d_model)}
    if not cfg.tie_embeddings:
        params["unembed"] = dense_init(ks[2], cfg.d_model, cfg.vocab)
    fam = cfg.family
    if fam in ("dense", "vlm"):
        params["layers"] = _stack(cfg, ks[3], cfg.n_layers, "dense")
    elif fam == "moe":
        params["layers"] = _stack(cfg, ks[3], cfg.n_layers, "moe")
    elif fam == "ssm":
        params["layers"] = _stack(cfg, ks[3], cfg.n_layers, "mamba")
    elif fam == "hybrid":
        k_g, k_t, k_s = jax.random.split(ks[3], 3)
        every = cfg.shared_attn_every
        n_groups = cfg.n_layers // every
        tail = cfg.n_layers - n_groups * every
        gkeys = jax.random.split(k_g, n_groups)
        params["mamba_groups"] = jax.vmap(
            lambda k: _stack(cfg, k, every, "mamba"))(gkeys)
        if tail:
            params["mamba_tail"] = _stack(cfg, k_t, tail, "mamba")
        params["shared"] = make_block(cfg, k_s, "dense")
    elif fam == "audio":
        params["encoder"] = _stack(cfg, ks[3], cfg.n_encoder_layers, "bidi")
        params["enc_norm"] = make_norm(cfg, ks[5], cfg.d_model)
        params["layers"] = _stack(cfg, ks[4], cfg.n_layers, "encdec")
    else:
        raise ValueError(f"unknown family {fam}")
    return params


# ------------------------------------------------------------------ helpers
def _embed(cfg: ArchConfig, params: dict, tokens) -> jax.Array:
    x = params["embed"].astype(COMPUTE_DTYPE)[tokens]
    if cfg.scale_embed:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    # the vocab-sharded gather can emit a replicated activation; pin it
    return annotate(x, "dp", None, None)


def _unembed(cfg: ArchConfig, params: dict, x) -> jax.Array:
    w = (params["embed"].T if cfg.tie_embeddings
         else params["unembed"]).astype(x.dtype)
    logits = x @ w
    return softcap(logits.astype(jnp.float32), cfg.logits_softcap)


def _is_global_flags(cfg: ArchConfig):
    if cfg.global_every:
        idx = jnp.arange(cfg.n_layers)
        return (idx + 1) % cfg.global_every == 0
    return None


def _positions(cfg: ArchConfig, batch: dict, tokens) -> jax.Array:
    if cfg.m_rope and "positions" in batch:
        return batch["positions"]
    b, s = tokens.shape
    pos = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    if cfg.m_rope:
        pos = jnp.repeat(pos[..., None], 3, axis=-1)
    return pos


def _encoder_forward(cfg: ArchConfig, params: dict, audio_embeds) -> jax.Array:
    x = audio_embeds.astype(COMPUTE_DTYPE)
    b, t, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(t)[None, :], (b, t))

    def body(xc, layer_p):
        xc, _ = apply_block(cfg, layer_p, xc, pos, causal=False)
        return xc, None

    x, _ = jax.lax.scan(body, x, params["encoder"])
    return apply_norm(cfg, params["enc_norm"], x)


def _maybe_remat(f, remat: str):
    if remat == "full":
        return jax.checkpoint(f)
    if remat == "dots":
        return jax.checkpoint(
            f, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    return f


# ------------------------------------------------------------------ forward
def forward(cfg: ArchConfig, params: dict, batch: dict, *,
            remat: str = "none", collect: bool = False,
            pre_logits: bool = False):
    """Teacher-forced logits (B, S, V); with ``collect=True`` also returns
    the serving caches built from this pass (used by prefill).
    ``pre_logits``: return the final-norm hidden states instead of logits
    (the training loss computes chunked CE itself)."""
    tokens = batch["tokens"]
    x = _embed(cfg, params, tokens)
    if cfg.family == "vlm" and "patch_embeds" in batch:
        npatch = batch["patch_embeds"].shape[1]
        x = jnp.concatenate(
            [batch["patch_embeds"].astype(x.dtype), x[:, npatch:]], axis=1)
    positions = _positions(cfg, batch, tokens)
    enc_out = None
    enc_pos = None
    if cfg.family == "audio":
        enc_out = _encoder_forward(cfg, params, batch["audio_embeds"])
        b_, t_ = enc_out.shape[:2]
        enc_pos = jnp.broadcast_to(jnp.arange(t_)[None, :], (b_, t_))

    caches: dict = {}
    fam = cfg.family
    if fam == "hybrid":
        def group_body(xc, group_p):
            def mamba_body(xi, lp):
                xi, mc = apply_block(cfg, lp, xi, positions, collect=collect)
                return xi, mc
            xc, mcs = jax.lax.scan(_maybe_remat(mamba_body, remat), xc, group_p)
            xc, kv = apply_block(cfg, params["shared"], xc, positions,
                                 collect=collect)
            return xc, (mcs, kv)

        x, (gmc, gkv) = jax.lax.scan(group_body, x, params["mamba_groups"])
        if "mamba_tail" in params:
            def tail_body(xc, lp):
                xc, mc = apply_block(cfg, lp, xc, positions, collect=collect)
                return xc, mc
            x, tmc = jax.lax.scan(_maybe_remat(tail_body, remat), x,
                                  params["mamba_tail"])
        else:
            tmc = None
        if collect:
            caches = {"groups": gmc, "shared_kv": gkv, "tail": tmc}
    else:
        flags = _is_global_flags(cfg)

        def body(xc, inp):
            if flags is not None:
                layer_p, is_g = inp
            else:
                layer_p, is_g = inp, None
            xc, kv = apply_block(cfg, layer_p, xc, positions, is_global=is_g,
                                 enc_out=enc_out, enc_positions=enc_pos,
                                 collect=collect)
            return xc, kv

        xs = (params["layers"], flags) if flags is not None else params["layers"]
        x, kvs = jax.lax.scan(_maybe_remat(body, remat), x, xs)
        if collect:
            caches = {"kv": kvs}
            if fam == "audio":
                caches["enc_out"] = enc_out

    x = apply_norm(cfg, params["final_norm"], x)
    if pre_logits:
        return (x, caches) if collect else x
    logits = _unembed(cfg, params, x)
    return (logits, caches) if collect else logits


# ====================================================================== serve
def init_cache(cfg: ArchConfig, batch: int, max_len: int) -> dict:
    """Empty serving cache (stacked per-layer pytrees)."""
    hkv, dh, L = cfg.n_kv_heads, cfg.d_head, cfg.n_layers
    kv = lambda n: {"k": jnp.zeros((n, batch, max_len, hkv, dh), CACHE_DTYPE),
                    "v": jnp.zeros((n, batch, max_len, hkv, dh), CACHE_DTYPE)}
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        return kv(L)
    if fam == "ssm":
        mc = init_mamba_cache(cfg, batch)
        return {"conv": jnp.stack([mc["conv"]] * L),
                "ssm": jnp.stack([mc["ssm"]] * L)}
    if fam == "hybrid":
        every = cfg.shared_attn_every
        g = L // every
        tail = L - g * every
        mc = init_mamba_cache(cfg, batch)
        out = {
            "groups": {"conv": jnp.broadcast_to(
                           mc["conv"], (g, every) + mc["conv"].shape).copy(),
                       "ssm": jnp.broadcast_to(
                           mc["ssm"], (g, every) + mc["ssm"].shape).copy()},
            "shared": kv(g),
        }
        if tail:
            out["tail"] = {"conv": jnp.stack([mc["conv"]] * tail),
                           "ssm": jnp.stack([mc["ssm"]] * tail)}
        return out
    if fam == "audio":
        out = kv(L)
        out["xk"] = jnp.zeros((L, batch, cfg.n_audio_frames, hkv, dh),
                              CACHE_DTYPE)
        out["xv"] = jnp.zeros_like(out["xk"])
        return out
    raise ValueError(fam)


def _pad_cache_seq(arr, max_len: int, axis: int):
    pad = [(0, 0)] * arr.ndim
    pad[axis] = (0, max_len - arr.shape[axis])
    return jnp.pad(arr, pad)


def prefill(cfg: ArchConfig, params: dict, batch: dict, max_len: int):
    """Run the full-sequence path, return (last_logits, cache, cache_len)."""
    logits, c = forward(cfg, params, batch, collect=True)
    s = batch["tokens"].shape[1]
    fam = cfg.family
    if fam in ("dense", "moe", "vlm", "audio"):
        k, v = c["kv"]
        cache = {"k": _pad_cache_seq(k, max_len, 2),
                 "v": _pad_cache_seq(v, max_len, 2)}
        if fam == "audio":
            # static cross-attention caches: project encoder output per layer
            enc = c["enc_out"]
            def proj(layer_p):
                _, xk, xv = _project_qkv(cfg, layer_p["xattn"], enc)
                return xk.astype(CACHE_DTYPE), xv.astype(CACHE_DTYPE)
            xk, xv = jax.vmap(proj)(params["layers"])
            cache["xk"], cache["xv"] = xk, xv
    elif fam == "ssm":
        # forward() returned the stacked (conv_state, ssm_state) per layer
        cache = {"conv": c["kv"][0], "ssm": c["kv"][1]}
    elif fam == "hybrid":
        gconv, gssm = c["groups"]
        sk, sv = c["shared_kv"]
        cache = {"groups": {"conv": gconv, "ssm": gssm},
                 "shared": {"k": _pad_cache_seq(sk, max_len, 2),
                            "v": _pad_cache_seq(sv, max_len, 2)}}
        if c["tail"] is not None:
            cache["tail"] = {"conv": c["tail"][0], "ssm": c["tail"][1]}
    else:
        raise ValueError(fam)
    return logits[:, -1], cache, jnp.int32(s)


def decode_step(cfg: ArchConfig, params: dict, cache: dict, tokens,
                cache_len):
    """One token for the whole batch. tokens: (B, 1) int32.

    Returns (logits (B, V), new_cache). ``cache_len`` is the number of valid
    positions already in the cache (scalar int32).
    """
    x = _embed(cfg, params, tokens)
    fam = cfg.family
    flags = _is_global_flags(cfg)

    if fam in ("dense", "moe", "vlm", "audio"):
        def body(xc, inp):
            if fam == "audio":
                layer_p, kc, vc, xk, xv = inp
                is_g = None
            elif flags is not None:
                layer_p, kc, vc, is_g = inp
            else:
                (layer_p, kc, vc), is_g = inp, None
            h, nk, nv = apply_attention_decode(
                cfg, layer_p["attn"], apply_norm(cfg, layer_p["norm1"], xc),
                kc, vc, cache_len, window=cfg.window, is_global=is_g)
            xc = xc + h
            if fam == "audio":
                h, _, _ = apply_attention_decode(
                    cfg, layer_p["xattn"],
                    apply_norm(cfg, layer_p["norm_x"], xc), xk, xv,
                    cache_len, cross=True, rope=False)
                xc = xc + h
            xc = _apply_ffn(cfg, layer_p, xc)
            return xc, (nk, nv)

        if fam == "audio":
            xs = (params["layers"], cache["k"], cache["v"],
                  cache["xk"], cache["xv"])
        elif flags is not None:
            xs = (params["layers"], cache["k"], cache["v"], flags)
        else:
            xs = (params["layers"], cache["k"], cache["v"])
        x, (nks, nvs) = jax.lax.scan(body, x, xs)
        new_cache = dict(cache)
        new_cache["k"], new_cache["v"] = nks, nvs

    elif fam == "ssm":
        def body(xc, inp):
            layer_p, conv, ssm = inp
            h, mc = decode_mamba(cfg, layer_p["mamba"], {"conv": conv, "ssm": ssm},
                                 apply_norm(cfg, layer_p["norm"], xc))
            return xc + h, (mc["conv"], mc["ssm"])
        x, (nconv, nssm) = jax.lax.scan(
            body, x, (params["layers"], cache["conv"], cache["ssm"]))
        new_cache = {"conv": nconv, "ssm": nssm}

    elif fam == "hybrid":
        def mamba_body(xc, inp):
            layer_p, conv, ssm = inp
            h, mc = decode_mamba(cfg, layer_p["mamba"], {"conv": conv, "ssm": ssm},
                                 apply_norm(cfg, layer_p["norm"], xc))
            return xc + h, (mc["conv"], mc["ssm"])

        def group_body(xc, inp):
            group_p, conv, ssm, kc, vc = inp
            xc, (nconv, nssm) = jax.lax.scan(mamba_body, xc,
                                             (group_p, conv, ssm))
            sp = params["shared"]
            h, nk, nv = apply_attention_decode(
                cfg, sp["attn"], apply_norm(cfg, sp["norm1"], xc), kc, vc,
                cache_len)
            xc = _apply_ffn(cfg, sp, xc + h)
            return xc, (nconv, nssm, nk, nv)

        x, (gconv, gssm, nks, nvs) = jax.lax.scan(
            group_body, x,
            (params["mamba_groups"], cache["groups"]["conv"],
             cache["groups"]["ssm"], cache["shared"]["k"],
             cache["shared"]["v"]))
        new_cache = {"groups": {"conv": gconv, "ssm": gssm},
                     "shared": {"k": nks, "v": nvs}}
        if "tail" in cache:
            x, (tconv, tssm) = jax.lax.scan(
                mamba_body, x,
                (params["mamba_tail"], cache["tail"]["conv"],
                 cache["tail"]["ssm"]))
            new_cache["tail"] = {"conv": tconv, "ssm": tssm}
    else:
        raise ValueError(fam)

    x = apply_norm(cfg, params["final_norm"], x)
    return _unembed(cfg, params, x)[:, 0], new_cache
