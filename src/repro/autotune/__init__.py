"""repro subpackage."""
