"""The paper's technique pointed at the framework itself (§Perf driver).

The distribution configuration of a dry-run cell — layout policy, remat
policy, microbatch count — is a constrained discrete search space exactly
like a kernel's tiling space. One "measurement" lowers+compiles the cell and
returns the roofline step-time bound:

    objective = max(compute_s, memory_s, collective_s)
    infeasible (status error) when peak HBM per chip exceeds the budget

The hillclimb is executed by a registered strategy (with hyperparameters
tuned by the hypertuner) through a LiveRunner-style wrapper; every
evaluation is logged hypothesis-loop style to experiments/perf/.

Usage:
  PYTHONPATH=src python -m repro.autotune.perf --arch olmo-1b \
      --shape train_4k --evals 12 [--strategy greedy_ils]
"""
import argparse
import json
import math
import os
import random
import time

from ..configs import ARCHS, SHAPES
from ..core.budget import Budget
from ..core.runner import Runner
from ..core.searchspace import SearchSpace
from ..core.strategies import get_strategy
from ..core.tunable import Constraint, tunables_from_dict

HBM_BUDGET = 16 * 2**30  # v5e per chip


def dist_space(shape_kind: str) -> SearchSpace:
    if shape_kind == "train":
        tunables = tunables_from_dict({
            "layout": ("2d", "dp", "2d_seq"),
            "remat": ("none", "dots", "full"),
            "microbatches": (1, 2, 4, 8),
        })
    else:  # prefill/decode: no remat/microbatching
        tunables = tunables_from_dict({
            "layout": ("2d", "dp", "2d_seq"),
            "remat": ("none",),
            "microbatches": (1,),
        })
    return SearchSpace(tunables, (), name=f"dist[{shape_kind}]")


class CellRunner(Runner):
    """Live runner: one evaluation = lower + compile + roofline analysis."""

    def __init__(self, arch: str, shape: str, mesh_kind: str,
                 budget: Budget, log_path: str | None = None):
        self.arch, self.shape, self.mesh_kind = arch, shape, mesh_kind
        self.records: list = []
        self.log_path = log_path
        super().__init__(dist_space(SHAPES[shape].kind), budget)

    def _evaluate(self, config) -> tuple:
        from ..launch.dryrun import run_cell
        d = self.space.as_dict(config)
        t0 = time.perf_counter()
        rec = run_cell(self.arch, self.shape, self.mesh_kind,
                       microbatches=d["microbatches"], remat=d["remat"],
                       layout=d["layout"])
        wall = time.perf_counter() - t0
        if rec["status"] != "ok":
            self.records.append({**d, "status": rec.get("status"),
                                 "error": rec.get("error", "")[:200]})
            self._flush()
            return math.inf, "error", wall
        rl = rec["roofline"]
        peak = rec["memory"]["peak_bytes_per_chip"]
        value = max(rl["compute_s"], rl["memory_s"], rl["collective_s"])
        status = "ok"
        if peak > HBM_BUDGET:
            value, status = math.inf, "error"  # OOM on a 16 GiB chip
        self.records.append({
            **d, "status": "ok" if status == "ok" else "oom",
            "objective_s": None if value == math.inf else value,
            "compute_s": rl["compute_s"], "memory_s": rl["memory_s"],
            "collective_s": rl["collective_s"], "dominant": rl["dominant"],
            "peak_gib": round(peak / 2**30, 2), "compile_s": rec["compile_s"],
        })
        self._flush()
        return value, status, wall

    def _flush(self):
        if self.log_path:
            with open(self.log_path, "w") as f:
                json.dump(self.records, f, indent=1)


def hillclimb(arch: str, shape: str, mesh_kind: str = "single",
              strategy: str = "greedy_ils", max_evals: int = 12,
              seed: int = 0, out_dir: str = "experiments/perf",
              hyperparams: dict | None = None) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    log_path = os.path.join(out_dir, f"{arch}__{shape}__{mesh_kind}.json")
    runner = CellRunner(arch, shape, mesh_kind,
                        Budget(max_evals=max_evals), log_path)
    # baseline first (the paper-faithful starting point)
    baseline_cfg = runner.space.from_dict(
        {"layout": "2d", "remat": "full" if SHAPES[shape].kind == "train"
         else "none", "microbatches": 1})
    base = runner.run(baseline_cfg)
    strat = get_strategy(strategy, **(hyperparams or {}))
    best = strat.run(runner.space, runner, random.Random(seed))
    result = {
        "arch": arch, "shape": shape, "mesh": mesh_kind,
        "baseline": {"config": runner.space.as_dict(baseline_cfg),
                     "objective_s": base.value},
        "best": {"config": runner.space.as_dict(best.config),
                 "objective_s": best.value},
        "improvement": (base.value / best.value
                        if best and math.isfinite(best.value) else None),
        "evaluations": runner.records,
    }
    with open(os.path.join(out_dir,
                           f"{arch}__{shape}__{mesh_kind}_summary.json"),
              "w") as f:
        json.dump(result, f, indent=1)
    return result


def main() -> None:
    # the dry-run cells lower against 512 host devices; set the flag only
    # on the CLI path, before jax's first backend init — library importers
    # must keep their 1-device view (see launch.dryrun)
    from ..launch.dryrun import force_host_devices
    force_host_devices()
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--strategy", default="greedy_ils")
    ap.add_argument("--evals", type=int, default=12)
    args = ap.parse_args()
    res = hillclimb(args.arch, args.shape, args.mesh,
                    strategy=args.strategy, max_evals=args.evals)
    print(json.dumps({k: v for k, v in res.items() if k != "evaluations"},
                     indent=1))


if __name__ == "__main__":
    main()
