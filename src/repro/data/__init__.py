"""repro subpackage."""
