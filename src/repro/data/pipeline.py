"""Deterministic synthetic data pipeline.

Stateless and resumable: the batch for (step, shard) is a pure function of
(seed, step, shard) via counter-based Philox bits — restart from a
checkpointed step reproduces the exact token stream with no iterator state
to save, and elastic re-sharding (different dp_shards) keeps global batches
identical because sharding happens by slicing the *global* batch.

Tokens follow a Zipf-ish marginal with short-range structure so the LM loss
actually decreases (pure uniform noise has no learnable signal beyond
unigram frequency).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..configs.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0


class TokenPipeline:
    def __init__(self, dc: DataConfig, arch: ArchConfig | None = None,
                 dp_shards: int = 1, shard_id: int = 0):
        assert dc.global_batch % dp_shards == 0
        self.dc = dc
        self.arch = arch
        self.dp_shards = dp_shards
        self.shard_id = shard_id
        self.local_batch = dc.global_batch // dp_shards
        # Zipf-ish unigram table (fixed per vocab/seed)
        rng = np.random.Generator(np.random.Philox(key=dc.seed))
        ranks = np.arange(1, dc.vocab + 1)
        probs = 1.0 / ranks ** 1.1
        self._probs = probs / probs.sum()

    def _bits(self, step: int, n: int) -> np.random.Generator:
        return np.random.Generator(
            np.random.Philox(key=[self.dc.seed, step]))

    def global_batch_at(self, step: int) -> dict:
        """Full global batch for a step (B, S+1) — sharding slices this."""
        dc = self.dc
        g = self._bits(step, dc.global_batch * (dc.seq_len + 1))
        u = g.random((dc.global_batch, dc.seq_len + 1))
        base = np.searchsorted(np.cumsum(self._probs), u).astype(np.int32)
        base = np.minimum(base, dc.vocab - 1)
        # short-range structure: every 4th token repeats an earlier one
        repeat = np.roll(base, 3, axis=1)
        mask = (np.arange(dc.seq_len + 1)[None, :] % 4 == 0)
        tokens = np.where(mask, repeat, base).astype(np.int32)
        out = {"tokens": tokens}
        if self.arch is not None and self.arch.family == "vlm":
            pos = np.broadcast_to(
                np.arange(dc.seq_len + 1, dtype=np.int32)[None, :, None],
                (dc.global_batch, dc.seq_len + 1, 3))
            out["positions"] = np.ascontiguousarray(pos)
            out["patch_embeds"] = g.standard_normal(
                (dc.global_batch, self.arch.n_patches, self.arch.d_model),
                dtype=np.float32) * 0.02
        if self.arch is not None and self.arch.family == "audio":
            out["audio_embeds"] = g.standard_normal(
                (dc.global_batch, self.arch.n_audio_frames,
                 self.arch.d_model), dtype=np.float32) * 0.1
        return out

    def batch_at(self, step: int) -> dict:
        """This shard's slice of the global batch (local_batch, S+1)."""
        full = self.global_batch_at(step)
        lo = self.shard_id * self.local_batch
        hi = lo + self.local_batch
        return {k: v[lo:hi] for k, v in full.items()}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
