"""Batched serving engine: prefill + greedy/temperature decode loop.

``ServingEngine`` jits one prefill and one decode step per (batch, length)
bucket and drives batched requests through them. The decode step is the
function the dry-run lowers for the ``decode_*``/``long_*`` cells.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..models.transformer import decode_step, init_cache, prefill


@dataclasses.dataclass
class Request:
    prompt: list            # token ids
    max_new_tokens: int = 16
    temperature: float = 0.0


def make_decode_fn(cfg: ArchConfig):
    """The jit-able single-token step (also lowered by the dry-run)."""
    def step(params, cache, tokens, cache_len):
        return decode_step(cfg, params, cache, tokens, cache_len)
    return step


class ServingEngine:
    def __init__(self, cfg: ArchConfig, params, max_len: int = 512):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self._decode = jax.jit(make_decode_fn(cfg))
        self._prefill = jax.jit(
            functools.partial(prefill, cfg), static_argnames=("max_len",))

    def generate(self, requests: list, key=None) -> list:
        """Greedy (or sampled) continuation for a batch of requests."""
        cfg = self.cfg
        b = len(requests)
        plen = max(len(r.prompt) for r in requests)
        toks = jnp.full((b, plen), 0, jnp.int32)
        for i, r in enumerate(requests):  # left-pad-free: right-align prompts
            toks = toks.at[i, :len(r.prompt)].set(jnp.asarray(r.prompt))
        batch = {"tokens": toks}
        if cfg.family == "audio":
            batch["audio_embeds"] = jnp.zeros(
                (b, cfg.n_audio_frames, cfg.d_model), jnp.float32)
        if cfg.family == "vlm":
            batch["patch_embeds"] = jnp.zeros(
                (b, cfg.n_patches, cfg.d_model), jnp.float32)
            batch["positions"] = jnp.broadcast_to(
                jnp.arange(plen, dtype=jnp.int32)[None, :, None], (b, plen, 3))

        last_logits, cache, cache_len = self._prefill(
            self.params, batch, max_len=self.max_len)
        max_new = max(r.max_new_tokens for r in requests)
        key = key if key is not None else jax.random.PRNGKey(0)
        outs = [[] for _ in range(b)]
        logits = last_logits
        for t in range(max_new):
            if requests[0].temperature > 0:
                key, sub = jax.random.split(key)
                nxt = jax.random.categorical(
                    sub, logits / requests[0].temperature, axis=-1)
            else:
                nxt = jnp.argmax(logits, axis=-1)
            for i in range(b):
                outs[i].append(int(nxt[i]))
            logits, cache = self._decode(self.params, cache,
                                         nxt[:, None].astype(jnp.int32),
                                         cache_len)
            cache_len = cache_len + 1
        return outs
