"""LLM token inference (batched prefill + decode serving engine).

Formerly ``repro.serving`` — renamed so that "serving" unambiguously means
the ConfigHub tuning service (``repro.service``); ``repro.serving`` remains
as a deprecation shim.
"""
