"""Scenario subsystem: every (kernel, shape, device) triple answerable.

Three pieces (docs/scenarios.md):

* ``matrix`` — ``ScenarioMatrix``, the registry of (kernel × problem
  shape × device) triples with per-triple provenance
  (``recorded | modeled | cold``) and the recorded best-time gate;
* ``surrogate`` — the deterministic roofline pricing model,
  ``SurrogateRunner`` (a strategy-compatible ``BatchRunner``), and
  ``best_modeled`` (the argmin the hub's ``modeled`` lookup tier serves);
* ``fleet`` — the journaled recording campaign that walks the matrix and
  registers results into the hub.
"""
from .fleet import FleetOutcome, run_fleet, runnable
from .matrix import (CoverageReport, CoverageRow, Scenario, ScenarioMatrix,
                     gate_recorded, kernel_shapes)
from .surrogate import (MODEL_NAME, MODELED_CONFIDENCE, ModeledBest,
                        SurrogatePrice, SurrogateRunner, best_modeled,
                        facts_from_compiled, price, price_from_facts)

__all__ = [
    "CoverageReport", "CoverageRow", "FleetOutcome", "MODELED_CONFIDENCE",
    "MODEL_NAME", "ModeledBest", "Scenario", "ScenarioMatrix",
    "SurrogatePrice", "SurrogateRunner", "best_modeled",
    "facts_from_compiled", "gate_recorded", "kernel_shapes", "price",
    "price_from_facts", "run_fleet", "runnable",
]
