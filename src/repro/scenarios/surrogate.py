"""Roofline surrogate: price any registered Pallas kernel config analytically.

The hub answers from *measurements* where they exist; this module answers
where they don't. ``price`` derives the classic roofline terms from the
kernel's declared workload — FLOPs, HBM bytes, VMEM footprint, grid size,
and an occupancy/efficiency factor, all functions of the config's tunables
(``repro.kernels.<kernel>.workload``) — and combines them through the same
``roofline()`` machinery the launch-time analysis uses
(``roofline/analysis.py``), normalized to the requested device model.

Unlike ``costmodel.estimate`` (the *synthetic* data generator behind the
brute-forced hub: lognormal observation noise, an overlap term, 32 fake
repeats), the surrogate is a pure deterministic bound: ``max(compute_s,
memory_s)`` plus a per-grid-cell launch cost, one observation, no noise.
Pricing the same config twice returns a bit-identical ``CachedResult`` —
the property the ``modeled`` lookup tier and the conformance tests pin.

For workloads that were actually compiled, ``facts_from_compiled`` reads
XLA's compile-only cost analysis (via ``launch.dryrun.cost_analysis_dict``,
which normalizes the list-vs-dict jax API difference) and
``price_from_facts`` turns those measured FLOP/byte counts into the same
roofline bound — the calibration path for non-registry workloads.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping

from ..core.budget import Budget
from ..core.cache import CachedResult
from ..core.costmodel import KernelWorkload
from ..core.devices import DEVICES_BY_NAME, DeviceModel
from ..core.runner import Runner
from ..core.searchspace import SearchSpace
from ..roofline.analysis import HBM_BW, PEAK_FLOPS, Roofline, roofline

INVALID = float("inf")

# provenance tag carried by every modeled answer
MODEL_NAME = "roofline-v1"

# confidence of a modeled answer: above the cold floor (0.0) and above a
# far-shape/cross-device transfer, below any near-shape donor. A transfer
# whose ``transfer_confidence`` falls under this value yields to the
# surrogate in ``service.hub`` — see docs/scenarios.md for the calibration
# (same-device donors keep winning out to shape distance ~2.3).
MODELED_CONFIDENCE = 0.3

# per-grid-cell launch/dispatch cost; deliberately a plain constant (no
# noise, no overlap modeling) so the surrogate stays a deterministic bound
GRID_LAUNCH_S = 120e-9

# floor for the declared compute efficiency: a pathological workload factor
# must degrade the estimate, not divide by zero
MIN_EFF = 1e-3


@dataclasses.dataclass(frozen=True)
class SurrogatePrice:
    """One priced config: the roofline decomposition plus the scalar bound."""

    status: str               # "ok" | "error"
    time_s: float             # the bound (inf when infeasible)
    roofline: Roofline | None  # per-device compute/memory split, dominant
    eff: float = 0.0          # occupancy/efficiency factor used
    reason: str = ""          # error provenance ("vmem overflow")


def price(workload: KernelWorkload, config: Mapping,
          device: DeviceModel) -> SurrogatePrice:
    """Deterministic roofline bound for one config dict on one device.

    The shared ``roofline()`` combiner is written against the v5e module
    constants, so the workload terms are normalized into that frame first
    (``flops * PEAK/device.peak``): the returned seconds are then exact for
    ``device``. Collectives are zero — registry kernels are single-chip.
    """
    if workload.vmem_bytes(config) > device.vmem_bytes:
        return SurrogatePrice("error", INVALID, None, reason="vmem overflow")
    eff = min(max(workload.compute_eff(config, device), MIN_EFF), 1.0)
    flops = workload.flops(config)
    hbm = workload.hbm_bytes(config, device)
    rf = roofline(
        flops_per_chip=flops / eff * (PEAK_FLOPS / device.peak_flops),
        bytes_per_chip=hbm * (HBM_BW / device.hbm_bw),
        collective_wire_bytes=0.0, n_chips=1, mflops=flops)
    t = (max(rf.compute_s, rf.memory_s)
         + workload.grid_size(config) * GRID_LAUNCH_S)
    return SurrogatePrice("ok", t, rf, eff=eff)


def price_from_facts(facts: Mapping, device: DeviceModel,
                     eff: float = 1.0) -> SurrogatePrice:
    """Roofline bound from compile-only XLA cost-analysis facts
    (``{"flops": ..., "bytes accessed": ...}``) instead of an analytic
    workload — the ``facts_from_compiled`` calibration path."""
    flops = float(facts.get("flops", 0.0))
    hbm = float(facts.get("bytes accessed", facts.get("bytes_accessed", 0.0)))
    eff = min(max(eff, MIN_EFF), 1.0)
    rf = roofline(
        flops_per_chip=flops / eff * (PEAK_FLOPS / device.peak_flops),
        bytes_per_chip=hbm * (HBM_BW / device.hbm_bw),
        collective_wire_bytes=0.0, n_chips=1, mflops=flops)
    return SurrogatePrice("ok", max(rf.compute_s, rf.memory_s), rf, eff=eff)


def facts_from_compiled(compiled) -> dict:
    """Compile-only dry-run facts for a ``jax`` ``Compiled`` object —
    delegates to ``launch.dryrun.cost_analysis_dict`` (which papers over
    the 0.4.x list-of-dicts return shape)."""
    from ..launch.dryrun import cost_analysis_dict
    return dict(cost_analysis_dict(compiled))


class SurrogateRunner(Runner):
    """A ``Runner`` whose evaluations are surrogate prices.

    Drop-in wherever a ``SimulationRunner``/``CostModelRunner`` fits: the
    base-class memo/budget/trace machinery makes it a conforming
    ``BatchRunner``, so all registered strategies (and ``drive_many``)
    tune modeled scenarios unchanged. The budget is charged the modeled
    kernel time plus device overhead — no compile term, because the
    surrogate never compiles anything.
    """

    def __init__(self, space: SearchSpace, workload: KernelWorkload,
                 device: DeviceModel, budget: Budget):
        super().__init__(space, budget)
        self.workload = workload
        self.device = device

    def _evaluate(self, config) -> CachedResult:
        p = price(self.workload, self.space.as_dict(config), self.device)
        if p.status != "ok":
            return CachedResult("error", INVALID, (), 0.0,
                                self.device.overhead_s)
        return CachedResult("ok", p.time_s, (p.time_s,), 0.0,
                            self.device.overhead_s)


@dataclasses.dataclass(frozen=True)
class ModeledBest:
    """Argmin of the surrogate over a kernel's valid space — what the
    ``modeled`` lookup tier serves (and caches) per (kernel, device,
    problem) triple."""

    kernel: str
    device: str
    problem: dict
    config: dict
    value: float
    n_ok: int                 # feasible (priced-ok) configs
    n_valid: int              # valid configs considered
    dominant: str             # roofline term of the winner
    model: str = MODEL_NAME

    def provenance(self) -> dict:
        return {"model": self.model, "device_model": self.device,
                "dominant": self.dominant, "n_ok": self.n_ok,
                "n_valid": self.n_valid}


def best_modeled(kernel: str, problem: Mapping | None,
                 device: str | DeviceModel) -> ModeledBest | None:
    """Price the kernel's whole valid space and return the deterministic
    argmin (enumeration-order tie-break), or None when the kernel/device
    is not modelable or nothing is feasible.

    Problem dicts resolve through the registry convention (overrides of
    the kernel's ``SMOKE_PROBLEM``), the same resolution every recording
    uses — so a modeled answer and a later recording of the same triple
    price/measure the same workload.
    """
    from ..kernels import KERNELS
    spec = KERNELS.get(kernel)
    if spec is None:
        return None
    if isinstance(device, DeviceModel):
        dev = device
    else:
        dev = DEVICES_BY_NAME.get(device)
        if dev is None:
            return None
    problem = dict(problem or {})
    space = spec.space(problem)
    workload = spec.workload(problem)
    best_cfg, best_val, best_dom, n_ok = None, INVALID, "", 0
    n_valid = 0
    for config in space.valid_configs:
        n_valid += 1
        p = price(workload, space.as_dict(config), dev)
        if p.status != "ok":
            continue
        n_ok += 1
        if p.time_s < best_val:
            best_cfg, best_val = config, p.time_s
            best_dom = p.roofline.dominant
    if best_cfg is None:
        return None
    return ModeledBest(kernel=kernel, device=dev.name,
                       problem=spec.problem(problem),
                       config=space.as_dict(best_cfg), value=best_val,
                       n_ok=n_ok, n_valid=n_valid, dominant=best_dom)
