"""The scenario matrix: every (kernel × problem shape × device) triple.

The hub's promise after this subsystem is totality: any triple in the
matrix is answerable — from a *recorded* cache where one exists, from the
*roofline surrogate* where the kernel and device are modelable, and only
otherwise ``cold``. ``ScenarioMatrix`` is the registry of triples (the
``RooflineModel.kernels()``-style enumeration ROADMAP item 5 asks for);
``coverage`` classifies each triple against a live ``ConfigHub`` and is
what `python -m repro scenarios` prints, what the fleet consumes as its
work list, and what CI archives as the coverage artifact.

Shapes per kernel are the two canonical ones every other layer already
agrees on:

* ``default`` — the kernel's hub-default problem (the ``space()``
  signature defaults ``build_hub`` brute-forced; what ``lookup`` resolves
  a bare request to);
* ``smoke`` — the kernel's ``SMOKE_PROBLEM`` (what interpret-mode CI
  recordings run), when it differs from the default.

Device rows are the six hub device models plus ``cpu_interpret`` (the
live interpret-mode row recordings actually land on in CI).

``gate_recorded`` turns two coverage reports into a best-time regression
check, mirroring how ``benchmarks/check_regression.py`` gates evals/sec:
a recorded triple whose best time drifts above baseline × (1 + threshold)
fails, and a triple that *disappears* from the recorded tier fails too.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Mapping, Sequence

from ..core.devices import DEVICES_BY_NAME, HUB_DEVICES
from ..hub.storage import entry_key, hub_default_problem, problem_key

# the live interpret-mode device row (record's default target); not a
# DeviceModel, so never modelable — recorded or cold only
INTERPRET_DEVICE = "cpu_interpret"

SHAPE_LABELS = ("default", "smoke")
TIERS = ("recorded", "modeled", "cold")


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One (kernel, problem shape, device) triple. ``problem`` is the
    *resolved* shape as sorted (name, value) pairs — hashable, and equal
    exactly when the hub would treat the shapes as the same entry."""

    kernel: str
    device: str
    shape: str                 # "default" | "smoke" (display label)
    problem: tuple             # sorted ((name, value), ...) pairs

    @property
    def problem_dict(self) -> dict:
        return dict(self.problem)

    @property
    def pkey(self) -> str:
        return problem_key(self.problem_dict)

    @property
    def key(self) -> str:
        """Stable identity string — the hub entry key this triple maps to
        (``kernel@device#pkey``); also the gate/journal key."""
        return entry_key(self.kernel, self.device, self.pkey)

    def to_json(self) -> dict:
        return {"kernel": self.kernel, "device": self.device,
                "shape": self.shape, "problem": self.problem_dict,
                "key": self.key}


def kernel_shapes(kernel: str) -> dict:
    """The canonical shapes of one kernel: ``default`` always, ``smoke``
    when it resolves to a different hub entry."""
    from ..kernels import KERNELS
    spec = KERNELS[kernel]
    default = dict(hub_default_problem(kernel))
    shapes = {"default": default}
    smoke = dict(spec.problem({}))
    # smoke resolves through the same default-merge every lookup applies
    resolved = {**default, **smoke}
    if problem_key(resolved) != problem_key(default):
        shapes["smoke"] = resolved
    return shapes


class ScenarioMatrix:
    """Deterministic enumeration of the scenario triples.

    Order is registry order × shape-label order × device order (hub
    device models first, then ``cpu_interpret``) — stable across
    processes, so journals, coverage artifacts, and gate baselines key
    by position-independent ``Scenario.key`` but *print* identically.
    """

    def __init__(self, kernels: Sequence[str] | None = None,
                 devices: Sequence[str] | None = None,
                 shapes: Sequence[str] = SHAPE_LABELS):
        from ..kernels import KERNELS
        self.kernels = tuple(kernels or KERNELS)
        self.devices = tuple(devices if devices is not None else
                             [d.name for d in HUB_DEVICES]
                             + [INTERPRET_DEVICE])
        self.shapes = tuple(shapes)
        unknown = [k for k in self.kernels if k not in KERNELS]
        if unknown:
            raise ValueError(f"unknown kernels: {unknown}")

    def scenarios(self) -> list[Scenario]:
        out = []
        for kernel in self.kernels:
            shapes = kernel_shapes(kernel)
            for label in self.shapes:
                problem = shapes.get(label)
                if problem is None:
                    continue
                pairs = tuple(sorted(problem.items()))
                for device in self.devices:
                    out.append(Scenario(kernel, device, label, pairs))
        return out

    def __len__(self) -> int:
        return len(self.scenarios())

    def __iter__(self):
        return iter(self.scenarios())

    # ---------------------------------------------------------- coverage
    def coverage(self, hub=None, with_best: bool = False) -> "CoverageReport":
        """Classify every triple: ``recorded`` when the hub holds a
        measured entry for it, ``modeled`` when the surrogate can price
        it (registry kernel on a known device model), else ``cold``.

        ``with_best`` additionally resolves each answerable triple's best
        time through ``hub.lookup`` (exact for recorded, surrogate argmin
        for modeled) — what the CLI report and the regression gate use.
        """
        recorded = hub.recorded_keys() if hub is not None else frozenset()
        rows = []
        for sc in self.scenarios():
            if (sc.kernel, sc.device, sc.pkey) in recorded:
                tier = "recorded"
            elif sc.device in DEVICES_BY_NAME:
                tier = "modeled"
            else:
                tier = "cold"
            best = status = None
            if with_best and tier != "cold" and hub is not None:
                r = hub.lookup(sc.kernel, sc.problem_dict, sc.device)
                status = r.status
                if r.found:
                    best = r.best_value
            rows.append(CoverageRow(sc, tier, best, status))
        return CoverageReport(tuple(rows))


@dataclasses.dataclass(frozen=True)
class CoverageRow:
    scenario: Scenario
    tier: str                       # recorded | modeled | cold
    best_value: float | None = None  # filled by coverage(with_best=True)
    status: str | None = None        # the lookup status actually served

    def to_json(self) -> dict:
        d = self.scenario.to_json()
        d.update(tier=self.tier, best_value=self.best_value,
                 status=self.status)
        return d


@dataclasses.dataclass(frozen=True)
class CoverageReport:
    rows: tuple

    def counts(self) -> dict:
        c = {t: 0 for t in TIERS}
        for r in self.rows:
            c[r.tier] += 1
        return c

    def matrix(self) -> dict:
        """kernels × devices counts per tier — the `hub stats` coverage
        matrix shape: {kernel: {device: {tier: n}}}."""
        out: dict = {}
        for r in self.rows:
            cell = (out.setdefault(r.scenario.kernel, {})
                    .setdefault(r.scenario.device, {t: 0 for t in TIERS}))
            cell[r.tier] += 1
        return out

    def recorded_best(self) -> dict:
        """{scenario key: best seconds} over recorded rows with a value —
        the gate baseline payload."""
        return {r.scenario.key: r.best_value for r in self.rows
                if r.tier == "recorded" and r.best_value is not None}

    def to_json(self) -> dict:
        return {"format": "repro-scenario-coverage-v1",
                "counts": self.counts(), "matrix": self.matrix(),
                "rows": [r.to_json() for r in self.rows]}


def gate_recorded(current: Mapping, baseline: Mapping,
                  threshold: float = 0.2) -> list[str]:
    """Compare recorded best times against a baseline the way
    ``check_regression`` gates evals/sec: every baseline triple must still
    be recorded, and its best time must not regress past
    ``baseline × (1 + threshold)``. Returns failure lines (empty = pass);
    triples recorded now but absent from the baseline pass (new coverage
    is an improvement, the next baseline refresh picks them up)."""
    failures = []
    for key in sorted(baseline):
        base = baseline[key]
        cur = current.get(key)
        if cur is None:
            failures.append(f"{key}: was recorded in baseline, now absent")
        elif base > 0 and cur > base * (1.0 + threshold):
            failures.append(
                f"{key}: best {cur:.3e}s vs baseline {base:.3e}s "
                f"(+{(cur / base - 1.0) * 100:.1f}% > {threshold * 100:.0f}%)")
    return failures
