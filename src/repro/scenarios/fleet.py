"""The recording fleet: run the scenario matrix as a resumable campaign.

ROADMAP item 5's "continuous recording fleet": walk a ``ScenarioMatrix``
work list, record each triple (the same sharded, crash-safe
``Tuner.record`` machinery behind ``python -m repro record``), merge, and
``register`` the result into the hub — turning ``modeled``/``cold``
coverage cells into ``recorded`` ones.

Resume is two-layered, matching the repo's journal conventions:

* *within* a scenario, the observation shards under
  ``<hub>/.fleet/<key>/`` resume like any interrupted recording;
* *across* scenarios, a ``CampaignJournal`` at
  ``<hub>/.fleet/journal.jsonl`` marks each registered triple, so a
  re-run (same hub root) skips straight past completed work — the CI
  smoke job and a laptop sweep share one idempotent entry point.

Scenario selection: by default everything in the matrix that the chosen
runner can actually execute — ``live`` records only on
``cpu_interpret``; ``costmodel``/``surrogate`` record only on hub device
models. Triples already ``recorded`` in the hub are skipped before any
work starts.
"""
from __future__ import annotations

import dataclasses
import os
import re
from typing import Callable, Sequence

from ..core.devices import DEVICES_BY_NAME
from ..core.parallel import CampaignJournal
from .matrix import INTERPRET_DEVICE, Scenario, ScenarioMatrix

FLEET_FORMAT = "repro-fleet-journal-v1"
FLEET_DIR = ".fleet"


@dataclasses.dataclass(frozen=True)
class FleetOutcome:
    """One sweep's summary (JSON-friendly via ``to_json``)."""

    recorded: tuple          # scenario keys recorded+registered this run
    skipped: tuple           # already journaled (previous runs)
    covered: tuple           # already recorded in the hub, never journaled
    unrunnable: tuple        # runner can't execute these device rows

    def to_json(self) -> dict:
        return {"recorded": list(self.recorded),
                "skipped": list(self.skipped),
                "covered": list(self.covered),
                "unrunnable": list(self.unrunnable)}


def runnable(scenario: Scenario, runner: str) -> bool:
    """Can this runner actually execute this device row? ``live`` times
    real interpret-mode kernels (CPU only); the model-backed runners need
    a device model to price against."""
    if runner == "live":
        return scenario.device == INTERPRET_DEVICE
    return scenario.device in DEVICES_BY_NAME


def _slug(key: str) -> str:
    return re.sub(r"[^A-Za-z0-9_.@-]+", "_", key)


def run_fleet(hub_root: str,
              matrix: ScenarioMatrix | None = None,
              scenarios: Sequence[Scenario] | None = None,
              runner: str = "costmodel",
              strategy: str = "random_search",
              max_evals: int | None = 64,
              repeats: int = 3,
              workers: int = 1,
              backend: str = "serial",
              seed: int = 0,
              progress: Callable | None = None) -> FleetOutcome:
    """Record-and-register every runnable, not-yet-recorded scenario.

    Interrupt at any point and call again with the same ``hub_root``:
    journaled scenarios are skipped, the in-flight one resumes from its
    shards. Raises (via ``CampaignJournal.ensure_header``) if the journal
    at this root was written by a fleet with different recording settings
    — mixed-methodology hubs are exactly what the journal exists to
    prevent.
    """
    from ..api import Hub, Tuner

    say = progress or (lambda msg: None)
    work = list(scenarios if scenarios is not None
                else (matrix or ScenarioMatrix()).scenarios())
    hub = Hub(hub_root)
    service = hub.service()
    already = service.recorded_keys()

    fleet_dir = os.path.join(hub_root, FLEET_DIR)
    journal = CampaignJournal(os.path.join(fleet_dir, "journal.jsonl"),
                              fmt=FLEET_FORMAT)
    header = {"hub_root": os.path.abspath(hub_root), "runner": runner,
              "strategy": strategy, "max_evals": max_evals,
              "repeats": repeats, "seed": seed}
    done = {rec["key"] for rec in journal.ensure_header(header)}

    recorded, skipped, covered, unrunnable = [], [], [], []
    tuner = Tuner(hub_root=hub_root, repeats=repeats, seed=seed,
                  workers=workers, backend=backend)
    try:
        for sc in work:
            if not runnable(sc, runner):
                unrunnable.append(sc.key)
                continue
            if sc.key in done:
                skipped.append(sc.key)
                continue
            if (sc.kernel, sc.device, sc.pkey) in already:
                covered.append(sc.key)
                continue
            say(f"fleet: recording {sc.key} [{runner}]")
            out = os.path.join(fleet_dir, _slug(sc.key), "cache.json.gz")
            run = tuner.record(sc.kernel, runner=runner, device=sc.device,
                               problem=sc.problem_dict, strategy=strategy,
                               repeats=repeats, max_evals=max_evals,
                               out=out)
            entry = hub.register(run.cache, problem=sc.problem_dict)
            journal.append({"key": sc.key, "entry": entry,
                            "kernel": sc.kernel, "device": sc.device,
                            "problem": sc.problem_dict,
                            "best_value": run.best_value,
                            "n_evaluated": run.n_evaluated})
            recorded.append(sc.key)
            say(f"fleet: registered {entry} "
                f"(best {run.best_value!r}, {run.n_evaluated} evals)")
    finally:
        tuner.close()
    return FleetOutcome(tuple(recorded), tuple(skipped), tuple(covered),
                        tuple(unrunnable))
