"""Deprecated shim — LLM token serving moved to ``repro.inference``.

The name ``serving`` now belongs to the tuning-as-a-service story
(``repro.service``, the ConfigHub); the batched prefill+decode engine
lives in ``repro.inference.engine``. Importing through here keeps working
behind ``ServingMovedWarning`` (escalated to an error under pytest).
"""
from __future__ import annotations

import warnings

from ..deprecations import ServingMovedWarning

warnings.warn(
    "repro.serving moved to repro.inference (LLM token serving); "
    "repro.service is the ConfigHub tuning service",
    ServingMovedWarning, stacklevel=2)
