"""Deprecated shim — see ``repro.inference.engine``."""
from __future__ import annotations

from ..inference.engine import (Request, ServingEngine,  # noqa: F401
                                make_decode_fn)
