"""Logical sharding annotations for model internals.

GSPMD propagation can lose the batch sharding at ops it reshards poorly
(e.g. the vocab-sharded embedding gather triggers "involuntary full
rematerialization" and emits a replicated activation, which then poisons the
whole layer scan). Production JAX model code pins the layout at a few key
points with ``with_sharding_constraint``; these helpers do that with
*logical* axes resolved against an ambient (mesh, layout):

  logical "dp"  — the batch axis of activations
  logical "tp"  — the tensor-parallel axis (heads / ffn / experts)
  logical "sp"  — the sequence axis of the residual stream

Layout policies (the §Perf tunable):
  "2d"      baseline: dp=(pod,data), tp=model, sp unsharded — Megatron-style
            TP with activation all-reduces.
  "dp"      pure data parallel: dp=(pod,data,model) — all chips shard the
            batch, no tensor parallelism of activations (params stay 2D
            FSDP-sharded; XLA all-gathers them per layer).
  "2d_seq"  sequence parallelism: like 2d but the residual stream is
            sequence-sharded on the model axis between blocks (the
            activation all-reduce becomes reduce-scatter + all-gather and
            norms run on 1/16th of the tokens).

``annotation_mesh(mesh, layout)`` installs the context (the launcher/dry-run
does it); without one every annotate() is a no-op, so single-device smoke
tests never notice. A dim is only sharded when the axis size divides it.
"""
from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_STATE = threading.local()
LAYOUTS = ("2d", "dp", "2d_seq")


def _current():
    return getattr(_STATE, "mesh", None), getattr(_STATE, "layout", "2d")


@contextlib.contextmanager
def annotation_mesh(mesh, layout: str = "2d"):
    assert layout in LAYOUTS, layout
    prev = (getattr(_STATE, "mesh", None), getattr(_STATE, "layout", "2d"))
    _STATE.mesh, _STATE.layout = mesh, layout
    try:
        yield
    finally:
        _STATE.mesh, _STATE.layout = prev


def _resolve(mesh, layout: str, logical: str | None):
    names = mesh.axis_names
    if logical is None:
        return None
    if logical == "dp":
        if layout == "dp":
            return tuple(a for a in names if a in ("pod", "data", "model"))
        return tuple(a for a in names if a in ("pod", "data"))
    if logical == "tp":
        if layout == "dp":
            return None
        return "model" if "model" in names else None
    if logical == "sp":
        if layout == "2d_seq" and "model" in names:
            return "model"
        return None
    raise ValueError(logical)


def annotate(x: jax.Array, *logical_spec) -> jax.Array:
    """with_sharding_constraint with logical axes + divisibility fallback."""
    mesh, layout = _current()
    if mesh is None:
        return x
    spec = []
    for dim, logical in zip(x.shape, logical_spec):
        axes = _resolve(mesh, layout, logical)
        if axes is None:
            spec.append(None)
            continue
        size = 1
        for a in (axes if isinstance(axes, tuple) else (axes,)):
            size *= mesh.shape[a]
        spec.append(axes if dim % size == 0 else None)
    spec += [None] * (x.ndim - len(spec))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))


def current_layout() -> str:
    return _current()[1]
