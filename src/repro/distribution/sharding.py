"""Sharding rules: FSDP ("data", + "pod" when present) × TP ("model").

Explicit input shardings in JAX must divide the dims, so every rule is
divisibility-aware: a dim is sharded on its candidate axis only when the
axis size divides it, otherwise the next candidate (or replication) is used.
Leading stacked-layer dims (the scan axes) are never sharded.

Scheme (params):
  column-parallel (wq/wk/wv/wi/wg/in_proj):  (fsdp, tp)
  row-parallel    (wo/out_proj):             (tp, fsdp)
  embed (V, D): (tp, fsdp)   unembed (D, V): (fsdp, tp)
  MoE (E, D, F): experts on tp when E % tp == 0 (qwen3: 128/16), else the
  expert-FFN dim on tp (grok: 8 experts, F=32768/16) with D on fsdp.

Batch: leading batch dim on (pod, data). Decode caches: batch on dp when it
divides, else the *sequence* dim on dp (context parallelism — the long_500k
path); KV heads on tp with head-dim fallback (GQA with 1–4 KV heads).
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def mesh_axes(mesh: Mesh, layout: str = "2d") -> tuple:
    """Returns (dp_axes, tp_axis). layout="dp" folds the model axis into
    the batch axis (pure data parallelism of activations)."""
    names = mesh.axis_names
    if layout == "dp":
        return tuple(a for a in names
                     if a in ("pod", "data", "model")), "model"
    dp = tuple(a for a in names if a in ("pod", "data"))
    return dp, "model"


def _pick(mesh: Mesh, dim: int, candidates) -> object:
    """First candidate axis (or axis tuple) that divides ``dim``; else None."""
    for cand in candidates:
        if cand is None:
            return None
        if dim % _axis_size(mesh, cand) == 0:
            return cand
    return None


def _spec_for_param(mesh: Mesh, path: str, shape: tuple) -> P:
    dp, tp = mesh_axes(mesh)
    ndim = len(shape)
    leaf = path.split("/")[-1]
    in_moe = "/moe/" in path or path.endswith("moe")

    def lead(n_rule: int):
        return [None] * (ndim - n_rule)

    if ndim == 0 or leaf in ("scale", "conv_b", "A_log", "dt_bias", "D",
                             "gate_norm", "step"):
        return P()
    if leaf == "embed":
        return P(_pick(mesh, shape[0], [tp]), _pick(mesh, shape[1], [dp]))
    if leaf == "unembed":
        return P(_pick(mesh, shape[0], [dp]), _pick(mesh, shape[1], [tp]))
    if in_moe and leaf in ("wi", "wg", "wo") and ndim >= 3:
        e, d1, d2 = shape[-3:]
        if e % _axis_size(mesh, tp) == 0:
            spec = [tp, _pick(mesh, d1, [dp]), None]
        elif leaf == "wo":   # (E, F, D): F row-parallel
            spec = [None, _pick(mesh, d1, [tp]), _pick(mesh, d2, [dp])]
        else:                # (E, D, F): F column-parallel
            spec = [None, _pick(mesh, d1, [dp]), _pick(mesh, d2, [tp])]
        return P(*lead(3), *spec)
    if leaf in ("wq", "wk", "wv", "wi", "wg", "in_proj") and ndim >= 2:
        d_in, d_out = shape[-2:]
        return P(*lead(2), _pick(mesh, d_in, [dp]), _pick(mesh, d_out, [tp]))
    if leaf in ("wo", "out_proj") and ndim >= 2:
        d_in, d_out = shape[-2:]
        return P(*lead(2), _pick(mesh, d_in, [tp]), _pick(mesh, d_out, [dp]))
    if leaf == "router" and ndim >= 2:
        return P(*lead(2), _pick(mesh, shape[-2], [dp]), None)
    if leaf == "conv_w" and ndim >= 2:
        return P(*lead(2), None, _pick(mesh, shape[-1], [tp]))
    # default: replicate (small/unknown leaves)
    return P(*[None] * ndim)


def _tree_paths(tree):
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        yield key, leaf


def param_shardings(mesh: Mesh, params):
    """NamedSharding pytree matching ``params`` (works on ShapeDtypeStructs)."""
    def one(path, leaf):
        return NamedSharding(mesh, _spec_for_param(mesh, path, leaf.shape))
    flat = [(p, one(p, l)) for p, l in _tree_paths(params)]
    treedef = jax.tree_util.tree_structure(params)
    return jax.tree_util.tree_unflatten(treedef, [s for _, s in flat])


def opt_state_shardings(mesh: Mesh, opt_state):
    """mu/nu mirror the param layout; step is replicated."""
    return param_shardings(mesh, opt_state)


def batch_shardings(mesh: Mesh, batch, layout: str = "2d"):
    dp, tp = mesh_axes(mesh, layout)

    def one(path, leaf):
        shape = leaf.shape
        if not shape:
            return NamedSharding(mesh, P())
        spec = [None] * len(shape)
        spec[0] = _pick(mesh, shape[0], [dp, tuple(dp[1:]) or None])
        if path.endswith("audio_embeds") or path.endswith("patch_embeds"):
            pass  # (B, T, D) — batch only
        return NamedSharding(mesh, P(*spec))

    flat = [(p, one(p, l)) for p, l in _tree_paths(batch)]
    treedef = jax.tree_util.tree_structure(batch)
    return jax.tree_util.tree_unflatten(treedef, [s for _, s in flat])


def cache_shardings(mesh: Mesh, cache, batch_size: int,
                    layout: str = "2d"):
    """Decode-cache layout. KV caches (L, B, S, Hkv, Dh): B on dp when it
    divides; otherwise S on dp (context parallelism, the batch=1 long-context
    case). Hkv on tp with Dh fallback. SSM states (L, B, H, N, P): heads on
    tp with state/head-dim fallbacks."""
    dp, tp = mesh_axes(mesh, layout)
    dp_size = _axis_size(mesh, dp)
    batch_on_dp = batch_size % dp_size == 0

    def one(path, leaf):
        shape = leaf.shape
        leafname = path.split("/")[-1]
        spec = [None] * len(shape)
        if leafname in ("k", "v", "xk", "xv") and len(shape) == 5:
            # (L, B, S, Hkv, Dh)
            if batch_on_dp:
                spec[1] = dp
            else:
                spec[2] = _pick(mesh, shape[2], [dp])
            spec[3] = _pick(mesh, shape[3], [tp])
            if spec[3] is None:
                spec[4] = _pick(mesh, shape[4], [tp])
        elif leafname == "ssm":
            # (..., B, H, N, P)
            b_ax = len(shape) - 4
            if batch_on_dp:
                spec[b_ax] = dp
            spec[b_ax + 1] = _pick(mesh, shape[b_ax + 1], [tp])
            if spec[b_ax + 1] is None:
                spec[b_ax + 2] = _pick(mesh, shape[b_ax + 2], [tp])
        elif leafname == "conv":
            # (..., B, K-1, C)
            b_ax = len(shape) - 3
            if batch_on_dp:
                spec[b_ax] = dp
            spec[-1] = _pick(mesh, shape[-1], [tp])
        return NamedSharding(mesh, P(*spec))

    flat = [(p, one(p, l)) for p, l in _tree_paths(cache)]
    treedef = jax.tree_util.tree_structure(cache)
    return jax.tree_util.tree_unflatten(treedef, [s for _, s in flat])


def replicated(mesh: Mesh, tree):
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)
