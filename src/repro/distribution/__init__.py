"""repro subpackage."""
