"""repro subpackage."""
