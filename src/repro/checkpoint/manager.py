"""Checkpointing: atomic, async-capable, keep-k, elastic restore.

Fault-tolerance contract (exercised by tests/test_fault_tolerance.py):
  * ``save`` writes to a temp file and atomically renames — a crash mid-write
    never corrupts the latest checkpoint;
  * ``restore`` + the stateless data pipeline reproduce training bit-exactly
    from the saved step;
  * ``restore(..., shardings=...)`` re-lays a checkpoint onto a *different*
    mesh (elastic scaling: resume on more/fewer data shards);
  * ``AsyncCheckpointer`` overlaps serialization with the next train steps
    (the step only blocks if the previous write is still in flight).

Format: one .npz with path-flattened arrays + a JSON sidecar (step, config
fingerprint). Single-process container; on a real multi-host pod each host
writes its array shards (documented in DESIGN.md).
"""
from __future__ import annotations

import json
import os
import re
import threading

import jax
import numpy as np


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def _unflatten(template, flat: dict):
    leaves_paths = jax.tree_util.tree_flatten_with_path(template)[0]
    treedef = jax.tree_util.tree_structure(template)
    leaves = []
    for path, leaf in leaves_paths:
        key = "/".join(_path_str(p) for p in path)
        arr = flat[key]
        if hasattr(leaf, "dtype"):
            arr = arr.astype(leaf.dtype)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------ io
    def _path(self, step: int) -> str:
        return os.path.join(self.directory, f"ckpt_{step:08d}.npz")

    def save(self, step: int, state, meta: dict | None = None) -> str:
        flat = _flatten(state)
        path = self._path(step)
        tmp = path + ".tmp"
        with open(tmp, "wb") as f:
            np.savez(f, **flat)
        os.replace(tmp, path)  # atomic publish
        with open(path + ".json", "w") as f:
            json.dump({"step": step, **(meta or {})}, f)
        self._gc()
        return path

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            for suffix in (".npz", ".npz.json"):
                p = os.path.join(self.directory, f"ckpt_{s:08d}{suffix}")
                if os.path.exists(p):
                    os.remove(p)

    def all_steps(self) -> list:
        out = []
        for name in sorted(os.listdir(self.directory)):
            m = re.fullmatch(r"ckpt_(\d+)\.npz", name)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, template, shardings=None):
        """Restore into the template's structure/dtypes. With ``shardings``
        (a pytree of NamedSharding matching template) the arrays are placed
        onto the target mesh — this is the elastic-resharding path."""
        with np.load(self._path(step)) as data:
            flat = {k: data[k] for k in data.files}
        state = _unflatten(template, flat)
        if shardings is not None:
            state = jax.tree.map(jax.device_put, state, shardings)
        return state


class AsyncCheckpointer:
    """Background-thread writer: snapshot on the caller thread (device→host
    copy), serialize/write off-thread. ``wait()`` joins the in-flight write."""

    def __init__(self, manager: CheckpointManager):
        self.manager = manager
        self._thread: threading.Thread | None = None
        self._error: Exception | None = None

    def save(self, step: int, state, meta: dict | None = None) -> None:
        self.wait()
        snapshot = jax.tree.map(np.asarray, state)  # host copy now

        def work():
            try:
                self.manager.save(step, snapshot, meta)
            except Exception as e:  # pragma: no cover
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            raise self._error
