"""Training launcher: local end-to-end driver with checkpoint/restart.

On a pod this process runs per host with jax.distributed; in this container
it drives the host mesh. The loop is the production shape: async
checkpointing, stateless data pipeline keyed by step, resume from the latest
checkpoint, bf16 compute / fp32 master params.

  PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --preset tiny \
      --steps 200 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from ..checkpoint.manager import AsyncCheckpointer, CheckpointManager
from ..configs import get_config
from ..data.pipeline import DataConfig, TokenPipeline
from ..training.optimizer import OptimizerConfig
from ..training.train_step import (TrainConfig, init_train_state,
                                   make_train_step)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--preset", default="tiny", choices=["tiny", "full"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default="none",
                    choices=["none", "dots", "full"])
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.preset == "tiny":
        cfg = cfg.tiny()
        cfg = dataclasses.replace(cfg, name=args.arch + "-tiny")
    opt = OptimizerConfig(peak_lr=args.lr, warmup_steps=max(args.steps // 20, 1),
                          total_steps=args.steps)
    tc = TrainConfig(microbatches=args.microbatches, remat=args.remat)
    step_fn = jax.jit(make_train_step(cfg, opt, tc), donate_argnums=0)
    pipe = TokenPipeline(DataConfig(vocab=cfg.vocab, seq_len=args.seq_len,
                                    global_batch=args.global_batch), cfg)

    mgr = ckpt = None
    start = 0
    state = None
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir, keep=3)
        ckpt = AsyncCheckpointer(mgr)
        latest = mgr.latest_step()
        if latest is not None:
            template = jax.eval_shape(
                lambda: init_train_state(cfg, opt, jax.random.PRNGKey(0)))
            state = mgr.restore(latest, template)
            start = latest
            print(f"resumed from step {latest}")
    if state is None:
        state = init_train_state(cfg, opt, jax.random.PRNGKey(0))

    t0 = time.perf_counter()
    tokens_seen = 0
    for step in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(step).items()}
        state, metrics = step_fn(state, batch)
        tokens_seen += args.global_batch * args.seq_len
        if (step + 1) % args.log_every == 0:
            dt = time.perf_counter() - t0
            print(f"step {step+1:6d} loss={float(metrics['loss']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"lr={float(metrics['lr']):.2e} "
                  f"tok/s={tokens_seen/dt:,.0f}", flush=True)
        if ckpt and (step + 1) % args.save_every == 0:
            ckpt.save(step + 1, state)
    if ckpt:
        ckpt.save(args.steps, state)
        ckpt.wait()
        print(f"final checkpoint at step {args.steps} in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
