"""Multi-pod dry-run (deliverable e).

Lowers + compiles every (architecture × input shape × mesh) cell from
ShapeDtypeStructs — no allocation — and records memory_analysis(),
cost_analysis() and the collective schedule for the roofline analysis.

``main()`` starts by forcing 512 host devices via ``XLA_FLAGS`` — that
must happen before jax initializes a backend (jax locks the device count
at first init), which holds for the CLI entry because importing jax does
not initialize one. It must NOT happen at module import: this module is a
library too (``cost_analysis_dict`` feeds the scenario surrogate), and an
importing process — smoke tests, benches, the service — must keep seeing
1 device. ``parity-lint``'s ``ordering-import-env-mutation`` rule
enforces the distinction repo-wide.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
      --mesh both --out experiments/dryrun
"""
import argparse
import os
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import ARCHS, SHAPES, ArchConfig, ShapeConfig, cell_supported
from ..distribution.annotate import annotation_mesh
from ..distribution.sharding import (batch_shardings, cache_shardings,
                                     mesh_axes, param_shardings, _pick)
from ..models.transformer import decode_step, init_cache, init_params, prefill
from ..roofline.analysis import (analytic_cost, model_flops,
                                 parse_collectives, roofline)
from ..training.optimizer import OptimizerConfig
from ..training.train_step import TrainConfig, init_train_state, make_train_step
from .mesh import make_production_mesh


# ------------------------------------------------------------- input specs
def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b, s = shape.global_batch, shape.seq_len
    f32 = jnp.float32
    i32 = jnp.int32
    if shape.kind == "train":
        batch = {"tokens": jax.ShapeDtypeStruct((b, s + 1), i32)}
        if cfg.family == "vlm":
            batch["positions"] = jax.ShapeDtypeStruct((b, s + 1, 3), i32)
            batch["patch_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.n_patches, cfg.d_model), f32)
        if cfg.family == "audio":
            batch["audio_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.n_audio_frames, cfg.d_model), f32)
        return batch
    if shape.kind == "prefill":
        batch = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
        if cfg.family == "vlm":
            batch["positions"] = jax.ShapeDtypeStruct((b, s, 3), i32)
            batch["patch_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.n_patches, cfg.d_model), f32)
        if cfg.family == "audio":
            batch["audio_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.n_audio_frames, cfg.d_model), f32)
        return batch
    # decode: one new token against a seq_len-deep cache
    return {"tokens": jax.ShapeDtypeStruct((b, 1), i32),
            "cache_len": jax.ShapeDtypeStruct((), i32)}


def _logits_sharding(mesh, cfg: ArchConfig, batch: int):
    dp, tp = mesh_axes(mesh)
    return NamedSharding(mesh, P(_pick(mesh, batch, [dp]),
                                 _pick(mesh, cfg.vocab, [tp])))


# ------------------------------------------------------------------- cells
def lower_cell(cfg: ArchConfig, shape: ShapeConfig, mesh, *,
               microbatches: int = 1, remat: str = "full",
               layout: str = "2d"):
    """Returns the lowered computation. Raises on sharding/lowering errors."""
    with annotation_mesh(mesh, layout):
        return _lower_cell_inner(cfg, shape, mesh, microbatches=microbatches,
                                 remat=remat, layout=layout)


def _lower_cell_inner(cfg: ArchConfig, shape: ShapeConfig, mesh, *,
                      microbatches: int, remat: str, layout: str):
    specs = input_specs(cfg, shape)
    params_t = jax.eval_shape(
        lambda: init_params(cfg, jax.random.PRNGKey(0)))
    p_sh = param_shardings(mesh, params_t)

    if shape.kind == "train":
        opt_cfg = OptimizerConfig()
        tc = TrainConfig(microbatches=microbatches, remat=remat)
        state_t = jax.eval_shape(
            lambda: init_train_state(cfg, opt_cfg, jax.random.PRNGKey(0)))
        state_sh = {"params": p_sh,
                    "opt": {"mu": p_sh, "nu": p_sh,
                            "step": NamedSharding(mesh, P())}}
        b_sh = batch_shardings(mesh, specs, layout)
        metrics_sh = {"loss": NamedSharding(mesh, P()),
                      "grad_norm": NamedSharding(mesh, P()),
                      "lr": NamedSharding(mesh, P())}
        fn = make_train_step(cfg, opt_cfg, tc)
        lowered = jax.jit(fn, in_shardings=(state_sh, b_sh),
                          out_shardings=(state_sh, metrics_sh),
                          donate_argnums=0).lower(state_t, specs)
        return lowered

    if shape.kind == "prefill":
        b_sh = batch_shardings(mesh, specs, layout)
        cache_t = jax.eval_shape(
            lambda: init_cache(cfg, shape.global_batch, shape.seq_len))
        c_sh = cache_shardings(mesh, cache_t, shape.global_batch, layout)

        def fn(params, batch):
            return prefill(cfg, params, batch, max_len=shape.seq_len)

        out_sh = (_logits_sharding(mesh, cfg, shape.global_batch), c_sh,
                  NamedSharding(mesh, P()))
        lowered = jax.jit(fn, in_shardings=(p_sh, b_sh),
                          out_shardings=out_sh).lower(params_t, specs)
        return lowered

    # decode
    cache_t = jax.eval_shape(
        lambda: init_cache(cfg, shape.global_batch, shape.seq_len))
    c_sh = cache_shardings(mesh, cache_t, shape.global_batch, layout)
    specs_d = input_specs(cfg, shape)
    tok_sh = NamedSharding(
        mesh, P(_pick(mesh, shape.global_batch,
                      [mesh_axes(mesh, layout)[0]]), None))

    def fn(params, cache, tokens, cache_len):
        return decode_step(cfg, params, cache, tokens, cache_len)

    out_sh = (_logits_sharding(mesh, cfg, shape.global_batch), c_sh)
    lowered = jax.jit(
        fn, in_shardings=(p_sh, c_sh, tok_sh, NamedSharding(mesh, P())),
        out_shardings=out_sh, donate_argnums=1,
    ).lower(params_t, cache_t, specs_d["tokens"], specs_d["cache_len"])
    return lowered


def cost_analysis_dict(compiled) -> dict:
    """``Compiled.cost_analysis()`` returns one dict on current jax but a
    per-device list of dicts on 0.4.x; normalize to the (replicated) dict."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def run_cell(arch_name: str, shape_name: str, mesh_kind: str, *,
             microbatches: int = 1, remat: str = "full", layout: str = "2d",
             collect_hlo: bool = True) -> dict:
    cfg = ARCHS[arch_name]
    shape = SHAPES[shape_name]
    rec: dict = {"arch": arch_name, "shape": shape_name, "mesh": mesh_kind,
                 "microbatches": microbatches, "remat": remat,
                 "layout": layout}
    ok, reason = cell_supported(cfg, shape)
    if not ok:
        rec.update(status="skipped", reason=reason)
        return rec
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = mesh.size
    try:
        t0 = time.perf_counter()
        lowered = lower_cell(cfg, shape, mesh, microbatches=microbatches,
                             remat=remat, layout=layout)
        t_lower = time.perf_counter() - t0
        t0 = time.perf_counter()
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0
        mem = compiled.memory_analysis()
        cost = cost_analysis_dict(compiled)
        flops = float(cost.get("flops", 0.0))
        bytes_acc = float(cost.get("bytes accessed", 0.0))
        coll = parse_collectives(compiled.as_text(), n_chips) if collect_hlo \
            else None
        mf = model_flops(cfg, shape)
        # cost_analysis counts scan bodies once; the roofline terms use the
        # analytic (trip-count-exact) cost, validated in tests/test_roofline
        mb_used = microbatches if shape.kind == "train" else 1
        a_flops, a_bytes = analytic_cost(cfg, shape, remat, n_chips)
        rl = roofline(a_flops, a_bytes,
                      coll.total_wire_bytes if coll else 0.0, n_chips, mf)
        rec.update(
            status="ok", n_chips=n_chips,
            lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
            memory={
                "argument_bytes_per_chip": mem.argument_size_in_bytes,
                "output_bytes_per_chip": mem.output_size_in_bytes,
                "temp_bytes_per_chip": mem.temp_size_in_bytes,
                "alias_bytes_per_chip": mem.alias_size_in_bytes,
                "peak_bytes_per_chip": (mem.argument_size_in_bytes
                                        + mem.output_size_in_bytes
                                        + mem.temp_size_in_bytes
                                        - mem.alias_size_in_bytes),
            },
            cost={"hlo_flops_per_chip": flops,
                  "hlo_bytes_per_chip": bytes_acc,
                  "analytic_flops_per_chip": a_flops,
                  "analytic_bytes_per_chip": a_bytes},
            collectives=coll.to_json() if coll else None,
            roofline=rl.to_json(),
        )
    except Exception as e:
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
    return rec


def force_host_devices(n: int = 512) -> None:
    """Point XLA at ``n`` host platform devices — CLI entry points only,
    and only before jax's first backend init."""
    os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"


def main() -> None:
    force_host_devices()
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default="full", choices=["none", "dots", "full"])
    ap.add_argument("--layout", default="2d", choices=["2d", "dp", "2d_seq"])
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    archs = list(ARCHS) if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    os.makedirs(args.out, exist_ok=True)

    n_ok = n_err = n_skip = 0
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                t0 = time.perf_counter()
                rec = run_cell(arch, shape, mesh_kind,
                               microbatches=args.microbatches,
                               remat=args.remat, layout=args.layout)
                path = os.path.join(args.out,
                                    f"{arch}__{shape}__{mesh_kind}.json")
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                dt = time.perf_counter() - t0
                if rec["status"] == "ok":
                    n_ok += 1
                    r = rec["roofline"]
                    print(f"[ok]   {arch:22s} {shape:12s} {mesh_kind:6s} "
                          f"compile={rec['compile_s']:7.1f}s "
                          f"peakmem={rec['memory']['peak_bytes_per_chip']/2**30:6.2f}GiB "
                          f"dom={r['dominant']:10s} "
                          f"useful={r['useful_ratio']:6.3f} ({dt:.0f}s)",
                          flush=True)
                elif rec["status"] == "skipped":
                    n_skip += 1
                    print(f"[skip] {arch:22s} {shape:12s} {mesh_kind:6s} "
                          f"{rec['reason']}", flush=True)
                else:
                    n_err += 1
                    print(f"[ERR]  {arch:22s} {shape:12s} {mesh_kind:6s} "
                          f"{rec['error']}", flush=True)
    print(f"\ndry-run: {n_ok} ok, {n_skip} skipped, {n_err} errors")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
