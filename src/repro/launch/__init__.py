"""repro subpackage."""
