"""Serving launcher: batched requests through prefill + decode.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --preset tiny \
      --batch 4 --new-tokens 16
"""
from __future__ import annotations

import argparse
import time

import jax

from ..configs import get_config
from ..models.transformer import init_params
from ..inference.engine import Request, ServingEngine


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--preset", default="tiny", choices=["tiny", "full"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.preset == "tiny":
        cfg = cfg.tiny()
    params = init_params(cfg, jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, params, max_len=args.max_len)
    rng = jax.random.PRNGKey(1)
    prompts = jax.random.randint(rng, (args.batch, args.prompt_len), 0,
                                 cfg.vocab)
    reqs = [Request(prompt=list(map(int, prompts[i])),
                    max_new_tokens=args.new_tokens,
                    temperature=args.temperature)
            for i in range(args.batch)]
    t0 = time.perf_counter()
    outs = engine.generate(reqs)
    dt = time.perf_counter() - t0
    total_new = sum(len(o) for o in outs)
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len} "
          f"new={args.new_tokens}")
    for i, o in enumerate(outs):
        print(f"  req{i}: {o}")
    print(f"generated {total_new} tokens in {dt:.2f}s "
          f"({total_new/dt:.1f} tok/s incl. prefill+compile)")


if __name__ == "__main__":
    main()
