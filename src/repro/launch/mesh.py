"""Production meshes.

A function (not a module constant) so importing never touches jax device
state. Single-pod: 16×16 = 256 chips ("data", "model"); multi-pod: 2×16×16 =
512 chips ("pod", "data", "model") — the pod axis is data-parallel across
the inter-pod (DCN/ICI) links.
"""
from __future__ import annotations

import jax

try:  # jax >= 0.5: explicit axis types (Auto is the pre-AxisType behaviour)
    from jax.sharding import AxisType
except ImportError:  # older jax: no AxisType, make_mesh takes no axis_types
    AxisType = None


def _make_mesh(shape, axes):
    if AxisType is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh():
    """1×1 mesh on the local device — smoke tests and examples."""
    return _make_mesh((1, 1), ("data", "model"))
