"""Pallas API compatibility across jax versions.

jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams`` (and for
a window of releases ships both, one as a deprecated alias). The kernels in
this package only pass ``dimension_semantics``, which both spellings accept,
so a single resolved name keeps every kernel importable on any installed jax
— the live-tuning path the recorder depends on must not rot with the
toolchain.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

if hasattr(pltpu, "CompilerParams"):
    CompilerParams = pltpu.CompilerParams
else:  # older jax (e.g. 0.4.x): pre-rename spelling
    CompilerParams = pltpu.TPUCompilerParams

__all__ = ["CompilerParams"]
