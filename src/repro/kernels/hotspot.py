"""Hotspot — thermal simulation stencil (benchmark-hub kernel, Rodinia).

Iteratively solves T' = T + dt·(power + conduction(5-point stencil)). The
classic GPU tuning axis is *temporal blocking* (ghost-zone / pyramid
blocking): fuse ``t_block`` timesteps per kernel launch, reading a halo of
``t_block`` cells and recomputing the shrinking pyramid in registers/VMEM —
trading redundant compute for HBM round-trips. That insight carries to TPU
directly: the strip lives in VMEM, the pyramid shrinks by 2 rows/cols per
fused step, HBM traffic drops ~t_block×.

Tunables: strip_h, block_w (spatial tile), t_block (temporal fusion).
"""
from __future__ import annotations

import functools
from typing import Mapping

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams

from ..core.costmodel import KernelWorkload, alignment_eff, dma_eff
from ..core.devices import DeviceModel
from ..core.searchspace import SearchSpace
from ..core.tunable import Constraint, tunables_from_dict

HUB_H, HUB_W = 4096, 4096
HUB_STEPS = 16           # timesteps per hub measurement
BYTES = 4                # fp32 grids

# Recording problem size (CPU interpret-mode live tuning)
SMOKE_PROBLEM = {"h": 64, "w": 128}
# physical coefficients (Rodinia-style, folded constants)
C_CENTER, C_NEIGH, C_POWER = 0.6, 0.1, 0.5


def _stencil_once(t, p):
    """One step on an (r, c) block; returns (r-2, c-2) interior."""
    interior = t[1:-1, 1:-1]
    neigh = (t[:-2, 1:-1] + t[2:, 1:-1] + t[1:-1, :-2] + t[1:-1, 2:])
    return (C_CENTER * interior + C_NEIGH * neigh
            + C_POWER * p[1:-1, 1:-1])


# ----------------------------------------------------------------- kernel
def _hotspot_kernel(t_ref, p_ref, out_ref, *, t_block: int, strip_h: int,
                    block_w: int):
    # t_ref/p_ref: (1, strip_h + 2*t_block, block_w + 2*t_block)
    t = t_ref[0].astype(jnp.float32)
    p = p_ref[0].astype(jnp.float32)
    for _ in range(t_block):
        t = _stencil_once(t, p)
        p = p[1:-1, 1:-1]
    out_ref[0, ...] = t.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("strip_h", "block_w", "t_block",
                                             "interpret"))
def hotspot(temp: jax.Array, power: jax.Array, *, strip_h: int = 64,
            block_w: int = 256, t_block: int = 1,
            interpret: bool = False) -> jax.Array:
    """Advance the thermal grid by ``t_block`` fused steps (periodic BC).

    With periodic boundaries, ghost-zone temporal blocking is *exact*: halo
    cells hold true step-0 neighbor data and the shrinking pyramid recomputes
    the evolution, so fused == sequential everywhere."""
    h, w = temp.shape
    assert h % strip_h == 0 and w % block_w == 0
    halo = t_block
    tp = jnp.pad(temp, halo, mode="wrap")
    pp = jnp.pad(power, halo, mode="wrap")

    def strip_tiles(a):
        n_i, n_j = h // strip_h, w // block_w
        ii, jj = jnp.meshgrid(jnp.arange(n_i), jnp.arange(n_j), indexing="ij")
        def take(i, j):
            return jax.lax.dynamic_slice(
                a, (i * strip_h, j * block_w),
                (strip_h + 2 * halo, block_w + 2 * halo))
        return jax.vmap(jax.vmap(take))(ii, jj).reshape(
            n_i * n_j, strip_h + 2 * halo, block_w + 2 * halo)

    ts, ps = strip_tiles(tp), strip_tiles(pp)
    kernel = functools.partial(_hotspot_kernel, t_block=t_block,
                               strip_h=strip_h, block_w=block_w)
    n_tiles = (h // strip_h) * (w // block_w)
    out = pl.pallas_call(
        kernel,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((1, strip_h + 2 * halo, block_w + 2 * halo),
                         lambda i: (i, 0, 0)),
            pl.BlockSpec((1, strip_h + 2 * halo, block_w + 2 * halo),
                         lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, strip_h, block_w), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_tiles, strip_h, block_w), temp.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(ts, ps)
    n_i, n_j = h // strip_h, w // block_w
    return (out.reshape(n_i, n_j, strip_h, block_w)
               .transpose(0, 2, 1, 3).reshape(h, w))


# -------------------------------------------------------------------- ref
def hotspot_ref(temp: jax.Array, power: jax.Array, *, t_block: int = 1,
                **_unused) -> jax.Array:
    """Pure-jnp oracle: t_block edge-padded stencil steps."""
    t = temp.astype(jnp.float32)
    p = power.astype(jnp.float32)
    for _ in range(t_block):
        tp = jnp.pad(t, 1, mode="wrap")
        pp = jnp.pad(p, 1, mode="wrap")
        t = _stencil_once(tp, pp)
    return t.astype(temp.dtype)


# ----------------------------------------------------------- live recording
def make_live(problem: Mapping | None = None):
    """Recorder callable: ``t_block`` fused stencil steps on a fixed grid.
    Constraints bound to the problem size (divisibility, pyramid halo) are
    enforced by ``space(h, w)``; dtype/grid-order tunables are
    cost-model-only."""
    p = {**SMOKE_PROBLEM, **(problem or {})}
    t = jax.random.normal(jax.random.PRNGKey(p.get("seed", 3)),
                          (p["h"], p["w"]), jnp.float32)
    pw = jax.random.normal(jax.random.PRNGKey(p.get("seed", 3) + 1),
                           (p["h"], p["w"]), jnp.float32) * 0.1

    def fn(conf: Mapping) -> None:
        out = hotspot(t, pw, strip_h=conf["strip_h"], block_w=conf["block_w"],
                      t_block=conf["t_block"], interpret=True)
        jax.block_until_ready(out)

    return fn


# ------------------------------------------------------------ search space
def space(h: int = HUB_H, w: int = HUB_W) -> SearchSpace:
    tunables = tunables_from_dict({
        "strip_h": (8, 16, 32, 64, 128, 256, 512, 1024),
        "block_w": (128, 256, 512, 1024, 2048, 4096),
        "io_dtype": ("f32", "bf16"),
        "t_block": tuple(range(1, 17)),
        "acc_dtype": ("f32", "bf16"),
        "grid_order": ("row", "col"),
    })
    constraints = (
        Constraint(lambda c: h % c["strip_h"] == 0, "strip_h divides H"),
        Constraint(lambda c: w % c["block_w"] == 0, "block_w divides W"),
        Constraint(lambda c: 2 * c["t_block"] < c["strip_h"],
                   "pyramid halo must fit the strip"),
    )
    return SearchSpace(tunables, constraints, name="hotspot")


# -------------------------------------------------------------- cost model
def workload(h: int = HUB_H, w: int = HUB_W,
             steps: int = HUB_STEPS) -> KernelWorkload:
    def flops(c: Mapping) -> float:
        tb, sh, bw = c["t_block"], c["strip_h"], c["block_w"]
        # redundant pyramid compute: each fused step s processes
        # (sh + 2(tb-s))×(bw + 2(tb-s)) instead of sh×bw
        per_tile = sum((sh + 2 * (tb - s)) * (bw + 2 * (tb - s))
                       for s in range(1, tb + 1))
        n_tiles = (h // sh) * (w // bw)
        launches = -(-steps // tb)
        return 8.0 * per_tile * n_tiles * launches

    def hbm_bytes(c: Mapping, dev: DeviceModel) -> float:
        tb, sh, bw = c["t_block"], c["strip_h"], c["block_w"]
        halo_factor = ((sh + 2 * tb) / sh) * ((bw + 2 * tb) / bw)
        blk = (sh + 2 * tb) * (bw + 2 * tb) * BYTES
        byt = BYTES if c["io_dtype"] == "f32" else 2
        per_launch = (h * w * byt * 2 * halo_factor / dma_eff(blk)
                      + h * w * byt / dma_eff(sh * bw * byt))
        return per_launch * -(-steps // tb)

    def vmem_bytes(c: Mapping) -> float:
        tb, sh, bw = c["t_block"], c["strip_h"], c["block_w"]
        blk = (sh + 2 * tb) * (bw + 2 * tb) * BYTES
        return 2 * (2 * blk + sh * bw * BYTES) + blk  # T,P in, out, scratch

    def grid_size(c: Mapping) -> float:
        return ((h // c["strip_h"]) * (w // c["block_w"])
                * -(-steps // c["t_block"]))

    def compute_eff(c: Mapping, dev: DeviceModel) -> float:
        eff = (alignment_eff(c["strip_h"], dev.sublane)
               * alignment_eff(c["block_w"], dev.lane))
        eff *= 0.11  # VPU-bound stencil
        if c["acc_dtype"] == "bf16":
            eff *= 1.05
        if c["io_dtype"] == "bf16":
            eff *= 0.97  # conversion cost (but traffic halves)
        if c["grid_order"] == "col":
            eff *= 0.95
        return eff

    return KernelWorkload("hotspot", flops, hbm_bytes, vmem_bytes, grid_size,
                          compute_eff)
