"""2-D convolution stencil (benchmark-hub kernel; image filtering).

TPU adaptation: GPU implementations tune threads/block and shared-memory
staging of the halo. On TPU the analogue is **overlap decomposition**: the
input is pre-tiled into row strips *with halo* (a cheap gather done once in
the jit wrapper), so every Pallas program owns an independent (strip_h+fh-1,
W+fw-1) VMEM block and no overlapping BlockSpec is needed. Within a strip the
filter is applied as fh·fw shifted multiply-adds on the VPU, with a tunable
unroll of the filter-row loop and a tunable output column tile.

Tunables: strip_h (rows per program), block_w (output column tile),
unroll_fh (filter-row unroll), accumulate dtype.
"""
from __future__ import annotations

import functools
from typing import Mapping

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams

from ..core.costmodel import KernelWorkload, alignment_eff, dma_eff
from ..core.devices import DeviceModel
from ..core.searchspace import SearchSpace
from ..core.tunable import Constraint, tunables_from_dict

# Hub problem: 4096×4096 image, 17×17 filter (Kernel Tuner's conv benchmark)
HUB_H, HUB_W, HUB_FH, HUB_FW = 4096, 4096, 17, 17
BYTES = 4  # fp32 image

# Recording problem size (CPU interpret-mode live tuning)
SMOKE_PROBLEM = {"h": 128, "w": 256, "fh": 7, "fw": 7}


# ----------------------------------------------------------------- kernel
def _conv_kernel(x_ref, f_ref, out_ref, *, fh: int, fw: int, block_w: int):
    # x_ref: (1, strip_h+fh-1, block_w+fw-1); out_ref: (1, strip_h, block_w)
    x = x_ref[0]
    sh = out_ref.shape[1]
    acc = jnp.zeros((sh, block_w), jnp.float32)
    for dy in range(fh):
        for dx in range(fw):
            tile = x[dy:dy + sh, dx:dx + block_w]
            acc += tile.astype(jnp.float32) * f_ref[dy, dx]
    out_ref[0, ...] = acc.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("strip_h", "block_w", "interpret"))
def conv2d(x: jax.Array, f: jax.Array, *, strip_h: int = 64,
           block_w: int = 256, interpret: bool = False) -> jax.Array:
    """'Same'-padded 2-D convolution (cross-correlation, like the hub kernel).

    x: (H, W) image; f: (fh, fw) filter. strip_h must divide H, block_w must
    divide W.
    """
    h0, w0 = x.shape
    fh, fw = f.shape
    h = -(-h0 // strip_h) * strip_h
    w = -(-w0 // block_w) * block_w
    ph, pw = fh // 2, fw // 2
    xp = jnp.pad(x, ((ph, fh - 1 - ph + h - h0), (pw, fw - 1 - pw + w - w0)))
    # overlap decomposition: gather patches with halo in both dims (blocks
    # stride by their own shape, so overlapping BlockSpecs are not possible —
    # the halo is materialized once here instead)
    n_i, n_j = h // strip_h, w // block_w
    ii, jj = jnp.meshgrid(jnp.arange(n_i), jnp.arange(n_j), indexing="ij")

    def take(i, j):
        return jax.lax.dynamic_slice(
            xp, (i * strip_h, j * block_w),
            (strip_h + fh - 1, block_w + fw - 1))

    patches = jax.vmap(jax.vmap(take))(ii, jj).reshape(
        n_i * n_j, strip_h + fh - 1, block_w + fw - 1)

    kernel = functools.partial(_conv_kernel, fh=fh, fw=fw, block_w=block_w)
    out = pl.pallas_call(
        kernel,
        grid=(n_i * n_j,),
        in_specs=[
            pl.BlockSpec((1, strip_h + fh - 1, block_w + fw - 1),
                         lambda i: (i, 0, 0)),
            pl.BlockSpec((fh, fw), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, strip_h, block_w), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_i * n_j, strip_h, block_w), x.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(patches, f)
    return (out.reshape(n_i, n_j, strip_h, block_w)
               .transpose(0, 2, 1, 3).reshape(h, w))[:h0, :w0]


# -------------------------------------------------------------------- ref
def conv2d_ref(x: jax.Array, f: jax.Array, **_unused) -> jax.Array:
    """Pure-jnp oracle: same-padded cross-correlation."""
    fh, fw = f.shape
    ph, pw = fh // 2, fw // 2
    xp = jnp.pad(x, ((ph, fh - 1 - ph), (pw, fw - 1 - pw)))
    acc = jnp.zeros(x.shape, jnp.float32)
    for dy in range(fh):
        for dx in range(fw):
            acc += xp[dy:dy + x.shape[0], dx:dx + x.shape[1]].astype(jnp.float32) * f[dy, dx]
    return acc.astype(x.dtype)


# ----------------------------------------------------------- live recording
def make_live(problem: Mapping | None = None):
    """Recorder callable: same-padded conv on a fixed image/filter; the
    unroll/vector/accumulator tunables are cost-model-only."""
    p = {**SMOKE_PROBLEM, **(problem or {})}
    x = jax.random.normal(jax.random.PRNGKey(p.get("seed", 1)),
                          (p["h"], p["w"]), jnp.float32)
    f = jax.random.normal(jax.random.PRNGKey(p.get("seed", 1) + 1),
                          (p["fh"], p["fw"]), jnp.float32)

    def fn(conf: Mapping) -> None:
        out = conv2d(x, f, strip_h=conf["strip_h"], block_w=conf["block_w"],
                     interpret=True)
        jax.block_until_ready(out)

    return fn


# ------------------------------------------------------------ search space
def space(h: int = HUB_H, w: int = HUB_W, fh: int = HUB_FH,
          fw: int = HUB_FW) -> SearchSpace:
    tunables = tunables_from_dict({
        "strip_h": (8, 16, 24, 32, 48, 64, 80, 96, 128, 160, 192, 256, 384,
                    512),
        "block_w": (96, 128, 160, 256, 320, 512, 640, 1024, 1280, 2048, 4096),
        "unroll_fh": (1, 2, 4, 8, 17),
        "acc_dtype": ("f32", "bf16"),
        "vector_w": (128, 256, 512),       # VPU vectorization width hint
    })
    constraints = (
        Constraint(lambda c: c["vector_w"] <= c["block_w"],
                   "vector width within column tile"),
    )
    return SearchSpace(tunables, constraints, name="convolution")


# -------------------------------------------------------------- cost model
def workload(h: int = HUB_H, w: int = HUB_W, fh: int = HUB_FH,
             fw: int = HUB_FW) -> KernelWorkload:
    def _padded(c: Mapping):
        sh, bw = c["strip_h"], c["block_w"]
        return (-(-h // sh) * sh, -(-w // bw) * bw)

    def flops(c: Mapping) -> float:
        hp, wp = _padded(c)
        return 2.0 * hp * wp * fh * fw

    def hbm_bytes(c: Mapping, dev: DeviceModel) -> float:
        sh, bw = c["strip_h"], c["block_w"]
        hp, wp = _padded(c)
        # halo duplication in both dims + one write; small patches stream badly
        blk = (sh + fh - 1) * (bw + fw - 1) * BYTES
        reads = hp * wp * BYTES * ((sh + fh - 1) / sh) * ((bw + fw - 1) / bw)
        return reads / dma_eff(blk) + hp * wp * BYTES / dma_eff(sh * bw * BYTES)

    def vmem_bytes(c: Mapping) -> float:
        sh, bw = c["strip_h"], c["block_w"]
        acc = 4 if c["acc_dtype"] == "f32" else 2
        in_blk = (sh + fh - 1) * (bw + fw - 1) * BYTES
        out_blk = sh * bw * BYTES
        return 2 * (in_blk + out_blk) + sh * bw * acc

    def grid_size(c: Mapping) -> float:
        hp, wp = _padded(c)
        return (hp // c["strip_h"]) * (wp // c["block_w"])

    def compute_eff(c: Mapping, dev: DeviceModel) -> float:
        sh, bw = c["strip_h"], c["block_w"]
        eff = alignment_eff(sh, dev.sublane) * alignment_eff(bw, dev.lane)
        # conv runs on the VPU: peak is ~1/8 of MXU peak for this model
        eff *= 0.125
        # loop unrolling amortizes scalar overhead; too much spills
        unroll = c["unroll_fh"]
        eff *= {1: 0.72, 2: 0.85, 4: 1.0, 8: 0.97, 17: 0.88}[unroll]
        if c["acc_dtype"] == "bf16":
            eff *= 1.08  # fewer register bytes, slightly better issue rate
        # vector width: full-lane vectors best
        eff *= {128: 1.0, 256: 0.99, 512: 0.96}[c["vector_w"]]
        return eff

    return KernelWorkload("convolution", flops, hbm_bytes, vmem_bytes,
                          grid_size, compute_eff)
