"""Pallas TPU kernels with tunable BlockSpec tilings.

Four benchmark-hub kernels (the paper's applications: dedispersion,
convolution, hotspot, GEMM) plus the framework's own hot spots (flash
attention, Mamba2 SSD). Each module provides: the ``pl.pallas_call`` kernel,
a jit'd wrapper, a pure-jnp oracle (``*_ref``), a tunable ``space()``, an
analytic ``workload()`` for the cost model, and a recording contract
(``SMOKE_PROBLEM`` + ``make_live``) that turns the kernel into a live
interpret-mode objective the recorder (``core.record``) can measure.

``KERNELS``/``get_kernel`` is the registry the record→merge→replay pipeline
resolves kernels through: every registered kernel is a simulation scenario —
record it once (live on CPU/device or via a cost model), then replay the
cache through thousands of hypertuning campaigns.

The ``HUB_KERNELS``/``FRAMEWORK_KERNELS`` tiers only say how each kernel's
hub data is *produced*: hub-tier spaces are brute-forced across all six
device models by ``build_hub``; framework-tier kernels enter the hub as
recorded campaigns (their committed ``SMOKE_PROBLEM`` interpret-mode
entries, plus whatever the scenario fleet records). All six are equally
first-class to lookup — any (kernel, shape, device) triple the hub lacks
a measurement for is served by the roofline surrogate
(``repro.scenarios``).
"""
from __future__ import annotations

import dataclasses
import inspect
from types import ModuleType
from typing import Callable, Mapping

from ..core.costmodel import KernelWorkload
from ..core.searchspace import SearchSpace
from . import (convolution, dedispersion, flash_attention, gemm, hotspot,
               ssd)

# registry used by the hub builder and the autotune layer
HUB_KERNELS = {
    "dedispersion": dedispersion,
    "convolution": convolution,
    "hotspot": hotspot,
    "gemm": gemm,
}

FRAMEWORK_KERNELS = {
    "flash_attention": flash_attention,
    "ssd": ssd,
}

ALL_KERNELS = {**HUB_KERNELS, **FRAMEWORK_KERNELS}


def _accepted(fn: Callable, problem: Mapping) -> dict:
    """Restrict a problem dict to the keyword arguments ``fn`` declares —
    problem dicts carry the union of space/workload/input sizes (e.g. flash
    attention's ``space(seq, d)`` vs its ``workload(bh, seq, d)``)."""
    params = inspect.signature(fn).parameters
    return {k: v for k, v in problem.items() if k in params}


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """Registry view of one kernel module for the recording pipeline.

    ``problem`` dicts override the module's ``SMOKE_PROBLEM`` (the
    CPU-interpret-affordable default); constraints that depend on problem
    sizes (divisibility, halo fit) adapt because the module's ``space()``
    is re-invoked with the resolved sizes.
    """

    name: str
    module: ModuleType
    tier: str  # "hub" | "framework"

    def problem(self, overrides: Mapping | None = None) -> dict:
        return {**self.module.SMOKE_PROBLEM, **(overrides or {})}

    def space(self, problem: Mapping | None = None) -> SearchSpace:
        p = self.problem(problem)
        return self.module.space(**_accepted(self.module.space, p))

    def workload(self, problem: Mapping | None = None) -> KernelWorkload:
        p = self.problem(problem)
        return self.module.workload(**_accepted(self.module.workload, p))

    def make_live(self, problem: Mapping | None = None) -> Callable:
        """Interpret-mode ``fn(config_dict)`` over fixed inputs, for a
        ``LiveRunner``. Built inside the worker that uses it (the closure
        holds jax arrays and is not picklable)."""
        return self.module.make_live(self.problem(problem))


KERNELS: dict[str, KernelSpec] = {
    name: KernelSpec(name, mod,
                     "hub" if name in HUB_KERNELS else "framework")
    for name, mod in ALL_KERNELS.items()
}


def get_kernel(name: str) -> KernelSpec:
    try:
        return KERNELS[name]
    except KeyError:
        raise KeyError(
            f"unknown kernel {name!r}; registered: {sorted(KERNELS)}")
