"""Pallas TPU kernels with tunable BlockSpec tilings.

Four benchmark-hub kernels (the paper's applications: dedispersion,
convolution, hotspot, GEMM) plus the framework's own hot spots (flash
attention, Mamba2 SSD). Each module provides: the ``pl.pallas_call`` kernel,
a jit'd wrapper, a pure-jnp oracle (``*_ref``), a tunable ``space()`` and an
analytic ``workload()`` for the cost model.
"""
from __future__ import annotations

from . import (convolution, dedispersion, flash_attention, gemm, hotspot,
               ssd)

# registry used by the hub builder and the autotune layer
HUB_KERNELS = {
    "dedispersion": dedispersion,
    "convolution": convolution,
    "hotspot": hotspot,
    "gemm": gemm,
}

FRAMEWORK_KERNELS = {
    "flash_attention": flash_attention,
    "ssd": ssd,
}

ALL_KERNELS = {**HUB_KERNELS, **FRAMEWORK_KERNELS}
