"""Flash attention (forward) — fused online-softmax attention for TPU.

The framework's training/prefill hot spot. Pallas kernel with tunable
``block_q`` × ``block_kv`` VMEM tiling, causal and sliding-window masking,
and GQA (kv-head sharing) via the index map. Fully-masked KV blocks are
skipped through the grid bound, not branches, by iterating only the lower
triangle when causal.

The pure-jnp oracle is the blockwise attention used by the model stack
(models/attention.py implements the same math with lax.scan so the compiled
graph is memory-sublinear in sequence length as well).

Tunables (autotune space): block_q, block_kv, accumulator dtype.
"""
from __future__ import annotations

import functools
from typing import Mapping

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams

from ..core.costmodel import KernelWorkload, alignment_eff
from ..core.devices import DeviceModel
from ..core.searchspace import SearchSpace
from ..core.tunable import Constraint, tunables_from_dict

NEG_INF = -1e30

# Recording problem size (CPU interpret-mode live tuning): 4 q heads over a
# GQA group of 2, short sequence
SMOKE_PROBLEM = {"bh": 4, "bh_kv": 2, "seq": 256, "d": 64}


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                 block_q: int, block_kv: int, n_kv: int, causal: bool,
                 window: int | None, scale: float):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                      # (block_q, d)
    k = k_ref[0]                      # (block_kv, d)
    v = v_ref[0]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    kv_pos = ki * block_kv + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = jnp.ones(s.shape, jnp.bool_)
    if causal:
        mask &= q_pos >= kv_pos
    if window is not None:
        mask &= (q_pos - kv_pos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
    acc_ref[...] = (acc_ref[...] * alpha[:, None]
                    + jax.lax.dot(p.astype(v.dtype), v,
                                  preferred_element_type=jnp.float32))
    m_ref[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _emit():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_q", "block_kv", "causal",
                                             "window", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    block_q: int = 128, block_kv: int = 128,
                    causal: bool = True, window: int | None = None,
                    interpret: bool = False) -> jax.Array:
    """q: (BH, S, D); k/v: (BH_kv, S, D) with BH % BH_kv == 0 (GQA).

    Heads are pre-flattened into the leading dim; the kv index map folds the
    GQA group so each q head reads its shared kv head.
    """
    bh, s, d = q.shape
    bh_kv = k.shape[0]
    assert bh % bh_kv == 0
    group = bh // bh_kv
    assert s % block_q == 0 and s % block_kv == 0
    n_kv = s // block_kv
    scale = 1.0 / (d ** 0.5)

    kernel = functools.partial(_attn_kernel, block_q=block_q,
                               block_kv=block_kv, n_kv=n_kv, causal=causal,
                               window=window, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(bh, s // block_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, block_kv, d), lambda h, i, j, g=group: (h // g, j, 0)),
            pl.BlockSpec((1, block_kv, d), lambda h, i, j, g=group: (h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)


# -------------------------------------------------------------------- ref
def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int | None = None,
                  **_unused) -> jax.Array:
    """Pure-jnp oracle (materializes S×S — test sizes only)."""
    bh, s, d = q.shape
    bh_kv = k.shape[0]
    group = bh // bh_kv
    kf = jnp.repeat(k, group, axis=0)
    vf = jnp.repeat(v, group, axis=0)
    logits = jnp.einsum("hqd,hkd->hqk", q.astype(jnp.float32),
                        kf.astype(jnp.float32)) / (d ** 0.5)
    q_pos = jnp.arange(s)[:, None]
    kv_pos = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= q_pos >= kv_pos
    if window is not None:
        mask &= (q_pos - kv_pos) < window
    logits = jnp.where(mask, logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("hqk,hkd->hqd", p, vf.astype(jnp.float32)).astype(q.dtype)


# ------------------------------------------------------------ search space
def make_live(problem: Mapping | None = None):
    """Recorder callable: causal GQA attention on fixed q/k/v; the
    accumulator-dtype tunable is cost-model-only."""
    p = {**SMOKE_PROBLEM, **(problem or {})}
    ks = jax.random.split(jax.random.PRNGKey(p.get("seed", 6)), 3)
    q = jax.random.normal(ks[0], (p["bh"], p["seq"], p["d"]), jnp.float32)
    k = jax.random.normal(ks[1], (p["bh_kv"], p["seq"], p["d"]), jnp.float32)
    v = jax.random.normal(ks[2], (p["bh_kv"], p["seq"], p["d"]), jnp.float32)

    def fn(conf: Mapping) -> None:
        out = flash_attention(q, k, v, block_q=conf["block_q"],
                              block_kv=conf["block_kv"], causal=True,
                              interpret=True)
        jax.block_until_ready(out)

    return fn


def space(seq: int = 4096, d: int = 128) -> SearchSpace:
    tunables = tunables_from_dict({
        "block_q": (64, 128, 256, 512, 1024),
        "block_kv": (128, 256, 512, 1024, 2048),
        "acc_dtype": ("f32", "bf16"),
    })
    constraints = (
        Constraint(lambda c: seq % c["block_q"] == 0, "block_q divides S"),
        Constraint(lambda c: seq % c["block_kv"] == 0, "block_kv divides S"),
    )
    return SearchSpace(tunables, constraints, name="flash_attention")


def workload(bh: int = 32, seq: int = 4096, d: int = 128,
             causal: bool = True) -> KernelWorkload:
    frac = 0.5 if causal else 1.0  # causal halves useful work

    def flops(c: Mapping) -> float:
        return 4.0 * bh * seq * seq * d * frac  # qk^T + pv

    def hbm_bytes(c: Mapping, dev: DeviceModel) -> float:
        bq, bkv = c["block_q"], c["block_kv"]
        # k/v streamed once per q block
        kv_reads = 2 * bh * seq * d * 2 * (seq // bq) * frac
        qo = 2 * bh * seq * d * 2
        return kv_reads + qo

    def vmem_bytes(c: Mapping) -> float:
        bq, bkv = c["block_q"], c["block_kv"]
        acc = 4 if c["acc_dtype"] == "f32" else 2
        return (2 * (bq * d + 2 * bkv * d + bq * d) * 2
                + bq * d * acc + bq * bkv * 4 + 2 * bq * 4)

    def grid_size(c: Mapping) -> float:
        return bh * (seq // c["block_q"]) * (seq // c["block_kv"]) * frac

    def compute_eff(c: Mapping, dev: DeviceModel) -> float:
        bq, bkv = c["block_q"], c["block_kv"]
        eff = alignment_eff(bq, dev.mxu) * alignment_eff(bkv, dev.lane)
        eff *= min(1.0, bkv / dev.mxu) ** 0.5
        if c["acc_dtype"] == "bf16":
            eff *= 0.9  # extra rescaling passes
        return 0.75 * eff  # softmax/VPU overhead between the two matmuls

    return KernelWorkload("flash_attention", flops, hbm_bytes, vmem_bytes,
                          grid_size, compute_eff)
