"""GEMM — C = alpha·A·B + beta·C (benchmark-hub kernel, CLBlast analogue).

Pallas TPU kernel with tunable BlockSpec tiling (block_m/n/k) and grid order.
The MXU wants 128-aligned tiles; the search space deliberately includes
misaligned and VMEM-overflowing configurations, because real auto-tuning
spaces contain them (the cost model penalizes/invalidates those, the Pallas
kernel itself is validated on the aligned subset in interpret mode).

TPU adaptation of the paper's GPU GEMM space: instead of threads-per-block /
shared-memory staging, the tunables are VMEM tile shapes and the K-loop
placement (innermost "arbitrary" grid dim accumulating into a VMEM scratch).
"""
from __future__ import annotations

import functools
from typing import Mapping

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams

from ..core.costmodel import KernelWorkload, alignment_eff, dma_eff
from ..core.devices import DeviceModel
from ..core.searchspace import SearchSpace
from ..core.tunable import tunables_from_dict

# Hub problem size (dense square GEMM, bf16 in / fp32 accumulate)
HUB_M, HUB_N, HUB_K = 4096, 4096, 4096
BYTES = 2  # bf16

# Recording problem size: small enough that a CPU interpret-mode evaluation
# takes milliseconds, so live-recording a tuning run is affordable
SMOKE_PROBLEM = {"m": 128, "n": 128, "k": 128}


# ----------------------------------------------------------------- kernel
def _gemm_kernel(a_ref, b_ref, c0_ref, out_ref, acc_ref, *, n_k: int,
                 alpha: float, beta: float):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _emit():
        out_ref[...] = (alpha * acc_ref[...]
                        + beta * c0_ref[...].astype(jnp.float32)
                        ).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "alpha", "beta", "interpret"))
def gemm(a: jax.Array, b: jax.Array, c0: jax.Array, *, block_m: int = 128,
         block_n: int = 128, block_k: int = 128, alpha: float = 1.0,
         beta: float = 1.0, interpret: bool = False) -> jax.Array:
    """Tiled Pallas GEMM. Non-dividing blocks are zero-padded (and the
    padding waste is what the cost model charges for them)."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2 and c0.shape == (m, n)
    m0, n0 = m, n
    mp = -(-m // block_m) * block_m
    np_ = -(-n // block_n) * block_n
    kp = -(-k // block_k) * block_k
    if (mp, np_, kp) != (m, n, k):
        a = jnp.pad(a, ((0, mp - m), (0, kp - k)))
        b = jnp.pad(b, ((0, kp - k), (0, np_ - n)))
        c0 = jnp.pad(c0, ((0, mp - m), (0, np_ - n)))
    m, n, k = mp, np_, kp
    n_k = k // block_k
    kernel = functools.partial(_gemm_kernel, n_k=n_k, alpha=alpha, beta=beta)
    return pl.pallas_call(
        kernel,
        grid=(m // block_m, n // block_n, n_k),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, b, c0)[:m0, :n0]


# -------------------------------------------------------------------- ref
def gemm_ref(a: jax.Array, b: jax.Array, c0: jax.Array, *, alpha: float = 1.0,
             beta: float = 1.0, **_unused) -> jax.Array:
    """Pure-jnp oracle."""
    acc = jnp.dot(a, b, preferred_element_type=jnp.float32)
    return (alpha * acc + beta * c0.astype(jnp.float32)).astype(a.dtype)


# ----------------------------------------------------------- live recording
def make_live(problem: Mapping | None = None):
    """Interpret-mode evaluation callable for the recorder: fixed inputs,
    ``fn(config_dict)`` runs the Pallas kernel with that tiling and blocks
    until ready. Tunables the TPU wrapper does not consume (grid order,
    accumulator dtype) are cost-model-only and ignored here."""
    p = {**SMOKE_PROBLEM, **(problem or {})}
    ks = jax.random.split(jax.random.PRNGKey(p.get("seed", 0)), 3)
    a = jax.random.normal(ks[0], (p["m"], p["k"]), jnp.float32).astype(jnp.bfloat16)
    b = jax.random.normal(ks[1], (p["k"], p["n"]), jnp.float32).astype(jnp.bfloat16)
    c0 = jax.random.normal(ks[2], (p["m"], p["n"]), jnp.float32).astype(jnp.bfloat16)

    def fn(conf: Mapping) -> None:
        out = gemm(a, b, c0, block_m=conf["block_m"], block_n=conf["block_n"],
                   block_k=conf["block_k"], interpret=True)
        jax.block_until_ready(out)

    return fn


# ------------------------------------------------------------ search space
def space(m: int = HUB_M, n: int = HUB_N, k: int = HUB_K) -> SearchSpace:
    tunables = tunables_from_dict({
        "block_m": (8, 16, 24, 32, 48, 64, 96, 128, 160, 192, 256, 320, 384,
                    448, 512),
        "block_n": (64, 96, 128, 160, 192, 256, 320, 384, 512, 640, 768, 896,
                    1024),
        "block_k": (32, 48, 64, 96, 128, 192, 256, 384, 512, 768, 1024, 1536,
                    2048),
        "grid_order": ("mn", "nm"),          # output-stationary sweep order
        "acc_dtype": ("f32", "bf16"),        # accumulator precision
    })
    # non-dividing blocks are legal (zero-padded) — the padding waste is
    # costed, so the space is rich in mediocre configurations, like real
    # auto-tuning spaces.
    return SearchSpace(tunables, (), name="gemm")


# -------------------------------------------------------------- cost model
def workload(m: int = HUB_M, n: int = HUB_N, k: int = HUB_K) -> KernelWorkload:
    def _padded(c: Mapping):
        bm, bn, bk = c["block_m"], c["block_n"], c["block_k"]
        return (-(-m // bm) * bm, -(-n // bn) * bn, -(-k // bk) * bk)

    def flops(c: Mapping) -> float:
        mp, np_, kp = _padded(c)
        return 2.0 * mp * np_ * kp + 3.0 * mp * np_  # incl. padding waste

    def hbm_bytes(c: Mapping, dev: DeviceModel) -> float:
        bm, bn, bk = c["block_m"], c["block_n"], c["block_k"]
        mp, np_, kp = _padded(c)
        # A is re-read for every N-tile, B for every M-tile; C0/out once.
        n_m, n_n = mp // bm, np_ // bn
        a_reads = mp * kp * BYTES * n_n / dma_eff(bm * bk * BYTES)
        b_reads = kp * np_ * BYTES * n_m / dma_eff(bk * bn * BYTES)
        c_traffic = 2 * mp * np_ * BYTES / dma_eff(bm * bn * BYTES)
        return a_reads + b_reads + c_traffic

    def vmem_bytes(c: Mapping) -> float:
        bm, bn, bk = c["block_m"], c["block_n"], c["block_k"]
        acc = 4 if c["acc_dtype"] == "f32" else 2
        # double-buffered in/out blocks + accumulator scratch
        return 2 * (bm * bk + bk * bn + 2 * bm * bn) * BYTES + bm * bn * acc

    def grid_size(c: Mapping) -> float:
        mp, np_, kp = _padded(c)
        return ((mp // c["block_m"]) * (np_ // c["block_n"])
                * (kp // c["block_k"]))

    def compute_eff(c: Mapping, dev: DeviceModel) -> float:
        bm, bn, bk = c["block_m"], c["block_n"], c["block_k"]
        eff = (alignment_eff(bm, dev.sublane)
               * alignment_eff(bn, dev.lane)
               * alignment_eff(bk, dev.lane))
        # MXU likes >= mxu-sized matmul dims; smaller tiles underfill it
        eff *= min(1.0, bm / dev.mxu) ** 0.5
        # bf16 accumulate halves epilogue traffic but costs extra passes on
        # the MXU for large K (numerical chunking): mild penalty
        if c["acc_dtype"] == "bf16":
            eff *= 0.92
        # "nm" order is slightly worse for row-major A prefetch
        if c["grid_order"] == "nm":
            eff *= 0.97
        return eff

    return KernelWorkload("gemm", flops, hbm_bytes, vmem_bytes, grid_size,
                          compute_eff)
