"""Mamba2 SSD (state-space duality) chunked scan — TPU Pallas kernel.

The attention-free hot spot for mamba2/zamba2. Implements the SSD chunked
algorithm (Dao & Gu, arXiv:2405.21060) for one head group:

    h_t = exp(dt_t·A) · h_{t-1} + dt_t · B_t ⊗ x_t          (state update)
    y_t = C_t · h_t                                          (readout)

Chunked over the sequence: within a chunk of Q steps the output splits into
an *intra-chunk* quadratic term ((C Bᵀ) ∘ decay-mask) X — two MXU matmuls —
and an *inter-chunk* term C · (decay · h_in); the carried state is updated
with a third matmul. The chunk loop is the innermost ("arbitrary") grid dim
with the state in VMEM scratch — the TPU-native replacement for the paper's
GPU warp-level scan.

Tunables: chunk length Q, state block, accumulate dtype.
"""
from __future__ import annotations

import functools
from typing import Mapping

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams

from ..core.costmodel import KernelWorkload, alignment_eff
from ..core.devices import DeviceModel
from ..core.searchspace import SearchSpace
from ..core.tunable import Constraint, tunables_from_dict

# Recording problem size (CPU interpret-mode live tuning)
SMOKE_PROBLEM = {"bh": 4, "seq": 256, "p": 32, "n": 32}


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, h_ref, *,
                chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0].astype(jnp.float32)     # (Q, P)
    dt = dt_ref[0].astype(jnp.float32)   # (Q,)
    a = a_ref[0]                          # scalar A (negative)
    b = b_ref[0].astype(jnp.float32)     # (Q, N)
    c = c_ref[0].astype(jnp.float32)     # (Q, N)

    log_decay = dt * a                    # (Q,) log per-step decay
    cum = jnp.cumsum(log_decay)           # (Q,) cumulative within chunk
    # intra-chunk: mask[i,j] = exp(cum_i - cum_j) for j <= i (strict decay
    # between step j and i), scaled by dt_j
    li = cum[:, None] - cum[None, :]
    iota_i = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    iota_j = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    mask = iota_i >= iota_j
    decay_ij = jnp.where(mask, jnp.exp(li), 0.0)
    cb = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q, Q)
    w = cb * decay_ij * dt[None, :]
    y_intra = jax.lax.dot(w, x, preferred_element_type=jnp.float32)

    # inter-chunk: y_inter_i = exp(cum_i) * C_i · h_in
    h_in = h_ref[...]                     # (N, P)
    y_inter = jnp.exp(cum)[:, None] * jax.lax.dot(
        c, h_in, preferred_element_type=jnp.float32)

    y_ref[0] = (y_intra + y_inter).astype(y_ref.dtype)

    # state update: h_out = exp(total) * h_in + Σ_j exp(total - cum_j)·dt_j·B_j⊗X_j
    total = cum[-1]
    suffix = jnp.exp(total - cum) * dt    # (Q,)
    bx = jax.lax.dot_general(b * suffix[:, None], x, (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (N, P)
    h_ref[...] = jnp.exp(total) * h_in + bx


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
             c: jax.Array, *, chunk: int = 128,
             interpret: bool = False) -> jax.Array:
    """SSD scan for flattened (batch·heads) leading dim.

    x: (BH, L, P); dt: (BH, L); a: (BH,); b/c: (BH, L, N). Returns y like x.
    """
    bh, l, p = x.shape
    n = b.shape[-1]
    assert l % chunk == 0
    n_chunks = l // chunk
    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(bh, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, p), lambda h, i: (h, i, 0)),
            pl.BlockSpec((1, chunk), lambda h, i: (h, i)),
            pl.BlockSpec((1,), lambda h, i: (h,)),
            pl.BlockSpec((1, chunk, n), lambda h, i: (h, i, 0)),
            pl.BlockSpec((1, chunk, n), lambda h, i: (h, i, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, p), lambda h, i: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt, a, b, c)


# -------------------------------------------------------------------- ref
def ssd_ref(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
            c: jax.Array, **_unused) -> jax.Array:
    """Sequential oracle: literal recurrence, step by step."""
    bh, l, p = x.shape
    n = b.shape[-1]

    def step(h, inputs):
        x_t, dt_t, b_t, c_t = inputs  # (BH,P), (BH,), (BH,N), (BH,N)
        decay = jnp.exp(dt_t * a)     # (BH,)
        h = (decay[:, None, None] * h
             + dt_t[:, None, None] * b_t[:, :, None] * x_t[:, None, :])
        y_t = jnp.einsum("bnp,bn->bp", h, c_t)
        return h, y_t

    h0 = jnp.zeros((bh, n, p), jnp.float32)
    xs = (x.transpose(1, 0, 2).astype(jnp.float32),
          dt.transpose(1, 0).astype(jnp.float32),
          b.transpose(1, 0, 2).astype(jnp.float32),
          c.transpose(1, 0, 2).astype(jnp.float32))
    _, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2).astype(x.dtype)


# ------------------------------------------------------------ search space
def make_live(problem: Mapping | None = None):
    """Recorder callable: chunked SSD scan on fixed inputs; state_block and
    accumulator-dtype tunables are cost-model-only."""
    p = {**SMOKE_PROBLEM, **(problem or {})}
    ks = jax.random.split(jax.random.PRNGKey(p.get("seed", 9)), 5)
    bh, l = p["bh"], p["seq"]
    x = jax.random.normal(ks[0], (bh, l, p["p"]), jnp.float32)
    dt = jax.random.uniform(ks[1], (bh, l), jnp.float32, 0.001, 0.1)
    a = -jax.random.uniform(ks[2], (bh,), jnp.float32, 0.5, 1.5)
    b = jax.random.normal(ks[3], (bh, l, p["n"]), jnp.float32)
    c = jax.random.normal(ks[4], (bh, l, p["n"]), jnp.float32)

    def fn(conf: Mapping) -> None:
        out = ssd_scan(x, dt, a, b, c, chunk=conf["chunk"], interpret=True)
        jax.block_until_ready(out)

    return fn


def space(seq: int = 4096) -> SearchSpace:
    tunables = tunables_from_dict({
        "chunk": (32, 64, 128, 256, 512),
        "acc_dtype": ("f32", "bf16"),
        "state_block": (32, 64, 128),
    })
    constraints = (
        Constraint(lambda c: seq % c["chunk"] == 0, "chunk divides L"),
        Constraint(lambda c: c["state_block"] <= 128, "state fits a tile"),
    )
    return SearchSpace(tunables, constraints, name="ssd")


def workload(bh: int = 24 * 8, seq: int = 4096, p: int = 64,
             n: int = 128) -> KernelWorkload:
    def flops(c: Mapping) -> float:
        q = c["chunk"]
        per_chunk = 2 * q * q * n + 2 * q * q * p + 4 * q * n * p
        return bh * (seq // q) * per_chunk

    def hbm_bytes(c: Mapping, dev: DeviceModel) -> float:
        return bh * seq * (p + 2 * n + 1) * 2 * 2  # in+out streams, bf16

    def vmem_bytes(c: Mapping) -> float:
        q = c["chunk"]
        acc = 4 if c["acc_dtype"] == "f32" else 2
        return (2 * (q * p + 2 * q * n + q) * 2 + q * q * acc + n * p * 4
                + q * p * acc)

    def grid_size(c: Mapping) -> float:
        return bh * (seq // c["chunk"])

    def compute_eff(c: Mapping, dev: DeviceModel) -> float:
        q = c["chunk"]
        eff = alignment_eff(q, dev.mxu) * alignment_eff(n, dev.lane)
        eff *= min(1.0, q / dev.mxu) ** 0.5
        if c["acc_dtype"] == "bf16":
            eff *= 0.93
        eff *= {32: 0.9, 64: 1.0, 128: 1.0}[c["state_block"]]
        return 0.7 * eff  # cumsum/exp VPU work between matmuls

    return KernelWorkload("ssd", flops, hbm_bytes, vmem_bytes, grid_size,
                          compute_eff)
