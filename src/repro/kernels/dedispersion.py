"""Dedispersion — radio-astronomy signal reconstruction (benchmark-hub kernel).

out[dm, t] = Σ_c x[c, t + delay[c, dm]] — a bandwidth-bound gather-reduce.
GPU implementations tune thread tiles over (dm, time) and channel chunking;
the TPU adaptation tiles (dm, time) over the grid with the channel loop
inside the kernel, using per-(channel, dm-tile) dynamic slices of a
VMEM-resident channel block. Delay table is precomputed (as real pipelines
do) and passed as scalar-prefetch-style operand.

Tunables: block_dm, block_t (output tile), chan_chunk (channels per inner
accumulation round), delay layout.
"""
from __future__ import annotations

import functools
from typing import Mapping

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams

from ..core.costmodel import KernelWorkload, alignment_eff, dma_eff
from ..core.devices import DeviceModel
from ..core.searchspace import SearchSpace
from ..core.tunable import Constraint, tunables_from_dict

# Hub problem: 256 channels, 16384 samples, 256 dispersion measures
HUB_NCHAN, HUB_NTIME, HUB_NDM = 256, 16384, 256
BYTES = 4
MAX_DELAY = 512  # delay table values are in [0, MAX_DELAY)

# Recording problem size (CPU interpret-mode live tuning); ntime includes
# the MAX_DELAY halo the wrapper slices off
SMOKE_PROBLEM = {"nchan": 32, "ntime": 768 + MAX_DELAY, "ndm": 24}


def make_delays(nchan: int = HUB_NCHAN, ndm: int = HUB_NDM,
                max_delay: int = MAX_DELAY) -> jax.Array:
    """Quadratic-in-frequency dispersion delays (int32), shape (nchan, ndm)."""
    c = jnp.arange(nchan, dtype=jnp.float32)[:, None] / nchan
    d = jnp.arange(ndm, dtype=jnp.float32)[None, :] / ndm
    delays = (max_delay - 1) * d * (1.0 / (0.25 + 0.75 * (1 - c)) ** 2 - 1.0) / 15.0
    return jnp.clip(delays.astype(jnp.int32), 0, max_delay - 1)


# ----------------------------------------------------------------- kernel
def _dedisp_kernel(delay_ref, x_ref, out_ref, *, nchan: int, block_dm: int,
                   block_t: int):
    # x_ref: (1, nchan, block_t + MAX_DELAY); delay_ref: (nchan, block_dm)
    # out_ref: (block_dm, block_t)
    acc = jnp.zeros((block_dm, block_t), jnp.float32)

    def chan_body(c, acc):
        row = x_ref[0, c, :]

        def dm_body(i, acc):
            off = delay_ref[c, i]
            seg = jax.lax.dynamic_slice(row, (off,), (block_t,))
            return acc.at[i, :].add(seg.astype(jnp.float32))

        return jax.lax.fori_loop(0, block_dm, dm_body, acc)

    acc = jax.lax.fori_loop(0, nchan, chan_body, acc)
    out_ref[...] = acc.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_dm", "block_t", "interpret"))
def dedisperse(x: jax.Array, delays: jax.Array, *, block_dm: int = 32,
               block_t: int = 512, interpret: bool = False) -> jax.Array:
    """x: (nchan, ntime) padded so gathers stay in range; delays: (nchan, ndm).

    Output: (ndm, ntime - MAX_DELAY).
    """
    nchan, ntime = x.shape
    nchan2, ndm = delays.shape
    assert nchan == nchan2
    nt_out0 = ntime - MAX_DELAY
    ndm0 = ndm
    nt_out = -(-nt_out0 // block_t) * block_t
    ndm = -(-ndm // block_dm) * block_dm
    if nt_out != nt_out0:
        x = jnp.pad(x, ((0, 0), (0, nt_out - nt_out0)))
    if ndm != ndm0:
        delays = jnp.pad(delays, ((0, 0), (0, ndm - ndm0)))

    # pre-tile time strips with MAX_DELAY halo (BlockSpecs cannot overlap)
    n_t = nt_out // block_t
    strips = jax.vmap(
        lambda j: jax.lax.dynamic_slice(
            x, (0, j * block_t), (nchan, block_t + MAX_DELAY))
    )(jnp.arange(n_t))  # (n_t, nchan, block_t + MAX_DELAY)

    kernel = functools.partial(_dedisp_kernel, nchan=nchan, block_dm=block_dm,
                               block_t=block_t)
    return pl.pallas_call(
        kernel,
        grid=(ndm // block_dm, n_t),
        in_specs=[
            pl.BlockSpec((nchan, block_dm), lambda i, j: (0, i)),
            pl.BlockSpec((1, nchan, block_t + MAX_DELAY),
                         lambda i, j: (j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_dm, block_t), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((ndm, nt_out), x.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(delays, strips)[:ndm0, :nt_out0]


# -------------------------------------------------------------------- ref
def dedisperse_ref(x: jax.Array, delays: jax.Array, **_unused) -> jax.Array:
    """Pure-jnp oracle."""
    nchan, ntime = x.shape
    _, ndm = delays.shape
    nt_out = ntime - MAX_DELAY
    t_idx = jnp.arange(nt_out)

    def one_dm(dm):
        # sum over channels of x[c, t + delay[c, dm]]
        idx = t_idx[None, :] + delays[:, dm][:, None]  # (nchan, nt_out)
        gathered = jnp.take_along_axis(x, idx, axis=1)
        return gathered.astype(jnp.float32).sum(axis=0)

    out = jax.vmap(one_dm)(jnp.arange(ndm))
    return out.astype(x.dtype)


# ----------------------------------------------------------- live recording
def make_live(problem: Mapping | None = None):
    """Recorder callable: fixed signal + delay table; chan_chunk/layout/
    unroll tunables are cost-model-only."""
    p = {**SMOKE_PROBLEM, **(problem or {})}
    x = jax.random.normal(jax.random.PRNGKey(p.get("seed", 5)),
                          (p["nchan"], p["ntime"]), jnp.float32)
    delays = make_delays(p["nchan"], p["ndm"])

    def fn(conf: Mapping) -> None:
        out = dedisperse(x, delays, block_dm=conf["block_dm"],
                         block_t=conf["block_t"], interpret=True)
        jax.block_until_ready(out)

    return fn


# ------------------------------------------------------------ search space
def space(nchan: int = HUB_NCHAN, ntime: int = HUB_NTIME,
          ndm: int = HUB_NDM) -> SearchSpace:
    nt_out = ntime - MAX_DELAY
    tunables = tunables_from_dict({
        "block_dm": (1, 2, 4, 8, 12, 16, 24, 32, 48, 64, 96, 128),
        "block_t": (128, 192, 256, 384, 512, 768, 1024, 1536, 2048, 3968),
        "chan_chunk": (8, 16, 32, 64, 128, 256),
        "delay_layout": ("dm_major", "chan_major"),
        "time_unroll": (1, 2, 4),
    })
    constraints = (
        Constraint(lambda c: nchan % c["chan_chunk"] == 0,
                   "chan_chunk divides channels"),
    )
    return SearchSpace(tunables, constraints, name="dedispersion")


# -------------------------------------------------------------- cost model
def workload(nchan: int = HUB_NCHAN, ntime: int = HUB_NTIME,
             ndm: int = HUB_NDM) -> KernelWorkload:
    nt_out = ntime - MAX_DELAY

    def _padded(c: Mapping):
        bdm, bt = c["block_dm"], c["block_t"]
        return (-(-ndm // bdm) * bdm, -(-nt_out // bt) * bt)

    def flops(c: Mapping) -> float:
        ndm_p, nt_p = _padded(c)
        return 1.0 * nchan * ndm_p * nt_p  # adds only

    def hbm_bytes(c: Mapping, dev: DeviceModel) -> float:
        bt = c["block_t"]
        ndm_p, nt_p = _padded(c)
        # channel block re-read per dm-tile; halo MAX_DELAY per time tile
        n_dm_tiles = ndm_p // c["block_dm"]
        x_blk = nchan * (bt + MAX_DELAY) * BYTES
        x_reads = (nchan * (bt + MAX_DELAY) * BYTES * n_dm_tiles
                   * (nt_p // bt) / dma_eff(x_blk))
        out_write = ndm_p * nt_p * BYTES / dma_eff(
            c["block_dm"] * c["block_t"] * BYTES)
        delay_reads = nchan * ndm_p * 4
        return x_reads + out_write + delay_reads

    def vmem_bytes(c: Mapping) -> float:
        bdm, bt = c["block_dm"], c["block_t"]
        x_blk = nchan * (bt + MAX_DELAY) * BYTES
        return 2 * (x_blk + nchan * bdm * 4) + bdm * bt * (4 + BYTES)

    def grid_size(c: Mapping) -> float:
        ndm_p, nt_p = _padded(c)
        return (ndm_p // c["block_dm"]) * (nt_p // c["block_t"])

    def compute_eff(c: Mapping, dev: DeviceModel) -> float:
        eff = (alignment_eff(c["block_dm"], dev.sublane)
               * alignment_eff(c["block_t"], dev.lane))
        eff *= 0.08  # gather-bound VPU kernel
        # larger chan chunks amortize loop control until VREG pressure bites
        eff *= {8: 0.8, 16: 0.9, 32: 1.0, 64: 1.0, 128: 0.93, 256: 0.85}[
            c["chan_chunk"]]
        if c["delay_layout"] == "chan_major":
            eff *= 0.97
        eff *= {1: 0.95, 2: 1.0, 4: 0.98}[c["time_unroll"]]
        return eff

    return KernelWorkload("dedispersion", flops, hbm_bytes, vmem_bytes,
                          grid_size, compute_eff)
