"""Public jit'd entry points for every Pallas kernel (the ops facade).

Each op takes the tunable tile parameters as keyword arguments with the
framework defaults; pass ``interpret=True`` to execute on CPU (used by the
test suite, which sweeps shapes/dtypes against the ``ref`` oracles).
"""
from __future__ import annotations

from .convolution import conv2d, conv2d_ref
from .dedispersion import dedisperse, dedisperse_ref, make_delays
from .flash_attention import attention_ref, flash_attention
from .gemm import gemm, gemm_ref
from .hotspot import hotspot, hotspot_ref
from .ssd import ssd_ref, ssd_scan

__all__ = [
    "conv2d", "conv2d_ref",
    "dedisperse", "dedisperse_ref", "make_delays",
    "flash_attention", "attention_ref",
    "gemm", "gemm_ref",
    "hotspot", "hotspot_ref",
    "ssd_scan", "ssd_ref",
]
