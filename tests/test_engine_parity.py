"""Vectorized-engine parity: the array-backed simulation engine must be
bit-identical to the scalar reference path, observation for observation.

The scalar path is kept in-tree exactly for this purpose (``engine="scalar"``
scorers, ``SimulationRunner(columnar=False)``, the ``*_scalar`` methodology
functions); these tests pin the two together across random caches, including
inf-valued failed configs, out-of-space lookups, empty traces, and budget
exhaustion mid-batch.
"""
import math
import random

import numpy as np
import pytest
from _compat import given, settings, st

from repro.core.budget import Budget, BudgetExhausted
from repro.core.cache import CacheColumns, CachedResult, CacheFile
from repro.core.methodology import (_virtual_random_runs,
                                    _virtual_random_runs_scalar,
                                    evaluate_strategy, make_scorer)
from repro.core.runner import SimulationRunner
from repro.core.searchspace import SearchSpace
from repro.core.strategies import get_strategy
from repro.core.tunable import tunables_from_dict

BATCH_STRATEGIES = ("random_search", "genetic_algorithm", "pso",
                    "differential_evolution")


def _random_cache(seed: int, n_a: int = 24, n_b: int = 4,
                  fail_frac: float = 0.15, name: str = "rand") -> CacheFile:
    """A random space with inf-valued failures and heterogeneous charges."""
    rng = np.random.default_rng(seed)
    space = SearchSpace(tunables_from_dict({"a": tuple(range(n_a)),
                                            "b": tuple(range(n_b))}),
                        name=f"{name}{seed}")
    results = {}
    for cfg in space.valid_configs:
        key = space.config_id(cfg)
        if rng.random() < fail_frac:
            results[key] = CachedResult("error", math.inf, (),
                                        float(rng.uniform(0.1, 2.0)), 0.01)
        else:
            v = float(rng.lognormal(-6, 0.8))
            reps = tuple(float(v * rng.uniform(0.9, 1.1))
                         for _ in range(3))
            results[key] = CachedResult("ok", v, reps,
                                        float(rng.uniform(0.1, 1.0)), 0.01)
    return CacheFile(f"{name}{seed}", "dev", space, results)


def _observable(runner: SimulationRunner):
    return (runner.trace, runner.fresh_evals, runner.budget.spent_seconds,
            runner.budget.spent_evals, sorted(runner.memo))


# ------------------------------------------------------------ batch runner
def test_run_batch_matches_scalar_loop_exactly():
    cache = _random_cache(0)
    configs = cache.space.valid_configs * 2  # revisits included
    vec = SimulationRunner(cache, Budget(max_seconds=1e9), columnar=True)
    sca = SimulationRunner(cache, Budget(max_seconds=1e9), columnar=False)
    obs_v = vec.run_batch(configs)
    obs_s = [sca.run(c) for c in configs]
    assert obs_v == obs_s
    assert _observable(vec) == _observable(sca)


def test_run_batch_budget_exhaustion_point_matches():
    cache = _random_cache(1)
    configs = cache.space.valid_configs
    total = sum(r.charge_s for r in cache.results.values())
    budget_s = total * 0.21  # exhausts somewhere mid-space
    vec = SimulationRunner(cache, Budget(max_seconds=budget_s), columnar=True)
    sca = SimulationRunner(cache, Budget(max_seconds=budget_s),
                           columnar=False)
    with pytest.raises(BudgetExhausted):
        vec.run_batch(configs)
    with pytest.raises(BudgetExhausted):
        for c in configs:
            sca.run(c)
    # identical committed state at the exhaustion point
    assert _observable(vec) == _observable(sca)


def test_run_batch_out_of_space_miss_matches_scalar():
    cache = _random_cache(2)
    # drop some recorded configs so lookups miss while staying space-valid
    victims = list(cache.results)[::5]
    for key in victims:
        del cache.results[key]
    cache.invalidate_columns()
    configs = cache.space.valid_configs
    vec = SimulationRunner(cache, Budget(max_seconds=1e9), columnar=True)
    sca = SimulationRunner(cache, Budget(max_seconds=1e9), columnar=False)
    obs_v = vec.run_batch(configs)
    obs_s = [sca.run(c) for c in configs]
    assert obs_v == obs_s
    miss = [o for o in obs_v if o.status == "error" and not o.result.times_s
            and o.charge_s == cache.mean_eval_charge()]
    assert miss, "expected imputed misses"


def test_run_batch_empty():
    cache = _random_cache(3)
    runner = SimulationRunner(cache, Budget(max_seconds=1e9))
    assert runner.run_batch([]) == []
    assert runner.trace == []


# -------------------------------------------------------------- columns
def test_columns_match_scalar_reductions():
    cache = _random_cache(4)
    cols = cache.columns
    for i, (key, r) in enumerate(cache.results.items()):
        assert cols.keys[i] == key
        assert cols.index[key] == i
        assert cols.records[i] is r
        assert cols.charge_list[i] == r.charge_s  # same fixed-order sum
        assert cols.time_list[i] == r.time_s
    assert cols.mean_charge == sum(
        r.charge_s for r in cache.results.values()) / len(cache.results)
    rows = cols.rows_for(list(cols.keys[:5]) + ["no,such"])
    assert rows.tolist() == [0, 1, 2, 3, 4, -1]


def test_insert_invalidates_columns():
    cache = _random_cache(5)
    cols = cache.columns
    key = "999,999"
    cache.insert(key, CachedResult("ok", 1e-9, (1e-9,), 0.1))
    fresh = cache.columns
    assert fresh is not cols
    assert key in fresh.index
    assert len(fresh) == len(cols) + 1
    # the new optimum is immediately visible through the array view
    assert fresh.time_s.min() == 1e-9


def test_direct_dict_addition_caught_by_length_guard():
    cache = _random_cache(6)
    cache.columns
    cache.results["888,888"] = CachedResult("ok", 2e-9, (2e-9,), 0.1)
    assert "888,888" in cache.columns.index


def test_merged_cache_columns_are_fresh(tmp_path):
    """merge_shards builds via insert → the columnar view always reflects
    the final merged result set."""
    from repro.core.record import ObservationShard, merge_shards
    space = SearchSpace(tunables_from_dict({"x": (0, 1, 2, 3)}), name="m")
    paths = []
    for w in range(2):
        shard = ObservationShard(str(tmp_path / f"s{w}.jsonl"))
        shard.ensure_header(ObservationShard.header(
            "k", "d", space, runner="costmodel", problem={}, repeats=1,
            worker=w))
        for cfg in space.valid_configs[w::2]:
            v = 0.1 * (space.config_id(cfg).count("1") + 1 + w)
            shard.append(space.config_id(cfg),
                         CachedResult("ok", v, (v,), 0.2))
        paths.append(shard.path)
    cache = merge_shards(paths, space=space)
    cols = cache.columns
    assert len(cols) == len(cache.results) == 4
    for key, r in cache.results.items():
        assert cols.records[cols.index[key]] is r


def test_cachefile_pickles_without_columns():
    import pickle
    cache = _random_cache(7)
    cache.columns
    clone = pickle.loads(pickle.dumps(cache))
    assert clone._columns is None  # rebuilt lazily on the other side
    assert clone.columns.keys == cache.columns.keys
    assert np.array_equal(clone.columns.charge_s, cache.columns.charge_s)


# ------------------------------------------------------------ methodology
def test_virtual_random_runs_parity_small_and_large():
    for n, runs in ((64, 200), (2000, 50)):  # crosses the cutover
        rng = np.random.default_rng(n)
        vals = rng.lognormal(-6, 0.8, n)
        vals[rng.random(n) < 0.1] = np.inf
        charges = rng.uniform(0.1, 2.0, n)
        a, b = _virtual_random_runs(vals, charges, runs, seed=13)
        c, d = _virtual_random_runs_scalar(vals, charges, runs, seed=13)
        assert np.array_equal(a, c) and np.array_equal(b, d)


def test_scorer_parity_fields():
    cache = _random_cache(8)
    sv = make_scorer(cache, engine="vectorized")
    ss = make_scorer(cache, engine="scalar")
    assert sv.budget_s == ss.budget_s
    assert sv.mean_charge == ss.mean_charge
    assert sv.optimum == ss.optimum and sv.median == ss.median
    assert np.array_equal(sv.values, ss.values)
    assert np.array_equal(sv._imp_times, ss._imp_times)
    assert np.array_equal(sv._imp_values, ss._imp_values)


def test_score_trace_parity_on_real_traces():
    cache = _random_cache(9)
    sv = make_scorer(cache, engine="vectorized")
    ss = make_scorer(cache, engine="scalar")
    times = sv.sample_times()
    baseline = sv.baseline_at_time(times)
    for seed in range(5):
        runner = SimulationRunner(cache, Budget(max_seconds=sv.budget_s))
        get_strategy("random_search").run(cache.space, runner,
                                          random.Random(seed))
        out_v = sv.score_trace(runner.trace, times, baseline)
        out_s = ss.score_trace(runner.trace, times, baseline)
        assert np.array_equal(out_v, out_s)


def test_score_trace_empty_and_all_failed_trace():
    cache = _random_cache(10)
    sv = make_scorer(cache, engine="vectorized")
    ss = make_scorer(cache, engine="scalar")
    times = sv.sample_times(10)
    assert np.array_equal(sv.score_trace([], times), ss.score_trace([], times))
    assert np.all(sv.score_trace([], times) == 0.0)
    # a trace with only failed (inf) observations scores 0 everywhere
    failed = [(0.5 * (i + 1), math.inf, ("c",)) for i in range(4)]
    out_v = sv.score_trace(failed, times)
    out_s = ss.score_trace(failed, times)
    assert np.array_equal(out_v, out_s)
    assert np.all(out_v == 0.0)


@pytest.mark.parametrize("strategy", BATCH_STRATEGIES)
def test_end_to_end_scores_bit_identical(strategy):
    caches = [_random_cache(11), _random_cache(12, n_a=16, fail_frac=0.4)]
    rep_v = evaluate_strategy(
        lambda: get_strategy(strategy),
        [make_scorer(c, engine="vectorized") for c in caches],
        repeats=4, seed=2)
    rep_s = evaluate_strategy(
        lambda: get_strategy(strategy),
        [make_scorer(c, engine="scalar") for c in caches],
        repeats=4, seed=2)
    assert rep_v.score == rep_s.score
    assert np.array_equal(rep_v.curve, rep_s.curve)
    assert rep_v.per_space_score == rep_s.per_space_score
    assert rep_v.fresh_evals == rep_s.fresh_evals
    assert rep_v.simulated_seconds == rep_s.simulated_seconds


def test_deferred_de_still_batches_and_scores():
    """updating='deferred' is the whole-generation ask/tell variant; it is
    a different algorithm (snapshot selection) but must run, respect the
    budget, and stay deterministic."""
    cache = _random_cache(13)

    def run_once():
        runner = SimulationRunner(cache, Budget(max_evals=60))
        get_strategy("differential_evolution", updating="deferred").run(
            cache.space, runner, random.Random(3))
        return [(v, c) for _, v, c in runner.trace]

    first = run_once()
    assert first == run_once()
    assert len(first) <= 60


# ----------------------------------------------------- hypothesis sweep
@given(st.integers(0, 10_000))
@settings(max_examples=25, deadline=None)
def test_property_random_cache_batch_parity(seed):
    """Across random caches (failures included): whole-space batch replay
    through the columnar engine is observation-for-observation identical to
    the scalar loop, budgets included."""
    cache = _random_cache(seed % 997, n_a=12, n_b=3,
                          fail_frac=(seed % 7) / 10.0)
    if not any(r.status == "ok" for r in cache.results.values()):
        return  # no replayable optimum; covered by error-path tests
    configs = cache.space.valid_configs
    total = sum(r.charge_s for r in cache.results.values())
    frac = 0.1 + (seed % 13) / 15.0
    bv = Budget(max_seconds=total * frac)
    bs = Budget(max_seconds=total * frac)
    vec = SimulationRunner(cache, bv, columnar=True)
    sca = SimulationRunner(cache, bs, columnar=False)
    err_v = err_s = False
    try:
        vec.run_batch(configs)
    except BudgetExhausted:
        err_v = True
    try:
        for c in configs:
            sca.run(c)
    except BudgetExhausted:
        err_s = True
    assert err_v == err_s
    assert _observable(vec) == _observable(sca)


@given(st.integers(0, 10_000))
@settings(max_examples=15, deadline=None)
def test_property_scorer_parity(seed):
    """make_scorer (baseline runs, budget bisection) and P_t sampling agree
    bit-for-bit between engines on random caches."""
    cache = _random_cache(seed % 499, n_a=10, n_b=2,
                          fail_frac=(seed % 5) / 10.0)
    if not any(r.status == "ok" for r in cache.results.values()):
        return
    sv = make_scorer(cache, n_baseline_runs=60, engine="vectorized")
    ss = make_scorer(cache, n_baseline_runs=60, engine="scalar")
    assert sv.budget_s == ss.budget_s
    assert np.array_equal(sv._imp_times, ss._imp_times)
    times = sv.sample_times(12)
    runner = SimulationRunner(cache, Budget(max_seconds=sv.budget_s))
    get_strategy("random_search").run(cache.space, runner,
                                      random.Random(seed))
    assert np.array_equal(sv.score_trace(runner.trace, times),
                          ss.score_trace(runner.trace, times))
