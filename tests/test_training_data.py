"""Training substrate: optimizer math, microbatching, data determinism."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _compat import given, settings, st

from repro.configs import get_config
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.training.optimizer import (OptimizerConfig, adamw_update,
                                      init_opt_state, schedule)
from repro.training.train_step import (TrainConfig, init_train_state,
                                       make_train_step)


def test_schedule_warmup_and_decay():
    cfg = OptimizerConfig(peak_lr=1.0, warmup_steps=10, total_steps=100,
                          min_lr_ratio=0.1)
    assert float(schedule(cfg, 0)) == 0.0
    assert float(schedule(cfg, 10)) == pytest.approx(1.0)
    assert float(schedule(cfg, 100)) == pytest.approx(0.1)
    lrs = [float(schedule(cfg, s)) for s in range(101)]
    assert max(lrs) <= 1.0 + 1e-6


def test_adamw_known_step():
    cfg = OptimizerConfig(peak_lr=0.1, warmup_steps=0,
                          total_steps=1_000_000,
                          weight_decay=0.0, clip_norm=1e9)
    params = {"w": jnp.array([1.0])}
    grads = {"w": jnp.array([0.5])}
    state = init_opt_state(cfg, params)
    new_params, state, _ = adamw_update(cfg, params, grads, state)
    # first Adam step moves by ~lr in the gradient direction
    assert float(new_params["w"][0]) == pytest.approx(1.0 - 0.1, abs=1e-3)


def test_grad_clipping_limits_update():
    cfg = OptimizerConfig(peak_lr=1.0, warmup_steps=0, total_steps=10,
                          weight_decay=0.0, clip_norm=1.0)
    params = {"w": jnp.array([0.0])}
    state = init_opt_state(cfg, params)
    _, _, m1 = adamw_update(cfg, params, {"w": jnp.array([1e6])}, state)
    assert float(m1["grad_norm"]) == pytest.approx(1e6)


def test_microbatch_equivalence():
    """1 vs 4 microbatches must produce (near-)identical updates."""
    cfg = get_config("olmo-1b").tiny()
    opt = OptimizerConfig(total_steps=10)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 17), 0,
                                          cfg.vocab)}
    outs = []
    for mb in (1, 4):
        state = init_train_state(cfg, opt, jax.random.PRNGKey(0))
        step = make_train_step(cfg, opt, TrainConfig(microbatches=mb,
                                                     remat="none"))
        state, metrics = step(state, batch)
        outs.append((float(metrics["loss"]),
                     np.asarray(state["params"]["embed"])))
    assert outs[0][0] == pytest.approx(outs[1][0], rel=1e-3)
    np.testing.assert_allclose(outs[0][1], outs[1][1], rtol=1e-3, atol=1e-5)


def test_mu_dtype_bf16_option():
    cfg = get_config("olmo-1b").tiny()
    opt = OptimizerConfig(total_steps=10, mu_dtype="bfloat16")
    state = init_train_state(cfg, opt, jax.random.PRNGKey(0))
    assert state["opt"]["mu"]["embed"].dtype == jnp.bfloat16


# ------------------------------------------------------------------- data
def test_pipeline_deterministic():
    dc = DataConfig(vocab=1000, seq_len=16, global_batch=8, seed=7)
    p1 = TokenPipeline(dc)
    p2 = TokenPipeline(dc)
    np.testing.assert_array_equal(p1.batch_at(5)["tokens"],
                                  p2.batch_at(5)["tokens"])


def test_pipeline_sharding_consistent_with_global():
    """Elastic contract: shard batches are slices of the same global batch
    regardless of the number of shards."""
    dc = DataConfig(vocab=1000, seq_len=16, global_batch=8, seed=7)
    full = TokenPipeline(dc).global_batch_at(3)["tokens"]
    for n_shards in (1, 2, 4):
        got = np.concatenate([
            TokenPipeline(dc, dp_shards=n_shards, shard_id=i)
            .batch_at(3)["tokens"]
            for i in range(n_shards)])
        np.testing.assert_array_equal(got, full)


@given(st.integers(0, 10_000))
@settings(max_examples=20, deadline=None)
def test_pipeline_tokens_in_range(step):
    dc = DataConfig(vocab=211, seq_len=8, global_batch=4, seed=1)
    toks = TokenPipeline(dc).batch_at(step)["tokens"]
    assert toks.min() >= 0 and toks.max() < 211


def test_pipeline_has_learnable_structure():
    """Every 4th token repeats an earlier one — a learnable signal."""
    dc = DataConfig(vocab=5000, seq_len=64, global_batch=4, seed=0)
    t = TokenPipeline(dc).batch_at(0)["tokens"]
    idx = np.arange(0, 65, 4)[1:]
    assert np.mean(t[:, idx] == t[:, idx - 3]) > 0.99
