"""Tuning the tuner: exhaustive + meta-strategy hyperparameter tuning."""
import numpy as np
import pytest

from repro.core.cache import CachedResult, CacheFile
from repro.core.hypertuner import (exhaustive_hypertune,
                                   hyperparam_searchspace, meta_hypertune,
                                   results_to_cache)
from repro.core.methodology import make_scorer
from repro.core.searchspace import SearchSpace
from repro.core.strategies import STRATEGIES
from repro.core.tunable import tunables_from_dict


def _cache(seed=0):
    rng = np.random.default_rng(seed)
    space = SearchSpace(tunables_from_dict({
        "x": tuple(range(12)), "y": tuple(range(8))}), name="hp")
    results = {}
    for cfg in space.valid_configs:
        x, y = cfg
        v = 1e-3 * (1 + (x - 3) ** 2 + 2 * (y - 6) ** 2
                    + 0.3 * rng.random())
        results[space.config_id(cfg)] = CachedResult("ok", v, (v,) * 2, 0.05)
    return CacheFile("hp", "d", space, results)


def test_hyperparam_searchspace_matches_table():
    s = hyperparam_searchspace("simulated_annealing")
    assert s.size == 81  # 3×3×3×3 (paper Table III)
    s_ext = hyperparam_searchspace("simulated_annealing", extended=True)
    assert s_ext.size > s.size


def test_exhaustive_hypertune_ranks(tmp_path):
    scorers = [make_scorer(_cache())]
    res = exhaustive_hypertune("greedy_ils", scorers, repeats=3, seed=0)
    assert len(res.results) == hyperparam_searchspace("greedy_ils").size
    ranked = res.ranked()
    assert ranked[0].score >= ranked[-1].score
    avg = res.closest_to_mean()
    assert ranked[-1].score <= avg.score <= ranked[0].score


def test_meta_hypertune_finds_good_config():
    scorers = [make_scorer(_cache())]
    exh = exhaustive_hypertune("greedy_ils", scorers, repeats=3, seed=0)
    meta = meta_hypertune("greedy_ils", "random_search", scorers,
                          extended=False, max_hp_evals=8, repeats=3, seed=0)
    scores = sorted(r.score for r in exh.results.values())
    # meta with 8/12 evals should land in the upper half of the exhaustive
    # distribution (objective values are identical given same seeds)
    assert meta.best_score >= scores[len(scores) // 2]


def test_results_to_cache_roundtrip():
    scorers = [make_scorer(_cache())]
    exh = exhaustive_hypertune("greedy_ils", scorers, repeats=2, seed=0)
    hp_cache = results_to_cache(exh)
    # objective is negated score: the cache optimum equals -best score
    assert hp_cache.optimum == pytest.approx(-exh.best.score)
    sc = make_scorer(hp_cache)
    assert sc.n_total == len(exh.results)
