"""Simulation runner, budget accounting and T4 cache round-trips."""
import math
import os
import random

import pytest

from repro.core.budget import Budget, BudgetExhausted
from repro.core.cache import CachedResult, CacheFile
from repro.core.runner import SimulationRunner
from repro.core.searchspace import SearchSpace
from repro.core.tunable import tunables_from_dict


def _cache(n_bad: int = 2):
    space = SearchSpace(tunables_from_dict({"a": tuple(range(8)),
                                            "b": (0, 1)}), name="toy")
    results = {}
    for i, cfg in enumerate(space.valid_configs):
        cid = space.config_id(cfg)
        if i < n_bad:
            results[cid] = CachedResult("error", math.inf, (), 0.5)
        else:
            t = 0.001 * (1 + i)
            results[cid] = CachedResult("ok", t, (t,) * 4, 0.5, 0.01)
    return CacheFile("toy", "dev0", space, results)


def test_simulation_replay_is_deterministic():
    cache = _cache()
    cfg = cache.space.valid_configs[5]
    r1 = SimulationRunner(cache, Budget(max_seconds=100)).run(cfg)
    r2 = SimulationRunner(cache, Budget(max_seconds=100)).run(cfg)
    assert r1.value == r2.value and r1.charge_s == r2.charge_s


def test_memoized_revisit_is_free():
    cache = _cache()
    runner = SimulationRunner(cache, Budget(max_seconds=100))
    cfg = cache.space.valid_configs[3]
    runner.run(cfg)
    spent = runner.budget.spent_seconds
    runner.run(cfg)  # revisit
    assert runner.budget.spent_seconds == spent
    assert runner.fresh_evals == 1


def test_budget_exhaustion_raises():
    cache = _cache()
    charge = cache.results[cache.space.config_id(
        cache.space.valid_configs[4])].charge_s
    runner = SimulationRunner(cache, Budget(max_seconds=charge * 1.5))
    runner.run(cache.space.valid_configs[4])
    runner.run(cache.space.valid_configs[5])
    with pytest.raises(BudgetExhausted):
        runner.run(cache.space.valid_configs[6])


def test_failed_config_counts_and_charges():
    cache = _cache()
    runner = SimulationRunner(cache, Budget(max_seconds=100))
    bad = cache.space.valid_configs[0]
    obs = runner.run(bad)
    assert obs.status == "error" and obs.value == math.inf
    assert runner.budget.spent_seconds > 0
    assert runner.best is None


def test_trace_records_cumulative_time():
    cache = _cache()
    runner = SimulationRunner(cache, Budget(max_seconds=100))
    for cfg in cache.space.valid_configs[:5]:
        runner.run(cfg)
    times = [t for t, _, _ in runner.trace]
    assert times == sorted(times)
    assert times[-1] == pytest.approx(runner.budget.spent_seconds)


@pytest.mark.parametrize("ext", [".json", ".json.zst"])
def test_cache_roundtrip(tmp_path, ext):
    cache = _cache()
    path = os.path.join(tmp_path, "toy" + ext)
    cache.save(path)
    loaded = CacheFile.load(path)
    assert loaded.kernel == "toy" and loaded.device == "dev0"
    assert loaded.space.size == cache.space.size
    for cfg in cache.space.valid_configs:
        a = cache.lookup(cfg)
        b = loaded.lookup(cfg)
        assert a.status == b.status
        assert a.charge_s == pytest.approx(b.charge_s)


def test_loaded_space_validity_matches_results(tmp_path):
    cache = _cache()
    path = os.path.join(tmp_path, "t.json")
    cache.save(path)
    loaded = CacheFile.load(path)
    # membership constraint: every valid config of the loaded space is in
    # the result set (runtime failures included)
    for cfg in loaded.space.valid_configs:
        assert loaded.space.config_id(cfg) in loaded.results
