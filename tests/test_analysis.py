"""parity-lint conformance: fixture snippets per rule (trigger + pass),
suppression and baseline behavior, CLI exit codes, and the meta-test that
keeps the live ``src/repro`` tree clean modulo the checked-in baseline.
"""
import json
import os
import textwrap
from pathlib import Path

import pytest

from repro.analysis import (ERROR, UNUSED_SUPPRESSION, WARNING,
                            default_rules, lint_paths, run_source)
from repro.analysis.baseline import Baseline, baseline_dict
from repro.analysis.report import to_json
from repro import cli

REPO = Path(__file__).resolve().parents[1]
BASELINE = REPO / "parity-lint-baseline.json"


def lint(src: str, path: str = "core/module.py"):
    return run_source(textwrap.dedent(src), path)


def rule_names(findings):
    return [f.rule for f in findings]


# ----------------------------------------------------------- rng discipline
class TestRngRules:
    def test_np_module_draw_triggers(self):
        out = lint("np.random.shuffle(order)\n")
        assert rule_names(out) == ["rng-module-draw"]
        assert out[0].severity == ERROR

    def test_py_module_draw_triggers(self):
        out = lint("x = random.randint(0, 7)\n")
        assert rule_names(out) == ["rng-module-draw"]

    def test_seeded_constructors_pass(self):
        assert lint("""
            rng = np.random.default_rng(seed)
            g = np.random.Generator(np.random.Philox(key=seed))
            r = random.Random(seed * 3 + 1)
            x = rng.random()
        """) == []

    def test_scope_outside_core_passes(self):
        assert lint("np.random.shuffle(order)\n",
                    path="training/optimizer.py") == []

    def test_time_seed_triggers_everywhere(self):
        out = lint("rng = random.Random(time.time())\n",
                   path="serving/engine.py")
        assert rule_names(out) == ["rng-time-seed"]

    def test_unseeded_constructor_triggers(self):
        out = lint("rng = np.random.default_rng()\n", path="hub/x.py")
        assert rule_names(out) == ["rng-time-seed"]

    def test_seed_method_from_clock_triggers(self):
        out = lint("rng.seed(int(time.time_ns()))\n", path="data/x.py")
        assert rule_names(out) == ["rng-time-seed"]

    def test_draw_in_set_loop_triggers(self):
        out = lint("""
            for key in set(pending):
                order.append(rng.random())
        """)
        # the ordering rule independently flags the set-ordered loop
        assert sorted(rule_names(out)) == ["ordering-set-iteration",
                                           "rng-set-iteration"]

    def test_draw_in_set_comprehension_triggers(self):
        out = lint("picks = [rng.choice(vals) for v in {1, 2, 3}]\n")
        assert sorted(rule_names(out)) == ["ordering-set-iteration",
                                           "rng-set-iteration"]

    def test_sorted_set_loop_passes(self):
        assert lint("""
            for key in sorted(set(pending)):
                order.append(rng.random())
        """) == []

    def test_draw_over_list_passes(self):
        assert lint("""
            for key in pending_list:
                order.append(rng.random())
        """) == []


# ------------------------------------------------------------ pickle safety
class TestPickleRules:
    def test_jax_memo_without_getstate_triggers(self):
        out = lint("""
            class Columns:
                def __init__(self):
                    self._jax = None
        """, path="serving/engine.py")
        assert rule_names(out) == ["pickle-device-cache"]

    def test_jax_memo_in_slots_triggers(self):
        out = lint("""
            class Columns:
                __slots__ = ("time_s", "_jax")
        """)
        assert rule_names(out) == ["pickle-device-cache"]

    def test_jax_memo_with_getstate_passes(self):
        assert lint("""
            class Columns:
                def __init__(self):
                    self._jax = None
                def __getstate__(self):
                    return {k: v for k, v in self.__dict__.items()
                            if k != "_jax"}
        """) == []

    def test_plain_attrs_pass(self):
        assert lint("""
            class Columns:
                def __init__(self):
                    self.time_s = []
        """) == []

    def test_state_device_attr_triggers(self):
        out = lint("""
            class _FastState(SearchState):
                def tell(self, observations):
                    self.pop = jnp.zeros((8, 4))
        """)
        assert rule_names(out) == ["pickle-state-device-attr"]

    def test_state_numpy_attr_passes(self):
        assert lint("""
            class _FastState(SearchState):
                def tell(self, observations):
                    self.pop = np.zeros((8, 4))
        """) == []

    def test_state_underscore_device_attr_passes(self):
        # underscore attrs are dropped by SearchState.__getstate__
        assert lint("""
            class _FastState(SearchState):
                def tell(self, observations):
                    self._scratch = jnp.zeros((8, 4))
        """) == []


# ------------------------------------------------------- f64 budget rules
class TestF64Rules:
    def test_cumsum_in_engine_triggers(self):
        out = lint("t = jnp.cumsum(charges)\n",
                   path="core/engine_jax/fast.py")
        assert rule_names(out) == ["f64-parallel-scan"]

    def test_np_cumsum_passes(self):
        # numpy's cumsum is the sequential host reference
        assert lint("t = np.cumsum(charges)\n",
                    path="core/engine_jax/fast.py") == []

    def test_cumsum_outside_engine_passes(self):
        assert lint("t = jnp.cumsum(charges)\n",
                    path="core/methodology.py") == []

    def test_sum_without_dtype_warns(self):
        out = lint("total = jnp.sum(spent)\n",
                   path="core/engine_jax/fast.py")
        assert rule_names(out) == ["f64-sum-dtype"]
        assert out[0].severity == WARNING

    def test_sum_with_dtype_passes(self):
        assert lint("total = jnp.sum(spent, dtype=jnp.float64)\n",
                    path="core/engine_jax/fast.py") == []

    def test_float32_literal_triggers(self):
        out = lint("""
            a = jnp.float32(0.0)
            b = charges.astype(jnp.float32)
            c = jnp.zeros(4, dtype="float32")
        """, path="core/engine_jax/tables2.py")
        assert rule_names(out) == ["f64-float32-literal"] * 3

    def test_float64_and_int32_pass(self):
        assert lint("""
            a = jnp.float64(0.0)
            b = rows.astype(jnp.int32)
        """, path="core/engine_jax/tables2.py") == []


# ---------------------------------------------------- device-sync rule
class TestDeviceSyncRule:
    PATH = "core/engine_jax/fast.py"

    def test_per_element_float_in_loop_triggers(self):
        out = lint("""
            def drain(rows, n):
                out = _replay_jit(rows)
                total = 0.0
                for i in range(n):
                    total += float(out[i])
                return total
        """, path=self.PATH)
        assert rule_names(out) == ["device-sync-in-loop"]
        assert out[0].severity == ERROR

    def test_asarray_per_iteration_triggers(self):
        out = lint("""
            def gather(rows):
                out = jnp.stack(rows)
                vals = []
                for o in out:
                    vals.append(np.asarray(o))
                return vals
        """, path=self.PATH)
        assert rule_names(out) == ["device-sync-in-loop"]

    def test_item_in_comprehension_triggers(self):
        out = lint("""
            def flatten(keys):
                out = jax.random.split(key, 8)
                return [v.item() for v in out]
        """, path=self.PATH)
        assert rule_names(out) == ["device-sync-in-loop"]

    def test_tolist_in_while_triggers(self):
        out = lint("""
            def drain(queue, work):
                mask = jnp.asarray(queue)
                while work:
                    work = submit(work, mask.tolist())
        """, path=self.PATH)
        assert rule_names(out) == ["device-sync-in-loop"]

    def test_convert_where_dispatched_passes(self):
        # the batched-output idiom of campaign._drive_group: dispatch and
        # the one bulk conversion live in the same loop iteration
        assert lint("""
            def drive(runs):
                while runs:
                    out = _replay_vjit(segment(runs))
                    accept = np.asarray(out[0])
                    runs = survivors(runs, accept)
        """, path=self.PATH) == []

    def test_conversion_result_is_host(self):
        # spent is a numpy array after np.asarray — indexing it in the
        # commit loop syncs nothing
        assert lint("""
            def commit(rows, runs):
                out = _replay_vjit(rows)
                spent = np.asarray(out[4])
                for i, run in enumerate(runs):
                    run.spent = float(spent[i])
        """, path=self.PATH) == []

    def test_bulk_conversion_outside_loop_passes(self):
        assert lint("""
            def once(rows):
                out = _replay_jit(rows)
                return np.asarray(out)
        """, path=self.PATH) == []

    def test_for_iterable_is_evaluated_once(self):
        # np.asarray in the iterable position runs once, not per iteration
        assert lint("""
            def walk(rows):
                out = _replay_jit(rows)
                for v in np.asarray(out):
                    consume(v)
        """, path=self.PATH) == []

    def test_numpy_values_pass(self):
        assert lint("""
            def commit(vals, n):
                acc = np.zeros(n)
                total = 0.0
                for i in range(n):
                    total += float(acc[i])
                return total
        """, path=self.PATH) == []

    def test_scope_outside_engine_passes(self):
        assert lint("""
            def drain(rows, n):
                out = _replay_jit(rows)
                return [float(out[i]) for i in range(n)]
        """, path="core/methodology.py") == []


# ------------------------------------------------------- protocol rules
class TestProtocolRules:
    def test_runner_call_in_strategy_triggers(self):
        out = lint("""
            def _optimize(self, space, runner, rng):
                return runner.run_batch(configs)
        """, path="core/strategies/fast_sa.py")
        assert rule_names(out) == ["protocol-runner-call"]

    def test_runner_call_outside_strategies_passes(self):
        assert lint("obs = self.runner.run_batch(configs)\n",
                    path="core/driver.py") == []

    def test_runner_attr_read_passes(self):
        assert lint("best = runner.best\n",
                    path="core/strategies/fast_sa.py") == []

    def test_state_retention_triggers(self):
        out = lint("""
            class _FastState(SearchState):
                def attach_runner(self, runner):
                    self.runner = runner
        """)
        assert rule_names(out) == ["protocol-state-retention"]

    def test_state_retention_underscore_passes(self):
        assert lint("""
            class _FastState(SearchState):
                def attach_runner(self, runner):
                    self._runner = runner
        """) == []

    def test_bind_and_init_pass(self):
        assert lint("""
            class _FastState(SearchState):
                def __init__(self, space, rng):
                    self.space = space
                def bind(self, space):
                    self.space = space
        """) == []


# -------------------------------------------------------- ordering rules
class TestOrderingRules:
    def test_unsorted_listdir_triggers(self):
        out = lint("""
            for name in os.listdir(root):
                shards.append(name)
        """, path="launch/serve.py")
        assert rule_names(out) == ["ordering-listdir"]

    def test_sorted_listdir_passes(self):
        assert lint("""
            for name in sorted(os.listdir(root)):
                shards.append(name)
        """, path="launch/serve.py") == []

    def test_unsorted_path_glob_triggers(self):
        out = lint("paths = list(root.glob('*.jsonl'))\n")
        assert rule_names(out) == ["ordering-listdir"]

    def test_set_loop_in_core_warns(self):
        out = lint("""
            for key in {"a", "b"}:
                journal.append(key)
        """)
        assert rule_names(out) == ["ordering-set-iteration"]
        assert out[0].severity == WARNING

    def test_set_loop_outside_core_passes(self):
        assert lint("""
            for key in {"a", "b"}:
                journal.append(key)
        """, path="models/mlp.py") == []

    def test_sorted_set_loop_passes(self):
        assert lint("""
            for key in sorted({"a", "b"}):
                journal.append(key)
        """) == []

    def test_import_time_environ_assign_triggers(self):
        out = lint("""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
        """, path="launch/dryrun.py")
        assert rule_names(out) == ["ordering-import-env-mutation"]
        assert out[0].severity == ERROR

    def test_import_time_environ_setdefault_triggers(self):
        out = lint("""
            import os
            os.environ.setdefault("JAX_PLATFORMS", "cpu")
        """, path="models/mlp.py")
        assert rule_names(out) == ["ordering-import-env-mutation"]

    def test_env_mutation_inside_function_passes(self):
        assert lint("""
            import os
            def main():
                os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
                os.environ.setdefault("JAX_PLATFORMS", "cpu")
        """, path="launch/dryrun.py") == []

    def test_import_time_environ_read_passes(self):
        assert lint("""
            import os
            FAST = os.environ.get("REPRO_FAST") == "1"
        """) == []


# ------------------------------------------------ suppressions & baseline
class TestSuppression:
    def test_inline_disable_silences(self):
        out = lint("np.random.shuffle(x)"
                   "  # parity-lint: disable=rng-module-draw\n")
        assert out == []

    def test_disable_all_silences(self):
        out = lint("np.random.shuffle(x)  # parity-lint: disable=all\n")
        assert out == []

    def test_disable_other_rule_does_not_silence(self):
        out = lint("np.random.shuffle(x)"
                   "  # parity-lint: disable=ordering-listdir\n")
        assert sorted(rule_names(out)) == ["rng-module-draw",
                                           UNUSED_SUPPRESSION]

    def test_unused_suppression_flagged(self):
        out = lint("x = 1  # parity-lint: disable=rng-module-draw\n")
        assert rule_names(out) == [UNUSED_SUPPRESSION]
        assert out[0].severity == WARNING

    def test_unused_suppression_not_self_suppressible(self):
        out = lint("x = 1  # parity-lint: disable=unused-suppression\n")
        assert rule_names(out) == [UNUSED_SUPPRESSION]

    def test_syntax_error_is_a_finding(self):
        out = lint("def broken(:\n")
        assert rule_names(out) == ["syntax-error"]
        assert out[0].severity == ERROR


class TestBaseline:
    def _findings(self):
        return lint("np.random.shuffle(x)\nnp.random.shuffle(x)\n")

    def test_baseline_filters_matching_findings(self, tmp_path):
        tree = tmp_path / "core"
        tree.mkdir()
        (tree / "mod.py").write_text("np.random.shuffle(x)\n")
        res = lint_paths([str(tmp_path)])
        assert rule_names(res.findings) == ["rng-module-draw"]
        data = baseline_dict(res.findings,
                             lambda f: "np.random.shuffle(x)")
        bpath = tmp_path / "baseline.json"
        bpath.write_text(json.dumps(data))
        res2 = lint_paths([str(tmp_path)], baseline=str(bpath))
        assert res2.findings == [] and len(res2.baselined) == 1
        assert res2.stale_baseline == []

    def test_baseline_is_count_limited(self):
        findings = self._findings()
        assert len(findings) == 2
        bl = Baseline(baseline_dict(findings[:1],
                                    lambda f: "np.random.shuffle(x)")
                      ["entries"])
        survivors = [f for f in findings
                     if not bl.match(f, "np.random.shuffle(x)")]
        assert len(survivors) == 1  # the second duplicate still gates

    def test_stale_entries_reported(self, tmp_path):
        tree = tmp_path / "core"
        tree.mkdir()
        (tree / "mod.py").write_text("x = 1\n")
        bpath = tmp_path / "baseline.json"
        bpath.write_text(json.dumps(
            {"format": "parity-lint-baseline", "version": 1,
             "entries": [{"rule": "rng-module-draw", "path": "core/mod.py",
                          "context": "np.random.shuffle(x)"}]}))
        res = lint_paths([str(tmp_path)], baseline=str(bpath))
        assert res.findings == []
        assert len(res.stale_baseline) == 1

    def test_malformed_baseline_is_value_error(self, tmp_path):
        bad = tmp_path / "baseline.json"
        bad.write_text("{not json")
        with pytest.raises(ValueError):
            lint_paths([str(tmp_path)], baseline=str(bad))


# ------------------------------------------------------------ report shape
class TestReport:
    def test_json_report_shape(self, tmp_path):
        tree = tmp_path / "core"
        tree.mkdir()
        (tree / "mod.py").write_text("np.random.shuffle(x)\n")
        rules = default_rules()
        data = to_json(lint_paths([str(tmp_path)], rules=rules), rules)
        assert data["format"] == "parity-lint-report"
        assert data["ok"] is False and data["n_errors"] == 1
        assert data["findings"][0]["rule"] == "rng-module-draw"
        catalogued = {r["rule"] for r in data["rules"]}
        assert {"rng-module-draw", "pickle-device-cache",
                "f64-parallel-scan", "protocol-runner-call",
                "ordering-listdir"} <= catalogued
        json.dumps(data)  # round-trippable

    def test_at_least_five_rule_families(self):
        prefixes = {r.name.split("-")[0] for r in default_rules()}
        assert {"rng", "pickle", "f64", "protocol", "ordering"} <= prefixes


# ----------------------------------------------------------------- meta
class TestLiveTree:
    def test_live_tree_clean_modulo_baseline(self):
        res = lint_paths([str(REPO / "src" / "repro")],
                         baseline=str(BASELINE))
        assert res.findings == [], "\n".join(
            f.format() for f in res.findings)

    def test_baseline_has_no_stale_entries(self):
        res = lint_paths([str(REPO / "src" / "repro")],
                         baseline=str(BASELINE))
        assert res.stale_baseline == []
        # the grandfathered findings are exactly the deliberate ones:
        # the free-running tier (strategies.py) and the per-output bulk
        # conversions after a replay dispatch (replay.py / strategies.py)
        assert all(f.path in ("core/engine_jax/strategies.py",
                              "core/engine_jax/replay.py")
                   for f in res.baselined)

    def test_api_entry_point(self):
        from repro import api
        res = api.lint([str(REPO / "src" / "repro")],
                       baseline=str(BASELINE))
        assert res.ok and res.n_files > 50


# ------------------------------------------------------------------- CLI
class TestCli:
    def _tree(self, tmp_path, source="np.random.shuffle(x)\n"):
        tree = tmp_path / "core"
        tree.mkdir()
        (tree / "mod.py").write_text(source)
        return tmp_path

    def test_lint_clean_exit_zero(self, tmp_path, capsys):
        root = self._tree(tmp_path, "x = 1\n")
        assert cli.main(["lint", str(root), "--no-baseline"]) == 0
        assert "clean" in capsys.readouterr().out

    def test_lint_findings_exit_one(self, tmp_path, capsys):
        root = self._tree(tmp_path)
        assert cli.main(["lint", str(root), "--no-baseline"]) == 1
        assert "rng-module-draw" in capsys.readouterr().out

    def test_lint_missing_path_one_line_error(self):
        with pytest.raises(SystemExit) as exc:
            cli.main(["lint", "/no/such/tree"])
        assert "no such path" in str(exc.value.code)

    def test_lint_json_format(self, tmp_path, capsys):
        root = self._tree(tmp_path)
        assert cli.main(["lint", str(root), "--no-baseline",
                         "--format", "json"]) == 1
        data = json.loads(capsys.readouterr().out)
        assert data["n_errors"] == 1

    def test_lint_report_artifact(self, tmp_path):
        root = self._tree(tmp_path)
        report = tmp_path / "lint-report.json"
        cli.main(["lint", str(root), "--no-baseline",
                  "--report", str(report)])
        assert json.loads(report.read_text())["findings"]

    def test_lint_write_baseline_roundtrip(self, tmp_path, capsys):
        root = self._tree(tmp_path)
        bpath = tmp_path / "bl.json"
        assert cli.main(["lint", str(root), "--write-baseline",
                         "--baseline", str(bpath)]) == 0
        capsys.readouterr()
        assert cli.main(["lint", str(root),
                         "--baseline", str(bpath)]) == 0
        assert "1 baselined" in capsys.readouterr().out

    def test_report_missing_journal_one_line(self, tmp_path):
        with pytest.raises(SystemExit) as exc:
            cli.main(["report", str(tmp_path / "none.jsonl")])
        assert "no journal" in str(exc.value.code)

    def test_report_on_directory_one_line(self, tmp_path):
        with pytest.raises(SystemExit) as exc:
            cli.main(["report", str(tmp_path)])
        assert str(exc.value.code).startswith("error:")

    def test_report_malformed_journal_one_line(self, tmp_path):
        bad = tmp_path / "bad.jsonl"
        bad.write_bytes(b"\x00\x01 not a journal")
        with pytest.raises(SystemExit) as exc:
            cli.main(["report", str(bad)])
        assert str(exc.value.code).startswith("error:")

    def test_spaces_missing_cache_one_line(self, tmp_path):
        with pytest.raises(SystemExit) as exc:
            cli.main(["spaces", "--cache",
                      str(tmp_path / "missing.json")])
        assert str(exc.value.code).startswith("error:")

    def test_spaces_malformed_cache_one_line(self, tmp_path):
        bad = tmp_path / "bad.json"
        bad.write_text("definitely not a cache")
        with pytest.raises(SystemExit) as exc:
            cli.main(["spaces", "--cache", str(bad)])
        assert str(exc.value.code).startswith("error:")

    def test_lint_malformed_baseline_one_line(self, tmp_path):
        root = self._tree(tmp_path, "x = 1\n")
        bad = tmp_path / "bl.json"
        bad.write_text("{broken")
        with pytest.raises(SystemExit) as exc:
            cli.main(["lint", str(root), "--baseline", str(bad)])
        assert str(exc.value.code).startswith("error:")
