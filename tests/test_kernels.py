"""Per-kernel shape/dtype sweeps: Pallas (interpret) vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ALL_KERNELS, HUB_KERNELS
from repro.kernels import (convolution as cv, dedispersion as dd,
                           flash_attention as fa, gemm as gm, hotspot as hs,
                           ssd)

RTOL = {jnp.float32: 2e-4, jnp.bfloat16: 2e-2}


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("m,n,k,bm,bn,bk", [
    (128, 128, 128, 64, 128, 128),
    (192, 256, 320, 96, 128, 64),     # non-dividing K handled by padding
    (200, 130, 90, 64, 128, 128),     # all dims padded
])
def test_gemm_sweep(dtype, m, n, k, bm, bn, bk):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    a, b = _rand(ks[0], (m, k), dtype), _rand(ks[1], (k, n), dtype)
    c0 = _rand(ks[2], (m, n), dtype)
    out = gm.gemm(a, b, c0, block_m=bm, block_n=bn, block_k=bk,
                  alpha=0.5, beta=1.5, interpret=True)
    ref = gm.gemm_ref(a, b, c0, alpha=0.5, beta=1.5)
    tol = RTOL[dtype] * k ** 0.5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("h,w,fh,fw,sh,bw", [
    (64, 128, 5, 5, 32, 128),
    (96, 130, 3, 7, 48, 96),          # padded width
    (128, 256, 17, 17, 16, 128),      # hub filter size
])
def test_convolution_sweep(h, w, fh, fw, sh, bw):
    x = _rand(jax.random.PRNGKey(1), (h, w), jnp.float32)
    f = _rand(jax.random.PRNGKey(2), (fh, fw), jnp.float32)
    out = cv.conv2d(x, f, strip_h=sh, block_w=bw, interpret=True)
    ref = cv.conv2d_ref(x, f)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("tb", [1, 2, 4])
def test_hotspot_temporal_blocking_exact(tb):
    t = _rand(jax.random.PRNGKey(3), (64, 128), jnp.float32)
    p = _rand(jax.random.PRNGKey(4), (64, 128), jnp.float32) * 0.1
    out = hs.hotspot(t, p, strip_h=32, block_w=128, t_block=tb,
                     interpret=True)
    ref = hs.hotspot_ref(t, p, t_block=tb)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("bdm,bt", [(8, 256), (4, 192), (16, 128)])
def test_dedispersion_sweep(bdm, bt):
    x = _rand(jax.random.PRNGKey(5), (32, 768 + dd.MAX_DELAY), jnp.float32)
    delays = dd.make_delays(32, 24)
    out = dd.dedisperse(x, delays, block_dm=bdm, block_t=bt, interpret=True)
    ref = dd.dedisperse_ref(x, delays)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("causal,window", [(True, None), (False, None),
                                           (True, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(causal, window, dtype):
    ks = jax.random.split(jax.random.PRNGKey(6), 3)
    q = _rand(ks[0], (4, 256, 64), dtype)
    k = _rand(ks[1], (2, 256, 64), dtype)   # GQA group of 2
    v = _rand(ks[2], (2, 256, 64), dtype)
    out = fa.flash_attention(q, k, v, block_q=128, block_kv=128,
                             causal=causal, window=window, interpret=True)
    ref = fa.attention_ref(q, k, v, causal=causal, window=window)
    tol = RTOL[dtype]
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("chunk", [32, 64, 128])
def test_ssd_sweep(chunk):
    bh, l, p, n = 3, 256, 16, 8
    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    x = _rand(ks[0], (bh, l, p), jnp.float32)
    dt = jax.nn.softplus(_rand(ks[1], (bh, l), jnp.float32)) * 0.1
    a = -jax.nn.softplus(_rand(ks[2], (bh,), jnp.float32))
    b = _rand(ks[3], (bh, l, n), jnp.float32)
    c = _rand(ks[4], (bh, l, n), jnp.float32)
    out = ssd.ssd_scan(x, dt, a, b, c, chunk=chunk, interpret=True)
    ref = ssd.ssd_ref(x, dt, a, b, c)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-3, atol=3e-3)


def test_every_kernel_exposes_space_and_workload():
    for name, mod in ALL_KERNELS.items():
        space = mod.space()
        assert space.size >= 30, name
        wl = mod.workload()
        cfg = space.as_dict(space.valid_configs[0])
        assert wl.flops(cfg) > 0
        assert wl.vmem_bytes(cfg) > 0


def test_hub_kernel_spaces_have_failures():
    """Real auto-tuning spaces contain configs that fail at runtime (VMEM
    overflow on the smallest device model)."""
    from repro.core.costmodel import estimate
    from repro.core.devices import LITE_A
    failing = 0
    for name in ("convolution", "hotspot", "gemm"):
        mod = HUB_KERNELS[name]
        space, wl = mod.space(), mod.workload()
        if any(estimate(wl, space.as_dict(cfg), LITE_A,
                        space.config_id(cfg)).status == "error"
               for cfg in space.valid_configs):
            failing += 1
    assert failing >= 2
