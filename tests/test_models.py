"""Per-arch smoke tests (reduced configs) + serve-path consistency.

Every assigned architecture: instantiate the tiny same-family config, run a
forward/train step on CPU, assert output shapes and finiteness; then check
prefill+decode agree with the teacher-forced forward pass.
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, SHAPES, cell_supported, get_config
from repro.models.transformer import (decode_step, forward, init_cache,
                                      init_params, prefill)

ALL_ARCHS = sorted(ARCHS)


def _tiny_batch(cfg, b=2, s=32, seed=1):
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(seed),
                                          (b, s), 0, cfg.vocab)}
    if cfg.family == "audio":
        batch["audio_embeds"] = jax.random.normal(
            jax.random.PRNGKey(2), (b, cfg.n_audio_frames, cfg.d_model))
    if cfg.family == "vlm":
        batch["patch_embeds"] = 0.02 * jax.random.normal(
            jax.random.PRNGKey(3), (b, cfg.n_patches, cfg.d_model))
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(s)[None, :, None], (b, s, 3))
    return batch


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_smoke_forward(name):
    cfg = get_config(name).tiny()
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _tiny_batch(cfg)
    logits = forward(cfg, params, batch)
    assert logits.shape == (2, 32, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_smoke_train_step(name):
    from repro.training.optimizer import OptimizerConfig
    from repro.training.train_step import (TrainConfig, init_train_state,
                                           make_train_step)
    cfg = get_config(name).tiny()
    opt = OptimizerConfig(total_steps=10)
    state = init_train_state(cfg, opt, jax.random.PRNGKey(0))
    step = make_train_step(cfg, opt, TrainConfig(remat="none"))
    batch = _tiny_batch(cfg, s=33)
    state, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    assert float(metrics["grad_norm"]) > 0


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_prefill_decode_matches_forward(name):
    cfg = get_config(name).tiny()
    if cfg.family == "moe":
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)  # dropless
    params = init_params(cfg, jax.random.PRNGKey(0))
    b, s = 2, 33
    batch = _tiny_batch(cfg, s=s)
    full = forward(cfg, params, batch)
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :32]
    if "positions" in pre:
        pre["positions"] = pre["positions"][:, :32]
    last, cache, clen = prefill(cfg, params, pre, max_len=48)
    assert float(jnp.max(jnp.abs(last - full[:, 31]))) < 0.05
    dec, new_cache = decode_step(cfg, params, cache,
                                 batch["tokens"][:, 32:33], clen)
    scale = float(jnp.std(full[:, 32])) + 1e-6
    assert float(jnp.max(jnp.abs(dec - full[:, 32]))) / scale < 0.3


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_cache_structure_constant_shape(name):
    """Decode must not change cache shapes/dtypes (steady-state serving)."""
    cfg = get_config(name).tiny()
    params = init_params(cfg, jax.random.PRNGKey(0))
    cache = init_cache(cfg, batch=2, max_len=16)
    toks = jnp.zeros((2, 1), jnp.int32)
    _, new_cache = decode_step(cfg, params, cache, toks, jnp.int32(3))
    a = jax.tree.map(lambda x: (x.shape, x.dtype), cache)
    b = jax.tree.map(lambda x: (x.shape, x.dtype), new_cache)
    assert a == b


def test_cell_support_rules():
    cells = [(a, s) for a in ARCHS.values() for s in SHAPES.values()]
    supported = [cell_supported(a, s)[0] for a, s in cells]
    assert len(cells) == 40
    assert sum(supported) == 33
    # the skips are exactly long_500k on full-attention/audio archs
    for (a, s), ok in zip(cells, supported):
        if not ok:
            assert s.name == "long_500k"


def test_gemma3_local_global_pattern():
    cfg = get_config("gemma3-1b")
    assert cfg.global_every == 6 and cfg.window == 512
    from repro.models.transformer import _is_global_flags
    flags = _is_global_flags(cfg)
    assert int(flags.sum()) == cfg.n_layers // 6


def test_mamba2_chunked_matches_sequential():
    """Chunked SSD (model path) vs the literal recurrence (kernel oracle)."""
    import numpy as np
    from repro.kernels.ssd import ssd_ref
    from repro.models.mamba2 import _ssd_chunked
    bsz, s, nh, p, n = 2, 64, 3, 8, 4
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.normal(ks[0], (bsz, s, nh, p))
    dt = 0.1 * jax.nn.softplus(jax.random.normal(ks[1], (bsz, s, nh)))
    a = -jax.nn.softplus(jax.random.normal(ks[2], (nh,)))
    bm = jax.random.normal(ks[3], (bsz, s, n))
    cm = jax.random.normal(ks[4], (bsz, s, n))
    y, h = _ssd_chunked(x, dt, a, bm, cm, chunk=16)
    # oracle over flattened (B,H) with per-bh dt/b/c
    xf = x.transpose(0, 2, 1, 3).reshape(bsz * nh, s, p)
    dtf = dt.transpose(0, 2, 1).reshape(bsz * nh, s)
    af = jnp.tile(a, bsz)
    bf = jnp.repeat(bm, nh, axis=0)
    cf = jnp.repeat(cm, nh, axis=0)
    ref = ssd_ref(xf, dtf, af, bf, cf)
    ref = ref.reshape(bsz, nh, s, p).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)
