"""Deterministic synthetic caches for parity testing.

Unlike the ``default_rng``-built caches in test_engine_parity.py (which are
compared engine-vs-engine inside one process), these values are closed-form
functions of the config index — no RNG anywhere — so traces recorded into
committed fixtures reproduce bit-for-bit on any numpy version, platform, or
interpreter.
"""
import math

from repro.core.cache import CachedResult, CacheFile
from repro.core.searchspace import SearchSpace
from repro.core.tunable import tunables_from_dict


def parity_cache(n_a: int = 24, n_b: int = 4, name: str = "parity",
                 fail_every: int = 11) -> CacheFile:
    """A structured space with inf-valued failures and heterogeneous
    charges, all derived arithmetically from the enumeration index."""
    space = SearchSpace(tunables_from_dict({"a": tuple(range(n_a)),
                                            "b": tuple(range(n_b)),
                                            "m": ("p", "q")}),
                        name=name)
    results = {}
    for i, cfg in enumerate(space.valid_configs):
        key = space.config_id(cfg)
        a, b, m = cfg
        if fail_every and i % fail_every == 3:
            results[key] = CachedResult("error", math.inf, (),
                                        0.1 + ((i * 7) % 13) / 13.0, 0.01)
        else:
            # smooth bowl + deterministic "noise" so local structure exists
            v = 1e-3 * (1.0 + (a - 17) ** 2 + 3.0 * (b - 1) ** 2
                        + (2.5 if m == "q" else 0.0)
                        + ((i * 31) % 97) / 97.0)
            reps = (v * 0.98, v, v * 1.02)
            results[key] = CachedResult("ok", v, reps,
                                        0.05 + ((i * 5) % 7) / 70.0, 0.01)
    return CacheFile(name, "synth", space, results)


def total_charge(cache: CacheFile) -> float:
    return sum(r.charge_s for r in cache.results.values())
