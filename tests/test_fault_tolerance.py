"""Fault tolerance: checkpoint/restart bit-exactness, async, keep-k,
elastic restore. The restart test is the core contract: crash at step k,
resume from the checkpoint, and reproduce the uninterrupted run exactly
(enabled by atomic checkpoints + the stateless data pipeline)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import AsyncCheckpointer, CheckpointManager
from repro.configs import get_config
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.training.optimizer import OptimizerConfig
from repro.training.train_step import (TrainConfig, init_train_state,
                                       make_train_step)


def _setup():
    cfg = get_config("olmo-1b").tiny()
    opt = OptimizerConfig(peak_lr=1e-3, warmup_steps=2, total_steps=20)
    step = jax.jit(make_train_step(cfg, opt, TrainConfig(remat="none")))
    pipe = TokenPipeline(DataConfig(vocab=cfg.vocab, seq_len=16,
                                    global_batch=4), cfg)
    return cfg, opt, step, pipe


def _run(step, state, pipe, start, n):
    losses = []
    for i in range(start, start + n):
        state, m = step(state, {k: jnp.asarray(v)
                                for k, v in pipe.batch_at(i).items()})
        losses.append(float(m["loss"]))
    return state, losses


def test_restart_is_bit_exact(tmp_path):
    cfg, opt, step, pipe = _setup()
    mgr = CheckpointManager(str(tmp_path), keep=2)

    # uninterrupted run: 8 steps
    state0 = init_train_state(cfg, opt, jax.random.PRNGKey(0))
    full_state, full_losses = _run(step, state0, pipe, 0, 8)

    # crash after 4: save, "restart", resume from the checkpoint
    state0 = init_train_state(cfg, opt, jax.random.PRNGKey(0))
    mid_state, l1 = _run(step, state0, pipe, 0, 4)
    mgr.save(4, mid_state)
    template = jax.eval_shape(
        lambda: init_train_state(cfg, opt, jax.random.PRNGKey(0)))
    restored = mgr.restore(4, template)
    end_state, l2 = _run(step, restored, pipe, 4, 4)

    assert l1 + l2 == pytest.approx(full_losses)
    for a, b in zip(jax.tree.leaves(full_state), jax.tree.leaves(end_state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_atomic_write_no_partial_files(tmp_path):
    cfg, opt, step, pipe = _setup()
    mgr = CheckpointManager(str(tmp_path), keep=5)
    state = init_train_state(cfg, opt, jax.random.PRNGKey(0))
    mgr.save(1, state)
    assert not [f for f in os.listdir(tmp_path) if f.endswith(".tmp")]
    assert mgr.latest_step() == 1


def test_keep_k_garbage_collection(tmp_path):
    cfg, opt, step, pipe = _setup()
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = init_train_state(cfg, opt, jax.random.PRNGKey(0))
    for s in (1, 2, 3, 4):
        mgr.save(s, state)
    assert mgr.all_steps() == [3, 4]


def test_async_checkpointer_overlaps_and_matches(tmp_path):
    cfg, opt, step, pipe = _setup()
    mgr = CheckpointManager(str(tmp_path), keep=3)
    ac = AsyncCheckpointer(mgr)
    state = init_train_state(cfg, opt, jax.random.PRNGKey(0))
    ac.save(7, state)
    state2, _ = _run(step, state, pipe, 7, 1)  # train while writing
    ac.wait()
    template = jax.eval_shape(
        lambda: init_train_state(cfg, opt, jax.random.PRNGKey(0)))
    restored = mgr.restore(7, template)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_elastic_restore_reshard(tmp_path):
    """Resume a checkpoint onto a different mesh layout (1×1 here — the API
    path; on hardware the same call re-lays onto more/fewer data shards)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.distribution.sharding import param_shardings
    from repro.launch.mesh import make_host_mesh

    cfg, opt, step, pipe = _setup()
    mgr = CheckpointManager(str(tmp_path))
    state = init_train_state(cfg, opt, jax.random.PRNGKey(0))
    mgr.save(2, state)
    mesh = make_host_mesh()
    template = jax.eval_shape(
        lambda: init_train_state(cfg, opt, jax.random.PRNGKey(0)))
    p_sh = param_shardings(mesh, template["params"])
    shardings = {"params": p_sh,
                 "opt": {"mu": p_sh, "nu": p_sh,
                         "step": NamedSharding(mesh, P())}}
    restored = mgr.restore(2, template, shardings=shardings)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # the restored arrays carry the target shardings
    assert restored["params"]["embed"].sharding.mesh.shape == mesh.shape


def test_data_pipeline_elastic_resharding():
    """Changing dp_shards mid-run preserves the global stream (restart on a
    smaller/bigger pod sees the same data)."""
    dc = DataConfig(vocab=500, seq_len=8, global_batch=8, seed=3)
    a = np.concatenate([TokenPipeline(dc, dp_shards=2, shard_id=i)
                        .batch_at(9)["tokens"] for i in range(2)])
    b = np.concatenate([TokenPipeline(dc, dp_shards=8, shard_id=i)
                        .batch_at(9)["tokens"] for i in range(8)])
    np.testing.assert_array_equal(a, b)
