"""Optimization-strategy behaviour on a seeded structured landscape."""
import math
import random

import pytest

from repro.core.budget import Budget
from repro.core.cache import CachedResult, CacheFile
from repro.core.runner import SimulationRunner
from repro.core.searchspace import SearchSpace
from repro.core.strategies import PAPER_STRATEGIES, STRATEGIES, get_strategy
from repro.core.tunable import tunables_from_dict


def _structured_cache():
    """Smooth bowl + noise: local search should exploit the structure."""
    space = SearchSpace(tunables_from_dict({
        "x": tuple(range(16)), "y": tuple(range(16)), "m": ("p", "q"),
    }), name="bowl")
    results = {}
    for cfg in space.valid_configs:
        x, y, m = cfg
        v = 1e-3 * (1 + (x - 11) ** 2 + (y - 4) ** 2
                    + (3 if m == "q" else 0))
        results[space.config_id(cfg)] = CachedResult(
            "ok", v, (v,) * 2, 0.05, 0.0)
    return CacheFile("bowl", "d", space, results)


@pytest.mark.parametrize("name", sorted(STRATEGIES))
def test_strategy_runs_and_respects_budget(name):
    cache = _structured_cache()
    budget = Budget(max_evals=40)
    runner = SimulationRunner(cache, budget)
    best = get_strategy(name).run(cache.space, runner, random.Random(0))
    assert runner.fresh_evals <= 40
    assert best is None or math.isfinite(best.value)


@pytest.mark.parametrize("name", sorted(STRATEGIES))
def test_strategy_deterministic_given_seed(name):
    cache = _structured_cache()

    def run_once():
        runner = SimulationRunner(cache, Budget(max_evals=30))
        get_strategy(name).run(cache.space, runner, random.Random(42))
        return [(v, c) for _, v, c in runner.trace]

    assert run_once() == run_once()


@pytest.mark.parametrize("name", ["greedy_ils", "mls",
                                  "simulated_annealing"])
def test_local_search_beats_tiny_random_budget(name):
    """On a smooth bowl with 512 configs and 60 evals, exploiting locality
    should find a better config than random search (same budget)."""
    cache = _structured_cache()

    def best_of(nm, seed):
        runner = SimulationRunner(cache, Budget(max_evals=60))
        get_strategy(nm).run(cache.space, runner, random.Random(seed))
        return runner.best.value if runner.best else math.inf

    wins = sum(best_of(name, s) <= best_of("random_search", s)
               for s in range(7))
    assert wins >= 4, f"{name} lost to random search too often"


def test_hyperparameters_validated():
    with pytest.raises(ValueError):
        get_strategy("pso", bogus=3)


def test_paper_strategy_registry():
    assert set(PAPER_STRATEGIES) <= set(STRATEGIES)
    for name in PAPER_STRATEGIES:
        cls = STRATEGIES[name]
        assert cls.HYPERPARAM_SPACE, f"{name} must expose Table III values"


@pytest.mark.parametrize("n", [0, 1, 2, 3, 5, 8, 17, 100, 512, 1023, 1024,
                               1025, 4096])
@pytest.mark.parametrize("seed", [0, 1, 42])
def test_rng_permutation_matches_shuffle_stream(n, seed):
    """``_rng_permutation`` is a drop-in for ``rng.shuffle(range(n))``:
    same permutation AND same consumed getrandbits stream, so seeded runs
    recorded before the fast path still replay bit-for-bit — including
    every subsequent draw from the same rng."""
    from repro.core.strategies.random_search import _rng_permutation
    a, b = random.Random(seed), random.Random(seed)
    ref = list(range(n))
    a.shuffle(ref)
    assert _rng_permutation(n, b) == ref
    # the rejection-sampling draws consumed are identical too: the two
    # generators stay in lockstep afterwards
    assert [a.getrandbits(64) for _ in range(4)] \
        == [b.getrandbits(64) for _ in range(4)]
