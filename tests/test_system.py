"""End-to-end behaviour tests for the paper's system.

1. The full "tuning the tuner" pipeline on a real hub slice: brute-forced
   caches -> methodology scorers -> exhaustive hypertuning -> the tuned
   configuration beats the worst and generalizes across seeds (the paper's
   core claim, at CI scale).
2. Train -> checkpoint -> restart -> serve on a tiny model.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config


@pytest.fixture(scope="module")
def hub_slice(tmp_path_factory):
    from repro.hub import build_hub, load_hub
    root = str(tmp_path_factory.mktemp("hub"))
    build_hub(root, progress=lambda *_: None)
    return load_hub(root, kernels=("gemm", "hotspot"),
                    devices=("tpu_v5e", "tpu_lite_b"))


def test_hub_is_valid(hub_slice):
    assert len(hub_slice) == 4
    for (k, d), cache in hub_slice.items():
        assert cache.meta["n_ok"] > 0.8 * cache.meta["n_configs"]


def test_tuning_the_tuner_end_to_end(hub_slice):
    from repro.core.hypertuner import exhaustive_hypertune, score_hyperconfig
    from repro.core.methodology import make_scorer
    scorers = [make_scorer(c) for c in hub_slice.values()]
    res = exhaustive_hypertune("greedy_ils", scorers, repeats=4, seed=0)
    best, worst = res.best, res.worst
    assert best.score > worst.score
    re_best = score_hyperconfig("greedy_ils", best.hyperparams, scorers,
                                repeats=4, seed=99)
    re_worst = score_hyperconfig("greedy_ils", worst.hyperparams, scorers,
                                 repeats=4, seed=99)
    assert re_best.score > re_worst.score


def test_simulation_mode_speedup(hub_slice):
    """Simulated tuning must be orders of magnitude faster than the live
    tuning it replays (paper Sec. IV-E)."""
    from repro.core.methodology import evaluate_strategy, make_scorer
    from repro.core.strategies import get_strategy
    scorers = [make_scorer(c) for c in list(hub_slice.values())[:2]]
    rep = evaluate_strategy(lambda: get_strategy("random_search"), scorers,
                            repeats=3, seed=0)
    assert rep.simulated_seconds > 50 * rep.wall_seconds


def test_train_checkpoint_serve_roundtrip(tmp_path):
    from repro.checkpoint.manager import CheckpointManager
    from repro.data.pipeline import DataConfig, TokenPipeline
    from repro.inference.engine import Request, ServingEngine
    from repro.training.optimizer import OptimizerConfig
    from repro.training.train_step import (TrainConfig, init_train_state,
                                           make_train_step)

    cfg = get_config("olmo-1b").tiny()
    opt = OptimizerConfig(peak_lr=3e-3, warmup_steps=2, total_steps=12)
    state = init_train_state(cfg, opt, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, opt, TrainConfig(remat="none")))
    pipe = TokenPipeline(DataConfig(vocab=cfg.vocab, seq_len=24,
                                    global_batch=4), cfg)
    first = last = None
    for i in range(12):
        state, m = step(state, {k: jnp.asarray(v)
                                for k, v in pipe.batch_at(i).items()})
        first = float(m["loss"]) if first is None else first
        last = float(m["loss"])
    assert last < first  # learned something

    mgr = CheckpointManager(str(tmp_path))
    mgr.save(12, state)
    template = jax.eval_shape(
        lambda: init_train_state(cfg, opt, jax.random.PRNGKey(0)))
    restored = mgr.restore(12, template)

    engine = ServingEngine(cfg, restored["params"], max_len=64)
    outs = engine.generate([Request(prompt=[5, 17, 3], max_new_tokens=8),
                            Request(prompt=[9, 2], max_new_tokens=8)])
    assert len(outs) == 2 and all(len(o) == 8 for o in outs)
    assert all(0 <= t < cfg.vocab for o in outs for t in o)
    # greedy decode is deterministic
    outs2 = engine.generate([Request(prompt=[5, 17, 3], max_new_tokens=8),
                             Request(prompt=[9, 2], max_new_tokens=8)])
    assert outs == outs2
