"""Sharding rules: divisibility validity on the production meshes.

These run with the default single-device runtime: NamedSharding validity
(divisibility) is checked structurally against an *abstract* 16×16 / 2×16×16
mesh — no 512-device init, which belongs to the dry-run only.
"""
import jax
import numpy as np
import pytest

try:
    from jax.sharding import AbstractMesh, AxisType, PartitionSpec as P
except ImportError:  # jax < 0.5 has no AxisType / kwarg-style AbstractMesh
    pytest.skip("jax.sharding.AxisType unavailable (jax too old)",
                allow_module_level=True)

from repro.configs import ARCHS, SHAPES, cell_supported
from repro.distribution.sharding import (_spec_for_param, batch_shardings,
                                         cache_shardings, mesh_axes,
                                         param_shardings)
from repro.models.transformer import init_cache, init_params


def abstract_mesh(multi_pod: bool):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return AbstractMesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def _axis_size(mesh, axes):
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _check_divisible(mesh, tree, shardings):
    for (path, leaf), sh in zip(
            jax.tree_util.tree_flatten_with_path(tree)[0],
            jax.tree.leaves(shardings, is_leaf=lambda x: hasattr(x, "spec"))):
        spec = sh.spec
        for dim, axes in zip(leaf.shape, spec):
            size = _axis_size(mesh, axes)
            assert dim % size == 0, (path, leaf.shape, spec)


@pytest.mark.parametrize("arch", sorted(ARCHS))
@pytest.mark.parametrize("multi_pod", [False, True])
def test_param_shardings_divisible(arch, multi_pod):
    cfg = ARCHS[arch]
    mesh = abstract_mesh(multi_pod)
    params = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    shardings = param_shardings(mesh, params)
    _check_divisible(mesh, params, shardings)


@pytest.mark.parametrize("arch", ["gemma3-1b", "qwen3-moe-235b-a22b",
                                  "zamba2-1.2b", "whisper-small"])
@pytest.mark.parametrize("shape", sorted(SHAPES))
def test_cache_shardings_divisible(arch, shape):
    cfg, sh = ARCHS[arch], SHAPES[shape]
    if sh.kind != "decode" or not cell_supported(cfg, sh)[0]:
        pytest.skip("decode cells only")
    mesh = abstract_mesh(False)
    cache = jax.eval_shape(
        lambda: init_cache(cfg, sh.global_batch, sh.seq_len))
    shardings = cache_shardings(mesh, cache, sh.global_batch)
    _check_divisible(mesh, cache, shardings)


def test_tp_shards_big_matrices():
    """The big FFN/attention matrices must actually be sharded on the model
    axis (not silently replicated)."""
    mesh = abstract_mesh(False)
    spec = _spec_for_param(mesh, "layers/mlp/wi", (16, 2048, 8192))
    assert "model" in jax.tree.leaves(tuple(spec))
    spec_o = _spec_for_param(mesh, "layers/attn/wo", (16, 2048, 2048))
    assert spec_o[1] == "model"


def test_moe_expert_sharding_adapts():
    mesh = abstract_mesh(False)
    # qwen3: 128 experts divisible by 16 -> expert-parallel
    s = _spec_for_param(mesh, "layers/moe/wi", (94, 128, 4096, 1536))
    assert s[1] == "model"
    # grok: 8 experts NOT divisible -> FFN dim sharded instead
    s = _spec_for_param(mesh, "layers/moe/wi", (64, 8, 6144, 32768))
    assert s[1] is None and s[3] == "model"


def test_long_context_cache_context_parallel():
    """batch=1 long_500k: the sequence dim (not batch) goes on data."""
    mesh = abstract_mesh(False)
    cfg = ARCHS["gemma3-1b"]
    cache = jax.eval_shape(lambda: init_cache(cfg, 1, 524288))
    shardings = cache_shardings(mesh, cache, 1)
    k_spec = shardings["k"].spec
    assert k_spec[2] == ("data",) or k_spec[2] == "data"


def test_batch_shardings_use_dp():
    mesh = abstract_mesh(True)
    batch = {"tokens": jax.ShapeDtypeStruct((256, 4097), np.int32)}
    sh = batch_shardings(mesh, batch)
    assert sh["tokens"].spec[0] == ("pod", "data")
