"""Frozen pre-refactor strategy loops — the ask/tell parity oracle.

These are verbatim copies of the imperative ``Strategy._optimize`` bodies as
they existed before the ask/tell protocol redesign (PR 4), inlined with
their helpers so that nothing here can drift when the real strategies
evolve. ``tests/test_protocol.py`` runs every registered strategy through
the new ``SearchDriver`` path and asserts the observable runner state
(trace, memo, budget, fresh_evals) is bit-identical to these loops.

Deliberately self-contained: only SearchSpace/Runner machinery (whose
semantics the redesign does not touch) is shared with ``src/``.
"""
from __future__ import annotations

import math
import random

import numpy as np

from repro.core.budget import BudgetExhausted

FAILURE_FITNESS = 1e12


def _fitness(value: float) -> float:
    return FAILURE_FITNESS if value == float("inf") else value


# ------------------------------------------------------------ random search
def _rs(hp, space, runner, rng):
    order = list(space.valid_configs)
    rng.shuffle(order)
    runner.run_batch(order)


# -------------------------------------------------------------------- GA
def _single_point(a, b, rng):
    if len(a) < 2:
        return a, b
    p = rng.randrange(1, len(a))
    return a[:p] + b[p:], b[:p] + a[p:]


def _two_point(a, b, rng):
    if len(a) < 3:
        return _single_point(a, b, rng)
    p, q = sorted(rng.sample(range(1, len(a)), 2))
    return (a[:p] + b[p:q] + a[q:], b[:p] + a[p:q] + b[q:])


def _uniform(a, b, rng):
    c1, c2 = list(a), list(b)
    for i in range(len(a)):
        if rng.random() < 0.5:
            c1[i], c2[i] = c2[i], c1[i]
    return tuple(c1), tuple(c2)


def _disruptive_uniform(a, b, rng):
    diff = [i for i in range(len(a)) if a[i] != b[i]]
    rng.shuffle(diff)
    k = max((len(diff) + 1) // 2, min(1, len(diff)))
    c1, c2 = list(a), list(b)
    for i in diff[:k]:
        c1[i], c2[i] = c2[i], c1[i]
    return tuple(c1), tuple(c2)


_CROSSOVERS = {
    "single_point": _single_point,
    "two_point": _two_point,
    "uniform": _uniform,
    "disruptive_uniform": _disruptive_uniform,
}


def _ga_mutate(config, space, rng, p_mut):
    out = list(config)
    for i, t in enumerate(space.tunables):
        if rng.random() < p_mut:
            out[i] = t.values[rng.randrange(t.cardinality)]
    return tuple(out)


def _ga(hp, space, runner, rng):
    popsize = int(hp["popsize"])
    generations = int(hp["maxiter"])
    p_mut = 1.0 / float(hp["mutation_chance"])
    crossover = _CROSSOVERS[str(hp["method"])]

    pop = [space.random_config(rng) for _ in range(popsize)]
    while True:
        for _gen in range(generations):
            obs = runner.run_batch(pop)
            scored = sorted(((_fitness(o.value), i, c)
                             for i, (o, c) in enumerate(zip(obs, pop))),
                            key=lambda t: (t[0], t[1]))
            ranked = [c for _, _, c in scored]
            weights = list(range(popsize, 0, -1))
            children = [ranked[0]]
            while len(children) < popsize:
                a, b = rng.choices(ranked, weights=weights, k=2)
                c1, c2 = crossover(a, b, rng)
                for child in (c1, c2):
                    child = _ga_mutate(child, space, rng, p_mut)
                    child = space.nearest_valid(child, rng)
                    children.append(child)
                    if len(children) >= popsize:
                        break
            pop = children
        pop = [space.random_config(rng) for _ in range(popsize)]


# -------------------------------------------------------------------- PSO
def _pso(hp, space, runner, rng):
    popsize = int(hp["popsize"])
    maxiter = int(hp["maxiter"])
    c1, c2, w = float(hp["c1"]), float(hp["c2"]), float(hp["w"])
    np_rng = np.random.default_rng(rng.getrandbits(64))

    lo = np.zeros(len(space.tunables))
    hi = np.array([t.cardinality - 1 for t in space.tunables], dtype=float)
    span = np.maximum(hi - lo, 1.0)

    while True:
        pos = np.stack([space.to_indices(space.random_config(rng))
                        for _ in range(popsize)])
        vel = np_rng.uniform(-1, 1, pos.shape) * span * 0.25
        pbest = pos.copy()
        pbest_f = np.full(popsize, np.inf)
        gbest, gbest_f = pos[0].copy(), np.inf
        for _ in range(maxiter):
            cfgs = space.decode_batch(pos, rng)
            obs = runner.run_batch(cfgs)
            for i, (o, cfg) in enumerate(zip(obs, cfgs)):
                f = _fitness(o.value)
                if f < pbest_f[i]:
                    pbest_f[i] = f
                    pbest[i] = space.to_indices(cfg)
                if f < gbest_f:
                    gbest_f = f
                    gbest = space.to_indices(cfg)
            r1 = np_rng.uniform(size=pos.shape)
            r2 = np_rng.uniform(size=pos.shape)
            vel = w * vel + c1 * r1 * (pbest - pos) + c2 * r2 * (gbest - pos)
            vel = np.clip(vel, -span, span)
            pos = np.clip(pos + vel, lo, hi)


# --------------------------------------------------------------------- DE
def _de(hp, space, runner, rng):
    popsize = max(4, int(hp["popsize"]))
    maxiter = int(hp["maxiter"])
    F, CR = float(hp["F"]), float(hp["CR"])
    deferred = str(hp["updating"]) == "deferred"
    np_rng = np.random.default_rng(rng.getrandbits(64))
    lo = np.zeros(len(space.tunables))
    hi = np.array([t.cardinality - 1 for t in space.tunables], dtype=float)

    def eval_idx(x):
        cfg = space.nearest_valid(space.from_indices(x), rng)
        return _fitness(runner(cfg))

    def eval_batch(xs):
        cfgs = space.decode_batch(np.asarray(xs), rng)
        return [_fitness(o.value) for o in runner.run_batch(cfgs)]

    def make_trial(i, snapshot):
        a, b, c = np_rng.choice(
            [j for j in range(popsize) if j != i], 3, replace=False)
        mutant = np.clip(snapshot[a] + F * (snapshot[b] - snapshot[c]),
                         lo, hi)
        cross = np_rng.uniform(size=len(lo)) < CR
        cross[np_rng.integers(len(lo))] = True
        return np.where(cross, mutant, snapshot[i])

    while True:
        pop = np.stack([space.to_indices(space.random_config(rng))
                        for _ in range(popsize)])
        fit = np.array(eval_batch(pop))
        for _ in range(maxiter):
            if deferred:
                trials = [make_trial(i, pop) for i in range(popsize)]
                fs = eval_batch(trials)
                for i, (trial, f) in enumerate(zip(trials, fs)):
                    if f <= fit[i]:
                        pop[i], fit[i] = trial, f
            else:
                for i in range(popsize):
                    trial = make_trial(i, pop)
                    f = eval_idx(trial)
                    if f <= fit[i]:
                        pop[i], fit[i] = trial, f


# --------------------------------------------------------------------- SA
def _sa(hp, space, runner, rng):
    T0 = float(hp["T"])
    T_min = float(hp["T_min"])
    alpha = float(hp["alpha"])
    maxiter = int(hp["maxiter"])

    while True:
        current = space.random_config(rng)
        f_cur = _fitness(runner(current))
        T = T0
        while T > T_min:
            for _ in range(maxiter):
                nbrs = space.neighbors(current)
                if not nbrs:
                    current = space.random_config(rng)
                    f_cur = _fitness(runner(current))
                    continue
                cand = nbrs[rng.randrange(len(nbrs))]
                f_new = _fitness(runner(cand))
                d_rel = (f_new - f_cur) / max(abs(f_cur), 1e-30)
                if d_rel <= 0 or rng.random() < math.exp(-d_rel / max(T, 1e-9)):
                    current, f_cur = cand, f_new
            T *= alpha


# ----------------------------------------------------------- dual annealing
def _dual_annealing(hp, space, runner, rng):
    import scipy.optimize

    method = str(hp["method"])
    bounds = space.bounds
    bounds = [(lo, hi if hi > lo else lo + 1e-6) for lo, hi in bounds]

    def objective(x):
        cfg = space.nearest_valid(space.from_indices(x), rng)
        v = runner(cfg)
        return FAILURE_FITNESS if v == float("inf") else v

    while True:
        try:
            scipy.optimize.dual_annealing(
                objective, bounds,
                minimizer_kwargs={"method": method},
                seed=rng.getrandbits(32),
                maxiter=1000,
            )
        except BudgetExhausted:
            raise
        except Exception:
            continue


# ------------------------------------------------------------ basin hopping
def _bh_greedy_descent(start, space, runner, max_iters):
    cur, f_cur = start, _fitness(runner(start))
    for _ in range(max_iters):
        improved = False
        for n in space.neighbors(cur, strictly_adjacent=True):
            f = _fitness(runner(n))
            if f < f_cur:
                cur, f_cur, improved = n, f, True
                break
        if not improved:
            break
    return cur, f_cur


def _basin_hopping(hp, space, runner, rng):
    T = float(hp["T"])
    step = int(hp["stepsize"])
    local_iters = int(hp["local_iters"])
    cur, f_cur = _bh_greedy_descent(space.random_config(rng), space,
                                    runner, local_iters)
    while True:
        jumped = list(cur)
        for i, t in enumerate(space.tunables):
            if rng.random() < 0.5:
                j = t.index_of(jumped[i]) + rng.choice((-step, step))
                j = max(0, min(t.cardinality - 1, j))
                jumped[i] = t.values[j]
        start = space.nearest_valid(tuple(jumped), rng)
        cand, f_cand = _bh_greedy_descent(start, space, runner, local_iters)
        d_rel = (f_cand - f_cur) / max(abs(f_cur), 1e-30)
        if d_rel <= 0 or rng.random() < math.exp(-d_rel / max(T, 1e-9)):
            cur, f_cur = cand, f_cand


# -------------------------------------------------------------- greedy ILS
def _greedy_ils(hp, space, runner, rng):
    k = int(hp["perturbation"])
    p_restart = float(hp["restart_chance"])
    cur = space.random_config(rng)
    f_cur = _fitness(runner(cur))
    while True:
        while True:
            nbrs = space.neighbors(cur)
            best_n, best_f = None, f_cur
            for n in nbrs:
                f = _fitness(runner(n))
                if f < best_f:
                    best_n, best_f = n, f
            if best_n is None:
                break
            cur, f_cur = best_n, best_f
        if rng.random() < p_restart:
            cur = space.random_config(rng)
        else:
            out = list(cur)
            idxs = rng.sample(range(len(space.tunables)),
                              min(k, len(space.tunables)))
            for i in idxs:
                t = space.tunables[i]
                out[i] = t.values[rng.randrange(t.cardinality)]
            cur = space.nearest_valid(tuple(out), rng)
        f_cur = _fitness(runner(cur))


# --------------------------------------------------------------------- MLS
def _mls(hp, space, runner, rng):
    adjacent = bool(hp["adjacent_only"])
    while True:
        cur = space.random_config(rng)
        f_cur = _fitness(runner(cur))
        while True:
            nbrs = space.neighbors(cur, strictly_adjacent=adjacent)
            best_n, best_f = None, f_cur
            for n in nbrs:
                f = _fitness(runner(n))
                if f < best_f:
                    best_n, best_f = n, f
            if best_n is None:
                break
            cur, f_cur = best_n, best_f


LEGACY_OPTIMIZE = {
    "random_search": _rs,
    "genetic_algorithm": _ga,
    "pso": _pso,
    "differential_evolution": _de,
    "simulated_annealing": _sa,
    "dual_annealing": _dual_annealing,
    "basin_hopping": _basin_hopping,
    "greedy_ils": _greedy_ils,
    "mls": _mls,
}


def legacy_run(name: str, hyperparams: dict, space, runner,
               rng: random.Random):
    """The pre-refactor ``Strategy.run``: imperative loop until
    BudgetExhausted, then return the best observation."""
    from repro.core.strategies import STRATEGIES
    hp = {**STRATEGIES[name].DEFAULTS, **hyperparams}
    try:
        LEGACY_OPTIMIZE[name](hp, space, runner, rng)
    except BudgetExhausted:
        pass
    return runner.best
