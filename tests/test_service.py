"""ConfigHub service tests: lookup semantics, transfer determinism,
single-flight warm-start, invalidation, pickling, and the deprecation
shims of the retired hub/serving surfaces (docs/service.md)."""
from __future__ import annotations

import os
import pickle
import threading

import pytest

from repro.core.cache import CachedResult, CacheFile
from repro.core.searchspace import SearchSpace
from repro.core.tunable import tunables_from_dict
from repro.hub import storage
from repro.service import (ConfigHub, notify_cache_merged, shape_distance,
                           transfer_confidence)


def toy_cache(kernel: str, device: str, values, n_err: int = 0) -> CacheFile:
    """A tiny deterministic cache: config x=i scores ``values[i]``."""
    space = SearchSpace(tunables_from_dict(
        {"x": tuple(range(len(values) + n_err))}), name=f"{kernel}@{device}")
    results = {}
    for i, cfg in enumerate(space.valid_configs):
        key = space.config_id(cfg)
        if i < len(values):
            v = float(values[i])
            results[key] = CachedResult("ok", v, (v,), 0.1)
        else:
            results[key] = CachedResult("error", float("inf"), (), 0.1)
    return CacheFile(kernel, device, space, results, {})


@pytest.fixture()
def toy_root(tmp_path):
    """A synthetic hub: one kernel, two devices, three problem shapes."""
    root = str(tmp_path / "hub")
    storage.register_cache(root, toy_cache("toy", "devA", [3.0, 1.0, 2.0]),
                           problem={"m": 64})
    storage.register_cache(root, toy_cache("toy", "devA", [5.0, 4.0]),
                           problem={"m": 128})
    storage.register_cache(root, toy_cache("toy", "devB", [9.0, 8.0]),
                           problem={"m": 64})
    return root


# ------------------------------------------------------------------ lookup
def test_exact_hit(toy_root):
    hub = ConfigHub(toy_root)
    r = hub.lookup("toy", {"m": 64}, "devA")
    assert r.status == "exact" and r.confidence == 1.0
    assert r.best_config == {"x": 1} and r.best_value == 1.0
    assert r.source == "toy@devA#m=64" and r.n_configs == 3
    assert r.found and r.mode == "lookup"


def test_exact_hit_touches_disk_once(toy_root, monkeypatch):
    hub = ConfigHub(toy_root)
    assert hub.disk_loads == 0  # construction reads only the manifest
    hub.lookup("toy", {"m": 64}, "devA")
    assert hub.disk_loads == 1
    # after warm-up the hot path must not be able to touch disk at all
    monkeypatch.setattr(storage, "load_cache",
                        lambda *a, **k: pytest.fail("disk on hot path"))
    for _ in range(32):
        r = hub.lookup("toy", {"m": 64}, "devA")
    assert r.status == "exact" and hub.disk_loads == 1


def test_transfer_same_device_shape_miss(toy_root):
    hub = ConfigHub(toy_root)
    r = hub.lookup("toy", {"m": 96}, "devA")
    assert r.status == "transfer"
    # m=128 is log-nearer to 96 than m=64 is (ln(128/96) < ln(96/64))
    assert r.source == "toy@devA#m=128"
    assert r.best_config == {"x": 1}
    assert r.donor_problem == {"m": 128}
    assert r.distance == pytest.approx(shape_distance({"m": 96}, {"m": 128}))
    assert r.confidence == pytest.approx(
        transfer_confidence(r.distance, cross_device=False))
    assert 0.0 < r.confidence < 1.0


def test_transfer_prefers_same_device_shape_over_cross_device_exact():
    # ordering is by distance first: an exact shape on another device beats
    # a different shape on the requested device
    assert (0.0, True) < (shape_distance({"m": 128}, {"m": 64}), False)


def test_transfer_cross_device(toy_root):
    hub = ConfigHub(toy_root)
    r = hub.lookup("toy", {"m": 64}, "devC")
    assert r.status == "transfer" and r.source == "toy@devA#m=64"
    assert r.confidence == pytest.approx(
        transfer_confidence(0.0, cross_device=True))


def test_transfer_tiebreak_is_deterministic(tmp_path):
    # two donors at identical distance (ln 2 on either side of m=64) and
    # identical device: the lexicographically smaller problem_key wins,
    # independent of registration order
    for order in (("a", "b"), ("b", "a")):
        root = str(tmp_path / f"hub-{order[0]}")
        caches = {"a": ({"m": 32}, [2.0]), "b": ({"m": 128}, [4.0])}
        for name in order:
            problem, values = caches[name]
            storage.register_cache(root, toy_cache("toy", "devA", values),
                                   problem=problem)
        r = ConfigHub(root).lookup("toy", {"m": 64}, "devA")
        assert r.status == "transfer"
        assert r.source == "toy@devA#m=128"  # "m=128" < "m=32" lexicographic


def test_cold_without_warm_start(toy_root):
    hub = ConfigHub(toy_root)
    r = hub.lookup("other_kernel", {"m": 8}, "devA")
    assert r.status == "cold" and r.best_config is None and not r.found
    assert r.confidence == 0.0


def test_lookup_many_batches(toy_root):
    hub = ConfigHub(toy_root)
    rs = hub.lookup_many([
        {"kernel": "toy", "problem": {"m": 64}, "device": "devA"},
        {"kernel": "toy", "problem": {"m": 64}, "device": "devA"},
        {"kernel": "toy", "problem": {"m": 96}, "device": "devA"},
    ])
    assert [r.status for r in rs] == ["exact", "exact", "transfer"]
    # two distinct entries served (m=64 exact, m=128 donor), each loaded once
    assert hub.disk_loads == 2


def test_shape_distance_properties():
    assert shape_distance({"m": 64}, {"m": 64}) == 0.0
    assert shape_distance({"m": 64}, {"m": 128}) == \
        shape_distance({"m": 128}, {"m": 64})
    # unshared dimensions cost a flat penalty on top of the shared part
    d_shared = shape_distance({"m": 64}, {"m": 64, "n": 32})
    assert d_shared == pytest.approx(1.0)
    # non-numeric dims compare by equality
    assert shape_distance({"layout": "nchw"}, {"layout": "nchw"}) == 0.0
    assert shape_distance({"layout": "nchw"}, {"layout": "nhwc"}) == 1.0


# --------------------------------------------------------- warm-start path
def test_single_flight_warm_start(tmp_path):
    root = str(tmp_path / "hub")
    # seed the root with an unrelated kernel so the manifest exists
    storage.register_cache(root, toy_cache("toy", "devA", [1.0]),
                           problem={"m": 64})
    hub = ConfigHub(root, warm_start={"max_evals": 4, "workers": 1})
    from repro.kernels import get_kernel
    problem = get_kernel("ssd").problem()  # smoke sizes: cheap space

    results, barrier = [], threading.Barrier(2)

    def go():
        barrier.wait()
        results.append(hub.lookup("ssd", problem, "tpu_v5e"))

    threads = [threading.Thread(target=go) for _ in range(2)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert {r.status for r in results} <= {"warming", "warm"}
    assert hub.warm_start.launches == 1  # single-flight: one campaign

    flight = hub.warm_start.ensure("ssd", "tpu_v5e", problem)
    assert flight.join(120.0) and flight.error is None
    r = hub.lookup("ssd", problem, "tpu_v5e")
    assert r.status == "exact" and r.best_config is not None
    assert hub.stats()["warm_campaigns"] == 1
    # the campaign journal is on disk (crash-safe, resumable shards)
    journal_dir = os.path.join(root, ".warmstart")
    assert any(p.endswith(".jsonl") for p in os.listdir(journal_dir))


def test_warm_start_not_used_for_unknown_kernel(toy_root):
    hub = ConfigHub(toy_root, warm_start=True)
    r = hub.lookup("definitely_not_registered", {"m": 4}, "tpu_v5e")
    assert r.status == "cold" and hub.warm_start.launches == 0


# ----------------------------------------------------------- invalidation
def test_register_invalidates_live_service(toy_root):
    hub = ConfigHub(toy_root)
    assert hub.lookup("toy", {"m": 64}, "devA").best_value == 1.0
    # a re-recording found a better config; registering it must evict the
    # live service's precomputed best (the merge-cache --hub-root hook)
    storage.register_cache(toy_root, toy_cache("toy", "devA", [3.0, 0.5]),
                           problem={"m": 64})
    notified = notify_cache_merged(toy_root, kernel="toy")
    assert notified >= 1
    r = hub.lookup("toy", {"m": 64}, "devA")
    assert r.best_value == 0.5 and r.n_configs == 2


def test_ttl_picks_up_changed_file(toy_root):
    hub = ConfigHub(toy_root, ttl_s=0.0)  # every lookup re-stats
    assert hub.lookup("toy", {"m": 64}, "devA").best_value == 1.0
    loads = hub.disk_loads
    # unchanged file: TTL refresh re-stats but must not re-load
    assert hub.lookup("toy", {"m": 64}, "devA").best_value == 1.0
    assert hub.disk_loads == loads
    storage.register_cache(toy_root, toy_cache("toy", "devA", [0.25]),
                           problem={"m": 64})
    assert hub.lookup("toy", {"m": 64}, "devA").best_value == 0.25


# ------------------------------------------------------- pickling / lint
def test_confighub_pickles_without_columns(toy_root):
    hub = ConfigHub(toy_root)
    hub.lookup("toy", {"m": 64}, "devA")
    state = hub.__getstate__()
    assert state["_lock"] is None and state["_materialized"] == {}
    assert state["_warm"] is None
    clone = pickle.loads(pickle.dumps(hub))
    # the computed best ships; the hot path works without any re-loading
    r = clone.lookup("toy", {"m": 64}, "devA")
    assert r.status == "exact" and r.best_value == 1.0
    assert clone.disk_loads == hub.disk_loads


def test_service_package_is_parity_lint_clean():
    from repro.analysis import lint_paths
    result = lint_paths(["src/repro/service", "src/repro/hub"])
    assert result.ok, [f"{f.rule}:{f.path}:{f.line}"
                       for f in result.findings]


# ------------------------------------------------ hub storage / facade
def test_missing_hub_errors_instead_of_rebuilding(tmp_path):
    from repro.hub import HubError
    with pytest.raises(HubError, match="no hub manifest"):
        storage.load_hub(str(tmp_path / "nope"))


def test_sha256_verification_and_escape_hatch(toy_root):
    from repro.hub import HubError
    manifest = storage.read_manifest(toy_root)
    key = "toy@devA#m=64"
    # stale manifest: the recorded digest no longer matches the file
    manifest["files"][key]["sha256"] = "0" * 64
    storage.write_manifest(toy_root, manifest)
    with pytest.raises(HubError, match="sha256 mismatch"):
        storage.load_cache(toy_root, key)
    with pytest.raises(HubError, match="failed verification"):
        ConfigHub(toy_root).lookup("toy", {"m": 64}, "devA")
    assert key in storage.verify_manifest(toy_root)
    # the explicit escape hatch still reads the intact file as-is
    cache = storage.load_cache(toy_root, key, verify=False)
    assert cache.kernel == "toy"
    r = ConfigHub(toy_root, verify=False).lookup("toy", {"m": 64}, "devA")
    assert r.status == "exact" and r.best_value == 1.0


def test_hub_facade_verify_and_stats(toy_root):
    from repro.api import Hub
    hub = Hub(toy_root)
    assert hub.verify() == {}
    st = hub.stats()
    assert st["entries"] == 3 and st["kernels"] == ["toy"]
    assert st["devices"] == ["devA", "devB"]
    r = hub.lookup("toy", {"m": 64}, "devA")
    assert r.status == "exact"
    assert hub.stats()["service"]["lookups"]["exact"] == 1


def test_default_root_is_normalized():
    from repro.hub import DEFAULT_ROOT
    assert ".." not in DEFAULT_ROOT
    assert DEFAULT_ROOT == os.path.normpath(DEFAULT_ROOT)


# ---------------------------------------------------- deprecation shims
def test_dataset_shims_warn_and_delegate(toy_root):
    from repro.core import dataset
    from repro.deprecations import HubDeprecationWarning
    with pytest.warns(HubDeprecationWarning, match="repro.hub.load_hub"):
        old = dataset.load_hub(toy_root)
    new = storage.load_hub(toy_root)
    assert set(old) == set(new)  # suffixed entries are skipped identically
    for k in old:
        assert old[k].results == new[k].results


def test_train_test_caches_shim_warns(toy_root):
    from repro.core import dataset
    from repro.deprecations import HubDeprecationWarning
    with pytest.warns(HubDeprecationWarning):
        train, test = dataset.train_test_caches(toy_root)
    assert train == [] and test == []  # toy devices are in neither split


def test_serving_import_shim_warns():
    import importlib
    import sys
    from repro.deprecations import ServingMovedWarning
    sys.modules.pop("repro.serving", None)
    sys.modules.pop("repro.serving.engine", None)
    with pytest.warns(ServingMovedWarning, match="repro.inference"):
        import repro.serving  # noqa: F401
        importlib.import_module("repro.serving.engine")
    from repro.inference.engine import ServingEngine
    assert sys.modules["repro.serving.engine"].ServingEngine is ServingEngine


# ----------------------------------------------------------- CLI surface
def test_cli_lookup_and_serve(toy_root, capsys):
    import json

    from repro.cli import main, serve_requests
    assert main(["lookup", "--hub-root", toy_root, "--kernel", "toy",
                 "--problem", "m=64", "--device", "devA", "--json"]) == 0
    out = json.loads(capsys.readouterr().out)
    assert out["status"] == "exact" and out["best_config"] == {"x": 1}

    hub = ConfigHub(toy_root)
    lines = [
        json.dumps({"kernel": "toy", "problem": {"m": 64},
                    "device": "devA"}),
        json.dumps([{"kernel": "toy", "device": "devA"},
                    {"kernel": "toy", "problem": {"m": 96},
                     "device": "devA"}]),
        "not json",
        "",
    ]
    results = list(serve_requests(hub, lines))
    assert [r.get("status") for r in results[:3]] == \
        ["exact", "transfer", "transfer"]
    assert "error" in results[3]


def test_cli_merge_cache_registers_into_hub(toy_root, tmp_path, capsys):
    from repro.cli import main
    # produce one tiny costmodel recording shard via the facade
    from repro.api import Tuner
    out = str(tmp_path / "rec" / "ssd.json.gz")
    with Tuner(workers=1) as tuner:
        run = tuner.record("ssd", runner="costmodel", device="tpu_v5e",
                           max_evals=4, out=out)
    shard = out[:-len(".json.gz")] + ".shard-00.jsonl"
    live = ConfigHub(toy_root)
    # nothing recorded for ssd in the toy hub: the roofline surrogate
    # answers (modeled tier) until the recording below is registered
    assert live.lookup("ssd", None, "tpu_v5e").status == "modeled"
    merged = str(tmp_path / "rec" / "merged.json.gz")
    assert main(["merge-cache", shard, "--out", merged,
                 "--hub-root", toy_root]) == 0
    assert "registered in hub" in capsys.readouterr().out
    # the live service was invalidated and now serves the recording
    r = live.lookup("ssd", run.cache.meta["problem"], "tpu_v5e")
    assert r.status == "exact" and r.best_value == run.best_value
