"""Unit + property tests for the constrained search-space core."""
import random

import pytest
from _compat import given, settings, st

from repro.core.searchspace import SearchSpace
from repro.core.tunable import Constraint, Tunable, tunables_from_dict


def _space():
    tunables = tunables_from_dict({
        "a": (1, 2, 4, 8),
        "b": (16, 32, 64),
        "c": ("x", "y"),
    })
    constraints = (Constraint(lambda d: d["a"] * d["b"] <= 256,
                              "a*b <= 256"),)
    return SearchSpace(tunables, constraints, name="test")


def test_enumeration_respects_constraints():
    s = _space()
    assert s.cartesian_size == 24
    assert all(c[0] * c[1] <= 256 for c in s.valid_configs)
    assert s.size == sum(1 for a in (1, 2, 4, 8) for b in (16, 32, 64)
                         if a * b <= 256) * 2


def test_config_id_roundtrip():
    s = _space()
    for c in s.valid_configs:
        assert s.config_from_id(s.config_id(c)) == c


def test_dict_views():
    s = _space()
    c = s.valid_configs[0]
    assert s.from_dict(s.as_dict(c)) == c


def test_duplicate_values_rejected():
    with pytest.raises(ValueError):
        Tunable("t", (1, 1, 2))


def test_neighbors_differ_in_one_tunable():
    s = _space()
    for c in s.valid_configs:
        for n in s.neighbors(c):
            assert s.is_valid(n)
            assert sum(x != y for x, y in zip(c, n)) == 1


def test_neighbors_strictly_adjacent():
    s = _space()
    c = (2, 32, "x")
    nbrs = s.neighbors(c, strictly_adjacent=True)
    for n in nbrs:
        i = next(j for j in range(3) if n[j] != c[j])
        t = s.tunables[i]
        assert abs(t.index_of(n[i]) - t.index_of(c[i])) == 1


@given(st.integers(0, 2**32 - 1))
@settings(max_examples=30, deadline=None)
def test_random_config_always_valid(seed):
    s = _space()
    assert s.is_valid(s.random_config(random.Random(seed)))


@given(st.integers(0, 2**32 - 1))
@settings(max_examples=30, deadline=None)
def test_nearest_valid_returns_valid(seed):
    rng = random.Random(seed)
    s = _space()
    invalid = (8, 64, "x")  # violates a*b <= 256
    assert not s.is_valid(invalid)
    assert s.is_valid(s.nearest_valid(invalid, rng))


def test_index_vector_roundtrip():
    s = _space()
    for c in s.valid_configs:
        assert s.from_indices(s.to_indices(c)) == c


def test_from_indices_clamps():
    s = _space()
    c = s.from_indices([99.0, -5.0, 0.4])
    assert c == (8, 16, "x")
