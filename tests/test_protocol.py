"""Ask/tell protocol conformance, run over ALL registered strategies.

Three pillars pin the api redesign:

  * parity — driving a strategy through ``SearchDriver`` (and through
    ``drive_many``'s fused batches) is observation-for-observation
    identical to the frozen pre-refactor imperative loops
    (tests/_legacy_reference.py) and to the committed recorded fixtures
    (tests/fixtures/strategy_traces.json);
  * suspendability — a state pickled mid-run (plus the runner's
    ``state_dict``) resumes to a bit-identical completion, for native
    states, generator bridges, and the thread bridge alike;
  * termination — ``BudgetExhausted`` ends the run between ask and tell
    (a strategy is never told a partially evaluated batch), and legacy
    ``_optimize`` subclasses run through the bridge with a
    ``ProtocolDeprecationWarning`` (escalated to an error by pytest.ini
    unless asserted, so untested legacy paths fail tier-1).
"""
import json
import math
import os
import pickle
import random

import pytest
from _compat import given, settings, st
from _legacy_reference import legacy_run
from _synth import parity_cache, total_charge

from repro.core.budget import Budget, BudgetExhausted
from repro.core.driver import (GeneratorBridgeState, ProtocolDeprecationWarning,
                               SearchDriver, SearchState, ThreadBridgeState,
                               drive_many)
from repro.core.methodology import evaluate_strategy, make_scorer
from repro.core.runner import SimulationRunner, run_fused
from repro.core.strategies import STRATEGIES, Strategy, get_strategy

FIXTURES = os.path.join(os.path.dirname(__file__), "fixtures",
                        "strategy_traces.json")

CACHE = parity_cache()
TOTAL = total_charge(CACHE)


def _runner(**budget_kw) -> SimulationRunner:
    return SimulationRunner(CACHE, Budget(**budget_kw))


def observable(r: SimulationRunner):
    return (list(r.trace), r.fresh_evals, r.budget.spent_seconds,
            r.budget.spent_evals, sorted(r.memo))


# ------------------------------------------------------------ fixture parity
with open(FIXTURES) as _f:
    _FIXTURES = json.load(_f)


@pytest.mark.parametrize("case", sorted(_FIXTURES["cases"]))
def test_trace_matches_prerefactor_fixture(case):
    """Traces recorded from the pre-refactor ``_optimize`` loops replay
    bit-for-bit through the ask/tell driver."""
    spec = _FIXTURES["cases"][case]
    if spec["strategy"] == "dual_annealing":
        import scipy
        if scipy.__version__ != _FIXTURES["env"]["scipy"]:
            pytest.skip("dual_annealing fixtures pin the recording scipy "
                        "version (scipy owns its RNG stream); in-process "
                        "legacy parity below still covers this strategy")
    c = _FIXTURES["cache"]
    cache = parity_cache(n_a=c["n_a"], n_b=c["n_b"],
                         fail_every=c["fail_every"])
    runner = SimulationRunner(
        cache, Budget(max_evals=spec["budget"]["max_evals"],
                      max_seconds=spec["budget"]["max_seconds"]))
    get_strategy(spec["strategy"]).run(cache.space, runner,
                                       random.Random(spec["seed"]))
    got = [[t, (None if v == math.inf else v), list(cfg)]
           for t, v, cfg in runner.trace]
    assert got == spec["trace"]
    assert runner.fresh_evals == spec["fresh_evals"]
    assert runner.budget.spent_seconds == spec["spent_seconds"]
    assert runner.budget.spent_evals == spec["spent_evals"]


# ------------------------------------------------------- legacy-loop parity
@pytest.mark.parametrize("name", sorted(STRATEGIES))
@pytest.mark.parametrize("budget_kw", [{"max_evals": 48},
                                       {"max_seconds": TOTAL * 0.08}],
                         ids=["evals", "seconds"])
def test_driver_matches_legacy_loop(name, budget_kw):
    r_legacy = _runner(**budget_kw)
    r_driver = _runner(**budget_kw)
    best_l = legacy_run(name, {}, CACHE.space, r_legacy, random.Random(5))
    best_d = get_strategy(name).run(CACHE.space, r_driver, random.Random(5))
    assert observable(r_driver) == observable(r_legacy)
    assert best_d == best_l


@given(st.integers(0, 10_000))
@settings(max_examples=12, deadline=None)
def test_property_driver_matches_legacy_loop(seed):
    """Hypothesis sweep: random strategy × seed × budget point, driver vs
    frozen legacy loop, full observable runner state."""
    names = sorted(STRATEGIES)
    name = names[seed % len(names)]
    frac = 0.02 + (seed % 7) / 80.0
    budget_kw = ({"max_evals": 8 + seed % 48} if seed % 2
                 else {"max_seconds": TOTAL * frac})
    r_legacy = _runner(**budget_kw)
    r_driver = _runner(**budget_kw)
    legacy_run(name, {}, CACHE.space, r_legacy, random.Random(seed))
    get_strategy(name).run(CACHE.space, r_driver, random.Random(seed))
    assert observable(r_driver) == observable(r_legacy)


def test_deferred_de_parity_with_legacy():
    budget_kw = {"max_evals": 60}
    r_legacy = _runner(**budget_kw)
    r_driver = _runner(**budget_kw)
    legacy_run("differential_evolution", {"updating": "deferred"},
               CACHE.space, r_legacy, random.Random(2))
    get_strategy("differential_evolution", updating="deferred").run(
        CACHE.space, r_driver, random.Random(2))
    assert observable(r_driver) == observable(r_legacy)


# --------------------------------------------------------- suspend / resume
@pytest.mark.parametrize("name", sorted(STRATEGIES))
def test_state_pickle_roundtrip_mid_run(name):
    """Pickle the SearchState + runner snapshot mid-run, resume on a fresh
    runner, and finish bit-identically to the uninterrupted run — including
    the replay-based bridge states (generator frames and threads do not
    pickle; their states reconstruct by replaying the observation log)."""
    budget_kw = {"max_evals": 48}
    ref = _runner(**budget_kw)
    get_strategy(name).run(CACHE.space, ref, random.Random(9))

    part = _runner(**budget_kw)
    driver = SearchDriver(get_strategy(name), CACHE.space, part,
                          random.Random(9))
    payload = None
    for _ in range(3):
        if not driver.step():
            break
        payload = pickle.dumps(driver.snapshot())
    driver.state.close()
    if payload is None:
        pytest.skip(f"{name} finishes in one generation at this budget")

    fresh = _runner(**budget_kw)
    resumed = SearchDriver.resume(get_strategy(name), CACHE.space, fresh,
                                  pickle.loads(payload))
    resumed.run()
    assert observable(fresh) == observable(ref)


@pytest.mark.parametrize("name", ("genetic_algorithm", "pso",
                                  "simulated_annealing"))
@pytest.mark.parametrize("engines", [("jax", "numpy"), ("numpy", "jax")],
                         ids=["jax-to-numpy", "numpy-to-jax"])
def test_cross_engine_pickle_resume(name, engines):
    """A snapshot taken mid-run under one engine resumes bit-identically
    under the other: replay-from-log is engine-invariant, so suspended
    state carries no engine fingerprint. Runs everywhere — without a jax
    backend ``engine="jax"`` degrades to the numpy path, which is exactly
    the property being pinned."""
    eng_a, eng_b = engines
    budget_kw = {"max_evals": 48}
    ref = _runner(**budget_kw)  # engine-free reference completion
    get_strategy(name).run(CACHE.space, ref, random.Random(9))

    part = SimulationRunner(CACHE, Budget(**budget_kw), engine=eng_a)
    driver = SearchDriver(get_strategy(name), CACHE.space, part,
                          random.Random(9))
    payload = None
    for _ in range(3):
        if not driver.step():
            break
        payload = pickle.dumps(driver.snapshot())
    driver.state.close()
    if payload is None:
        pytest.skip(f"{name} finishes in one generation at this budget")

    fresh = SimulationRunner(CACHE, Budget(**budget_kw), engine=eng_b)
    resumed = SearchDriver.resume(get_strategy(name), CACHE.space, fresh,
                                  pickle.loads(payload))
    resumed.run()
    assert observable(fresh) == observable(ref)


def test_pickled_state_drops_space_and_runtime():
    strat = get_strategy("simulated_annealing")
    runner = _runner(max_evals=12)
    driver = SearchDriver(strat, CACHE.space, runner, random.Random(0))
    driver.step()
    state = pickle.loads(pickle.dumps(driver.state))
    assert state.space is None  # re-bound via bind() on resume
    assert not any(k.startswith("_") for k in state.__dict__
                   if k != "space")
    state.bind(CACHE.space)
    assert state.space is CACHE.space
    driver.state.close()


# ------------------------------------------------------ budget / termination
class _TellSpy(Strategy):
    """Native strategy that records every tell it receives."""

    name = "tell_spy"

    def __init__(self):
        super().__init__()
        self.told = []

    def init_state(self, space, rng):
        state = SearchState(space, rng)
        state.i = 0
        return state

    def ask(self, state):
        order = state.space.valid_configs
        batch = order[state.i:state.i + 7]
        state.i += 7
        return batch

    def tell(self, state, observations):
        self.told.append(list(observations))


def test_budget_exhaustion_never_tells_partial_batch():
    """BudgetExhausted mid-batch ends the run between ask and tell: the
    partial batch is committed to memo/trace (scalar-loop semantics) but
    the strategy never observes it."""
    spy = _TellSpy()
    runner = _runner(max_evals=17)  # exhausts inside the 3rd batch of 7
    SearchDriver(spy, CACHE.space, runner, random.Random(0)).run()
    assert [len(t) for t in spy.told] == [7, 7]
    assert runner.fresh_evals == 17  # 14 told + 3 committed from the cut batch
    assert len(runner.trace) == 17
    assert runner.budget.spent_evals == 17


def test_strategy_completion_without_exhaustion():
    """A strategy that runs out of proposals (ask -> None) ends the run
    with budget to spare — random search surviving a whole-space budget."""
    runner = _runner(max_evals=10_000)
    best = get_strategy("random_search").run(CACHE.space, runner,
                                             random.Random(1))
    assert runner.fresh_evals == CACHE.space.size
    assert best is not None and best.value == make_scorer(CACHE).optimum


# ----------------------------------------------------------- legacy bridge
class _LegacyOnly(Strategy):
    """Out-of-tree-style subclass that still overrides ``_optimize``."""

    name = "legacy_only"

    def _optimize(self, space, runner, rng):
        while True:
            runner.run(space.random_config(rng))


def test_legacy_optimize_bridge_warns_and_matches():
    runner = _runner(max_evals=25)
    with pytest.warns(ProtocolDeprecationWarning):
        best = _LegacyOnly().run(CACHE.space, runner, random.Random(3))
    # the bridge is observably the legacy loop
    ref = _runner(max_evals=25)
    rng = random.Random(3)
    try:
        while True:
            ref.run(CACHE.space.random_config(rng))
    except BudgetExhausted:
        pass
    assert observable(runner) == observable(ref)
    assert best == ref.best


def test_thread_bridge_state_is_thread_bridge_for_dual_annealing():
    strat = get_strategy("dual_annealing")
    state = strat.init_state(CACHE.space, random.Random(0))
    assert isinstance(state, ThreadBridgeState)
    state.close()


def test_generator_bridge_close_is_idempotent():
    strat = get_strategy("simulated_annealing")
    runner = _runner(max_evals=6)
    driver = SearchDriver(strat, CACHE.space, runner, random.Random(0))
    driver.step()
    assert isinstance(driver.state, GeneratorBridgeState)
    driver.state.close()
    driver.state.close()


# ------------------------------------------------------- fused drive parity
FUSE_STRATEGIES = ("genetic_algorithm", "pso", "differential_evolution",
                   "random_search", "simulated_annealing", "greedy_ils")


@pytest.mark.parametrize("name", FUSE_STRATEGIES)
def test_drive_many_matches_sequential(name):
    budget = TOTAL * 0.04
    sequential = []
    for rep in range(6):
        r = _runner(max_seconds=budget)
        get_strategy(name).run(CACHE.space, r, random.Random(50 + rep))
        sequential.append(r)
    drivers = [SearchDriver(get_strategy(name), CACHE.space,
                            _runner(max_seconds=budget),
                            random.Random(50 + rep))
               for rep in range(6)]
    drive_many(drivers)
    for d, ref in zip(drivers, sequential):
        assert observable(d.runner) == observable(ref)


def test_drive_many_mixed_strategies_and_exhaustion():
    """Different strategies (native, generator, thread-bridge) interleaved
    over one cache, budgets exhausting at different rounds."""
    mix = ["genetic_algorithm", "simulated_annealing", "dual_annealing",
           "random_search"]
    budgets = [TOTAL * 0.02, TOTAL * 0.05, TOTAL * 0.03, TOTAL * 0.01]
    sequential = []
    for name, b in zip(mix, budgets):
        r = _runner(max_seconds=b)
        get_strategy(name).run(CACHE.space, r, random.Random(7))
        sequential.append(r)
    drivers = [SearchDriver(get_strategy(name), CACHE.space,
                            _runner(max_seconds=b), random.Random(7))
               for name, b in zip(mix, budgets)]
    drive_many(drivers)
    for d, ref in zip(drivers, sequential):
        assert observable(d.runner) == observable(ref)
        assert d.state.finished


@given(st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_property_drive_many_parity(seed):
    name = FUSE_STRATEGIES[seed % len(FUSE_STRATEGIES)]
    n_runs = 2 + seed % 5
    frac = 0.01 + (seed % 9) / 120.0
    sequential = []
    for rep in range(n_runs):
        r = _runner(max_seconds=TOTAL * frac)
        get_strategy(name).run(CACHE.space, r, random.Random(seed + rep))
        sequential.append(r)
    drivers = [SearchDriver(get_strategy(name), CACHE.space,
                            _runner(max_seconds=TOTAL * frac),
                            random.Random(seed + rep))
               for rep in range(n_runs)]
    drive_many(drivers)
    for d, ref in zip(drivers, sequential):
        assert observable(d.runner) == observable(ref)


def test_run_fused_matches_run_batch_per_runner():
    configs = CACHE.space.valid_configs
    batches = []
    refs = []
    for i, sl in enumerate((slice(0, 60), slice(30, 120), slice(0, 192))):
        batches.append((_runner(max_seconds=TOTAL * 0.05 * (i + 1)),
                        configs[sl] * 2))
        refs.append(_runner(max_seconds=TOTAL * 0.05 * (i + 1)))
    results = run_fused(batches)
    for (runner, cfgs), ref, res in zip(batches, refs, results):
        try:
            expected = ref.run_batch(cfgs)
        except BudgetExhausted as e:
            assert isinstance(res, BudgetExhausted)
            assert str(res) == str(e)
        else:
            assert res == expected
        assert observable(runner) == observable(ref)


def test_run_fused_falls_back_for_scalar_runners():
    sca = SimulationRunner(CACHE, Budget(max_evals=10), columnar=False)
    ref = SimulationRunner(CACHE, Budget(max_evals=10), columnar=False)
    configs = CACHE.space.valid_configs[:30]
    (res,) = run_fused([(sca, configs)])
    assert isinstance(res, BudgetExhausted)
    with pytest.raises(BudgetExhausted):
        ref.run_batch(configs)
    assert observable(sca) == observable(ref)


def test_evaluate_strategy_fused_equals_sequential():
    scorer_a = make_scorer(parity_cache(name="fuseA"))
    scorer_b = make_scorer(parity_cache(n_a=16, name="fuseB"))
    for name in ("genetic_algorithm", "pso"):
        rep_f = evaluate_strategy(lambda: get_strategy(name),
                                  [scorer_a, scorer_b], repeats=5, seed=3,
                                  drive="fused")
        rep_s = evaluate_strategy(lambda: get_strategy(name),
                                  [scorer_a, scorer_b], repeats=5, seed=3,
                                  drive="sequential")
        assert rep_f.score == rep_s.score
        assert rep_f.per_space_score == rep_s.per_space_score
        assert rep_f.fresh_evals == rep_s.fresh_evals
        assert rep_f.simulated_seconds == rep_s.simulated_seconds
