"""Record → merge → replay pipeline: round-trip determinism, shard
merging (idempotence, conflicts, corruption tolerance), partial-cache
error handling, and the CLI end-to-end."""
import math
import os
import random

import pytest

from repro.cli import main as cli_main
from repro.core.budget import Budget
from repro.core.cache import CachedResult, CacheFile
from repro.core.record import (ObservationShard, RecordSpec, RecordingRunner,
                               bruteforce_shard_task, merge_shards,
                               record_shard_task, registry_space, shard_path)
from repro.core.runner import LiveRunner, SimulationRunner
from repro.core.strategies import get_strategy
from repro.kernels import get_kernel


def _record_costmodel(tmp_path, kernel="gemm", workers=2, max_evals=12,
                      strategy="random_search", seed=7):
    """Record a strategy-sampled cost-model run; returns (spec, prefix)."""
    spec = RecordSpec.create(kernel, runner="costmodel", device="tpu_v5e",
                             strategy=strategy, max_evals=max_evals,
                             seed=seed)
    prefix = str(tmp_path / kernel)
    for w in range(workers):
        record_shard_task(spec, w, workers, prefix)
    return spec, prefix


# ------------------------------------------------------ round-trip replay
def test_costmodel_roundtrip_bit_identical(tmp_path):
    """Record with the deterministic cost model, then replay the same seeded
    strategy against the recorded cache: the full trajectory — configs,
    objective values, cumulative simulated time — must match bit-for-bit."""
    kspec = get_kernel("gemm")
    space = kspec.space()
    spec = RecordSpec.create("gemm", runner="costmodel", device="tpu_v5e",
                             max_evals=20, seed=3)
    shard = ObservationShard(str(tmp_path / "g.jsonl"))
    shard.ensure_header(spec.shard_header(space, 0, 1))
    runner = spec.make_runner(space, Budget(max_evals=20))
    rec = RecordingRunner(runner, shard)
    get_strategy("simulated_annealing").run(space, rec, random.Random(11))

    cache = merge_shards([shard.path], space=space)
    sim = SimulationRunner(cache, Budget(max_evals=20))
    get_strategy("simulated_annealing").run(space, sim, random.Random(11))
    assert sim.trace == runner.trace
    assert sim.fresh_evals == runner.fresh_evals


def test_live_pallas_roundtrip_bit_identical(tmp_path):
    """The acceptance contract: live-record a registered Pallas kernel
    (interpret mode), replay through a disk round-trip of the cache, and
    get a bit-identical trajectory."""
    kspec = get_kernel("hotspot")  # smallest smoke space: cheap live evals
    space = kspec.space()
    shard = ObservationShard(str(tmp_path / "h.jsonl"))
    shard.ensure_header(ObservationShard.header(
        "hotspot", "cpu_interpret", space, runner="live", problem={},
        repeats=1))
    live = LiveRunner(space, kspec.make_live(), Budget(max_evals=4),
                      repeats=1)
    rec = RecordingRunner(live, shard)
    get_strategy("random_search").run(space, rec, random.Random(42))
    assert live.fresh_evals == 4

    path = str(tmp_path / "h.json.gz")
    merge_shards([shard.path], space=space).save(path)
    cache = CacheFile.load(path, space=space)
    sim = SimulationRunner(cache, Budget(max_evals=4))
    get_strategy("random_search").run(space, sim, random.Random(42))
    assert sim.trace == live.trace


def test_recording_failed_configs_replay_as_failures(tmp_path):
    """Live runtime failures (here: hotspot's divisibility asserts on
    configs outside the constrained space) are recorded with status 'error'
    and replay as failures with the same charge."""
    kspec = get_kernel("hotspot")
    space = kspec.space()
    bad = space.from_dict({"strip_h": 8, "block_w": 256, "io_dtype": "f32",
                           "t_block": 1, "acc_dtype": "f32",
                           "grid_order": "row"})  # block_w > W: assert fires
    assert not space.is_valid(bad)
    shard = ObservationShard(str(tmp_path / "h.jsonl"))
    shard.ensure_header(ObservationShard.header(
        "hotspot", "cpu_interpret", space, runner="live"))
    live = LiveRunner(space, kspec.make_live(), Budget(max_evals=2),
                      repeats=1)
    obs = RecordingRunner(live, shard).run(bad)
    assert obs.status == "error" and obs.value == math.inf
    cache = merge_shards([shard.path], space=space)
    replay = SimulationRunner(cache, Budget(max_evals=2)).run(bad)
    assert replay.status == "error" and replay.charge_s == obs.charge_s


# -------------------------------------------------------------- resuming
def test_record_resume_preloads_and_extends(tmp_path):
    """Re-running a recording against an existing shard must re-measure
    nothing (preloaded memo) and extend coverage with fresh configs."""
    spec, prefix = _record_costmodel(tmp_path, workers=1, max_evals=5)
    _, first = ObservationShard(shard_path(prefix, 0)).read()
    assert len(first) == 5
    summary = record_shard_task(spec, 0, 1, prefix)  # same seed: resumes
    assert summary["resumed"] == 5
    _, after = ObservationShard(shard_path(prefix, 0)).read()
    # the strategy revisits the 5 preloaded configs for free, then records
    # 5 more fresh ones before the per-run budget fires
    assert len(after) == 10
    assert {k: after[k] for k in first} == first  # originals untouched


# --------------------------------------------------------------- merging
def test_shard_merge_is_idempotent_and_order_independent(tmp_path):
    _, prefix = _record_costmodel(tmp_path, workers=2)
    paths = [shard_path(prefix, w) for w in range(2)]
    once = merge_shards(paths)
    twice = merge_shards(paths + paths)          # duplicates fold away
    reverse = merge_shards(list(reversed(paths)))
    assert once.results == twice.results == reverse.results
    assert once.kernel == "gemm" and once.device == "tpu_v5e"


def test_merge_rejects_conflicting_measurements(tmp_path):
    space = registry_space("gemm", None)
    cfg = space.valid_configs[0]
    cid = space.config_id(cfg)
    header = ObservationShard.header("gemm", "dev", space)
    a = ObservationShard(str(tmp_path / "a.jsonl"))
    b = ObservationShard(str(tmp_path / "b.jsonl"))
    a.ensure_header(header)
    b.ensure_header(header)
    a.append(cid, CachedResult("ok", 1.0, (1.0,), 0.1))
    b.append(cid, CachedResult("ok", 2.0, (2.0,), 0.1))
    with pytest.raises(ValueError, match="disagree"):
        merge_shards([a.path, b.path])


def test_merge_reconciles_live_duplicates_deterministically(tmp_path):
    """Independently-seeded live workers legitimately measure the same
    config with different timings; the merge keeps the lowest worker's
    observation, independent of shard order (idempotent merge)."""
    space = registry_space("gemm", None)
    cid = space.config_id(space.valid_configs[0])
    shards = []
    for w, t in ((0, 1.0), (1, 2.0)):
        s = ObservationShard(str(tmp_path / f"w{w}.jsonl"))
        s.ensure_header(ObservationShard.header(
            "gemm", "cpu_interpret", space, runner="live", problem={},
            repeats=1, worker=w))
        s.append(cid, CachedResult("ok", t, (t,), 0.1))
        shards.append(s.path)
    forward = merge_shards(shards)
    backward = merge_shards(list(reversed(shards)))
    assert forward.results == backward.results
    assert forward.results[cid].time_s == 1.0  # worker 0 wins
    # an equal copy of a shard must not perturb conflict resolution,
    # whichever position it is listed in (rank tracking stays minimal)
    copy = ObservationShard(str(tmp_path / "w1copy.jsonl"))
    copy.ensure_header(ObservationShard.header(
        "gemm", "cpu_interpret", space, runner="live", problem={},
        repeats=1, worker=1))
    copy.append(cid, CachedResult("ok", 2.0, (2.0,), 0.1))
    for order in ([copy.path, shards[1], shards[0]],
                  [shards[1], copy.path, shards[0]],
                  [shards[0], copy.path, shards[1]]):
        assert merge_shards(order).results[cid].time_s == 1.0


def test_merge_rejects_mismatched_problem_sizes(tmp_path):
    """gemm's tunables are problem-size independent, so only the header's
    problem field distinguishes a 128^3 recording from a 256^3 one — they
    must not merge into one cache."""
    space = registry_space("gemm", None)
    a = ObservationShard(str(tmp_path / "a.jsonl"))
    b = ObservationShard(str(tmp_path / "b.jsonl"))
    a.ensure_header(ObservationShard.header(
        "gemm", "cpu_interpret", space, runner="live", problem={"m": 128}))
    b.ensure_header(ObservationShard.header(
        "gemm", "cpu_interpret", space, runner="live", problem={"m": 256}))
    with pytest.raises(ValueError, match="different space or workload"):
        merge_shards([a.path, b.path])


def test_merge_rejects_mismatched_spaces(tmp_path):
    a = ObservationShard(str(tmp_path / "a.jsonl"))
    b = ObservationShard(str(tmp_path / "b.jsonl"))
    a.ensure_header(ObservationShard.header(
        "gemm", "dev", registry_space("gemm", None)))
    b.ensure_header(ObservationShard.header(
        "ssd", "dev", registry_space("ssd", None)))
    with pytest.raises(ValueError, match="different space"):
        merge_shards([a.path, b.path])


def test_corrupted_shard_lines_are_tolerated(tmp_path):
    """A shard torn mid-write (kill -9 during an append) keeps every intact
    record; only the torn line is dropped."""
    _, prefix = _record_costmodel(tmp_path, workers=1, max_evals=6)
    path = shard_path(prefix, 0)
    _, intact = ObservationShard(path).read()
    with open(path, "ab") as f:
        f.write(b'{"id": "torn-mid-wri')  # no newline: a torn append
    header, results = ObservationShard(path).read()
    assert header is not None
    assert results == intact
    assert len(merge_shards([path]).results) == 6
    # a later append lands on a fresh line; the torn fragment stays isolated
    ObservationShard(path).append("9,9,9,x,y",
                                  CachedResult("error", math.inf, (), 0.5))
    _, results = ObservationShard(path).read()
    assert len(results) == 7


def test_merge_rejects_foreign_files(tmp_path):
    foreign = tmp_path / "campaign.jsonl"
    foreign.write_text('{"format": "repro-campaign", "mode": "exhaustive"}\n')
    with pytest.raises(ValueError, match="repro-shard"):
        merge_shards([str(foreign)])
    binary = tmp_path / "noise.bin"
    binary.write_bytes(b"\x00\x01\x02 definitely not json\n")
    with pytest.raises(ValueError, match="repro-shard"):
        merge_shards([str(binary)])


# ------------------------------------------------------------ bruteforce
def test_bruteforce_partition_covers_space_exactly(tmp_path):
    spec = RecordSpec.create("ssd", runner="costmodel", device="tpu_v5e",
                             max_evals=None)
    prefix = str(tmp_path / "ssd")
    for w in range(3):
        bruteforce_shard_task(spec, w, 3, prefix)
    cache = merge_shards([shard_path(prefix, w) for w in range(3)])
    space = registry_space("ssd", None)
    assert len(cache.results) == space.size
    # one worker sequentially produces the identical cache (determinism)
    solo_prefix = str(tmp_path / "ssd_solo")
    bruteforce_shard_task(spec, 0, 1, solo_prefix)
    solo = merge_shards([shard_path(solo_prefix, 0)])
    assert solo.results == cache.results


# ------------------------------------------- partial/empty cache handling
def test_empty_and_all_error_caches_raise_clear_errors():
    space = registry_space("ssd", None)
    empty = CacheFile("ssd", "dev", space, {})
    with pytest.raises(ValueError, match="empty"):
        empty.mean_eval_charge()
    with pytest.raises(ValueError, match="no successful results"):
        empty.optimum
    cid = space.config_id(space.valid_configs[0])
    all_err = CacheFile("ssd", "dev", space,
                        {cid: CachedResult("error", math.inf, (), 0.5)})
    with pytest.raises(ValueError, match="no successful results"):
        all_err.optimum
    assert all_err.mean_eval_charge() == pytest.approx(0.5)
    # a lookup miss against an empty cache surfaces the clear error too
    runner = SimulationRunner(empty, Budget(max_seconds=10))
    with pytest.raises(ValueError, match="empty"):
        runner.run(space.valid_configs[1])


def test_cache_insert_guards_conflicts():
    space = registry_space("ssd", None)
    cache = CacheFile("ssd", "dev", space, {})
    cid = space.config_id(space.valid_configs[0])
    r = CachedResult("ok", 1.0, (1.0,), 0.1)
    cache.insert(cid, r)
    cache.insert(cid, r)  # identical re-insert is fine (idempotent)
    with pytest.raises(ValueError, match="different result"):
        cache.insert(cid, CachedResult("ok", 2.0, (2.0,), 0.1))
    cache.insert(cid, CachedResult("ok", 2.0, (2.0,), 0.1), overwrite=True)
    assert cache.results[cid].time_s == 2.0


# -------------------------------------------------------------------- CLI
def test_cli_record_merge_simulate_end_to_end(tmp_path, capsys):
    out = str(tmp_path / "gemm.json.gz")
    rc = cli_main(["record", "--kernel", "gemm", "--runner", "costmodel",
                   "--device", "tpu_v5e", "--workers", "2", "--backend",
                   "thread", "--max-evals", "10", "--out", out])
    assert rc == 0 and os.path.exists(out)
    merged = str(tmp_path / "remerged.json")
    rc = cli_main(["merge-cache",
                   str(tmp_path / "gemm.shard-00.jsonl"),
                   str(tmp_path / "gemm.shard-01.jsonl"),
                   "--out", merged])
    assert rc == 0
    assert CacheFile.load(merged).results == CacheFile.load(out).results
    rc = cli_main(["simulate", "--strategy", "random_search",
                   "--cache", out, "--repeats", "2"])
    assert rc == 0
    assert "aggregate score" in capsys.readouterr().out


def test_cli_parallel_live_record_with_guaranteed_overlap(tmp_path):
    """Two live workers sampling flash attention's 12-config smoke space at
    7 evals each are guaranteed to overlap; the merge must reconcile the
    noisy duplicate timings instead of failing (regression: parallel live
    recording used to crash at the merge step)."""
    out = str(tmp_path / "fa.json.gz")
    rc = cli_main(["record", "--kernel", "flash_attention", "--workers", "2",
                   "--backend", "thread", "--max-evals", "7", "--repeats",
                   "1", "--out", out])
    assert rc == 0
    cache = CacheFile.load(out)
    space = registry_space("flash_attention", None)
    assert 7 <= len(cache.results) <= space.size == 12


def test_cli_rejects_unknown_kernel(tmp_path):
    with pytest.raises(SystemExit, match="unknown kernel"):
        cli_main(["record", "--kernel", "nope",
                  "--out", str(tmp_path / "x.json")])
