"""Roofline analysis: collective parser, trip-count scaling, analytic-cost
validation against XLA cost_analysis on trip-count-1 configurations."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import SHAPES, ShapeConfig, get_config
from repro.roofline.analysis import (analytic_cost, model_flops,
                                     parse_collectives, roofline)

SYNTH_HLO = """
HloModule test

%loop_body (p: (s32[], f32[128,256])) -> (s32[], f32[128,256]) {
  %p = (s32[], f32[128,256]) parameter(0)
  %ar = f32[128,256]{1,0} all-reduce(%gte), replica_groups={{0,1,2,3}}, to_apply=%add
  ROOT %t = (s32[], f32[128,256]) tuple(%c, %ar)
}

%loop_cond (p: (s32[], f32[128,256])) -> pred[] {
  %p2 = (s32[], f32[128,256]) parameter(0)
  %bound = s32[] constant(10)
  ROOT %cmp = pred[] compare(%i, %bound), direction=LT
}

ENTRY %main (a: f32[128,256]) -> f32[128,256] {
  %a = f32[128,256] parameter(0)
  %ag = f32[512,256]{1,0} all-gather(%a), replica_groups=[2,2]<=[4], dimensions={0}
  %w = (s32[], f32[128,256]) while(%tup), condition=%loop_cond, body=%loop_body
  ROOT %out = f32[128,256] get-tuple-element(%w), index=1
}
"""


def test_parser_counts_and_trip_scales():
    summary = parse_collectives(SYNTH_HLO, n_chips=4)
    # the all-reduce inside the 10-iteration loop counts 10 times
    assert summary.counts["all-reduce"] == 10
    assert summary.counts["all-gather"] == 1
    ar_payload = 128 * 256 * 4
    expected_ar = 2 * (4 - 1) / 4 * ar_payload * 4 * 10
    assert summary.wire_bytes["all-reduce"] == pytest.approx(expected_ar)
    ag_payload = 512 * 256 * 4
    expected_ag = (2 - 1) / 2 * ag_payload * 4  # iota groups of 2
    assert summary.wire_bytes["all-gather"] == pytest.approx(expected_ag)


def test_roofline_dominant_term():
    r = roofline(1e12, 1e9, 1e12, 256, mflops=2.56e14)
    assert r.dominant == "collective"
    assert r.useful_ratio == pytest.approx(1.0)


def test_analytic_cost_matches_xla_on_trip_count_one():
    """With L=1, one KV block and one microbatch every scan has trip count 1,
    so XLA's cost_analysis is exact — the analytic model must agree on FLOPs
    within 25 % (it approximates elementwise/softmax work)."""
    from repro.launch.dryrun import cost_analysis_dict, lower_cell
    from repro.launch.mesh import make_host_mesh

    base = get_config("olmo-1b")
    cfg = dataclasses.replace(base, name="olmo-probe", n_layers=1,
                              vocab=4096)
    shape = ShapeConfig("probe", seq_len=512, global_batch=4, kind="train")
    mesh = make_host_mesh()
    compiled = lower_cell(cfg, shape, mesh, remat="none").compile()
    xla_flops = float(cost_analysis_dict(compiled)["flops"])
    ours, _ = analytic_cost(cfg, shape, remat="none", n_chips=1)
    assert ours == pytest.approx(xla_flops, rel=0.25)


def test_model_flops_moe_uses_active_params():
    grok = get_config("grok-1-314b")
    mf = model_flops(grok, SHAPES["train_4k"])
    tokens = SHAPES["train_4k"].global_batch * SHAPES["train_4k"].seq_len
    # dominated by 6·N_active·T, plus attention
    assert mf > 6 * grok.active_param_count() * tokens * 0.9
    assert mf < 6 * grok.param_count() * tokens


def test_useful_ratio_bounded_for_all_cells():
    from repro.configs import ARCHS, cell_supported
    for cfg in ARCHS.values():
        for shape in SHAPES.values():
            if not cell_supported(cfg, shape)[0]:
                continue
            mf = model_flops(cfg, shape)
            af, ab = analytic_cost(cfg, shape, "full", 1)
            assert 0.0 < mf / af <= 1.02, (cfg.name, shape.name, mf / af)
            assert ab > 0
