"""Roofline analysis: collective parser, trip-count scaling, analytic-cost
validation against XLA cost_analysis on trip-count-1 configurations."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import SHAPES, ShapeConfig, get_config
from repro.roofline.analysis import (_max_element_bytes, analytic_cost,
                                     model_flops, parse_collectives,
                                     roofline)

SYNTH_HLO = """
HloModule test

%loop_body (p: (s32[], f32[128,256])) -> (s32[], f32[128,256]) {
  %p = (s32[], f32[128,256]) parameter(0)
  %ar = f32[128,256]{1,0} all-reduce(%gte), replica_groups={{0,1,2,3}}, to_apply=%add
  ROOT %t = (s32[], f32[128,256]) tuple(%c, %ar)
}

%loop_cond (p: (s32[], f32[128,256])) -> pred[] {
  %p2 = (s32[], f32[128,256]) parameter(0)
  %bound = s32[] constant(10)
  ROOT %cmp = pred[] compare(%i, %bound), direction=LT
}

ENTRY %main (a: f32[128,256]) -> f32[128,256] {
  %a = f32[128,256] parameter(0)
  %ag = f32[512,256]{1,0} all-gather(%a), replica_groups=[2,2]<=[4], dimensions={0}
  %w = (s32[], f32[128,256]) while(%tup), condition=%loop_cond, body=%loop_body
  ROOT %out = f32[128,256] get-tuple-element(%w), index=1
}
"""


def test_parser_counts_and_trip_scales():
    summary = parse_collectives(SYNTH_HLO, n_chips=4)
    # the all-reduce inside the 10-iteration loop counts 10 times
    assert summary.counts["all-reduce"] == 10
    assert summary.counts["all-gather"] == 1
    ar_payload = 128 * 256 * 4
    expected_ar = 2 * (4 - 1) / 4 * ar_payload * 4 * 10
    assert summary.wire_bytes["all-reduce"] == pytest.approx(expected_ar)
    ag_payload = 512 * 256 * 4
    expected_ag = (2 - 1) / 2 * ag_payload * 4  # iota groups of 2
    assert summary.wire_bytes["all-gather"] == pytest.approx(expected_ag)


# one ENTRY computation exercising every collective op the ring model
# prices, with explicit replica groups of 4 on 8 chips
ALL_OPS_HLO = """
HloModule ops

ENTRY %main (a: f32[128,256]) -> f32[128,256] {
  %a = f32[128,256] parameter(0)
  %ar = f32[128,256]{1,0} all-reduce(%a), replica_groups={{0,1,2,3},{4,5,6,7}}, to_apply=%add
  %ag = f32[512,256]{1,0} all-gather(%ar), replica_groups={{0,1,2,3},{4,5,6,7}}, dimensions={0}
  %rs = f32[32,256]{1,0} reduce-scatter(%ar), replica_groups={{0,1,2,3},{4,5,6,7}}, dimensions={0}, to_apply=%add
  %aa = f32[128,256]{1,0} all-to-all(%ar), replica_groups={{0,1,2,3},{4,5,6,7}}, dimensions={0}
  %cp = f32[128,256]{1,0} collective-permute(%ar), source_target_pairs={{0,1},{1,2},{2,3},{3,0}}
  ROOT %out = f32[128,256] add(%ar, %cp)
}
"""

# async -start variants print a tuple type (operand, result [, scratch]);
# the payload is the largest tuple element
START_HLO = """
HloModule starts

ENTRY %main (a: f32[128,256]) -> f32[512,256] {
  %a = f32[128,256] parameter(0)
  %ars = (f32[128,256], f32[128,256]) all-reduce-start(%a), replica_groups={{0,1}}, to_apply=%add
  %ard = f32[128,256] all-reduce-done(%ars)
  %ags = (f32[128,256], f32[512,256]) all-gather-start(%ard), replica_groups=[2,4]<=[8], dimensions={0}
  ROOT %agd = f32[512,256] all-gather-done(%ags)
}
"""


def test_parse_collectives_every_op_ring_model():
    s = parse_collectives(ALL_OPS_HLO, n_chips=8)
    assert s.counts == {"all-reduce": 1, "all-gather": 1,
                        "reduce-scatter": 1, "all-to-all": 1,
                        "collective-permute": 1}
    elt = 256 * 4  # f32 row
    # explicit groups of g=4; wire bytes aggregate across all 8 chips
    assert s.wire_bytes["all-reduce"] == pytest.approx(
        2 * 3 / 4 * 128 * elt * 8)
    # all-gather payload is the gathered (output) shape
    assert s.wire_bytes["all-gather"] == pytest.approx(
        3 / 4 * 512 * elt * 8)
    # reduce-scatter payload is its (scattered) result shape
    assert s.wire_bytes["reduce-scatter"] == pytest.approx(
        3 / 4 * 32 * elt * 8)
    assert s.wire_bytes["all-to-all"] == pytest.approx(
        3 / 4 * 128 * elt * 8)
    # permute: one hop, full payload, group size irrelevant
    assert s.wire_bytes["collective-permute"] == pytest.approx(
        128 * elt * 8)
    assert s.total_wire_bytes == pytest.approx(sum(s.wire_bytes.values()))


def test_parse_collectives_start_variants_and_iota_groups():
    s = parse_collectives(START_HLO, n_chips=8)
    # -start ops count under the base op name; -done ops don't double count
    assert s.counts == {"all-reduce": 1, "all-gather": 1}
    elt = 256 * 4
    # tuple type: payload is the largest element (here equal halves)
    assert s.wire_bytes["all-reduce"] == pytest.approx(
        2 * 1 / 2 * 128 * elt * 8)
    # iota replica_groups=[2,4]<=[8] means 2 groups of 4 => g=4;
    # payload is the larger tuple element (the gathered output)
    assert s.wire_bytes["all-gather"] == pytest.approx(
        3 / 4 * 512 * elt * 8)


def test_parse_collectives_defaults_group_to_world():
    # no replica_groups printed at all: the group is all n_chips
    hlo = """
HloModule w

ENTRY %main (a: bf16[64]) -> bf16[64] {
  %a = bf16[64] parameter(0)
  ROOT %ar = bf16[64]{0} all-reduce(%a), to_apply=%add
}
"""
    s = parse_collectives(hlo, n_chips=4)
    assert s.wire_bytes["all-reduce"] == pytest.approx(
        2 * 3 / 4 * 64 * 2 * 4)


def test_max_element_bytes_dtype_table():
    cases = {"f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
             "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "f8e4m3": 1,
             "f8e5m2": 1, "s8": 1, "u8": 1, "pred": 1}
    for dt, nbytes in cases.items():
        assert _max_element_bytes(f"{dt}[16,8]") == 16 * 8 * nbytes, dt
    # scalars have one element; unknown dtypes fall back to 4 bytes
    assert _max_element_bytes("s32[]") == 4
    assert _max_element_bytes("c64[8]") == 8 * 4
    # tuples: the largest element wins
    assert _max_element_bytes("(f32[8], bf16[128,64])") == 128 * 64 * 2
    assert _max_element_bytes("") == 0.0


def test_roofline_dominant_term():
    r = roofline(1e12, 1e9, 1e12, 256, mflops=2.56e14)
    assert r.dominant == "collective"
    assert r.useful_ratio == pytest.approx(1.0)


def test_analytic_cost_matches_xla_on_trip_count_one():
    """With L=1, one KV block and one microbatch every scan has trip count 1,
    so XLA's cost_analysis is exact — the analytic model must agree on FLOPs
    within 25 % (it approximates elementwise/softmax work)."""
    from repro.launch.dryrun import cost_analysis_dict, lower_cell
    from repro.launch.mesh import make_host_mesh

    base = get_config("olmo-1b")
    cfg = dataclasses.replace(base, name="olmo-probe", n_layers=1,
                              vocab=4096)
    shape = ShapeConfig("probe", seq_len=512, global_batch=4, kind="train")
    mesh = make_host_mesh()
    compiled = lower_cell(cfg, shape, mesh, remat="none").compile()
    xla_flops = float(cost_analysis_dict(compiled)["flops"])
    ours, _ = analytic_cost(cfg, shape, remat="none", n_chips=1)
    assert ours == pytest.approx(xla_flops, rel=0.25)


def test_model_flops_moe_uses_active_params():
    grok = get_config("grok-1-314b")
    mf = model_flops(grok, SHAPES["train_4k"])
    tokens = SHAPES["train_4k"].global_batch * SHAPES["train_4k"].seq_len
    # dominated by 6·N_active·T, plus attention
    assert mf > 6 * grok.active_param_count() * tokens * 0.9
    assert mf < 6 * grok.param_count() * tokens


def test_useful_ratio_bounded_for_all_cells():
    from repro.configs import ARCHS, cell_supported
    for cfg in ARCHS.values():
        for shape in SHAPES.values():
            if not cell_supported(cfg, shape)[0]:
                continue
            mf = model_flops(cfg, shape)
            af, ab = analytic_cost(cfg, shape, "full", 1)
            assert 0.0 < mf / af <= 1.02, (cfg.name, shape.name, mf / af)
            assert ab > 0
