"""Performance-score methodology (Eq. 2/3): baseline, budget, aggregation."""
import math

import numpy as np
import pytest
from _compat import given, settings, st

from repro.core.budget import Budget
from repro.core.cache import CachedResult, CacheFile
from repro.core.methodology import evaluate_strategy, make_scorer
from repro.core.runner import SimulationRunner
from repro.core.searchspace import SearchSpace
from repro.core.strategies import get_strategy
from repro.core.tunable import tunables_from_dict


def _cache(n: int = 64, seed: int = 0, name: str = "m"):
    rng = np.random.default_rng(seed)
    space = SearchSpace(tunables_from_dict({"a": tuple(range(n))}),
                        name=name)
    results = {}
    vals = rng.lognormal(mean=-6, sigma=0.8, size=n)
    for cfg, v in zip(space.valid_configs, vals):
        results[space.config_id(cfg)] = CachedResult(
            "ok", float(v), (float(v),) * 4, 0.3, 0.01)
    return CacheFile(name, "d", space, results)


def test_baseline_monotone_nonincreasing():
    sc = make_scorer(_cache())
    ts = np.linspace(0.5, sc.budget_s * 2, 40)
    base = sc.baseline_at_time(ts)
    assert np.all(np.diff(base) <= 1e-12)


def test_budget_hits_cutoff_value():
    sc = make_scorer(_cache(), cutoff=0.95)
    target = sc.median - 0.95 * (sc.median - sc.optimum)
    assert sc.baseline_at_time(sc.budget_s) <= target + 1e-12


def test_random_search_scores_near_zero():
    sc = make_scorer(_cache())
    rep = evaluate_strategy(lambda: get_strategy("random_search"), [sc],
                            repeats=40, seed=3)
    assert abs(rep.score) < 0.12  # unbiased vs its own baseline


def test_score_bounded_above_by_one():
    sc = make_scorer(_cache())
    rep = evaluate_strategy(lambda: get_strategy("greedy_ils"), [sc],
                            repeats=10, seed=0)
    assert np.all(rep.curve <= 1.0 + 1e-9)


def test_oracle_scores_close_to_one():
    """A 'strategy' that instantly finds the optimum scores ≈ 1."""
    sc = make_scorer(_cache())
    best_cfg = min(
        ((r.time_s, sc.cache.space.config_from_id(k))
         for k, r in sc.cache.results.items()), key=lambda t: t[0])[1]

    class Oracle:
        def run(self, space, runner, rng):
            return runner.run(best_cfg)

    rep = evaluate_strategy(Oracle, [sc], repeats=3, seed=0)
    # after the first sample point the curve should be ≈ 1
    assert rep.curve[-1] > 0.95


def test_aggregation_averages_spaces():
    a, b = _cache(seed=1, name="m1"), _cache(seed=2, name="m2")
    sa = make_scorer(a)
    sb = make_scorer(b)
    ra = evaluate_strategy(lambda: get_strategy("random_search"), [sa],
                           repeats=10, seed=5)
    rb = evaluate_strategy(lambda: get_strategy("random_search"), [sb],
                           repeats=10, seed=5)
    rab = evaluate_strategy(lambda: get_strategy("random_search"), [sa, sb],
                            repeats=10, seed=5)
    assert rab.score == pytest.approx((ra.score + rb.score) / 2, abs=1e-9)


@given(st.integers(0, 1000))
@settings(max_examples=10, deadline=None)
def test_score_trace_neutral_before_first_result(seed):
    sc = make_scorer(_cache(seed=seed % 7))
    times = sc.sample_times(10)
    p = sc.score_trace([], times)
    assert np.all(p == 0.0)
