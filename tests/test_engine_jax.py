"""Jax-engine conformance: the jitted replay path against the numpy oracle.

Two contracts, tested separately (see ``core.engine_jax``):

  * replay-from-log is **bit-identical** — given identical told
    observations, ``SimulationRunner(engine="jax")`` commits the same
    scores, traces, budget spends, and exhaustion points as the numpy
    engine, observation for observation. Deterministic fixtures pin the
    edge shapes (budget exhaustion mid-batch, inf failures, cache-miss
    rows, single-row asks, revisit-only batches, empty caches) and a
    hypothesis sweep drives random batches over one fixed space shape
    (bounding jit recompiles to the padded power-of-two ladder);
  * free-running is **statistically equivalent** only — device RNG cannot
    replay numpy streams, so pinned seeds reproduce against themselves
    and distributions (best value, spend) match the numpy strategies.

Marked ``jax_engine``; skipped with a reason when no jax backend can
dispatch (the engine itself then degrades to the numpy path, covered by
test_protocol.py's cross-engine resume tests which run everywhere).
"""
import math
import random

import numpy as np
import pytest
from _compat import given, settings, st
from _synth import parity_cache, total_charge

import repro.core.engine_jax as engine_jax
from repro.core.budget import Budget, BudgetExhausted
from repro.core.driver import SearchDriver, drive_many
from repro.core.methodology import evaluate_strategy, make_scorer
from repro.core.runner import SimulationRunner
from repro.core.space import RowBatch
from repro.core.strategies import get_strategy

pytestmark = [
    pytest.mark.jax_engine,
    pytest.mark.skipif(
        not engine_jax.engine_available(),
        reason=f"jax engine unavailable ({engine_jax.unavailable_reason()})"),
]

CACHE = parity_cache()
TOTAL = total_charge(CACHE)
# every strategy whose asks resolve through _run_rows, single-move shapes
# (simulated annealing, greedy ILS) included
STRATEGIES = ("random_search", "genetic_algorithm", "pso",
              "differential_evolution", "simulated_annealing", "greedy_ils")


def _observable(r: SimulationRunner):
    return (list(r.trace), r.fresh_evals, r.budget.spent_seconds,
            r.budget.spent_evals, sorted(r.memo))


def _runners(cache, **budget_kw):
    return (SimulationRunner(cache, Budget(**budget_kw), engine="numpy"),
            SimulationRunner(cache, Budget(**budget_kw), engine="jax"))


def _rows(cache, rows) -> RowBatch:
    """An index-native ask — the form whose resolution the jax engine
    owns (plain config lists take the keyed path on every engine)."""
    return RowBatch(cache.space.compiled, np.asarray(rows, dtype=np.int64))


# --------------------------------------------------------- replay-from-log
def test_whole_space_batch_bit_identical():
    """Full-space replay with revisits: every observation, trace entry,
    and budget float identical — and the jax runner actually dispatched."""
    n = CACHE.space.compiled.n_valid
    batch = _rows(CACHE, np.r_[np.arange(n), np.arange(n)])
    np_r, jx_r = _runners(CACHE, max_seconds=1e9)
    assert np_r.run_batch(batch) == jx_r.run_batch(batch)
    assert _observable(np_r) == _observable(jx_r)
    assert jx_r._jax_engine().dispatches > 0


def test_budget_exhaustion_mid_batch_matches():
    batch = _rows(CACHE, np.arange(CACHE.space.compiled.n_valid))
    np_r, jx_r = _runners(CACHE, max_seconds=TOTAL * 0.21)
    with pytest.raises(BudgetExhausted):
        np_r.run_batch(batch)
    with pytest.raises(BudgetExhausted):
        jx_r.run_batch(batch)
    assert _observable(np_r) == _observable(jx_r)


def test_eval_budget_exhaustion_matches():
    batch = _rows(CACHE, np.arange(CACHE.space.compiled.n_valid))
    np_r, jx_r = _runners(CACHE, max_evals=57)
    with pytest.raises(BudgetExhausted):
        np_r.run_batch(batch)
    with pytest.raises(BudgetExhausted):
        jx_r.run_batch(batch)
    assert _observable(np_r) == _observable(jx_r)
    assert jx_r.budget.spent_evals == 57


def test_inf_failures_flow_through_trace():
    """parity_cache plants inf-valued failures; they must commit (charged,
    traced as inf) identically on both engines."""
    batch = _rows(CACHE, np.arange(CACHE.space.compiled.n_valid))
    np_r, jx_r = _runners(CACHE, max_seconds=1e9)
    np_r.run_batch(batch)
    jx_r.run_batch(batch)
    assert _observable(np_r) == _observable(jx_r)
    infs = [t for t in jx_r.trace if math.isinf(t[1])]
    assert infs, "expected inf-valued failures in the fixture"


def test_cache_miss_rows_impute_mean_charge():
    cache = parity_cache(name="missy")
    for key in list(cache.results)[::5]:
        del cache.results[key]
    cache.invalidate_columns()
    batch = _rows(cache, np.arange(cache.space.compiled.n_valid))
    np_r, jx_r = _runners(cache, max_seconds=1e9)
    obs_n = np_r.run_batch(batch)
    obs_j = jx_r.run_batch(batch)
    assert obs_n == obs_j
    assert _observable(np_r) == _observable(jx_r)
    miss = [o for o in obs_j if o.status == "error" and not o.result.times_s
            and o.charge_s == cache.mean_eval_charge()]
    assert miss, "expected imputed misses"


def test_empty_cache_raises_same_clear_error():
    cache = parity_cache(name="empty")
    cache.results.clear()
    cache.invalidate_columns()
    batch = _rows(cache, np.arange(4))
    errors = {}
    for eng in ("numpy", "jax"):
        runner = SimulationRunner(cache, Budget(max_seconds=1e9), engine=eng)
        with pytest.raises(ValueError) as exc:
            runner.run_batch(batch)
        errors[eng] = str(exc.value)
    assert errors["numpy"] == errors["jax"]


def test_single_row_asks_dispatch_on_device():
    """Single-move shapes (simulated annealing et al.) must go through the
    device kernel too — uniform parity coverage, no silent host fallback."""
    np_r, jx_r = _runners(CACHE, max_seconds=1e9)
    for r in range(5):
        np_r.run_batch(_rows(CACHE, [r]))
        jx_r.run_batch(_rows(CACHE, [r]))
    np_r.run_batch(_rows(CACHE, [0]))  # revisit: memo gather, no dispatch
    jx_r.run_batch(_rows(CACHE, [0]))
    assert jx_r._jax_engine().dispatches == 5
    assert jx_r.fresh_evals == 5
    assert _observable(np_r) == _observable(jx_r)


@pytest.mark.parametrize("name", STRATEGIES)
@pytest.mark.parametrize(
    "budget_kw", [{"max_seconds": TOTAL * 0.3}, {"max_evals": 57},
                  {"max_seconds": TOTAL * 0.35, "max_evals": 57}],
    ids=["seconds", "evals", "both"])
def test_strategy_campaign_parity(name, budget_kw):
    """Whole campaigns (ask/tell through SearchDriver) are bit-identical
    across engines for every row-native strategy and budget kind."""
    runs = {}
    for eng in ("numpy", "jax"):
        runner = SimulationRunner(CACHE, Budget(**budget_kw), engine=eng)
        SearchDriver(get_strategy(name), CACHE.space, runner,
                     random.Random(1234)).run()
        runs[eng] = _observable(runner)
    assert runs["numpy"] == runs["jax"]


def test_drive_many_engine_jax_parity():
    def make(n=6):
        ds = []
        for i in range(n):
            runner = SimulationRunner(CACHE, Budget(max_seconds=TOTAL * 0.2))
            ds.append(SearchDriver(get_strategy("genetic_algorithm"),
                                   CACHE.space, runner, random.Random(100 + i)))
        return ds

    da, db = make(), make()
    drive_many(da)
    drive_many(db, engine="jax")
    for x, y in zip(da, db):
        assert _observable(x.runner) == _observable(y.runner)


def test_methodology_scores_bit_identical():
    reports = {
        eng: evaluate_strategy(lambda: get_strategy("genetic_algorithm"),
                               [make_scorer(CACHE, engine=eng)],
                               repeats=3, seed=3)
        for eng in ("vectorized", "jax")}
    assert reports["jax"].score == reports["vectorized"].score
    assert np.array_equal(reports["jax"].curve, reports["vectorized"].curve)
    assert reports["jax"].fresh_evals == reports["vectorized"].fresh_evals


def test_resume_mid_run_row_state_reseeds():
    """load_state_dict invalidates the row mirror; the jax engine must
    rebuild seen/obs_by_row from the restored memo, like the numpy path."""
    np_r, jx_r = _runners(CACHE, max_evals=48)
    np_r.run_batch(_rows(CACHE, np.arange(30)))
    snap = np_r.state_dict()
    jx_r.load_state_dict(snap)
    rest = _rows(CACHE, np.arange(10, 60))
    with pytest.raises(BudgetExhausted):
        np_r.run_batch(rest)
    with pytest.raises(BudgetExhausted):
        jx_r.run_batch(rest)
    assert _observable(np_r) == _observable(jx_r)


# ------------------------------------------------------------- replay_many
def test_replay_many_matches_runner_per_run():
    """The fused vmapped dispatch: each run's slice must equal what a
    SimulationRunner replaying the same fresh segment commits."""
    compiled = CACHE.space.compiled
    cols = CACHE.columns
    R, n = 8, compiled.n_valid
    rng = np.random.default_rng(7)
    rows = np.stack([rng.permutation(n) for _ in range(R)])
    max_s = TOTAL * 0.4
    accept, t_after, value, charge, spent, evals, exhausted = (
        np.asarray(o) for o in engine_jax.replay_many(
            cols, compiled, rows, max_seconds=max_s))
    for r in range(R):
        runner = SimulationRunner(CACHE, Budget(max_seconds=max_s))
        try:
            runner.run_batch(_rows(CACHE, rows[r]))
            assert not exhausted[r]
        except BudgetExhausted:
            assert exhausted[r]
        acc = accept[r]
        assert runner.budget.spent_seconds == spent[r]
        assert runner.budget.spent_evals == evals[r]
        trace_t = [t for t, _v, _c in runner.trace]
        trace_v = [v for _t, v, _c in runner.trace]
        assert trace_t == t_after[r][acc].tolist()
        assert trace_v == value[r][acc].tolist()


def test_replay_many_seen_basis_makes_revisits_free():
    compiled = CACHE.space.compiled
    cols = CACHE.columns
    seen = np.zeros(compiled.n_valid, dtype=bool)
    seen[::2] = True
    rows = np.arange(compiled.n_valid)[None, :]
    accept, _t, _v, _c, spent, evals, _x = (
        np.asarray(o) for o in engine_jax.replay_many(
            cols, compiled, rows, seen=seen))
    assert not accept[0][::2].any()
    assert accept[0][1::2].all()
    assert evals[0] == compiled.n_valid // 2


# ------------------------------------------------------------ free-running
def test_free_run_pinned_seed_reproduces_bitwise():
    a = engine_jax.free_run(CACHE, "genetic_algorithm", runs=8, seed=5,
                            generations=12, max_seconds=TOTAL * 0.3)
    b = engine_jax.free_run(CACHE, "genetic_algorithm", runs=8, seed=5,
                            generations=12, max_seconds=TOTAL * 0.3)
    for k in a:
        assert np.array_equal(a[k], b[k]), k


@pytest.mark.parametrize("name", sorted(engine_jax.FREE_RUN_STRATEGIES))
def test_free_run_budget_and_shape_invariants(name):
    runs, G = 6, 10
    out = engine_jax.free_run(CACHE, name, runs=runs, seed=1, generations=G,
                              max_evals=40)
    assert out["curve_spent"].shape == (runs, G)
    assert (out["spent_evals"] <= 40).all()
    assert (out["fresh_evals"] == out["spent_evals"]).all()
    # spend curves are monotone and end at the final spend
    assert (np.diff(out["curve_spent"], axis=1) >= 0).all()
    assert np.array_equal(out["curve_spent"][:, -1], out["spent_seconds"])
    # best rows are valid whenever a finite best exists
    finite = np.isfinite(out["best_value"])
    assert (out["best_row"][finite] >= 0).all()


def test_free_run_random_search_exhausts_space_exactly():
    """Unbudgeted random search over enough generations covers every row
    exactly once: fresh == n_valid, best == optimum, spend == total charge
    (order-independent up to float summation order)."""
    compiled = CACHE.space.compiled
    P = 20
    G = -(-compiled.n_valid // P) + 2
    out = engine_jax.free_run(CACHE, "random_search", runs=4, seed=2,
                              generations=G, popsize=P)
    assert (out["fresh_evals"] == compiled.n_valid).all()
    optimum = min(r.time_s for r in CACHE.results.values()
                  if r.status == "ok")
    assert np.array_equal(out["best_value"],
                          np.full(4, optimum))
    assert np.allclose(out["spent_seconds"], TOTAL, rtol=1e-10)
    assert not out["exhausted"].any()


def test_free_run_statistically_matches_numpy_ga():
    """Distribution check (deterministic given pinned seeds): mean best
    value over jax runs lands in the same range as the numpy GA under the
    same budget."""
    budget = TOTAL * 0.25
    out = engine_jax.free_run(CACHE, "genetic_algorithm", runs=24, seed=11,
                              generations=40, max_seconds=budget)
    np_best = []
    for i in range(24):
        runner = SimulationRunner(CACHE, Budget(max_seconds=budget))
        get_strategy("genetic_algorithm").run(CACHE.space, runner,
                                              random.Random(1000 + i))
        np_best.append(runner.best.value)
    jx = out["best_value"]
    assert np.isfinite(jx).all()
    lo, hi = min(np_best), max(np_best)
    spread = (hi - lo) or 1e-9
    assert abs(float(np.mean(jx)) - float(np.mean(np_best))) < 3 * spread


def test_free_run_rejects_unknown_hyperparameters():
    with pytest.raises(ValueError, match="unknown hyperparameters"):
        engine_jax.free_run(CACHE, "pso", runs=2, generations=2,
                            crossover="uniform")


# ------------------------------------------------------------------ tables
def test_tables_are_memoized_and_x64():
    compiled = CACHE.space.compiled
    cols = CACHE.columns
    rt = engine_jax.replay_tables(cols, compiled)
    assert engine_jax.replay_tables(cols, compiled) is rt
    st_ = engine_jax.space_tables(compiled)
    assert engine_jax.space_tables(compiled) is st_
    assert str(rt.time_s.dtype) == "float64"
    assert str(rt.charge_s.dtype) == "float64"
    assert str(rt.col_of_row.dtype) == "int32"


# ----------------------------------------------------- hypothesis sweeps
@given(st.integers(0, 10_000))
@settings(max_examples=20, deadline=None)
def test_property_random_batches_bit_identical(seed):
    """Random row batches (duplicates, revisits across calls, varying
    sizes) over the one fixed space shape: full observable parity. Batch
    sizes pad to the power-of-two ladder, so the sweep compiles a handful
    of kernel shapes, not one per example."""
    rng = np.random.default_rng(seed)
    n = CACHE.space.compiled.n_valid
    frac = 0.05 + (seed % 13) / 20.0
    budget_kw = ({"max_evals": 10 + seed % 120} if seed % 3 == 0
                 else {"max_seconds": TOTAL * frac})
    np_r, jx_r = _runners(CACHE, **budget_kw)
    for _ in range(3):
        size = int(rng.integers(1, 120))
        batch = _rows(CACHE, rng.integers(0, n, size))
        err = {}
        for tag, runner in (("numpy", np_r), ("jax", jx_r)):
            try:
                runner.run_batch(batch)
                err[tag] = False
            except BudgetExhausted:
                err[tag] = True
        assert err["numpy"] == err["jax"]
        assert _observable(np_r) == _observable(jx_r)
        if err["numpy"]:
            break
