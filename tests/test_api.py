"""The ``repro.api`` facade: one Tuner, four verbs, one TuningRun type."""
import os
import random

import pytest
from _synth import parity_cache

from repro.api import Tuner, TuningRun
from repro.core.budget import Budget
from repro.core.hypertuner import exhaustive_hypertune
from repro.core.methodology import make_scorer
from repro.core.runner import SimulationRunner
from repro.core.strategies import get_strategy


@pytest.fixture(scope="module")
def cache_path(tmp_path_factory):
    path = str(tmp_path_factory.mktemp("api") / "parity.json.gz")
    parity_cache().save(path)
    return path


def _tuner(cache_path, **kw) -> Tuner:
    kw.setdefault("repeats", 4)
    return Tuner(caches=[cache_path], **kw)


def test_simulate_matches_core_methodology(cache_path):
    with _tuner(cache_path) as tuner:
        run = tuner.simulate("genetic_algorithm")
    assert isinstance(run, TuningRun)
    assert run.mode == "simulate" and run.strategy == "genetic_algorithm"
    from repro.core.methodology import evaluate_strategy
    ref = evaluate_strategy(lambda: get_strategy("genetic_algorithm"),
                            [make_scorer(parity_cache())], repeats=4, seed=0)
    assert run.score == ref.score
    assert run.report.per_space_score == ref.per_space_score
    assert run.simulated_seconds == ref.simulated_seconds


def test_simulate_accepts_cachefile_objects_and_hyperparams(cache_path):
    with Tuner(caches=[parity_cache()], repeats=3) as tuner:
        run = tuner.simulate("pso", {"popsize": 10, "maxiter": 50})
    assert run.score is not None
    assert run.n_evaluated == 1


def test_hypertune_matches_core_campaign(cache_path):
    with _tuner(cache_path, repeats=3) as tuner:
        run = tuner.hypertune("mls")  # 2-point grid: fast
    ref = exhaustive_hypertune("mls", [make_scorer(parity_cache())],
                               repeats=3, seed=0)
    assert run.mode == "hypertune"
    assert run.score == ref.best.score
    assert run.best_hyperparams == ref.best.hyperparams
    assert run.n_evaluated == len(ref.results) == 2
    assert run.hypertuning.ranked()[0].score == run.score


def test_hypertune_journal_resume(cache_path, tmp_path):
    journal = str(tmp_path / "mls.jsonl")
    with _tuner(cache_path, repeats=3) as tuner:
        first = tuner.hypertune("mls", journal=journal)
        resumed = tuner.hypertune("mls", journal=journal)
    assert os.path.exists(journal)
    assert resumed.score == first.score
    assert resumed.best_hyperparams == first.best_hyperparams


def test_meta_returns_simulated_seconds(cache_path):
    with _tuner(cache_path, repeats=3) as tuner:
        run = tuner.meta("genetic_algorithm", "simulated_annealing",
                         extended=False, max_hp_evals=5)
    assert run.mode == "meta"
    assert run.meta.simulated_seconds == run.simulated_seconds > 0.0
    assert run.speedup is not None and run.speedup > 1.0
    assert run.best_hyperparams
    assert run.n_evaluated == len(run.meta.evaluated) <= 5


def test_meta_mid_run_checkpoints_in_journal(cache_path, tmp_path):
    journal = str(tmp_path / "meta.jsonl")
    with _tuner(cache_path, repeats=3) as tuner:
        run = tuner.meta("genetic_algorithm", "simulated_annealing",
                         extended=False, max_hp_evals=4, journal=journal)
    from repro.core.parallel import CampaignJournal
    _, records = CampaignJournal(journal).read()
    snaps = [r for r in records if r.get("type") == "checkpoint"]
    evals = [r for r in records if r.get("type") != "checkpoint"]
    assert snaps, "meta campaigns checkpoint SearchState mid-run"
    assert len(evals) == run.n_evaluated
    # resume restores the snapshot and recomputes nothing
    with _tuner(cache_path, repeats=3) as tuner:
        resumed = tuner.meta("genetic_algorithm", "simulated_annealing",
                             extended=False, max_hp_evals=4,
                             journal=journal)
    assert resumed.score == run.score
    assert resumed.best_hyperparams == run.best_hyperparams


def test_record_costmodel_produces_replayable_cache(tmp_path):
    out = str(tmp_path / "ssd.json.gz")
    with Tuner(workers=2, backend="thread") as tuner:
        run = tuner.record("ssd", runner="costmodel", device="tpu_v5e",
                           max_evals=6, out=out)
    assert run.mode == "record"
    assert os.path.exists(out) and run.cache_path == out
    assert run.best_config and run.best_value > 0
    assert run.n_evaluated == len(run.cache.results)
    # the recorded cache replays through the simulation engine
    runner = SimulationRunner(run.cache, Budget(max_evals=4))
    best = get_strategy("random_search").run(run.cache.space, runner,
                                             random.Random(0))
    assert best is not None


def test_unknown_kernel_fails_fast():
    with Tuner() as tuner:
        with pytest.raises(KeyError):
            tuner.record("nope", runner="costmodel")


def test_empty_hub_selection_raises():
    with pytest.raises(ValueError):
        Tuner(kernels=["no_such_kernel"]).scorers


def test_speedup_none_without_wall():
    assert TuningRun(mode="simulate", strategy="x").speedup is None
