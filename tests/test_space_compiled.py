"""Compiled-space parity and coverage (the ``core.space`` subsystem).

Pins the compiled facade to the frozen pre-compilation implementation
(``core.space.reference.ReferenceSearchSpace``) element-for-element AND
rng-draw-for-draw: ``neighbors`` (both semantics), ``is_valid``,
``random_config``, ``decode_batch``, ``nearest_valid`` (including the
depth-3 BFS exhaustion -> random-restart fallback), plus the index-native
row API (RowBatch, CSR degrees, id tables) and the empty-space error
paths the strategies rely on.
"""
import math
import pickle
import random

import numpy as np
import pytest
from _compat import given, settings, st

from repro.core.budget import Budget, BudgetExhausted
from repro.core.cache import CachedResult, CacheFile
from repro.core.runner import SimulationRunner, run_fused
from repro.core.searchspace import SearchSpace
from repro.core.space import RowBatch
from repro.core.space.reference import ReferenceSearchSpace
from repro.core.tunable import Constraint, Tunable, tunables_from_dict

# a small family of deterministic constraint shapes for the sweeps
_CONSTRAINTS = (
    None,
    ("sum%3", lambda d: sum(v if isinstance(v, int) else 0
                            for v in d.values()) % 3 != 0),
    ("product", lambda d: _int_product(d) <= 64),
    ("never", lambda d: False),
)


def _int_product(d):
    out = 1
    for v in d.values():
        if isinstance(v, int):
            out *= max(v, 1)
    return out


def _space_pair(seed: int):
    """(facade, frozen reference) over the same random tunables/constraint."""
    rng = random.Random(seed)
    n_t = 2 + seed % 3
    tun = []
    for i in range(n_t):
        card = 2 + rng.randrange(6)
        if i == n_t - 1 and seed % 4 == 0:
            values = tuple("abcdefgh"[:card])  # a string-valued tunable
        else:
            base = rng.randrange(4)
            values = tuple(base + 2 * k for k in range(card))
        tun.append(Tunable(f"t{i}", values))
    name, fn = _CONSTRAINTS[seed % len(_CONSTRAINTS)] or ("none", None)
    cons = (Constraint(fn, name),) if fn else ()
    return (SearchSpace(tun, cons, name=f"sweep{seed}"),
            ReferenceSearchSpace(tun, cons, name=f"sweep{seed}"))


# ------------------------------------------------------------ parity sweeps
# deterministic sweep (always runs) + hypothesis sweep (wider, when
# installed): both drive the same element-identity assertions
@pytest.mark.parametrize("seed", range(0, 24))
def test_enumeration_and_neighbors_match_reference(seed):
    _check_enumeration_parity(seed)


@given(st.integers(0, 10_000))
@settings(max_examples=40, deadline=None)
def test_property_enumeration_and_neighbors_match_reference(seed):
    _check_enumeration_parity(seed)


def _check_enumeration_parity(seed):
    s, r = _space_pair(seed)
    assert s.cartesian_size == r.cartesian_size
    assert s.valid_configs == r.valid_configs
    assert s.size == r.size
    for c in r.valid_configs:
        assert s.is_valid(c)
        assert s.neighbors(c) == r.neighbors(c)
        assert s.neighbors(c, strictly_adjacent=True) == \
            r.neighbors(c, strictly_adjacent=True)
    # invalid cartesian members agree too (bitmap vs constraint call)
    probe = random.Random(seed)
    for _ in range(20):
        c = tuple(t.values[probe.randrange(t.cardinality)]
                  for t in s.tunables)
        assert s.is_valid(c) == r.is_valid(c)
    assert not s.is_valid(("not-a-value",) * len(s.tunables))


@pytest.mark.parametrize("seed", range(0, 24))
def test_sampling_and_repair_draw_parity(seed):
    _check_sampling_parity(seed)


@given(st.integers(0, 10_000))
@settings(max_examples=40, deadline=None)
def test_property_sampling_and_repair_draw_parity(seed):
    _check_sampling_parity(seed)


def _check_sampling_parity(seed):
    """random_config / nearest_valid / decode_batch are value-identical AND
    leave the rng in the identical state (fallback draws included)."""
    s, r = _space_pair(seed)
    if s.size == 0:
        return  # sampling paths covered by the empty-space tests
    rs, rr = random.Random(seed), random.Random(seed)
    for _ in range(10):
        assert s.random_config(rs) == r.random_config(rr)
    assert rs.getstate() == rr.getstate()
    probe = random.Random(~seed & 0xFFFF)
    for _ in range(15):
        c = tuple(t.values[probe.randrange(t.cardinality)]
                  for t in s.tunables)
        assert s.nearest_valid(c, rs) == r.nearest_valid(c, rr)
        assert rs.getstate() == rr.getstate()
    x = np.random.default_rng(seed).uniform(
        -1.0, max(t.cardinality for t in s.tunables),
        size=(12, len(s.tunables)))
    assert s.decode_batch(x, rs) == r.decode_batch(x, rr)
    assert rs.getstate() == rr.getstate()


def test_from_indices_roundtrip_and_clamp_match_reference():
    s, r = _space_pair(7)
    for c in r.valid_configs:
        assert s.from_indices(s.to_indices(c)) == c
        assert np.array_equal(s.to_indices(c), r.to_indices(c))
    assert s.from_indices([99.0] * len(s.tunables)) == \
        r.from_indices([99.0] * len(s.tunables))
    assert s.bounds == r.bounds


# --------------------------------------------------- repair fallback / BFS
def _far_space():
    """Degenerate constraint: only the all-ones corner of a 6-bit cube is
    valid, so the all-zeros corner is > 3 single moves away — the depth-3
    BFS must exhaust and fall back to a random draw."""
    tun = tunables_from_dict({f"b{i}": (0, 1) for i in range(6)})
    cons = (Constraint(lambda d: all(v == 1 for v in d.values()),
                       "all ones"),)
    return (SearchSpace(tun, cons, name="far"),
            ReferenceSearchSpace(tun, cons, name="far"))


def test_repair_bfs_exhaustion_falls_back_to_random_draws():
    s, r = _far_space()
    bad = (0,) * 6
    only = (1,) * 6
    for seed in range(25):
        rs, rr = random.Random(seed), random.Random(seed)
        got = s.nearest_valid(bad, rs)
        assert got == r.nearest_valid(bad, rr) == only
        # the fallback consumed rng draws — and exactly the scalar ones
        assert rs.getstate() == rr.getstate()
        assert rs.getstate() != random.Random(seed).getstate()


def test_repair_bfs_within_depth_is_deterministic_and_drawless():
    s, r = _far_space()
    near = (1, 1, 1, 0, 1, 1)  # one move away: BFS finds it, no rng use
    rng = random.Random(0)
    state0 = rng.getstate()
    assert s.nearest_valid(near, rng) == (1,) * 6
    assert rng.getstate() == state0
    # memoized second call (including the negative BFS memo path)
    assert s.nearest_valid(near, rng) == (1,) * 6
    assert rng.getstate() == state0


def test_out_of_vocab_repair_matches_reference():
    s, r = _space_pair(5)
    assert s.size > 0
    oov = ("?!",) + tuple(t.values[0] for t in s.tunables[1:])
    for seed in range(10):
        rs, rr = random.Random(seed), random.Random(seed)
        assert s.nearest_valid(oov, rs) == r.nearest_valid(oov, rr)
        assert rs.getstate() == rr.getstate()


# ------------------------------------------------------------- empty space
def test_empty_space_errors():
    tun = tunables_from_dict({"a": (1, 2), "b": (3, 4)})
    s = SearchSpace(tun, (Constraint(lambda d: False, "never"),),
                    name="void")
    assert s.size == 0 and s.valid_configs == []
    assert s.compiled.n_valid == 0
    with pytest.raises(ValueError, match="no valid configs"):
        s.random_config(random.Random(0))
    with pytest.raises(ValueError, match="no valid configs"):
        # an unrepairable config ends in the random fallback -> same error
        s.nearest_valid((1, 3), random.Random(0))
    stats = s.compiled.stats()
    assert stats["n_valid"] == 0 and stats["valid_fraction"] == 0.0


# ------------------------------------------------------- config ids / rows
def test_config_from_id_uses_str_tables_and_first_match():
    # 1 and "1" stringify identically; the original linear scan returned
    # the first declared — the memoized table must too
    t = Tunable("x", (1, "1", 2))
    assert t.from_str("1") == 1 and isinstance(t.from_str("1"), int)
    assert t.from_str("2") == 2
    with pytest.raises(KeyError):
        t.from_str("7")
    s, r = _space_pair(11)
    for c in r.valid_configs:
        key = s.config_id(c)
        assert key == r.config_id(c)
        assert s.config_from_id(key) == r.config_from_id(key) == c


def test_row_tables_and_rowbatch():
    s, _ = _space_pair(12)
    cs = s.compiled
    assert len(cs.configs) == len(cs.ids) == len(cs.idx_tuples) == cs.n_valid
    for row, cfg in enumerate(cs.configs):
        assert cs.row_of_config(cfg) == row
        assert cs.id_to_row[cs.ids[row]] == row
        assert cs.rows_of_vidx([cs.idx_tuples[row]]).tolist() == [row]
    rb = RowBatch(cs, range(min(5, cs.n_valid)))
    assert len(rb) == min(5, cs.n_valid)
    assert list(rb) == cs.configs[:len(rb)]
    assert rb[0] == cs.configs[0]
    sliced = rb[1:3]
    assert isinstance(sliced, RowBatch) and list(sliced) == cs.configs[1:3]
    # RowBatch pickles as the plain config list it denotes
    assert pickle.loads(pickle.dumps(rb)) == list(rb)


def test_csr_degrees_match_neighbor_lists():
    s, r = _space_pair(13)
    cs = s.compiled
    for mode in (False, True):
        indptr, indices = cs.csr(mode)
        assert indptr[-1] == len(indices)
        for row, cfg in enumerate(cs.configs):
            assert (indptr[row + 1] - indptr[row]
                    == len(r.neighbors(cfg, strictly_adjacent=mode)))
    stats = cs.stats()
    assert stats["cartesian_size"] == s.cartesian_size
    assert stats["n_valid"] == s.size
    assert stats["compile_seconds"] >= 0.0
    for mode in ("strictly_adjacent", "hamming"):
        deg = stats["degrees"][mode]
        assert deg["min"] <= deg["median"] <= deg["max"]


def test_space_pickles_without_compiled_arrays():
    s, _ = _space_pair(16)  # constraint-free shape: Constraint fns of the
    #                         sweep family are lambdas and cannot pickle
    s.compiled  # force compilation
    clone = pickle.loads(pickle.dumps(s))
    assert clone._compiled is None  # recompiled lazily on the other side
    assert clone.valid_configs == s.valid_configs
    assert clone.compiled.n_valid == s.compiled.n_valid


# ------------------------------------------------ runner row-path coverage
def _cache(n_a: int = 12, n_b: int = 3) -> CacheFile:
    space = SearchSpace(tunables_from_dict({"a": tuple(range(n_a)),
                                            "b": tuple(range(n_b))}),
                        name="rows")
    results = {}
    for i, cfg in enumerate(space.valid_configs):
        key = space.config_id(cfg)
        if i % 7 == 2:
            results[key] = CachedResult("error", math.inf, (), 0.3, 0.01)
        else:
            v = 1e-3 * (1 + ((i * 13) % 29))
            results[key] = CachedResult("ok", v, (v,) * 2, 0.2, 0.01)
    return CacheFile("rows", "synth", space, results)


def _observable(runner):
    return (runner.trace, runner.fresh_evals, runner.budget.spent_seconds,
            runner.budget.spent_evals, sorted(runner.memo))


@pytest.mark.parametrize("budget_kw", [{"max_seconds": 1e9},
                                       {"max_evals": 17},
                                       {"max_seconds": 4.0}],
                         ids=["unbounded", "evals", "seconds"])
def test_rowbatch_run_matches_scalar_loop(budget_kw):
    cache = _cache()
    cs = cache.space.compiled
    rows = list(range(cs.n_valid)) * 2  # revisits included
    vec = SimulationRunner(cache, Budget(**budget_kw), columnar=True)
    sca = SimulationRunner(cache, Budget(**budget_kw), columnar=False)
    err_v = err_s = False
    try:
        vec.run_batch(RowBatch(cs, rows))
    except BudgetExhausted:
        err_v = True
    try:
        for r in rows:
            sca.run(cs.configs[r])
    except BudgetExhausted:
        err_s = True
    assert err_v == err_s
    assert _observable(vec) == _observable(sca)


def test_rowbatch_unrecorded_row_takes_imputed_miss_path():
    cache = _cache()
    victims = list(cache.results)[::4]
    for key in victims:
        del cache.results[key]
    cache.invalidate_columns()
    cs = cache.space.compiled
    rows = list(range(cs.n_valid))
    vec = SimulationRunner(cache, Budget(max_seconds=1e9), columnar=True)
    sca = SimulationRunner(cache, Budget(max_seconds=1e9), columnar=False)
    obs_v = vec.run_batch(RowBatch(cs, rows))
    obs_s = [sca.run(cs.configs[r]) for r in rows]
    assert obs_v == obs_s
    assert _observable(vec) == _observable(sca)
    assert any(o.charge_s == cache.mean_eval_charge() for o in obs_v)


def test_rowbatch_mixed_with_keyed_calls_stays_coherent():
    cache = _cache()
    cs = cache.space.compiled
    vec = SimulationRunner(cache, Budget(max_seconds=1e9), columnar=True)
    sca = SimulationRunner(cache, Budget(max_seconds=1e9), columnar=False)
    vec.run(cs.configs[5])                      # keyed scalar call
    sca.run(cs.configs[5])
    vec.run_batch(RowBatch(cs, [5, 6, 7]))      # row path sees the memo hit
    for r in (5, 6, 7):
        sca.run(cs.configs[r])
    vec.run_batch(cs.configs[:4])               # keyed batch path
    for c in cs.configs[:4]:
        sca.run(c)
    vec.run_batch(RowBatch(cs, range(cs.n_valid)))  # vectorized row commit
    for c in cs.configs:
        sca.run(c)
    assert _observable(vec) == _observable(sca)


def test_run_fused_rowbatch_parity():
    cache = _cache()
    cs = cache.space.compiled
    total = sum(r.charge_s for r in cache.results.values())
    batches, refs = [], []
    for i, sl in enumerate((slice(0, 20), slice(10, 36),
                            slice(0, cs.n_valid))):
        batches.append((SimulationRunner(cache,
                                         Budget(max_seconds=total * 0.2
                                                * (i + 1))),
                        RowBatch(cs, range(cs.n_valid)[sl])))
        refs.append(SimulationRunner(cache,
                                     Budget(max_seconds=total * 0.2
                                            * (i + 1)),
                                     columnar=False))
    results = run_fused(batches)
    for (runner, rb), ref, res in zip(batches, refs, results):
        try:
            expected = [ref.run(c) for c in rb]
        except BudgetExhausted as e:
            assert isinstance(res, BudgetExhausted)
            assert str(res) == str(e)
        else:
            assert res == expected
        assert _observable(runner) == _observable(ref)


def test_rows_for_space_maps_cache_columns():
    cache = _cache()
    cs = cache.space.compiled
    cols = cache.columns
    col_of_row = cols.rows_for_space(cs)
    assert len(col_of_row) == cs.n_valid
    for row, key in enumerate(cs.ids):
        assert col_of_row[row] == cols.index.get(key, -1)
    assert cols.rows_for_space(cs) is col_of_row  # memoized per view
