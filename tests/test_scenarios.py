"""Scenario subsystem conformance (docs/scenarios.md): surrogate
determinism and rank quality, the modeled lookup tier's ordering
(exact > transfer > modeled > cold), matrix coverage + the recorded
best-time gate, and fleet record/resume."""
import pickle

import pytest

from repro.core.budget import Budget
from repro.core.cache import CachedResult, CacheFile
from repro.core.devices import DEVICES_BY_NAME
from repro.core.searchspace import SearchSpace
from repro.core.tunable import tunables_from_dict
from repro.hub import storage
from repro.kernels import KERNELS
from repro.scenarios import (MODELED_CONFIDENCE, ScenarioMatrix,
                             SurrogateRunner, best_modeled, gate_recorded,
                             price, run_fleet, runnable)
from repro.service import ConfigHub

DEV = DEVICES_BY_NAME["tpu_v5e"]


def ssd_smoke():
    spec = KERNELS["ssd"]
    prob = spec.problem({})
    return spec.space(prob), spec.workload(prob)


def synthetic_cache(kernel: str, device: str, values) -> CacheFile:
    """A tiny hand-made recorded cache under a real kernel name: config
    x=i scores ``values[i]`` (the service never re-derives the space)."""
    space = SearchSpace(tunables_from_dict(
        {"x": tuple(range(len(values)))}), name=f"{kernel}@{device}")
    results = {space.config_id(c): CachedResult("ok", float(v), (float(v),),
                                                0.1)
               for c, v in zip(space.valid_configs, values)}
    return CacheFile(kernel, device, space, results, {})


@pytest.fixture()
def ssd_root(tmp_path):
    """A hub holding one recorded entry: ssd's default shape on tpu_v5e."""
    root = str(tmp_path / "hub")
    storage.register_cache(root, synthetic_cache("ssd", "tpu_v5e",
                                                 [2.0, 1.0]))
    return root


# --------------------------------------------------------------- surrogate
def test_price_is_deterministic():
    space, wl = ssd_smoke()
    for cfg in space.valid_configs:
        d = space.as_dict(cfg)
        a, b = price(wl, d, DEV), price(wl, d, DEV)
        assert a == b
        if a.status == "ok":
            assert a.time_s > 0 and a.roofline is not None


def test_surrogate_runner_bit_identical_cached_results():
    space, wl = ssd_smoke()

    def sweep() -> dict:
        runner = SurrogateRunner(space, wl, DEV, Budget())
        return {space.config_id(c): runner.run(c).result
                for c in space.valid_configs}

    first, second = sweep(), sweep()
    assert first == second
    # bit-identical, not merely equal: the modeled tier's cacheability
    # and the replayability of surrogate-recorded caches both rest on it
    assert pickle.dumps(first) == pickle.dumps(second)
    assert any(r.status == "ok" for r in first.values())


def test_best_modeled_deterministic_with_provenance():
    a = best_modeled("ssd", None, "tpu_v5e")
    b = best_modeled("ssd", None, DEV)  # device by name or by model
    assert a == b
    assert a.value > 0 and a.n_ok <= a.n_valid
    prov = a.provenance()
    assert prov["model"] == "roofline-v1"
    assert prov["device_model"] == "tpu_v5e"
    assert prov["dominant"] in ("compute", "memory")
    assert best_modeled("nope", None, "tpu_v5e") is None
    assert best_modeled("ssd", None, "gpu_x") is None


def test_surrogate_ranks_match_recorded_cache(tmp_path):
    """The acceptance bar: the surrogate's ranking of a kernel's configs
    correlates (Spearman >= 0.5) with a recorded cache's times."""
    from scipy.stats import spearmanr

    from repro.api import Tuner
    out = str(tmp_path / "ssd.json.gz")
    with Tuner(workers=1) as tuner:
        run = tuner.record("ssd", runner="costmodel", device="tpu_v5e",
                           out=out, bruteforce=True)
    cache = run.cache
    _, wl = ssd_smoke()
    recorded, modeled = [], []
    for cid, res in cache.results.items():
        if res.status != "ok":
            continue
        cfg = cache.space.as_dict(cache.space.config_from_id(cid))
        p = price(wl, cfg, DEV)
        assert p.status == "ok"
        recorded.append(res.time_s)
        modeled.append(p.time_s)
    assert len(recorded) >= 10
    rho = float(spearmanr(recorded, modeled).correlation)
    assert rho >= 0.5, f"surrogate rank correlation too weak: {rho:.3f}"


# ----------------------------------------------------------- modeled tier
def test_tier_order_exact_transfer_modeled_cold(ssd_root):
    hub = ConfigHub(ssd_root)
    # exact: the recorded default shape wins over everything
    assert hub.lookup("ssd", None, "tpu_v5e").status == "exact"
    # transfer: a close shape keeps the donor (confidence >= the
    # modeled-tier threshold), even though ssd is modelable
    r = hub.lookup("ssd", {"seq": 2048}, "tpu_v5e")
    assert r.status == "transfer" and r.confidence >= MODELED_CONFIDENCE
    # modeled: a registry kernel with nothing recorded on a known device
    m = hub.lookup("flash_attention", None, "tpu_v4")
    assert m.status == "modeled" and m.found
    assert m.confidence == pytest.approx(MODELED_CONFIDENCE)
    assert m.best_config is not None and m.best_value > 0
    assert m.model["model"] == "roofline-v1"
    assert m.model["device_model"] == "tpu_v4"
    # cold: unknown kernel, or a known kernel on an unknown device
    assert hub.lookup("nope", None, "tpu_v5e").status == "cold"
    assert hub.lookup("flash_attention", None, "gpu_x").status == "cold"
    assert hub.stats()["lookups"]["modeled"] == 1


def test_low_confidence_transfer_demoted_to_modeled(ssd_root):
    # the only donor is wildly far in shape; its confidence falls below
    # the threshold, so the analytic prior outranks it
    hub = ConfigHub(ssd_root)
    r = hub.lookup("ssd", {"seq": 4096 * 256}, "tpu_v5e")
    assert r.status == "modeled"
    assert r.confidence == pytest.approx(MODELED_CONFIDENCE)


def test_unmodelable_kernel_keeps_low_confidence_transfer(tmp_path):
    # a kernel outside the registry cannot be priced: the far donor is
    # still the best available answer
    root = str(tmp_path / "hub")
    storage.register_cache(root, synthetic_cache("toy", "devA", [1.0]),
                           problem={"m": 4})
    hub = ConfigHub(root)
    r = hub.lookup("toy", {"m": 4 * 4096}, "devA")
    assert r.status == "transfer" and r.confidence < MODELED_CONFIDENCE


def test_modeled_answers_cached_and_picklable(ssd_root):
    hub = ConfigHub(ssd_root)
    r1 = hub.lookup("flash_attention", None, "tpu_v4")
    r2 = hub.lookup("flash_attention", None, "tpu_v4")
    assert (r1.best_config, r1.best_value) == (r2.best_config, r2.best_value)
    assert hub.stats()["modeled_cached"] == 1
    j = r1.to_json()
    assert j["tier"] == "modeled" and j["model"]["n_valid"] >= j["model"]["n_ok"]
    # workers receive the cached surrogate argmin, not locks or threads
    clone = pickle.loads(pickle.dumps(hub))
    r3 = clone.lookup("flash_attention", None, "tpu_v4")
    assert r3.status == "modeled" and r3.best_config == r1.best_config


def test_register_invalidates_modeled_cache(ssd_root):
    hub = ConfigHub(ssd_root)
    assert hub.lookup("flash_attention", None, "tpu_v5e").status == "modeled"
    fa_default = dict(storage.hub_default_problem("flash_attention"))
    storage.register_cache(ssd_root,
                           synthetic_cache("flash_attention", "tpu_v5e",
                                           [4.0, 3.0]))
    hub.invalidate(kernel="flash_attention")
    r = hub.lookup("flash_attention", fa_default, "tpu_v5e")
    assert r.status == "exact" and r.best_value == 3.0


# ------------------------------------------------------- matrix & coverage
def test_matrix_enumerates_deterministically():
    mk = lambda: ScenarioMatrix(kernels=("gemm", "ssd"),
                                devices=("tpu_v5e", "cpu_interpret"))
    keys = [s.key for s in mk()]
    assert keys == [s.key for s in mk()]
    assert len(set(keys)) == len(keys) == len(mk())
    with pytest.raises(ValueError):
        ScenarioMatrix(kernels=("nope",))


def test_coverage_tiers_counts_and_best(ssd_root):
    hub = ConfigHub(ssd_root)
    m = ScenarioMatrix(kernels=("ssd",), devices=("tpu_v5e",
                                                  "cpu_interpret"))
    report = m.coverage(hub, with_best=True)
    tiers = {(r.scenario.shape, r.scenario.device): r.tier
             for r in report.rows}
    assert tiers == {("default", "tpu_v5e"): "recorded",
                     ("default", "cpu_interpret"): "cold",
                     ("smoke", "tpu_v5e"): "modeled",
                     ("smoke", "cpu_interpret"): "cold"}
    assert report.counts() == {"recorded": 1, "modeled": 1, "cold": 2}
    assert list(report.recorded_best().values()) == [1.0]
    j = report.to_json()
    assert j["counts"] == report.counts() and len(j["rows"]) == 4
    cell = j["matrix"]["ssd"]["tpu_v5e"]
    assert cell["recorded"] == 1 and cell["modeled"] == 1


def test_gate_recorded_failure_modes():
    base = {"a": 1.0, "b": 2.0}
    assert gate_recorded({"a": 1.0, "b": 2.0}, base) == []
    # within threshold, and brand-new coverage, both pass
    assert gate_recorded({"a": 1.19, "b": 2.0, "c": 9.9}, base) == []
    fails = gate_recorded({"a": 1.3}, base)
    assert len(fails) == 2
    assert any("absent" in f for f in fails)
    assert any("+30.0%" in f for f in fails)


# -------------------------------------------------------------------- fleet
def test_runnable_by_runner():
    scs = ScenarioMatrix(kernels=("ssd",),
                         devices=("tpu_v5e", "cpu_interpret")).scenarios()
    assert {s.device for s in scs if runnable(s, "live")} \
        == {"cpu_interpret"}
    for runner in ("costmodel", "surrogate"):
        assert {s.device for s in scs if runnable(s, runner)} == {"tpu_v5e"}


def test_fleet_records_then_resumes(ssd_root):
    matrix = ScenarioMatrix(kernels=("ssd",), devices=("tpu_v5e",))
    out1 = run_fleet(ssd_root, matrix=matrix, runner="costmodel",
                     max_evals=4)
    # the registered default shape is skipped, the smoke shape recorded
    assert len(out1.covered) == 1 and len(out1.recorded) == 1
    r = ConfigHub(ssd_root).lookup("ssd", KERNELS["ssd"].problem({}),
                                   "tpu_v5e")
    assert r.status == "exact"
    # re-run: the journal makes the sweep idempotent
    out2 = run_fleet(ssd_root, matrix=matrix, runner="costmodel",
                     max_evals=4)
    assert not out2.recorded and len(out2.skipped) == 1
    assert out2.to_json()["skipped"] == list(out2.skipped)
    # changed recording settings must refuse to reuse the journal
    with pytest.raises(ValueError):
        run_fleet(ssd_root, matrix=matrix, runner="costmodel", max_evals=8)


# ---------------------------------------------------------------- facades
def test_tuner_surrogate_exhaustive_and_strategy():
    from repro.api import Tuner
    with Tuner(workers=1) as tuner:
        run = tuner.surrogate("ssd")
        assert run.mode == "surrogate" and run.best_config is not None
        rerun = tuner.surrogate("ssd")
        assert (run.best_config, run.best_value) \
            == (rerun.best_config, rerun.best_value)
        sampled = tuner.surrogate("ssd", strategy="random_search",
                                  max_evals=8)
        # the exhaustive argmin bounds any sampled result
        assert sampled.best_value >= run.best_value
        with pytest.raises(KeyError):
            tuner.surrogate("nope")


def test_hub_coverage_facade(ssd_root):
    from repro.api import Hub
    report = Hub(ssd_root).coverage(kernels=("ssd",),
                                    devices=("tpu_v5e",))
    assert report.counts()["recorded"] == 1
    stats = Hub(ssd_root).stats()
    assert stats["coverage"]["counts"]["recorded"] >= 1
