"""Parallel campaign execution: determinism, journal resume, CLI e2e."""
import json
import os

import numpy as np
import pytest

from repro.cli import main as cli_main
from repro.core import hypertuner
from repro.core.cache import CachedResult, CacheFile
from repro.core.hypertuner import (exhaustive_hypertune,
                                   hyperparam_searchspace, meta_hypertune)
from repro.core.methodology import evaluate_strategy, make_scorer
from repro.core.parallel import (CampaignExecutor, CampaignJournal,
                                 StrategyFactory, report_from_json,
                                 report_to_json)
from repro.core.searchspace import SearchSpace
from repro.core.tunable import tunables_from_dict


def _cache(seed=0):
    rng = np.random.default_rng(seed)
    space = SearchSpace(tunables_from_dict({
        "x": tuple(range(12)), "y": tuple(range(8))}), name="hp")
    results = {}
    for cfg in space.valid_configs:
        x, y = cfg
        v = 1e-3 * (1 + (x - 3) ** 2 + 2 * (y - 6) ** 2
                    + 0.3 * rng.random())
        results[space.config_id(cfg)] = CachedResult("ok", v, (v,) * 2, 0.05)
    return CacheFile("hp", "d", space, results)


def _assert_same_results(a, b):
    assert list(a.results) == list(b.results)
    for key in a.results:
        ra, rb = a.results[key], b.results[key]
        assert ra.score == rb.score  # bit-identical, not approx
        assert np.array_equal(ra.report.curve, rb.report.curve)


# ------------------------------------------------------------- determinism
@pytest.mark.parametrize("backend", ["thread", "process"])
def test_parallel_exhaustive_bit_identical_to_serial(backend):
    scorers = [make_scorer(_cache())]
    serial = exhaustive_hypertune("simulated_annealing", scorers,
                                  repeats=2, seed=0)
    with CampaignExecutor(workers=4, backend=backend) as ex:
        par = exhaustive_hypertune("simulated_annealing", scorers,
                                   repeats=2, seed=0, executor=ex)
    _assert_same_results(serial, par)


def test_parallel_evaluate_strategy_bit_identical():
    scorers = [make_scorer(_cache(0)), make_scorer(_cache(1))]
    scorers[1].cache.kernel = "hp2"  # distinct space names
    factory = StrategyFactory.create("greedy_ils", {"perturbation": 2})
    serial = evaluate_strategy(factory, scorers, repeats=3, seed=0)
    with CampaignExecutor(workers=3, backend="thread") as ex:
        par = evaluate_strategy(factory, scorers, repeats=3, seed=0,
                                executor=ex)
    assert serial.score == par.score
    assert np.array_equal(serial.curve, par.curve)
    assert serial.per_space_score == par.per_space_score


def test_jax_device_arrays_never_pickle():
    """The jax engine memoizes device-array mirrors on ``CacheColumns`` and
    ``CompiledSpace`` (``_jax``); a pool worker must re-materialize them
    against its own backend (or fall back to numpy), never inherit device
    handles — so pickles drop them, even mid-campaign."""
    import pickle

    from repro.core.budget import Budget
    from repro.core.runner import SimulationRunner
    from repro.core.space import RowBatch

    cache = _cache(3)
    runner = SimulationRunner(cache, Budget(max_evals=30), engine="jax")
    # populate the device-table memos (a no-op without a jax backend —
    # the pickle contract must hold either way)
    runner.run_batch(RowBatch(cache.space.compiled,
                              np.arange(20, dtype=np.int64)))
    cols, cs = cache.columns, cache.space.compiled
    assert pickle.loads(pickle.dumps(cols))._jax is None
    assert pickle.loads(pickle.dumps(cs))._jax is None
    for payload in (pickle.dumps(cols), pickle.dumps(cs),
                    pickle.dumps(cache)):
        # no jax/jaxlib types smuggled in (the ``_jax`` attribute *name*
        # legitimately appears; module references must not)
        assert b"jaxlib" not in payload
        assert b"jax._src" not in payload
        assert b"ArrayImpl" not in payload


def test_parallel_jax_scorers_bit_identical_to_serial():
    """engine="jax" scorers fan out to process workers: each worker
    re-probes its own backend (using it when present, numpy otherwise) and
    the campaign is bit-identical to the serial run regardless."""
    scorers = [make_scorer(_cache(), engine="jax")]
    factory = StrategyFactory.create("genetic_algorithm", {})
    serial = evaluate_strategy(factory, scorers, repeats=2, seed=0)
    with CampaignExecutor(workers=2, backend="process") as ex:
        par = evaluate_strategy(factory, scorers, repeats=2, seed=0,
                                executor=ex)
    assert serial.score == par.score
    assert np.array_equal(serial.curve, par.curve)


# ----------------------------------------------------------- journal resume
def test_interrupted_campaign_resumes_without_rescoring(tmp_path, monkeypatch):
    scorers = [make_scorer(_cache())]
    path = str(tmp_path / "campaign.jsonl")
    full = exhaustive_hypertune("greedy_ils", scorers, repeats=2, seed=0)
    grid = hyperparam_searchspace("greedy_ils").size

    class Interrupt(Exception):
        pass

    seen = []

    def interrupting_progress(msg):
        seen.append(msg)
        if len(seen) == 3:
            raise Interrupt

    with pytest.raises(Interrupt):
        exhaustive_hypertune("greedy_ils", scorers, repeats=2, seed=0,
                             journal=CampaignJournal(path),
                             progress=interrupting_progress)
    header, records = CampaignJournal(path).read()
    assert header["mode"] == "exhaustive" and len(records) == 3

    calls = []
    real_task = hypertuner.score_hyperconfig_task

    def counting_task(scorers, name, hp, repeats, seed):
        calls.append(hp)
        return real_task(scorers, name, hp, repeats, seed)

    monkeypatch.setattr(hypertuner, "score_hyperconfig_task", counting_task)
    resumed = exhaustive_hypertune("greedy_ils", scorers, repeats=2, seed=0,
                                   journal=CampaignJournal(path))
    assert len(calls) == grid - 3  # completed configs were not re-scored
    _assert_same_results(full, resumed)


def test_journal_tolerates_truncated_tail(tmp_path):
    scorers = [make_scorer(_cache())]
    path = str(tmp_path / "campaign.jsonl")
    exhaustive_hypertune("greedy_ils", scorers, repeats=1, seed=0,
                         journal=CampaignJournal(path))
    with open(path, "a") as f:
        f.write('{"hp_id": "half-written')  # kill -9 mid-append
    journal = CampaignJournal(path)
    header, records = journal.read()
    assert header is not None
    size = hyperparam_searchspace("greedy_ils").size
    assert len(records) == size
    # appending after the torn tail starts a fresh line: the new record is
    # not merged into the fragment, and nothing after it is lost
    journal.append({"hp_id": "post-crash", "score": 1.0,
                    "simulated_seconds": 0.0})
    journal.append({"hp_id": "post-crash-2", "score": 2.0,
                    "simulated_seconds": 0.0})
    _, records = journal.read()
    assert [r["hp_id"] for r in records[-2:]] == ["post-crash",
                                                  "post-crash-2"]
    assert len(records) == size + 2


def test_journal_rejects_mismatched_campaign(tmp_path):
    scorers = [make_scorer(_cache())]
    path = str(tmp_path / "campaign.jsonl")
    exhaustive_hypertune("greedy_ils", scorers, repeats=2, seed=0,
                         journal=CampaignJournal(path))
    with pytest.raises(ValueError, match="different campaign"):
        exhaustive_hypertune("greedy_ils", scorers, repeats=3, seed=0,
                             journal=CampaignJournal(path))


def test_meta_resume_replays_journal(tmp_path, monkeypatch):
    scorers = [make_scorer(_cache())]
    path = str(tmp_path / "meta.jsonl")
    first = meta_hypertune("greedy_ils", "random_search", scorers,
                           extended=False, max_hp_evals=5, repeats=2,
                           seed=0, journal=CampaignJournal(path))
    calls = []
    monkeypatch.setattr(
        hypertuner, "score_hyperconfig",
        lambda *a, **k: calls.append(a) or pytest.fail("re-scored"))
    again = meta_hypertune("greedy_ils", "random_search", scorers,
                           extended=False, max_hp_evals=5, repeats=2,
                           seed=0, journal=CampaignJournal(path))
    assert not calls
    assert again.best_hyperparams == first.best_hyperparams
    assert again.best_score == first.best_score
    assert again.evaluated == first.evaluated


def test_journal_records_wall_clock_bookkeeping(tmp_path):
    """The journal carries what ``repro report`` needs to show wall-clock
    behaviour: per-config worker compute and completion timestamps."""
    scorers = [make_scorer(_cache())]
    path = str(tmp_path / "campaign.jsonl")
    with CampaignExecutor(workers=2, backend="thread") as ex:
        exhaustive_hypertune("greedy_ils", scorers, repeats=2, seed=0,
                             executor=ex, journal=CampaignJournal(path))
    _, records = CampaignJournal(path).read()
    assert records, "journal has completed records"
    assert all(r["report"]["wall_seconds"] >= 0 for r in records)
    walls = [r["done_wall"] for r in records]
    assert walls == sorted(walls)  # appended in completion order
    assert walls[-1] > 0


def test_report_json_roundtrip():
    scorers = [make_scorer(_cache())]
    res = exhaustive_hypertune("greedy_ils", scorers, repeats=1, seed=0)
    rep = res.best.report
    back = report_from_json(json.loads(json.dumps(report_to_json(rep))))
    assert back.score == rep.score
    assert np.array_equal(back.curve, rep.curve)
    assert back.per_space_score == rep.per_space_score


# -------------------------------------------------------------------- CLI
@pytest.fixture
def cache_path(tmp_path):
    p = str(tmp_path / "tiny.t4.json.zst")  # exercises the gzip fallback too
    _cache().save(p)
    return p


def test_cli_simulate(cache_path, capsys):
    assert cli_main(["simulate", "--cache", cache_path, "--strategy", "pso",
                     "--repeats", "2"]) == 0
    out = capsys.readouterr().out
    assert "aggregate score" in out and "hp@d" in out


def test_cli_hypertune_and_report(cache_path, tmp_path, capsys):
    journal = str(tmp_path / "c.jsonl")
    assert cli_main(["hypertune", "--cache", cache_path, "--strategy",
                     "greedy_ils", "--repeats", "2", "--workers", "2",
                     "--journal", journal, "--quiet"]) == 0
    out = capsys.readouterr().out
    assert "optimal vs average config" in out
    # re-run: resumes fully from the journal (instant)
    assert cli_main(["hypertune", "--cache", cache_path, "--strategy",
                     "greedy_ils", "--repeats", "2", "--journal", journal,
                     "--quiet"]) == 0
    capsys.readouterr()
    assert cli_main(["report", journal]) == 0
    out = capsys.readouterr().out
    size = hyperparam_searchspace("greedy_ils").size
    assert f"progress: {size}/{size}" in out


def test_cli_meta(cache_path, tmp_path, capsys):
    journal = str(tmp_path / "m.jsonl")
    assert cli_main(["meta", "--cache", cache_path, "--strategy",
                     "greedy_ils", "--meta-strategy", "random_search",
                     "--max-hp-evals", "4", "--repeats", "2",
                     "--journal", journal, "--quiet"]) == 0
    out = capsys.readouterr().out
    assert "best hyperparameters" in out
    assert cli_main(["report", journal]) == 0
    assert "campaign: meta" in capsys.readouterr().out
